"""Chaos suite: the serving stack under injected faults.

The contract under test — for EVERY injection point × fault kind, each
affected future resolves with either an EXACT result (reached through
the degradation ladder or a capacity retry, with `stats.degraded_steps`
recording any ladder walk) or its own typed error.  Never a hung flush,
never a wrong result; identity against a fresh fault-free engine is
asserted for every non-failed future.

Plus the governance behaviors the faults exercise: admission-control
shedding, the per-flush wall budget, budget aborts feeding the ladder,
the per-fingerprint circuit breaker (quarantine, cooldown, half-open
recovery), error-context wrapping on futures, and calibration hygiene
for degraded runs.
"""
import time

import pytest

from repro.core import make_engine, Thresholds
from repro.core.engine import EngineConfig
from repro.data import random_graph, random_query
from repro.serve import (QueryServer, GovernorConfig, BudgetExceeded,
                         DegradationExhausted, QuarantinedError,
                         QueryError, RejectedError, ServingError,
                         template_fingerprint)
from repro.testing import Fault, FaultInjector, INJECTION_POINTS, faults


# --------------------------- fixtures ---------------------------------- #
@pytest.fixture(scope="module")
def graph():
    return random_graph(n_nodes=80, n_edges=220, n_preds=3,
                        n_literals=20, seed=1)


@pytest.fixture(scope="module")
def pool(graph):
    return [random_query(graph, size=4, seed=40 + i, n_connection=i % 2,
                         d_c=2) for i in range(4)]


@pytest.fixture(scope="module")
def oracle(graph, pool):
    eng = make_engine(graph, "rdf_h", impl="ref")
    return [eng.execute(q).result_set() for q in pool]


def _forcing_cfg(point: str = "kernel_dispatch"):
    """Engine config that routes every join through the seam under test
    and every connection edge through the reach-join — so each injection
    point actually dispatches on this small workload (tiny tables
    otherwise resolve to nested/cross and never touch the faulted
    seams).  The join pipeline has mutually exclusive seams: the fused
    chain (fused_probe) bypasses the staged merge_probe/_merge_expand
    dispatches, and the radix strategy (radix_probe) bypasses sort-merge
    entirely — so the forced join path is chosen per point."""
    join_impl = "radix" if point == "radix_probe" else "sorted"
    fuse = point == "fused_probe"
    return EngineConfig(check_policy="selective", d_check=2, impl="ref",
                        thresholds=Thresholds(nested_join_max=1),
                        join_impl=join_impl, fuse_joins=fuse,
                        connection_impl="reach")


def _chaos_server(graph, point: str = "kernel_dispatch", **gov_kw):
    return QueryServer(graph, cfg=_forcing_cfg(point),
                       governor=GovernorConfig(**gov_kw))


# ----------------------- the chaos grid -------------------------------- #
@pytest.mark.parametrize("point", sorted(INJECTION_POINTS))
@pytest.mark.parametrize("kind", faults.FAULT_KINDS)
def test_chaos_grid_exact_or_typed(graph, pool, oracle, point, kind):
    """One fault at call 1 of each injection point: every future still
    resolves, and every resolved result is identical to the fault-free
    oracle.  A single transient fault must never surface to the client —
    the retry/ladder machinery absorbs it."""
    srv = _chaos_server(graph, point)
    # warm-up (fault-free): compiles shapes, fills the plan cache
    for f in srv.submit_many(pool, wait=True):
        f.result()
    with FaultInjector(Fault(point, kind, at=1, delay_s=0.01)) as fi:
        futures = srv.submit_many(pool, wait=True)
        assert all(f.done() for f in futures)   # flush never hangs
        for q_idx, f in enumerate(futures):
            res = f.result()                    # transient fault: no error
            assert res.result_set() == oracle[q_idx], (point, kind, q_idx)
    assert fi.fired, f"fault at {point} never exercised"
    t = srv.telemetry()
    assert t["query_errors"] == 0
    if kind == "raise":
        # a one-shot hard failure is absorbed by the transient retry
        # (fresh prepare, fresh budget, exact result) — or, if it
        # somehow repeats, by the ladder; either way it never surfaces
        gov = t["governor"]
        assert gov["transient_retries"] + gov["degraded_queries"] >= 1


@pytest.mark.parametrize("point", sorted(INJECTION_POINTS))
def test_chaos_persistent_fault_degrades_or_fails_typed(graph, pool,
                                                        oracle, point):
    """A PERSISTENT hard fault (raise on every call).  The ladder's
    force_simple_impls rung avoids the kernel/expand/reach seams
    entirely, so those recover exactly with degraded_steps recorded; the
    cache_lookup seam is hit by every rung and must fail typed —
    DegradationExhausted listing every attempt, never a wrong result."""
    srv = _chaos_server(graph, point)
    for f in srv.submit_many(pool, wait=True):
        f.result()
    with FaultInjector(Fault(point, "raise", every=1)) as fi:
        futures = srv.submit_many(pool, wait=True)
        assert all(f.done() for f in futures)
        for q_idx, f in enumerate(futures):
            try:
                res = f.result()
            except ServingError as e:
                assert isinstance(e, (QueryError, DegradationExhausted,
                                      QuarantinedError)), (point, q_idx)
            else:
                assert res.result_set() == oracle[q_idx], (point, q_idx)
                if res.stats.degraded_steps:
                    assert res.stats.degraded_steps[-1] in (
                        "skip_check", "greedy_plan", "force_simple_impls",
                        "truncate")
    assert fi.fired
    # the faulted point was exercised on every query that touched it,
    # and at least one query went through the ladder or failed typed
    gov = srv.telemetry()["governor"]
    assert gov["degraded_queries"] + srv.query_errors >= 1


def test_chaos_degraded_steps_recorded_and_calibration_skipped(graph,
                                                               pool):
    """Ladder successes stamp stats.degraded_steps, and the Calibrator
    refuses that evidence (degraded configs would poison the EWMAs)."""
    srv = _chaos_server(graph)
    for f in srv.submit_many(pool, wait=True):
        f.result()
    before = srv.calibrator.snapshot()
    with FaultInjector(Fault("kernel_dispatch", "raise", every=1)):
        futures = srv.submit_many(pool, wait=True)
        degraded = [f.result() for f in futures if f.done()]
    stepped = [r for r in degraded if r.stats.degraded_steps]
    assert stepped, "persistent kernel fault should force the ladder"
    assert srv.calibrator.degraded_skipped >= len(stepped)
    after = srv.calibrator.snapshot()
    for k in ("join_est_scale", "conn_sel_scale", "reach_scale"):
        assert after[k] == before[k]


# ----------------------- budgets feed the ladder ------------------------ #
def test_budget_exceeded_walks_ladder_then_fails_typed(graph, pool):
    """An impossible row budget aborts the primary AND every rung (each
    attempt gets a fresh budget with the same bounds), so the future
    fails with DegradationExhausted caused by BudgetExceeded — which
    still carries the partial stats of the aborted primary run."""
    srv = _chaos_server(graph, max_rows=0)
    q = pool[0]
    f = srv.submit(q)
    srv.flush()
    with pytest.raises(DegradationExhausted) as ei:
        f.result()
    exc = ei.value
    assert isinstance(exc.__cause__, BudgetExceeded)
    assert exc.__cause__.reason == "rows"
    assert exc.__cause__.stats is not None      # partial stats survive
    assert exc.__cause__.stats.budget_checks >= 1
    assert [name for name, _ in exc.attempts] == [
        "primary", "skip_check", "greedy_plan", "force_simple_impls",
        "truncate"]
    assert srv.telemetry()["governor"]["budget_exceeded"] == 1
    assert srv.telemetry()["governor"]["exhausted"] == 1


def test_generous_budget_never_triggers(graph, pool, oracle):
    srv = _chaos_server(graph, deadline_s=300.0, max_rows=1 << 40,
                        max_capacity=1 << 40)
    futures = srv.submit_many(pool, wait=True)
    for q_idx, f in enumerate(futures):
        assert f.result().result_set() == oracle[q_idx]
    gov = srv.telemetry()["governor"]
    assert gov["budget_exceeded"] == 0 and gov["degraded_queries"] == 0
    assert srv.telemetry()["stats_rollup"]["budget_checks"] > 0


# -------------------------- admission control --------------------------- #
def test_admission_control_sheds_beyond_max_pending(graph, pool, oracle):
    srv = QueryServer(graph, impl="ref",
                      governor=GovernorConfig(max_pending=2))
    futures = [srv.submit(pool[i % len(pool)]) for i in range(5)]
    shed = [f for f in futures if f.done()]
    assert len(shed) == 3                       # admitted 2, shed 3
    for f in shed:
        with pytest.raises(RejectedError):
            f.result()
    srv.flush()
    for f in futures[:2]:
        res = f.result()
        assert res.result_set() in oracle
    t = srv.telemetry()
    assert t["queries_shed"] == 3
    assert t["governor"]["shed_submit"] == 3
    # shed-at-admission is not an execution error
    assert t["query_errors"] == 0 and t["queries_served"] == 2


def test_flush_wall_budget_sheds_tail_not_head(graph, pool):
    """An exhausted per-flush wall budget sheds remaining buckets with
    RejectedError instead of hanging the flush; a generous budget sheds
    nothing."""
    srv = QueryServer(graph, impl="ref",
                      governor=GovernorConfig(flush_wall_s=0.0))
    futures = srv.submit_many(pool, wait=True)
    assert all(f.done() for f in futures)
    for f in futures:
        with pytest.raises(RejectedError, match="flush wall budget"):
            f.result()
    assert srv.telemetry()["governor"]["shed_flush"] >= 1
    assert srv.batcher.telemetry.shed == len(pool)

    srv2 = QueryServer(graph, impl="ref",
                       governor=GovernorConfig(flush_wall_s=300.0))
    for f in srv2.submit_many(pool, wait=True):
        f.result()                              # nothing shed
    assert srv2.telemetry()["governor"]["shed_flush"] == 0


def test_flush_wall_budget_serial_path(graph, pool):
    srv = QueryServer(graph, impl="ref", batching=False,
                      governor=GovernorConfig(flush_wall_s=0.0))
    futures = srv.submit_many(pool, wait=True)
    for f in futures:
        with pytest.raises(RejectedError):
            f.result()


# -------------------------- circuit breaker ----------------------------- #
def test_quarantine_cooldown_and_halfopen_recovery(graph, pool):
    """A template failing through the whole ladder trips its breaker:
    later submissions fail fast with QuarantinedError (no engine work),
    the cooldown expires into a half-open probe, and a healthy probe
    closes the breaker again."""
    q = pool[1]                                 # has a connection edge ->
    fp = None                                   # touches the reach cache
    srv = _chaos_server(graph, breaker_threshold=2,
                        breaker_cooldown_s=0.2)
    for f in srv.submit_many(pool, wait=True):
        f.result()                              # warm, healthy
    want = srv.query(q).result_set()
    # cache_lookup is on every rung's path (cross/exact-reach included),
    # so a persistent fault there defeats the entire ladder
    with FaultInjector(Fault("cache_lookup", "raise", every=1)):
        for _ in range(2):                      # threshold failures
            f = srv.submit(q)
            srv.flush()
            with pytest.raises(DegradationExhausted):
                f.result()
            fp = f.fingerprint
        assert srv.governor.breaker.state(fp) == "open"
        # count real engine executions from here: quarantined
        # submissions must fail fast without touching the engine
        engine_calls = []
        real_exec = srv.engine.execute_prepared

        def counting(pq, budget=None):
            engine_calls.append(pq.fingerprint)
            return (real_exec(pq) if budget is None
                    else real_exec(pq, budget=budget))

        srv.engine.execute_prepared = counting
        f = srv.submit(q)
        srv.flush()
        with pytest.raises(QuarantinedError) as ei:
            f.result()
        assert ei.value.retry_after_s > 0
    # fault gone, but cooldown not elapsed: still quarantined (and the
    # quarantined path did engine-visible work on neither attempt)
    f = srv.submit(q)
    srv.flush()
    with pytest.raises(QuarantinedError):
        f.result()
    assert not engine_calls                     # denied without engine work
    time.sleep(0.25)                            # cooldown expires
    res = srv.query(q)                          # half-open probe: healthy
    assert res.result_set() == want
    snap = srv.governor.breaker.snapshot()
    assert snap["trips"] >= 1 and snap["denials"] >= 2
    assert snap["probes"] >= 1 and snap["recoveries"] == 1
    assert srv.governor.breaker.state(fp) == "closed"
    assert len(engine_calls) == 1               # exactly the probe ran


# ---------------------- future error semantics -------------------------- #
def test_prepare_failure_isolated_and_phase_tagged(graph, pool,
                                                   monkeypatch):
    srv = QueryServer(graph, impl="ref")
    bad_fp = template_fingerprint(pool[0])
    real = srv.engine.prepare

    def flaky(query, fingerprint=None, version=0):
        if fingerprint == bad_fp:
            raise ValueError("planner blew up")
        return real(query, fingerprint=fingerprint, version=version)

    monkeypatch.setattr(srv.engine, "prepare", flaky)
    f_bad, f_ok = srv.submit_many([pool[0], pool[1]], wait=True)
    assert f_bad.done() and f_ok.done()
    with pytest.raises(QueryError) as ei:
        f_bad.result()
    assert ei.value.phase == "prepare"
    assert isinstance(ei.value.__cause__, ValueError)
    assert "planner blew up" in str(ei.value)
    assert f_ok.result() is not None
    assert srv.query_errors == 1


def test_execute_failure_wrapped_with_fingerprint_and_cause(graph, pool,
                                                            monkeypatch):
    srv = QueryServer(graph, impl="ref")
    boom = RuntimeError("engine exploded")
    monkeypatch.setattr(srv.engine, "execute_prepared",
                        lambda pq, budget=None: (_ for _ in ()).throw(boom))
    f = srv.submit(pool[0])
    srv.flush()
    with pytest.raises(QueryError) as ei:
        f.result()
    assert ei.value.__cause__ is boom
    assert ei.value.phase == "execute"
    assert ei.value.fingerprint == template_fingerprint(pool[0])
    # QueryError is a RuntimeError carrying the cause's message, so
    # pre-existing `except RuntimeError` / match= call sites still work
    assert isinstance(ei.value, RuntimeError)
    assert "engine exploded" in str(ei.value)


def test_failed_future_result_does_not_redrain(graph, pool, monkeypatch):
    srv = QueryServer(graph, impl="ref")
    monkeypatch.setattr(
        srv.engine, "execute_prepared",
        lambda pq, budget=None: (_ for _ in ()).throw(RuntimeError("x")))
    f = srv.submit(pool[0])
    with pytest.raises(QueryError):
        f.result()                              # lazy flush resolves it
    flushes = []
    monkeypatch.setattr(srv, "flush",
                        lambda: flushes.append(1))
    for _ in range(3):                          # terminal: no re-drain
        with pytest.raises(QueryError):
            f.result()
    assert not flushes


def test_query_errors_accounting_exact(graph, pool, monkeypatch):
    """Every failed future increments query_errors exactly once; served
    and failed partition the admitted set."""
    srv = QueryServer(graph, impl="ref")
    bad_fp = template_fingerprint(pool[0])
    real = srv.engine.execute_prepared

    def flaky(pq, budget=None):
        if pq.fingerprint == bad_fp:
            raise RuntimeError("boom")
        return real(pq)

    monkeypatch.setattr(srv.engine, "execute_prepared", flaky)
    futures = srv.submit_many([pool[0], pool[1], pool[0], pool[2]],
                              wait=True)
    failed = sum(1 for f in futures if f._error is not None)
    assert failed == 2                          # both pool[0] submissions
    assert srv.query_errors == 2
    assert srv.queries_served == 2
    assert srv.telemetry()["query_errors"] == 2
    # repeated result() calls never double-count
    for f in futures:
        for _ in range(2):
            try:
                f.result()
            except ServingError:
                pass
    assert srv.query_errors == 2


def test_unexpected_flush_crash_fails_all_futures_typed(graph, pool,
                                                        monkeypatch):
    """If the flush machinery ITSELF crashes (a bug, not a query
    failure), the backstop still resolves every pending future with a
    typed error — no future can dangle and re-drain forever."""
    from repro.serve import IncompleteFlushError
    srv = QueryServer(graph, impl="ref")
    monkeypatch.setattr(srv.batcher, "flush",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("batcher bug")))
    futures = srv.submit_many(pool)
    with pytest.raises(RuntimeError, match="batcher bug"):
        srv.flush()
    assert all(f.done() for f in futures)
    for f in futures:
        with pytest.raises(IncompleteFlushError):
            f.result()
    assert srv.query_errors == len(pool)


# ------------------------ crash-restart grid ---------------------------- #
@pytest.fixture(scope="module")
def snapshots(graph, pool, tmp_path_factory):
    """Per-injection-point warm snapshot: each forcing config learns its
    own plans (join impls differ per point), so each point snapshots its
    own warm server once and the grid cells restore from it."""
    d = tmp_path_factory.mktemp("chaos-snaps")
    out = {}
    for point in sorted(INJECTION_POINTS):
        srv = _chaos_server(graph, point)
        for _ in range(2):                      # cold + warm pass
            for f in srv.submit_many(pool, wait=True):
                f.result()
        path = d / f"{point}.snap"
        manifest = srv.save_snapshot(path)
        assert manifest["plans"] == len(pool)
        out[point] = path
    return out


@pytest.mark.parametrize("crash", ["before_snapshot", "after_snapshot"])
@pytest.mark.parametrize("kind", faults.FAULT_KINDS)
@pytest.mark.parametrize("point", sorted(INJECTION_POINTS))
def test_chaos_restart_grid(graph, pool, oracle, snapshots, point, kind,
                            crash, tmp_path):
    """Crash-restart × fault grid: a server that crashed AFTER saving a
    snapshot restores the warm state; one that crashed BEFORE finds no
    (usable) snapshot, gets a typed SnapshotError, and cold-starts.
    Either way, under an injected fault at every point × kind, every
    future resolves exact-or-typed — never wrong, never stale."""
    from repro.serve import SnapshotError
    srv = _chaos_server(graph, point)           # the restarted process
    if crash == "after_snapshot":
        manifest = srv.restore_snapshot(snapshots[point])
        assert manifest["plans"] == len(pool)
    else:
        with pytest.raises(SnapshotError):
            srv.restore_snapshot(tmp_path / "never-written.snap")
        assert len(srv.plan_cache) == 0         # clean cold start
    with FaultInjector(Fault(point, kind, at=1, delay_s=0.01)) as fi:
        futures = srv.submit_many(pool, wait=True)
        assert all(f.done() for f in futures)
        for q_idx, f in enumerate(futures):
            try:
                res = f.result()
            except ServingError as e:
                assert isinstance(e, (QueryError, DegradationExhausted,
                                      QuarantinedError)), (point, kind)
            else:
                assert res.result_set() == oracle[q_idx], \
                    (point, kind, crash, q_idx)
    assert fi.fired, f"fault at {point} never exercised"
    if crash == "after_snapshot":
        # restored plans were used, not re-learned from scratch
        assert srv.telemetry()["plan_cache"]["hits"] >= len(pool)


def test_restart_grid_corrupt_snapshot_cell(graph, pool, oracle,
                                            snapshots, tmp_path):
    """The third crash flavor: the snapshot file itself was damaged by
    the crash.  Typed SnapshotError, then an exact cold start."""
    from repro.serve import SnapshotError
    raw = bytearray(snapshots["kernel_dispatch"].read_bytes())
    raw[-5] ^= 0x55
    bad = tmp_path / "damaged.snap"
    bad.write_bytes(bytes(raw))
    srv = _chaos_server(graph, "kernel_dispatch")
    with pytest.raises(SnapshotError) as ei:
        srv.restore_snapshot(bad)
    assert ei.value.reason == "checksum"
    assert len(srv.plan_cache) == 0
    for q_idx, f in enumerate(srv.submit_many(pool, wait=True)):
        assert f.result().result_set() == oracle[q_idx]


# --------------------- rung memory: measured proof ---------------------- #
def _classify_attempt(cfg, base_cfg):
    """Name the ladder position of one engine execution by its config
    (rungs run on sibling engines, so instance identity is useless)."""
    if cfg.check_policy == "selective":
        return "primary"
    if cfg.plan_mode != "greedy":
        return "skip_check"
    if cfg.join_impl != "nested":
        return "greedy_plan"
    if cfg.max_rows == base_cfg.max_rows:
        return "force_simple_impls"
    return "truncate"


def test_rung_memory_jump_probe_and_recovery_measured(graph, pool,
                                                      monkeypatch):
    """The tentpole acceptance, with engine-call counting: under a
    persistent kernel fault, request 1 walks the ladder once; every
    later request jumps straight to the last-good rung (ZERO primary
    and ZERO intermediate-rung attempts); the re-probe interval buys at
    most ONE primary attempt; and full quality returns within one
    re-probe interval of the fault clearing."""
    import repro.core.engine as engine_mod
    q = pool[0]
    srv = _chaos_server(graph, "kernel_dispatch",
                        transient_retry=False,   # isolate the ladder path
                        reprobe_interval_s=60.0)
    gov = srv.governor
    clk = [0.0]
    gov.clock = lambda: clk[0]
    for _ in range(2):                           # fault-free warm-up
        srv.query(q)
    base_cfg = srv.engine.cfg
    attempts = []
    real_exec = engine_mod.Engine.execute_prepared

    def spy(self, pq, budget=None):
        attempts.append(_classify_attempt(self.cfg, base_cfg))
        return real_exec(self, pq, budget=budget)

    monkeypatch.setattr(engine_mod.Engine, "execute_prepared", spy)
    want = None
    with FaultInjector(Fault("kernel_dispatch", "raise", every=1)):
        # request 1: full ladder walk (primary + skip_check + greedy all
        # fail on the sorted-join path; force_simple_impls succeeds)
        res = srv.query(q)
        want = res.result_set()
        assert res.stats.degraded_steps[-1] == "force_simple_impls"
        assert attempts == ["primary", "skip_check", "greedy_plan",
                            "force_simple_impls"]
        # requests 2..5: memory jump — ONE rung execution each, zero
        # primary attempts, zero intermediate rungs
        attempts.clear()
        for _ in range(4):
            res = srv.query(q)
            assert res.result_set() == want
            assert res.stats.degraded_steps == ["force_simple_impls"]
        assert attempts == ["force_simple_impls"] * 4
        # re-probe interval elapses, fault still live: exactly ONE
        # primary attempt, then straight back to the remembered rung
        attempts.clear()
        clk[0] += 61.0
        res = srv.query(q)
        assert res.result_set() == want
        assert attempts == ["primary", "force_simple_impls"]
        assert gov.rung_memory.probe_failures == 1
        # and the interval slot is claimed: the next request jumps
        attempts.clear()
        srv.query(q)
        assert attempts == ["force_simple_impls"]
    # fault cleared: full quality restored within ONE re-probe interval
    attempts.clear()
    clk[0] += 61.0
    res = srv.query(q)                           # probe -> primary succeeds
    assert res.result_set() == want
    assert res.stats.degraded_steps == []        # full quality, no stamp
    assert attempts == ["primary"]
    assert gov.rung_memory.probe_recoveries == 1
    assert gov.rung_memory.rung(template_fingerprint(q)) is None
    res = srv.query(q)                           # steady state: primary
    assert res.stats.degraded_steps == []
    assert attempts == ["primary", "primary"]
    snap = srv.telemetry()["governor"]["rung_memory"]
    assert snap["jumps"] == 5 and snap["probes"] == 2
    assert snap["tracked"] == 0


def test_rung_memory_disabled_rewalks_ladder_every_time(graph, pool,
                                                        monkeypatch):
    """Control experiment: with rung_memory=False every faulted request
    re-walks the full ladder — the exact per-request waste the memory
    removes."""
    import repro.core.engine as engine_mod
    q = pool[0]
    srv = _chaos_server(graph, "kernel_dispatch", transient_retry=False,
                        rung_memory=False)
    for _ in range(2):
        srv.query(q)
    base_cfg = srv.engine.cfg
    attempts = []
    real_exec = engine_mod.Engine.execute_prepared

    def spy(self, pq, budget=None):
        attempts.append(_classify_attempt(self.cfg, base_cfg))
        return real_exec(self, pq, budget=budget)

    monkeypatch.setattr(engine_mod.Engine, "execute_prepared", spy)
    with FaultInjector(Fault("kernel_dispatch", "raise", every=1)):
        for _ in range(3):
            srv.query(q)
    assert attempts == ["primary", "skip_check", "greedy_plan",
                        "force_simple_impls"] * 3


# ------------------- transient-fault classification --------------------- #
@pytest.mark.parametrize("point", sorted(INJECTION_POINTS))
def test_transient_first1_fault_exact_no_stamp_no_strike(graph, pool,
                                                         oracle, point):
    """A first=1 transient (fires once, then heals): ONE jittered
    retry of the primary config absorbs it — exact results, ZERO
    degraded-result stamps, ZERO breaker strikes, ZERO ladder walks."""
    srv = _chaos_server(graph, point, retry_backoff_s=0.001)
    for f in srv.submit_many(pool, wait=True):
        f.result()                               # fault-free warm-up
    with FaultInjector(Fault(point, "raise", first=1)) as fi:
        futures = srv.submit_many(pool, wait=True)
        for q_idx, f in enumerate(futures):
            res = f.result()                     # no error surfaces
            assert res.result_set() == oracle[q_idx], (point, q_idx)
            assert res.stats.degraded_steps == []
    assert fi.fired
    gov = srv.telemetry()["governor"]
    assert gov["transient_retries"] == 1
    assert gov["transient_recoveries"] == 1
    assert gov["ladder_entries"] == 0
    assert gov["degraded_queries"] == 0
    assert gov["breaker"]["trips"] == 0
    assert gov["breaker"]["open"] == 0


def test_budget_failure_skips_transient_retry(graph, pool, monkeypatch):
    """Budget aborts are deterministic — re-running can only re-blow the
    same bound, so they go straight to the ladder (no retry burned)."""
    srv = _chaos_server(graph, max_rows=1)       # every query blows this
    with pytest.raises(DegradationExhausted):
        srv.query(pool[0])                       # plan cached (cold prep)
    # poison THIS engine's prepare: the transient retry would call it;
    # ladder rungs prepare on sibling engines and are unaffected
    retried = []
    monkeypatch.setattr(srv.engine, "prepare",
                        lambda *a, **k: retried.append(1) or
                        (_ for _ in ()).throw(AssertionError(
                            "transient retry ran for a budget abort")))
    f = srv.submit(pool[0])
    srv.flush()
    with pytest.raises(DegradationExhausted) as ei:
        f.result()                               # typed, never a retry
    assert ei.value.attempts[0][0] == "primary"
    assert isinstance(ei.value.attempts[0][1], BudgetExceeded)
    gov = srv.telemetry()["governor"]
    assert gov["transient_retries"] == 0
    assert gov["budget_exceeded"] == 2 and gov["ladder_entries"] == 2
    assert not retried


def test_chronic_degradation_surfaces_for_replan(graph, pool):
    """A fingerprint degraded `chronic_after` consecutive times is
    surfaced for re-planning: plan-cache entry dropped, calibrator
    notified (version bump), rung memory cleared — re-plan, not
    re-try."""
    q = pool[0]
    srv = _chaos_server(graph, "kernel_dispatch", transient_retry=False,
                        chronic_after=3, reprobe_interval_s=3600.0)
    for _ in range(2):
        srv.query(q)
    fp = template_fingerprint(q)
    v0 = srv.calibrator.version
    with FaultInjector(Fault("kernel_dispatch", "raise", every=1)):
        for _ in range(3):                       # walk + jump + jump=chronic
            srv.query(q)
        assert srv.calibrator.chronic_notices == 1
        assert srv.calibrator.chronic_fps == [fp]
        assert srv.calibrator.version == v0 + 1
        assert srv.plan_cache.drops == 1
        assert srv.plan_cache.get(srv.dataset_id, fp) is None
        assert srv.governor.rung_memory.rung(fp) is None
        # next request re-plans from scratch (fresh prepare) and starts
        # a new memory cycle — still exact through the ladder
        res = srv.query(q)
        assert res.stats.degraded_steps
    assert srv.telemetry()["governor"]["rung_memory"]["chronic"] == 1
