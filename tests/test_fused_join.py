"""Fused join pipeline + radix hash join: kernel-level parity against
the staged sort-merge path and a brute-force oracle, the single-column
identity key path, interpret-mode Pallas parity, overflow-resume
contracts for both pipelines, and warm-replay strategy pinning."""
import numpy as np
import jax.numpy as jnp
import pytest

import repro.kernels.fused_join as kfused
import repro.kernels.radix_join as krad
import repro.kernels.ops as kops
import repro.core.matching as matching
from repro.core.matching import (
    Table, CapacityOverflow, JoinTelemetry, join_tables, planned_join,
    dedup_project, _pow2,
)
from repro.core.planner import CapEstimate

RNG = np.random.default_rng(7)


def mk_table(cols, data):
    data = np.asarray(data, np.int32).reshape(-1, len(cols))
    cap = _pow2(len(data))
    rows = np.full((cap, len(cols)), -1, np.int32)
    rows[: len(data)] = data
    return Table(cols=tuple(cols), rows=jnp.asarray(rows), count=len(data))


def oracle_join(a, b):
    shared = [c for c in a.cols if c in b.cols]
    new = [j for j, c in enumerate(b.cols) if c not in a.cols]
    out = []
    for ra in a.numpy():
        for rb in b.numpy():
            if all(ra[a.cols.index(c)] == rb[b.cols.index(c)]
                   for c in shared):
                out.append(tuple(int(x) for x in ra)
                           + tuple(int(rb[j]) for j in new))
    return sorted(out)


def rows_multiset(t):
    return sorted(tuple(int(x) for x in r) for r in t.numpy())


def rand_pair(seed, na=60, nb=60, ncols_a=2, ncols_b=2, vmax=5):
    rng = np.random.default_rng(seed)
    a_cols = tuple(rng.choice(5, ncols_a, replace=False))
    b_cols = tuple(rng.choice(5, ncols_b, replace=False))
    a = mk_table(a_cols, rng.integers(0, vmax, (na, ncols_a)))
    b = mk_table(b_cols, rng.integers(0, vmax, (nb, ncols_b)))
    return a, b


# --------------------------- pack_keys -------------------------------- #
def test_pack_keys_multi_col_dense_rank_oracle():
    rng = np.random.default_rng(2)
    a = mk_table((0, 1), rng.integers(0, 4, (50, 2)))
    b = mk_table((0, 1), rng.integers(0, 4, (40, 2)))
    ak, bk = kfused.pack_keys(a.rows, b.rows, (0, 1), (0, 1))
    ak, bk = np.asarray(ak), np.asarray(bk)
    a_np, b_np = np.asarray(a.rows), np.asarray(b.rows)
    # keys agree with tuple equality across AND within sides
    for i in range(a.count):
        for j in range(b.count):
            same = bool((a_np[i] == b_np[j]).all())
            assert (ak[i] == bk[j]) == same
        for i2 in range(a.count):
            assert (ak[i] == ak[i2]) == bool((a_np[i] == a_np[i2]).all())
    # keys are order-preserving on the tuples
    pairs = sorted((tuple(a_np[i]), ak[i]) for i in range(a.count))
    ks = [k for _, k in pairs]
    assert ks == sorted(ks)
    # padding rows map to the side sentinels
    assert (ak[a.count:] == kfused.A_INVALID).all()
    assert (bk[b.count:] == kfused.B_INVALID).all()


def test_pack_keys_single_col_identity():
    """Single shared column skips dense-rank packing: keys ARE the
    column values (valid rows), so no lexsort dispatch happens at all."""
    a = mk_table((0, 1), [[i % 7, i] for i in range(30)])
    b = mk_table((0, 2), [[i % 7, i + 100] for i in range(20)])
    ak, bk = kfused.pack_keys(a.rows, b.rows, (0,), (0,))
    assert (np.asarray(ak)[: a.count] == np.asarray(a.rows)[: a.count, 0]).all()
    assert (np.asarray(bk)[: b.count] == np.asarray(b.rows)[: b.count, 0]).all()
    assert (np.asarray(ak)[a.count:] == kfused.A_INVALID).all()
    assert (np.asarray(bk)[b.count:] == kfused.B_INVALID).all()


# --------------------- fused chain vs staged path --------------------- #
@pytest.mark.parametrize("seed", range(6))
def test_fused_equals_unfused_equals_oracle(seed):
    a, b = rand_pair(seed, ncols_a=(seed % 3) + 1, ncols_b=2)
    want = oracle_join(a, b)
    fused = join_tables(a, b, impl="sorted", fuse=True)
    staged = join_tables(a, b, impl="sorted", fuse=False)
    assert rows_multiset(fused) == want
    assert rows_multiset(staged) == want


@pytest.mark.parametrize("probe", ["sorted", "ref", "interpret"])
def test_sort_probe_expand_probe_impl_parity(probe):
    a, b = rand_pair(11, ncols_a=2, ncols_b=2, vmax=4)
    want = oracle_join(a, b)
    got = join_tables(a, b, impl="sorted", probe_impl=probe, fuse=True)
    assert rows_multiset(got) == want


def test_expand_segments_pallas_matches_searchsorted():
    rng = np.random.default_rng(5)
    for n, cap in ((17, 256), (200, 1024), (1, 64)):
        cnt = rng.integers(0, 9, n).astype(np.int32)
        csum = np.cumsum(cnt).astype(np.int32)
        seg = np.asarray(kfused.expand_segments_pallas(
            jnp.asarray(csum), cap, interpret=True))
        t = np.arange(cap)
        want = np.searchsorted(csum, t, side="right").astype(np.int32)
        assert (seg == want).all(), (n, cap)


def test_fused_overflow_resume_skips_resort():
    """CapacityOverflow from the fused chain carries a _ProbeResume; the
    retry replays it without re-sorting (telemetry counts 2 sorts for the
    whole planned_join, not 4)."""
    rng = np.random.default_rng(9)
    a = mk_table((0, 1), rng.integers(0, 3, (64, 2)))
    b = mk_table((1, 2), rng.integers(0, 3, (64, 2)))
    want = oracle_join(a, b)
    assert len(want) > 16
    tel = JoinTelemetry()
    with pytest.raises(CapacityOverflow) as ei:
        join_tables(a, b, impl="sorted", cap=16, fuse=True, telemetry=tel)
    resume = getattr(ei.value, "resume", None)
    assert isinstance(resume, matching._ProbeResume)
    out = join_tables(a, b, impl="sorted", cap=_pow2(ei.value.needed),
                      _resume=resume, fuse=True, telemetry=tel)
    assert rows_multiset(out) == want
    assert tel.sorts_performed == 2        # resume did not re-sort


def test_fused_row_limit_truncation():
    a = mk_table((0,), [[i % 4] for i in range(40)])
    b = mk_table((0, 1), [[i % 4, i] for i in range(40)])
    full = join_tables(a, b, impl="sorted", fuse=True)
    lim = join_tables(a, b, impl="sorted", fuse=True, row_limit=17)
    assert full.count > 17 and lim.count == 17 and lim.truncated
    assert set(rows_multiset(lim)) <= set(rows_multiset(full))


# ------------------------------ radix --------------------------------- #
@pytest.mark.parametrize("seed", range(6))
def test_radix_equals_oracle(seed):
    a, b = rand_pair(seed + 100, ncols_a=2, ncols_b=2, vmax=6)
    want = oracle_join(a, b)
    got = join_tables(a, b, impl="radix")
    assert rows_multiset(got) == want


def test_radix_partition_window_probe_roundtrip():
    rng = np.random.default_rng(13)
    b_keys = jnp.asarray(
        np.concatenate([rng.integers(0, 50, 90),
                        np.full(38, kfused.B_INVALID)]).astype(np.int32))
    b_rows = jnp.asarray(rng.integers(0, 99, (128, 2)).astype(np.int32))
    bits = 5
    keys_p, rows_p, edges, maxlen = krad.radix_partition(b_keys, b_rows, bits)
    edges = np.asarray(edges)
    assert edges[0] == 0 and edges[-1] <= 128
    # every real bucket's slice hashes to that bucket AND is key-sorted
    # (the contiguous-match-run invariant the probe and assembly rely on)
    kp = np.asarray(keys_p)
    for bkt in range(1 << bits):
        sl = kp[edges[bkt]: edges[bkt + 1]]
        if sl.size:
            h = (sl.astype(np.uint32) * np.uint32(2654435761)) >> (32 - bits)
            assert (h == bkt).all()
            assert (np.diff(sl) >= 0).all()
    assert int(maxlen) == max(
        edges[b + 1] - edges[b] for b in range(1 << bits))
    a_keys = jnp.asarray(rng.integers(0, 50, 64).astype(np.int32))
    lmax = _pow2(int(maxlen), lo=8)
    win_keys, win_start = krad.radix_window(a_keys, edges, keys_p, bits, lmax)
    lt, cnt = krad.window_probe_ref(a_keys, win_keys)
    bk_np = np.asarray(b_keys)[:90]
    want_cnt = np.array([(bk_np == int(k)).sum() for k in a_keys])
    assert (np.asarray(cnt) == want_cnt).all()
    # lt + win_start locates each key's match run in the partition
    lt_np, ws_np = np.asarray(lt), np.asarray(win_start)
    for r, k in enumerate(np.asarray(a_keys)):
        if want_cnt[r]:
            run = kp[ws_np[r] + lt_np[r]: ws_np[r] + lt_np[r] + want_cnt[r]]
            assert (run == k).all()


def test_radix_probe_interpret_matches_ref():
    rng = np.random.default_rng(17)
    a_keys = jnp.asarray(rng.integers(0, 9, 40).astype(np.int32))
    win = jnp.asarray(np.sort(rng.integers(0, 9, (40, 16)), axis=1)
                      .astype(np.int32))
    r_lt, r_cnt = kops.radix_probe(a_keys, win, impl="ref")
    i_lt, i_cnt = kops.radix_probe(a_keys, win, impl="interpret")
    assert (np.asarray(r_lt) == np.asarray(i_lt)).all()
    assert (np.asarray(r_cnt) == np.asarray(i_cnt)).all()


def test_radix_overflow_resume():
    rng = np.random.default_rng(19)
    a = mk_table((0, 1), rng.integers(0, 4, (80, 2)))
    b = mk_table((1, 2), rng.integers(0, 4, (80, 2)))
    want = oracle_join(a, b)
    assert len(want) > 32
    with pytest.raises(CapacityOverflow) as ei:
        join_tables(a, b, impl="radix", cap=32)
    resume = getattr(ei.value, "resume", None)
    assert isinstance(resume, matching._RadixResume)
    out = join_tables(a, b, impl="radix", cap=_pow2(ei.value.needed),
                      _resume=resume)
    assert rows_multiset(out) == want


def test_radix_row_limit_and_order_preserved():
    a = mk_table((0, 1), [[i % 5, i] for i in range(50)])
    b = mk_table((0, 2), [[i % 5, i + 100] for i in range(30)])
    out = join_tables(a, b, impl="radix")
    # output preserves A's row order (radix never sorts the probe side)
    a_col1 = [r[1] for r in
              (tuple(int(x) for x in row) for row in out.numpy())]
    assert a_col1 == sorted(a_col1)
    lim = join_tables(a, b, impl="radix", row_limit=23)
    assert lim.count == 23 and lim.truncated


def test_radix_skew_falls_back_to_sorted_deterministically():
    """A hot key inflating the widest bucket past RADIX_WORK_MAX must
    fall back to sort-merge — both attempts, same answer."""
    hot = np.zeros((5000, 2), np.int32)          # all rows share key 0
    hot[:, 1] = np.arange(5000)
    a = mk_table((0, 1), hot)
    b = mk_table((0, 2), hot.copy())
    old = matching.RADIX_WORK_MAX
    matching.RADIX_WORK_MAX = 1                  # force the skew guard
    try:
        r1 = join_tables(a, b, impl="radix", row_limit=100)
        r2 = join_tables(a, b, impl="radix", row_limit=100)
    finally:
        matching.RADIX_WORK_MAX = old
    assert r1.count == r2.count == 100
    assert rows_multiset(r1) == rows_multiset(r2)


def test_radix_empty_tables():
    a = mk_table((0, 1), np.zeros((0, 2), np.int32))
    b = mk_table((0, 2), [[1, 2]])
    assert join_tables(a, b, impl="radix").count == 0
    assert join_tables(b, a, impl="radix").count == 0


# --------------------- three-strategy identity ------------------------ #
@pytest.mark.parametrize("seed", range(4))
def test_nested_sorted_radix_identity(seed):
    a, b = rand_pair(seed + 300, na=70, nb=50,
                     ncols_a=(seed % 2) + 1, ncols_b=2, vmax=4)
    r = {impl: rows_multiset(join_tables(a, b, impl=impl))
         for impl in ("nested", "sorted", "radix")}
    assert r["nested"] == r["sorted"] == r["radix"] == oracle_join(a, b)


# ------------------------ dedup_project fusion ------------------------ #
def test_dedup_project_fused_parity():
    rng = np.random.default_rng(23)
    t = mk_table((3, 1, 7), rng.integers(0, 4, (60, 3)))
    out = dedup_project(t, (7, 1))
    want = sorted({(int(r[2]), int(r[1])) for r in t.numpy()})
    assert rows_multiset(out) == want
    assert out.sort_order == (7, 1)


def test_lexsort_distinct_tolerates_scattered_valid_rows():
    """Valid rows may sit anywhere in the capacity, not just a prefix."""
    rows = np.full((16, 2), -1, np.int32)
    rows[3] = [2, 9]
    rows[7] = [1, 5]
    rows[12] = [2, 9]                            # duplicate
    t = Table(cols=(0, 1), rows=jnp.asarray(rows), count=3)
    out = dedup_project(t, (0, 1))
    assert rows_multiset(out) == [(1, 5), (2, 9)]


# ---------------------- warm-replay strategy pin ---------------------- #
def test_planned_join_cap_estimate_pins_impl():
    rng = np.random.default_rng(29)
    a = mk_table((0, 1), rng.integers(0, 6, (60, 2)))
    b = mk_table((1, 2), rng.integers(0, 6, (60, 2)))
    recorded = []
    rec = lambda *r: recorded.append(r)
    base = planned_join(a, b, est=700, impl="sorted", record=rec)
    for forced in ("radix", "nested", "sorted"):
        recorded.clear()
        out = planned_join(a, b, CapEstimate(base.count, base.cap, forced),
                           record=rec)
        assert recorded[0][0] == forced          # strategy replayed
        assert out.cap == base.cap               # capacity replayed
        assert rows_multiset(out) == rows_multiset(base)
