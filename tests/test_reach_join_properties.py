"""Property-based parity (hypothesis): reach_join must equal the
cross_join + connectivity_mask oracle on randomized graphs, distance
constraints (including d_c > ni.d_max -> exact BFS fallback), empty and
skewed tables, and bidirectional edges."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (build_ni_index, connectivity_mask, cross_join,
                        filter_rows, ReachCache, reach_join, reach_filter,
                        empty_table)
from repro.core.matching import Table, _pow2
from repro.data import random_graph


def mk_table(cols, vals):
    vals = np.asarray(vals, np.int32).reshape(-1, len(cols))
    cap = _pow2(len(vals))
    rows = np.full((cap, len(cols)), -1, np.int32)
    rows[: len(vals)] = vals
    return Table(cols=tuple(cols), rows=jnp.asarray(rows), count=len(vals))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), d_max=st.integers(1, 3),
       d_c=st.integers(1, 5), bidir=st.booleans(),
       rows_a=st.integers(0, 70), rows_b=st.integers(1, 70))
def test_reach_join_parity_randomized(seed, d_max, d_c, bidir,
                                      rows_a, rows_b):
    rng = np.random.default_rng(seed)
    g = random_graph(n_nodes=int(rng.integers(30, 90)),
                     n_edges=int(rng.integers(80, 300)),
                     n_preds=2, seed=seed)
    ni = build_ni_index(g, d_max=d_max)
    pool = rng.integers(0, g.num_nodes, max(g.num_nodes // 4, 2))
    ta = mk_table((0,), rng.choice(pool, rows_a)) if rows_a else \
        empty_table((0,))
    tb = mk_table((1,), rng.choice(pool, rows_b))
    out = reach_join(g, ni, ta, tb, 0, 1, d_c, bidir, cache=ReachCache())
    x = cross_join(ta, tb)
    rows = np.asarray(x.rows[: x.count])
    keep = connectivity_mask(g, ni, rows[:, 0], rows[:, 1], d_c, bidir)
    assert out.result_set() == filter_rows(x, keep).result_set()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), d_max=st.integers(1, 2),
       d_c=st.integers(1, 4), bidir=st.booleans())
def test_reach_filter_parity_randomized(seed, d_max, d_c, bidir):
    rng = np.random.default_rng(seed)
    g = random_graph(n_nodes=int(rng.integers(30, 80)),
                     n_edges=int(rng.integers(80, 240)),
                     n_preds=2, seed=seed + 1)
    ni = build_ni_index(g, d_max=d_max)
    a = rng.integers(0, g.num_nodes, 40)
    b = rng.integers(0, g.num_nodes, 40)
    t = mk_table((0, 1), np.stack([a, b], axis=1))
    got = reach_filter(g, ni, t, 0, 1, d_c, bidir)
    want = filter_rows(t, connectivity_mask(g, ni, a, b, d_c, bidir))
    assert got.result_set() == want.result_set()
