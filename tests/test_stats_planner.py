"""Dataset statistics (§4.1, §5) and planner (§4.3) behavior."""
import numpy as np

from repro.core import (compute_stats, make_engine, Thresholds,
                        neighborhood_selectivity, connection_selectivity,
                        expected_reach, endpoint_reach, plan_connections,
                        ConnFeatures, RDFGraph)
from repro.core.planner import decide
from repro.core.decompose import decompose
from repro.data import DATASETS, random_query


def _stats(name, scale=0.05):
    return compute_stats(DATASETS[name](scale=scale, seed=1))


def test_metric_orderings_match_paper():
    """LUBM-like: highest coherence, lowest specialty, lowest diversity —
    the paper's predictor of low pruning benefit (Table 1 / §5)."""
    lubm, dblp, imdb = _stats("lubm"), _stats("dblp"), _stats("imdb")
    assert lubm.coherence > dblp.coherence > 0
    assert lubm.coherence > imdb.coherence
    assert lubm.specialty < dblp.specialty
    assert lubm.specialty < imdb.specialty
    assert lubm.diversity < imdb.diversity


def test_predicate_selectivity_sums_to_one():
    g = DATASETS["dblp"](scale=0.05, seed=2)
    st = compute_stats(g)
    assert np.isclose(st.pred_selectivity.sum(), 1.0)


def test_literal_selectivity_decreases_with_n():
    g = DATASETS["dblp"](scale=0.05, seed=2)
    st = compute_stats(g)
    for pa, table in st.literal_selectivity.items():
        ns = sorted(table)
        vals = [table[n] for n in ns]
        # longer prefixes match fewer labels (non-strict monotonicity)
        assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_neighborhood_selectivity_nonnegative_and_grows_with_k():
    g = DATASETS["dblp"](scale=0.05, seed=2)
    st = compute_stats(g)
    q = random_query(g, size=5, seed=42)
    for node in range(q.num_nodes):
        s1 = neighborhood_selectivity(q, node, st, 1)
        s2 = neighborhood_selectivity(q, node, st, 2)
        assert 0 <= s1 <= s2 + 1e-9


def test_planner_thresholds_gate_the_check():
    g = DATASETS["dblp"](scale=0.05, seed=2)
    st = compute_stats(g)
    q = random_query(g, size=6, seed=7)
    iv = q.intervals(make_engine(g, "stwig+").idmap)
    sizes = {i: int(iv[i, 1] - iv[i, 0]) for i in range(q.num_nodes)}
    trees = [decompose(q, c, sizes) for c in q.components()]
    always = decide(q, trees, sizes, st, Thresholds(0, 0, 0), k=2)
    assert always.use_check       # zero thresholds -> complex & selective
    never = decide(q, trees, sizes, st,
                   Thresholds(1e18, 1e18, 1e18), k=2)
    assert not never.use_check


def test_engine_variants_policy():
    g = DATASETS["lubm"](scale=0.03, seed=1)
    q = random_query(g, size=4, seed=5)
    r_never = make_engine(g, "stwig+", impl="ref").execute(q)
    assert not r_never.stats.used_check
    r_always = make_engine(g, "spath_ni2", impl="ref").execute(q)
    assert r_always.stats.used_check
    assert r_never.result_set() == r_always.result_set()


def test_bloom_prefilter_engine_equality():
    """gStore-style bitstring prefilter never changes results (sound)."""
    from repro.core import brute_force_match, make_engine
    from repro.data import random_graph, random_query
    for seed in range(3):
        g = random_graph(n_nodes=50, n_edges=150, n_preds=3,
                         n_literals=15, seed=seed)
        q = random_query(g, size=4, seed=seed * 5 + 2, exact_nodes=0.5)
        want = {tuple(t[c] for c in sorted(range(q.num_nodes)))
                for t in brute_force_match(g, q)}
        eng = make_engine(g, "spath_ni2", impl="ref")
        eng.cfg.use_bloom = True
        assert eng.execute(q).result_set() == want


# ------------------- candidate-aware reach estimates ------------------- #
def _hub_graph(n_hub_edges=400, n_chain=400, n_mid=100, mid_deg=10):
    """Skewed fixture: one hub with out-degree n_hub_edges, a sparse
    degree-1 chain, and mid-degree filler nodes that pull the global
    average fanout to ~2 — so the hub sits far above the average and the
    chain below it, which is exactly what the global geometric estimate
    flattens away."""
    triples = [("hub/0", "pH", f"leaf/{i:04d}") for i in range(n_hub_edges)]
    triples += [(f"chain/{i:04d}", "pC", f"chain/{(i + 1) % n_chain:04d}")
                for i in range(n_chain)]
    triples += [(f"mid/{i:04d}", "pM", f"mid/{(i * mid_deg + k) % n_mid:04d}")
                for i in range(n_mid) for k in range(1, mid_deg + 1)]
    return RDFGraph.from_triples(triples, literal_objects=set())


def test_endpoint_reach_defaults_to_expected_reach():
    """Without candidate nodes the two estimates agree exactly (the
    candidate-aware formula collapses to the geometric series)."""
    st = _stats("dblp", scale=0.03)
    n = 10_000
    for hops in range(5):
        assert np.isclose(endpoint_reach(st, n, hops),
                          expected_reach(st, n, hops))


def test_endpoint_reach_separates_hubs_from_leaves():
    g = _hub_graph()
    st = compute_stats(g)
    idmap = make_engine(g, "stwig+").idmap
    hub = np.asarray([idmap.interval("hub/")[0]])
    lo, hi = idmap.interval("chain/")
    chain = np.arange(lo, lo + 50)
    n = g.num_nodes
    r_hub = endpoint_reach(st, n, 1, hub, +1)
    r_chain = endpoint_reach(st, n, 1, chain, +1)
    r_global = expected_reach(st, n, 1)
    # the hub's one-hop reach is ~400, a chain node's ~2; the global
    # average estimate cannot tell them apart
    assert r_hub > 100 * r_chain
    assert r_chain < r_global < r_hub


def test_connection_selectivity_candidate_aware():
    g = _hub_graph()
    st = compute_stats(g)
    idmap = make_engine(g, "stwig+").idmap
    hub = np.asarray([idmap.interval("hub/")[0]])
    lo, _ = idmap.interval("chain/")
    chain = np.arange(lo, lo + 50)
    n = g.num_nodes
    sel_global = connection_selectivity(st, n, 2)
    sel_hub = connection_selectivity(st, n, 2, a_nodes=hub, b_nodes=hub)
    sel_chain = connection_selectivity(st, n, 2, a_nodes=chain,
                                       b_nodes=chain)
    assert sel_hub > sel_global > sel_chain


def test_connection_plan_orders_selective_edge_first_on_hub_graph():
    """Two connection edges with identical d_c and group sizes: one
    between hub-heavy endpoint sets (non-selective: huge reach), one
    between leaf sets (selective).  The global estimate cannot rank them;
    candidate-aware features put the selective edge first, so the
    expensive hub merge runs on the already-shrunk tables."""
    g = _hub_graph()
    st = compute_stats(g)
    idmap = make_engine(g, "stwig+").idmap
    hub = np.asarray([idmap.interval("hub/")[0]])
    lo, _ = idmap.interval("chain/")
    chain = np.arange(lo, lo + 50)
    n = g.num_nodes
    sizes = [1000, 1000, 1000]
    # a chain of merges sharing group 1: edge 0 = hub-hub (non-selective,
    # its merge barely shrinks), edge 1 = leaf-leaf (selective)
    endpoints = [(0, 1), (1, 2)]
    sels = [connection_selectivity(st, n, 2, a_nodes=hub, b_nodes=hub),
            connection_selectivity(st, n, 2, a_nodes=chain, b_nodes=chain)]
    feats = [ConnFeatures(50, 50, endpoint_reach(st, n, 1, hub, +1),
                          endpoint_reach(st, n, 1, hub, -1)),
             ConnFeatures(50, 50, endpoint_reach(st, n, 1, chain, +1),
                          endpoint_reach(st, n, 1, chain, -1))]
    plan = plan_connections(sizes, endpoints, sels, feats=feats,
                            num_nodes=n)
    assert plan.order[0] == 1           # selective leaf edge first
    # with the global estimate both edges look identical (same d_c, same
    # sizes) — the candidate-aware ranking is strictly more informed
    sel_g = connection_selectivity(st, n, 2)
    assert sels[0] > sel_g > sels[1]


def test_tune_thresholds_grid():
    from repro.core import tune_thresholds, Thresholds

    # synthetic cost: cheaper when the check is OFF for simple queries
    class Q:
        pass

    def cost(q, th):
        # pretend: low tau_sel forces wasted checks
        return 1.0 if th.tau_sel >= 8 else 2.0
    th = tune_thresholds(cost, [Q(), Q()], grid_sel=(4.0, 8.0))
    assert th.tau_sel >= 8
