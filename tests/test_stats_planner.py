"""Dataset statistics (§4.1, §5) and planner (§4.3) behavior."""
import numpy as np

from repro.core import (compute_stats, make_engine, Thresholds,
                        neighborhood_selectivity)
from repro.core.planner import decide
from repro.core.decompose import decompose
from repro.data import DATASETS, random_query


def _stats(name, scale=0.05):
    return compute_stats(DATASETS[name](scale=scale, seed=1))


def test_metric_orderings_match_paper():
    """LUBM-like: highest coherence, lowest specialty, lowest diversity —
    the paper's predictor of low pruning benefit (Table 1 / §5)."""
    lubm, dblp, imdb = _stats("lubm"), _stats("dblp"), _stats("imdb")
    assert lubm.coherence > dblp.coherence > 0
    assert lubm.coherence > imdb.coherence
    assert lubm.specialty < dblp.specialty
    assert lubm.specialty < imdb.specialty
    assert lubm.diversity < imdb.diversity


def test_predicate_selectivity_sums_to_one():
    g = DATASETS["dblp"](scale=0.05, seed=2)
    st = compute_stats(g)
    assert np.isclose(st.pred_selectivity.sum(), 1.0)


def test_literal_selectivity_decreases_with_n():
    g = DATASETS["dblp"](scale=0.05, seed=2)
    st = compute_stats(g)
    for pa, table in st.literal_selectivity.items():
        ns = sorted(table)
        vals = [table[n] for n in ns]
        # longer prefixes match fewer labels (non-strict monotonicity)
        assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_neighborhood_selectivity_nonnegative_and_grows_with_k():
    g = DATASETS["dblp"](scale=0.05, seed=2)
    st = compute_stats(g)
    q = random_query(g, size=5, seed=42)
    for node in range(q.num_nodes):
        s1 = neighborhood_selectivity(q, node, st, 1)
        s2 = neighborhood_selectivity(q, node, st, 2)
        assert 0 <= s1 <= s2 + 1e-9


def test_planner_thresholds_gate_the_check():
    g = DATASETS["dblp"](scale=0.05, seed=2)
    st = compute_stats(g)
    q = random_query(g, size=6, seed=7)
    iv = q.intervals(make_engine(g, "stwig+").idmap)
    sizes = {i: int(iv[i, 1] - iv[i, 0]) for i in range(q.num_nodes)}
    trees = [decompose(q, c, sizes) for c in q.components()]
    always = decide(q, trees, sizes, st, Thresholds(0, 0, 0), k=2)
    assert always.use_check       # zero thresholds -> complex & selective
    never = decide(q, trees, sizes, st,
                   Thresholds(1e18, 1e18, 1e18), k=2)
    assert not never.use_check


def test_engine_variants_policy():
    g = DATASETS["lubm"](scale=0.03, seed=1)
    q = random_query(g, size=4, seed=5)
    r_never = make_engine(g, "stwig+", impl="ref").execute(q)
    assert not r_never.stats.used_check
    r_always = make_engine(g, "spath_ni2", impl="ref").execute(q)
    assert r_always.stats.used_check
    assert r_never.result_set() == r_always.result_set()


def test_bloom_prefilter_engine_equality():
    """gStore-style bitstring prefilter never changes results (sound)."""
    from repro.core import brute_force_match, make_engine
    from repro.data import random_graph, random_query
    for seed in range(3):
        g = random_graph(n_nodes=50, n_edges=150, n_preds=3,
                         n_literals=15, seed=seed)
        q = random_query(g, size=4, seed=seed * 5 + 2, exact_nodes=0.5)
        want = {tuple(t[c] for c in sorted(range(q.num_nodes)))
                for t in brute_force_match(g, q)}
        eng = make_engine(g, "spath_ni2", impl="ref")
        eng.cfg.use_bloom = True
        assert eng.execute(q).result_set() == want


def test_tune_thresholds_grid():
    from repro.core import tune_thresholds, Thresholds

    # synthetic cost: cheaper when the check is OFF for simple queries
    class Q:
        pass

    def cost(q, th):
        # pretend: low tau_sel forces wasted checks
        return 1.0 if th.tau_sel >= 8 else 2.0
    th = tune_thresholds(cost, [Q(), Q()], grid_sel=(4.0, 8.0))
    assert th.tau_sel >= 8
