"""Distributed-correctness tests.

These need >1 device, so they run a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
must keep seeing one device)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_sub(code: str) -> dict:
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              # forced host devices only exist on the CPU
                              # backend; without this each subprocess stalls
                              # for minutes probing for a TPU
                              "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_shard_check_matches_single_device():
    r = run_sub("""
    import json, numpy as np, jax
    from repro.core import build_ni_index
    from repro.core.distributed import shard_check
    from repro.kernels import ref as kref
    from repro.data import random_graph
    g = random_graph(n_nodes=100, n_edges=300, seed=5)
    ni = build_ni_index(g, d_max=1)
    e = ni.entries[1]
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    lo = np.asarray([0, 40], np.int32); hi = np.asarray([30, 90], np.int32)
    need = np.asarray([1, 1], np.int32)
    got = shard_check(mesh, e.ids, lo, hi, need, e.overflow)
    import jax.numpy as jnp
    cnt = np.asarray(kref.interval_count_ref(jnp.asarray(e.ids), jnp.asarray(lo), jnp.asarray(hi)))
    want = ((cnt >= need[None, :]).all(1)) | e.overflow
    print(json.dumps({"equal": bool((got == want).all())}))
    """)
    assert r["equal"]


def test_gather_candidates_collects_all():
    r = run_sub("""
    import json, numpy as np, jax
    from repro.core.distributed import gather_candidates
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    mask = rng.random(64) < 0.3
    got = gather_candidates(mesh, mask, cap=32)
    want = np.nonzero(mask)[0]
    print(json.dumps({"equal": sorted(got.tolist()) == want.tolist()}))
    """)
    assert r["equal"]


def test_sharded_train_step_matches_single():
    """DP+TP sharded train step == single-device step (same math)."""
    r = run_sub("""
    import json, numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as PS
    from repro.configs import ARCHS, reduced_config
    from repro.configs.base import InputShape, TrainConfig
    from repro.models import api
    from repro.optim import adamw_init

    cfg = reduced_config(ARCHS["stablelm-1.6b"])
    tcfg = TrainConfig(microbatch=1)
    params = api.init_model(cfg, 0)
    batch = api.concrete_batch(cfg, InputShape("s", 32, 4, "train"), seed=2)
    opt = adamw_init(params)

    # single device
    step1 = jax.jit(api.make_train_step(cfg, tcfg, None))
    p1, o1, m1 = step1(params, opt, batch, 0)

    # 4x2 mesh
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    pspec = api.model_pspecs(cfg, mesh)
    bspec = api.batch_pspecs(cfg, InputShape("s", 32, 4, "train"), mesh)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, PS))
    with mesh:
        step2 = jax.jit(api.make_train_step(cfg, tcfg, mesh),
                        in_shardings=(ns(pspec), ns(api.opt_pspecs(cfg, mesh)),
                                      ns(bspec), NamedSharding(mesh, PS())))
        p2, o2, m2 = step2(params, opt, batch, 0)
    dl = abs(float(m1["loss"]) - float(m2["loss"]))
    dp = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    print(json.dumps({"dloss": dl, "dparam": dp}))
    """)
    assert r["dloss"] < 1e-3, r
    assert r["dparam"] < 5e-3, r


def test_elastic_shrink_and_reshard():
    r = run_sub("""
    import json, numpy as np, jax
    from jax.sharding import PartitionSpec as PS
    from repro.runtime import shrink_mesh, reshard
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    small = shrink_mesh(mesh, "pod")
    x = np.arange(32, dtype=np.float32).reshape(8, 4)
    t = reshard({"x": x}, small, {"x": PS("data", "model")})
    ok = (np.asarray(t["x"]) == x).all() and small.axis_names == ("data", "model")
    print(json.dumps({"ok": bool(ok)}))
    """)
    assert r["ok"]
