"""Connectivity check (Alg. 3) vs BFS oracle, including the expensive
expansion path where d_c exceeds what the index covers."""
import numpy as np
import pytest

from repro.core import build_ni_index, connectivity_mask
from repro.core.connectivity import _bfs_within, reach_sets
from repro.data import random_graph


@pytest.mark.parametrize("d_max,d_c", [(1, 2), (1, 5), (2, 4), (2, 5),
                                       (3, 5), (3, 6)])
def test_connectivity_vs_bfs(d_max, d_c):
    g = random_graph(n_nodes=80, n_edges=240, n_preds=2, seed=d_max * 10 + d_c)
    ni = build_ni_index(g, d_max=d_max)
    rng = np.random.default_rng(0)
    a = rng.integers(0, g.num_nodes, 64)
    b = rng.integers(0, g.num_nodes, 64)
    got = connectivity_mask(g, ni, a, b, d_c, impl="ref")
    for i in range(len(a)):
        fwd = _bfs_within(g, int(a[i]), d_c, True)
        want = int(b[i]) in fwd
        assert got[i] == want, (a[i], b[i])


def test_connectivity_bidirectional():
    g = random_graph(n_nodes=60, n_edges=150, n_preds=2, seed=3)
    ni = build_ni_index(g, d_max=2)
    rng = np.random.default_rng(1)
    a = rng.integers(0, g.num_nodes, 32)
    b = rng.integers(0, g.num_nodes, 32)
    got = connectivity_mask(g, ni, a, b, 3, bidirectional=True, impl="ref")
    for i in range(len(a)):
        fwd = int(b[i]) in _bfs_within(g, int(a[i]), 3, True)
        bwd = int(a[i]) in _bfs_within(g, int(b[i]), 3, True)
        assert got[i] == (fwd or bwd)


def test_reach_sets_include_self_and_match_bfs():
    g = random_graph(n_nodes=50, n_edges=160, n_preds=2, seed=9)
    ni = build_ni_index(g, d_max=2)
    nodes = np.arange(0, 20)
    ids, overflow = reach_sets(ni, nodes, hops=2, sign=+1)
    for i, n in enumerate(nodes):
        if overflow[i]:
            continue
        got = {int(x) for x in ids[i] if x >= 0}
        want = _bfs_within(g, int(n), 2, True)
        assert got == want


def test_connectivity_vectorized_form_matches():
    from repro.core.connectivity import connectivity_mask_vectorized
    import numpy as np
    from repro.core import build_ni_index, connectivity_mask
    from repro.data import random_graph
    g = random_graph(n_nodes=70, n_edges=200, n_preds=2, seed=21)
    ni = build_ni_index(g, d_max=2)
    rng = np.random.default_rng(2)
    a = rng.integers(0, g.num_nodes, 40)
    b = rng.integers(0, g.num_nodes, 40)
    m1 = connectivity_mask(g, ni, a, b, 4)
    m2 = connectivity_mask_vectorized(g, ni, a, b, 4, impl="ref")
    assert (m1 == m2).all()


@pytest.mark.parametrize("seed,d_max,d_c", [
    (0, 1, 2), (1, 2, 3), (2, 2, 4), (3, 3, 5), (4, 1, 3), (5, 2, 2)])
def test_connectivity_vectorized_parity_randomized(seed, d_max, d_c):
    """connectivity_mask vs connectivity_mask_vectorized on randomized
    graphs and pairs — including repeated and self pairs — across index
    depths both covering and not covering d_c."""
    from repro.core.connectivity import connectivity_mask_vectorized
    rng = np.random.default_rng(seed)
    g = random_graph(n_nodes=int(rng.integers(40, 100)),
                     n_edges=int(rng.integers(120, 320)),
                     n_preds=3, seed=seed + 100)
    ni = build_ni_index(g, d_max=d_max)
    p = 48
    a = rng.integers(0, g.num_nodes, p)
    b = rng.integers(0, g.num_nodes, p)
    b[: p // 8] = a[: p // 8]                # self pairs
    a[p // 8: p // 4] = a[0]                 # repeated (memoized) sources
    m1 = connectivity_mask(g, ni, a, b, d_c, impl="ref")
    m2 = connectivity_mask_vectorized(g, ni, a, b, d_c, impl="ref")
    assert (m1 == m2).all()
    b1 = connectivity_mask(g, ni, a, b, d_c, bidirectional=True, impl="ref")
    b2 = connectivity_mask_vectorized(g, ni, a, b, d_c, bidirectional=True,
                                      impl="ref")
    assert (b1 == b2).all()


def test_enumerate_shortest_paths():
    from repro.core.connectivity import enumerate_shortest_paths
    import numpy as np
    from repro.core import build_ni_index, connectivity_mask
    from repro.data import random_graph
    g = random_graph(n_nodes=60, n_edges=170, n_preds=2, seed=13)
    ni = build_ni_index(g, d_max=2)
    rng = np.random.default_rng(0)
    a = rng.integers(0, g.num_nodes, 30)
    b = rng.integers(0, g.num_nodes, 30)
    mask = connectivity_mask(g, ni, a, b, 4)
    out_adj = {}
    for s, d in zip(g.src, g.dst):
        out_adj.setdefault(int(s), set()).add(int(d))
    for i in range(30):
        paths = enumerate_shortest_paths(g, int(a[i]), int(b[i]), 4)
        assert bool(paths) == bool(mask[i])       # consistent existence
        for p in paths:
            assert p[0] == a[i] and p[-1] == b[i]
            assert len(p) - 1 <= 4
            for u, v in zip(p, p[1:]):            # every hop is an edge
                assert v in out_adj.get(u, set())
        if paths:                                  # all same (shortest) len
            assert len({len(p) for p in paths}) == 1


def test_instantiate_connections_end_to_end():
    from repro.core.connectivity import instantiate_connections
    from repro.core import make_engine
    from repro.data import random_graph, random_query
    g = random_graph(n_nodes=50, n_edges=160, n_preds=2, seed=4)
    q = random_query(g, size=4, seed=17, n_connection=1, d_c=3)
    if not q.connections:
        return
    r = make_engine(g, "h2", impl="ref").execute(q)
    inst = instantiate_connections(g, r, q, max_paths=4)
    assert len(inst) == r.count
    for row_inst in inst:
        for paths in row_inst.values():
            assert paths                           # match => path exists
