import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS in-process); tests that need many host devices spawn
# subprocesses (see test_distributed.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
