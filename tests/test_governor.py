"""Resource governance units: budgets, circuit breaker, rung memory,
degradation ladder configs, reach-cache byte budget, and capacity
replay.

These are the fast, engine-free (or nearly so) tests of the governance
building blocks; the end-to-end behavior under injected faults lives in
test_chaos.py.
"""
import numpy as np
import pytest

from repro.core import (make_engine, CapEstimate, JoinEstimator,
                        ReachCache, Thresholds)
from repro.core.engine import EngineConfig
from repro.core.matching import CandidateTable, planned_join, _pow2
from repro.data import random_graph, random_query
from repro.serve import (Budget, BudgetExceeded, CircuitBreaker,
                         GovernorConfig, RungMemory, default_ladder)


# ------------------------------ Budget --------------------------------- #
def test_budget_rows_bound():
    b = Budget(max_rows=100)
    b.checkpoint("match", rows=60)
    with pytest.raises(BudgetExceeded) as ei:
        b.checkpoint("match", rows=60)
    assert ei.value.reason == "rows"
    assert ei.value.phase == "match"
    assert ei.value.rows == 120


def test_budget_capacity_bound():
    b = Budget(max_capacity=1 << 10)
    b.checkpoint("match", cap=1 << 10)          # at the bound: fine
    with pytest.raises(BudgetExceeded) as ei:
        b.checkpoint("connections", cap=1 << 11)
    assert ei.value.reason == "capacity"
    assert ei.value.phase == "connections"


def test_budget_deadline_bound():
    b = Budget(deadline_s=1e-9)
    import time
    time.sleep(0.001)
    with pytest.raises(BudgetExceeded) as ei:
        b.checkpoint("check")
    assert ei.value.reason == "deadline"


def test_budget_carries_partial_stats():
    from repro.core.engine import QueryStats
    qs = QueryStats()
    qs.join_actual_rows = 7
    b = Budget(max_rows=1)
    with pytest.raises(BudgetExceeded) as ei:
        b.checkpoint("match", rows=2, stats=qs)
    assert ei.value.stats is qs
    assert ei.value.stats.join_actual_rows == 7


def test_budget_unbounded_never_raises():
    b = Budget()
    for _ in range(100):
        b.checkpoint("match", rows=1 << 30, cap=1 << 30)
    assert b.checks == 100


# -------------------------- CircuitBreaker ----------------------------- #
def test_breaker_trips_after_threshold_and_recovers():
    cb = CircuitBreaker(threshold=3, cooldown_s=10.0)
    fp = "fp-a"
    now = 1000.0
    for _ in range(2):
        cb.record(fp, ok=False, now=now)
        assert cb.admit(fp, now=now) == "allow"
    cb.record(fp, ok=False, now=now)            # 3rd consecutive failure
    assert cb.state(fp) == "open"
    assert cb.admit(fp, now=now + 1.0) == "deny"
    assert cb.retry_after(fp, now=now + 1.0) == pytest.approx(9.0)
    # cooldown elapsed -> half-open single probe
    assert cb.admit(fp, now=now + 11.0) == "probe"
    cb.record(fp, ok=True, now=now + 11.0)
    assert cb.state(fp) == "closed"
    assert cb.admit(fp, now=now + 11.0) == "allow"
    assert cb.recoveries == 1 and cb.trips == 1


def test_breaker_failed_probe_backs_off_exponentially():
    cb = CircuitBreaker(threshold=1, cooldown_s=10.0, backoff=2.0)
    fp = "fp-b"
    cb.record(fp, ok=False, now=0.0)            # trip: cooldown 10
    assert cb.admit(fp, now=5.0) == "deny"
    assert cb.admit(fp, now=11.0) == "probe"
    cb.record(fp, ok=False, now=11.0)           # failed probe: cooldown 20
    assert cb.admit(fp, now=25.0) == "deny"
    assert cb.admit(fp, now=32.0) == "probe"
    assert cb.trips == 2


def test_breaker_success_resets_consecutive_count():
    cb = CircuitBreaker(threshold=3)
    fp = "fp-c"
    for _ in range(5):
        cb.record(fp, ok=False, now=0.0)
        cb.record(fp, ok=True, now=0.0)         # never 3 consecutive
    assert cb.state(fp) == "closed" and cb.trips == 0


def test_breaker_isolates_fingerprints():
    cb = CircuitBreaker(threshold=1, cooldown_s=10.0)
    cb.record("bad", ok=False, now=0.0)
    assert cb.admit("bad", now=1.0) == "deny"
    assert cb.admit("good", now=1.0) == "allow"


def test_breaker_cooldown_saturates_at_max():
    """Many consecutive failed probes: the exponential backoff must clamp
    at max_cooldown_s, not grow without bound."""
    cb = CircuitBreaker(threshold=1, cooldown_s=10.0, backoff=2.0,
                        max_cooldown_s=60.0)
    fp = "fp-sat"
    now = 0.0
    cb.record(fp, ok=False, now=now)            # trip, cooldown 10
    for _ in range(12):                         # 10 -> 20 -> 40 -> 60 -> 60...
        now = cb._st[fp]["until"] + 0.001
        assert cb.admit(fp, now=now) == "probe"
        cb.record(fp, ok=False, now=now)
        assert cb._st[fp]["cooldown"] <= 60.0
    assert cb._st[fp]["cooldown"] == 60.0
    # the open window itself is also bounded by the saturated cooldown
    assert cb.retry_after(fp, now=now) <= 60.0


def test_breaker_backwards_clock_cannot_reopen_recovered():
    """Injectable-clock monotonicity: after a probe recovery, a `now`
    passed backwards must not re-open (or extend) anything — observed
    times are clamped to the high-water mark."""
    cb = CircuitBreaker(threshold=1, cooldown_s=10.0)
    fp = "fp-mono"
    cb.record(fp, ok=False, now=100.0)          # open until 110
    assert cb.admit(fp, now=111.0) == "probe"
    cb.record(fp, ok=True, now=111.0)           # recovered
    assert cb.state(fp) == "closed"
    # clock runs backwards: still closed, still allowed
    assert cb.admit(fp, now=50.0) == "allow"
    assert cb.state(fp) == "closed"
    assert cb.retry_after(fp, now=50.0) == 0.0
    # a new trip recorded at a backwards time opens from the high-water
    # mark, not from the stale clock (no cooldown already half-expired)
    cb.record(fp, ok=False, now=40.0)
    assert cb._st[fp]["until"] >= 111.0 + 10.0


def test_breaker_eviction_bounds_tracked_states():
    """Fingerprint churn: closed fully-recovered entries are evicted
    LRU-style at max_tracked; open/half-open entries are never evicted;
    evictions are reported in snapshot()."""
    cb = CircuitBreaker(threshold=1, cooldown_s=1e6, max_tracked=4)
    cb.record("quarantined", ok=False, now=0.0)  # open forever
    assert cb.state("quarantined") == "open"
    for i in range(10):
        cb.record(f"ok-{i}", ok=True, now=0.0)   # closed, fully recovered
    assert len(cb._st) == 4
    assert cb.state("quarantined") == "open"     # survived all eviction
    assert "quarantined" in cb._st
    # newest closed entries retained, oldest evicted
    assert "ok-9" in cb._st and "ok-0" not in cb._st
    snap = cb.snapshot()
    assert snap["evictions"] == cb.evictions == 7
    assert snap["tracked"] == 4


def test_breaker_eviction_prefers_fully_recovered():
    """Closed entries with residual failure counts are evicted only
    after every fully-recovered entry is gone."""
    cb = CircuitBreaker(threshold=5, max_tracked=2)
    cb.record("failing", ok=False, now=0.0)      # closed, failures=1
    cb.record("clean-1", ok=True, now=0.0)
    cb.record("clean-2", ok=True, now=0.0)       # over cap: evict a clean
    assert "failing" in cb._st
    assert len(cb._st) == 2


def test_breaker_state_roundtrip_rebases_cooldowns():
    """save_state stores remaining cooldown as a relative duration;
    load_state rebases it on the new process's clock."""
    cb = CircuitBreaker(threshold=1, cooldown_s=10.0)
    cb.record("open-fp", ok=False, now=1000.0)   # open until 1010
    cb.record("ok-fp", ok=True, now=1000.0)
    state = cb.save_state(now=1004.0)            # 6s remaining
    cb2 = CircuitBreaker(threshold=1, cooldown_s=10.0)
    cb2.load_state(state, now=7.0)               # entirely different clock
    assert cb2.state("open-fp") == "open"
    assert cb2.retry_after("open-fp", now=7.0) == pytest.approx(6.0)
    assert cb2.admit("open-fp", now=8.0) == "deny"
    assert cb2.admit("open-fp", now=13.5) == "probe"
    assert cb2.admit("ok-fp", now=7.0) == "allow"
    assert cb2.trips == cb.trips


# ---------------------------- RungMemory ------------------------------- #
def test_rung_memory_routes_primary_then_jump_then_probe():
    mem = RungMemory(reprobe_interval_s=30.0, chronic_after=100)
    fp = "fp-r"
    assert mem.route(fp, now=0.0) == ("primary", None)
    mem.record_degraded(fp, "force_simple_impls", now=0.0)
    # inside the re-probe interval: every request jumps to the rung
    for t in (1.0, 10.0, 29.0):
        assert mem.route(fp, now=t) == ("jump", "force_simple_impls")
    # interval elapsed: exactly ONE probe, siblings keep jumping
    assert mem.route(fp, now=31.0) == ("probe", "force_simple_impls")
    assert mem.route(fp, now=31.0) == ("jump", "force_simple_impls")
    snap = mem.snapshot()
    assert snap["jumps"] == 4 and snap["probes"] == 1


def test_rung_memory_probe_recovery_forgets():
    mem = RungMemory(reprobe_interval_s=10.0, chronic_after=100)
    mem.record_degraded("fp", "skip_check", now=0.0)
    assert mem.route("fp", now=11.0)[0] == "probe"
    mem.record_primary_ok("fp")
    assert mem.route("fp", now=12.0) == ("primary", None)
    assert mem.probe_recoveries == 1


def test_rung_memory_chronic_fires_exactly_once_at_threshold():
    mem = RungMemory(chronic_after=3)
    flags = [mem.record_degraded("fp", "truncate", now=0.0)
             for _ in range(5)]
    assert flags == [False, False, True, False, False]
    assert mem.chronic == 1
    mem.clear("fp")
    assert mem.route("fp", now=0.0) == ("primary", None)


def test_rung_memory_lru_bound():
    mem = RungMemory(max_tracked=3)
    for i in range(6):
        mem.record_degraded(f"fp-{i}", "skip_check", now=0.0)
    assert len(mem._st) == 3 and mem.evictions == 3
    assert mem.rung("fp-5") == "skip_check" and mem.rung("fp-0") is None


def test_rung_memory_state_roundtrip_rebases_next_probe():
    mem = RungMemory(reprobe_interval_s=30.0)
    mem.record_degraded("fp", "greedy_plan", now=100.0)  # next probe 130
    state = mem.save_state(now=110.0)                    # 20s remaining
    mem2 = RungMemory(reprobe_interval_s=30.0)
    mem2.load_state(state, now=5.0)
    assert mem2.route("fp", now=6.0) == ("jump", "greedy_plan")
    assert mem2.route("fp", now=26.0)[0] == "probe"      # 5 + 20 elapsed


# --------------------------- Fault triggers ---------------------------- #
def test_fault_first_trigger_fires_then_clears():
    from repro.testing import Fault
    f = Fault("kernel_dispatch", "raise", first=2)
    assert [f.triggers(i) for i in (1, 2, 3, 4)] == [True, True,
                                                     False, False]
    # at/every unchanged
    assert Fault("kernel_dispatch", "raise", at=3).triggers(3)
    assert not Fault("kernel_dispatch", "raise", at=3).triggers(4)
    e = Fault("kernel_dispatch", "raise", every=2)
    assert [e.triggers(i) for i in (1, 2, 3, 4)] == [False, True,
                                                     False, True]


# ------------------------- degradation ladder -------------------------- #
def test_default_ladder_is_cumulative_and_exact_except_last():
    cfg = EngineConfig()
    gov = GovernorConfig(degraded_row_cap=1 << 10)
    rungs = default_ladder()
    names = [r.name for r in rungs]
    assert names == ["skip_check", "greedy_plan", "force_simple_impls",
                     "truncate"]
    c1 = rungs[0].apply(cfg, gov)
    assert c1.check_policy == "never" and c1.plan_mode == cfg.plan_mode
    c2 = rungs[1].apply(cfg, gov)
    assert c2.check_policy == "never" and c2.plan_mode == "greedy"
    c3 = rungs[2].apply(cfg, gov)
    assert (c3.join_impl, c3.connection_impl) == ("nested", "cross")
    assert c3.plan_mode == "greedy" and c3.check_policy == "never"
    # only the last rung may truncate (reduced row cap)
    assert [r.truncate for r in rungs] == [False, False, False, True]
    c4 = rungs[3].apply(cfg, gov)
    assert c4.max_rows == 1 << 10
    # rung application never mutates the base config
    assert cfg.check_policy == "selective" and cfg.max_rows == 1 << 20


def test_truncate_rung_respects_tighter_existing_cap():
    cfg = EngineConfig(max_rows=100)
    gov = GovernorConfig(degraded_row_cap=1 << 14)
    assert default_ladder()[3].apply(cfg, gov).max_rows == 100


def test_with_config_shares_dataset_state_not_reach_cache():
    g = random_graph(n_nodes=40, n_edges=100, n_preds=3, seed=5)
    eng = make_engine(g, "rdf_h", impl="ref")
    eng.reach_cache = ReachCache(max_entries=10)
    sib = eng.with_config(EngineConfig(check_policy="never"))
    assert sib.graph is eng.graph and sib.ni is eng.ni
    assert sib.stats is eng.stats and sib._dev_cache is eng._dev_cache
    assert sib.reach_cache is None
    assert sib.cfg.check_policy == "never"
    assert eng.cfg.check_policy == "selective"


# ----------------------- ReachCache byte budget ------------------------ #
def test_reach_cache_byte_budget_evicts_lru():
    rc = ReachCache(max_bytes=10 * 4 * 100)     # ~10 arrays of 100 int32
    for i in range(25):
        rc.put_array(i, 1, 1, np.arange(100, dtype=np.int32))
    assert rc.total_bytes <= rc.max_bytes
    assert rc.evictions == 15 and len(rc) == 10
    # LRU order: oldest keys evicted, newest retained
    assert rc.get_array(24, 1, 1) is not None
    assert rc.get_array(0, 1, 1) is None


def test_reach_cache_accounts_both_mirrors():
    rc = ReachCache()
    rc.put_array(7, 2, 1, np.arange(50, dtype=np.int32))
    b_array = rc.total_bytes
    assert b_array == 50 * 4
    rc.get_set(7, 2, 1)                         # lazy set mirror conversion
    assert rc.total_bytes == b_array + 8 * 50
    rc.put_set(8, 2, 1, set(range(10)))
    assert rc.total_bytes == b_array + 8 * 50 + 8 * 10


def test_reach_cache_oversized_entry_stays_as_cache_of_one():
    rc = ReachCache(max_bytes=64)
    rc.put_array(1, 1, 1, np.arange(1000, dtype=np.int32))   # >> budget
    assert len(rc) == 1                          # kept: it's in active use
    rc.put_array(2, 1, 1, np.arange(1000, dtype=np.int32))
    assert len(rc) == 1 and rc.evictions == 1    # old giant evicted


def test_reach_cache_entry_bound_still_enforced():
    rc = ReachCache(max_entries=3, max_bytes=None)
    for i in range(6):
        rc.put_set(i, 1, 1, {i})
    assert len(rc) == 3 and rc.evictions == 3
    assert rc.total_bytes == 3 * 8


# -------------------------- capacity replay ---------------------------- #
def _table(cols, rows):
    import jax.numpy as jnp
    arr = np.asarray(rows, dtype=np.int32)
    cap = _pow2(len(arr))
    pad = np.full((cap - len(arr), arr.shape[1]), -1, np.int32)
    return CandidateTable(cols=tuple(cols),
                          rows=jnp.asarray(np.vstack([arr, pad])),
                          count=len(arr))


def test_planned_join_pins_capacity_from_cap_estimate():
    a = _table((0, 1), [[i, i % 4] for i in range(20)])
    b = _table((1, 2), [[i % 4, i + 100] for i in range(20)])
    recorded = []
    out = planned_join(a, b, est=CapEstimate(100, 1 << 9), impl="nested",
                       record=lambda *r: recorded.append(r))
    assert out.cap == 1 << 9                    # pinned, not re-derived
    impl, est, actual, retried, cap = recorded[0]
    assert cap == 1 << 9 and not retried
    # without the pin the formula would have chosen a different capacity
    out2 = planned_join(a, b, est=100, impl="nested")
    assert out2.cap != 1 << 9
    assert out.result_set() == out2.result_set()


def test_planned_join_records_capacity():
    a = _table((0, 1), [[i, i % 4] for i in range(20)])
    b = _table((1, 2), [[i % 4, i + 100] for i in range(20)])
    recorded = []
    out = planned_join(a, b, est=4, impl="nested",
                       record=lambda *r: recorded.append(r))
    impl, est, actual, retried, cap = recorded[0]
    assert actual == out.count and cap == out.cap


def test_cold_run_records_caps_and_warm_replays_them(monkeypatch):
    """The satellite end-to-end: join_seq stores (rows, cap, impl)
    triples and warm run 1 executes every estimator-sized join at
    exactly the cold run's capacities AND strategies (steady-state jit
    shapes, no overflow retries, no strategy flips)."""
    import repro.core.matching as matching_mod
    import repro.core.engine as engine_mod
    g = random_graph(n_nodes=100, n_edges=300, n_preds=3, seed=11)
    q = random_query(g, size=4, seed=21, n_connection=1, d_c=2)
    eng = make_engine(g, "rdf_h", impl="ref")
    caps_per_run = []
    real = matching_mod.planned_join

    def spy(a, b, est, **kw):
        out = real(a, b, est, **kw)
        if est is not None:
            caps_per_run[-1].append(out.cap)
        return out

    monkeypatch.setattr(matching_mod, "planned_join", spy)
    monkeypatch.setattr(engine_mod, "planned_join", spy)
    pq = eng.prepare(q)
    caps_per_run.append([])
    cold = eng.execute_prepared(pq)
    assert pq.join_seq and all(isinstance(e, tuple) and len(e) == 3
                               for e in pq.join_seq)
    assert all(e[2] in ("nested", "sorted", "radix", "cross")
               for e in pq.join_seq)
    assert [c for _, c, _ in pq.join_seq] == caps_per_run[0]
    caps_per_run.append([])
    warm = eng.execute_prepared(pq)
    assert warm.stats.cache_hit
    assert warm.stats.join_retries == 0
    assert caps_per_run[1] == caps_per_run[0]   # byte-identical shapes
    assert warm.result_set() == cold.result_set()


def test_warm_replay_reuses_retry_capacity(monkeypatch):
    """A cold join that took an overflow retry lands on a capacity the
    size formula would not re-derive; the warm replay must still pin it."""
    from repro.core.planner import JoinEstimator
    g = random_graph(n_nodes=100, n_edges=300, n_preds=3, seed=11)
    q = random_query(g, size=5, seed=28, n_connection=0)
    eng = make_engine(g, "rdf_h", impl="ref")
    # sabotage the analytic estimator so the cold run underestimates
    # every join and is forced through the overflow-retry path
    monkeypatch.setattr(JoinEstimator, "edge_join",
                        lambda self, *a, **k: 1)
    monkeypatch.setattr(JoinEstimator, "table_join",
                        lambda self, *a, **k: 1)
    pq = eng.prepare(q)
    cold = eng.execute_prepared(pq)
    if cold.stats.join_retries == 0:
        pytest.skip("workload produced no overflow retry")
    warm = eng.execute_prepared(pq)
    assert warm.stats.join_retries == 0         # replay absorbed them
    assert warm.result_set() == cold.result_set()
