"""Validation of the trip-count-aware HLO analyzer against a program with
hand-computable FLOPs/collectives (run on 8 forced host devices in a
subprocess so the main process keeps one device)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def test_analyzer_counts_loops_and_collectives():
    prog = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as PS
    from repro.launch.hlo_analysis import analyze

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    N_ITERS, B, D, F = 4, 8, 64, 128

    def f(w1, w2, x):
        def body(x, ws):
            a, b = ws
            return jnp.tanh(x @ a) @ b, None
        y, _ = jax.lax.scan(body, x, (w1, w2))
        return jax.nn.logsumexp(y)

    args = (jax.ShapeDtypeStruct((N_ITERS, D, F), jnp.float32),
            jax.ShapeDtypeStruct((N_ITERS, F, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D), jnp.float32))
    sh = (NamedSharding(mesh, PS(None, None, "model")),
          NamedSharding(mesh, PS(None, "model", None)),
          NamedSharding(mesh, PS("data", None)))
    with mesh:
        txt = jax.jit(f, in_shardings=sh).lower(*args).compile().as_text()
    a = analyze(txt)
    # per device: dot1 [B/2, D] @ [D, F/4] = 2*B/2*F/4*D; dot2 partial
    # [B/2, F/4] @ [F/4, D] = 2*B/2*D*F/4; x N_ITERS
    want = N_ITERS * (2 * (B // 2) * (F // 4) * D
                      + 2 * (B // 2) * D * (F // 4))
    print(json.dumps({
        "flops": a["flops"], "want": want,
        "trips": [w["trips"] for w in a["while_loops"]],
        "ar_count": a["collectives"]["all-reduce"]["count"],
        "ar_bytes": a["collectives"]["all-reduce"]["bytes"],
    }))
    """)
    out = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=300,
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              # forced host devices only exist on the CPU
                              # backend; without this the subprocess stalls
                              # for minutes probing for a TPU
                              "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["flops"] == r["want"], r
    assert 4 in r["trips"], r
    # dot2's contraction is sharded -> one all-reduce of [B/2, D] f32 per
    # loop iteration (+ scalar logsumexp reductions)
    assert r["ar_count"] >= 4, r
    assert r["ar_bytes"] >= 4 * (8 // 2) * 64 * 4, r
