"""Property-based serving identity (hypothesis): any randomized
interleaved stream of repeated / renumbered templates, under any
combination of batching and calibration, returns result sets identical to
a fresh single-query engine — and the canonical fingerprint is invariant
under arbitrary node renumbering."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import make_engine, Thresholds  # noqa: E402
from repro.core.query import (QueryTemplate, QueryEdge,  # noqa: E402
                              ConnectionEdge)
from repro.data import random_graph, random_query  # noqa: E402
from repro.serve import QueryServer, template_fingerprint  # noqa: E402

_GRAPH = random_graph(n_nodes=80, n_edges=220, n_preds=3,
                      n_literals=20, seed=9)
_POOL = [random_query(_GRAPH, size=4, seed=40 + i, n_connection=i % 2,
                      d_c=2) for i in range(4)]
_FRESH = make_engine(_GRAPH, "rdf_h", impl="ref")
_ORACLE = [_FRESH.execute(q).result_set() for q in _POOL]


def _permute(query, perm):
    inv = {p: i for i, p in enumerate(perm)}
    return QueryTemplate(
        keywords=[query.keywords[inv[j]] for j in range(len(perm))],
        edges=[QueryEdge(perm[e.src], perm[e.dst], e.pred)
               for e in query.edges],
        connections=[ConnectionEdge(perm[c.src], perm[c.dst], c.max_dist,
                                    c.bidirectional)
                     for c in query.connections])


@settings(max_examples=12, deadline=None)
@given(stream=st.lists(st.integers(0, len(_POOL) - 1), min_size=1,
                       max_size=10),
       chunks=st.integers(1, 4),
       batching=st.booleans(), calibrate=st.booleans(),
       miscalibrated=st.booleans())
def test_interleaved_stream_identity(stream, chunks, batching, calibrate,
                                     miscalibrated):
    th = (Thresholds(tau_iter=0.5, tau_join=0.5, tau_sel=0.01)
          if miscalibrated else None)
    srv = QueryServer(_GRAPH, impl="ref", batching=batching,
                      calibrate=calibrate, thresholds=th)
    queries = [_POOL[i] for i in stream]
    step = max(1, len(queries) // chunks)
    futs = []
    for s in range(0, len(queries), step):
        futs.extend(srv.submit_many(queries[s:s + step], wait=True))
    for i, f in zip(stream, futs):
        assert f.result().result_set() == _ORACLE[i]
    assert srv.queries_served == len(stream)


@settings(max_examples=20, deadline=None)
@given(idx=st.integers(0, len(_POOL) - 1), seed=st.integers(0, 1000))
def test_fingerprint_renumbering_invariance(idx, seed):
    q = _POOL[idx]
    perm = np.random.default_rng(seed).permutation(q.num_nodes).tolist()
    qp = _permute(q, perm)
    assert template_fingerprint(qp) == template_fingerprint(q)


@settings(max_examples=8, deadline=None)
@given(idx=st.integers(0, len(_POOL) - 1), seed=st.integers(0, 1000))
def test_renumbered_submission_identity(idx, seed):
    """A renumbered template served through a cache warmed by the
    original numbering still returns its own correctly-labeled rows."""
    q = _POOL[idx]
    perm = np.random.default_rng(seed).permutation(q.num_nodes).tolist()
    qp = _permute(q, perm)
    srv = QueryServer(_GRAPH, impl="ref")
    srv.query(q)                          # warm the cache entry
    assert srv.query(qp).result_set() == _FRESH.execute(qp).result_set()
    assert srv.telemetry()["plan_cache"]["entries"] == 1
