"""End-to-end behaviour tests for the whole system."""
import numpy as np
import jax

from repro.core import make_engine, compute_stats
from repro.data import DATASETS, random_query
from repro.data.lm_data import TokenPipeline
from repro.checkpoint import Checkpointer
from repro.configs import ARCHS, reduced_config
from repro.configs.base import InputShape, TrainConfig
from repro.models import api
from repro.optim import adamw_init


def test_rdf_pipeline_end_to_end():
    """Dataset -> stats -> engine -> queries -> sane results + planner
    behaves differently on coherent vs hubby data."""
    g = DATASETS["dblp"](scale=0.04, seed=3)
    eng = make_engine(g, "rdf_h", impl="ref")
    n_match = 0
    used = 0
    for s in range(6):
        q = random_query(g, size=5, seed=40 + s)
        r = eng.execute(q)
        n_match += r.count
        used += r.stats.used_check
    assert n_match > 0          # sampled queries must match something


def test_engine_result_columns_cover_query():
    g = DATASETS["lubm"](scale=0.03, seed=1)
    q = random_query(g, size=5, seed=9)
    r = make_engine(g, "h2", impl="ref").execute(q)
    assert sorted(r.cols) == list(range(q.num_nodes))
    if r.count:
        iv = q.intervals(make_engine(g, "h2").idmap)
        for row in r.rows[:50]:
            for col, node in zip(r.cols, row):
                lo, hi = iv[col]
                assert lo <= node < hi


def test_train_checkpoint_restart_continuity(tmp_path):
    """Train 4 steps; restart from step-2 checkpoint; trajectories match."""
    cfg = reduced_config(ARCHS["qwen2-0.5b"])
    tcfg = TrainConfig(lr=1e-3, microbatch=1, total_steps=20, warmup=1)
    pipe = TokenPipeline(cfg.vocab_size, 32, 4, seed=1)
    step = jax.jit(api.make_train_step(cfg, tcfg))

    def batch(i):
        b = pipe.global_batch_at(i)
        return {"tokens": b["tokens"], "labels": b["labels"]}

    params = api.init_model(cfg, 0)
    opt = adamw_init(params)
    ck = Checkpointer(tmp_path)
    losses = []
    for i in range(4):
        if i == 2:
            ck.save(i, {"params": params, "opt": opt}, async_=False)
        params, opt, m = step(params, opt, batch(i), i)
        losses.append(float(m["loss"]))

    state, _ = ck.restore(template={"params": params, "opt": opt})
    p2, o2 = state["params"], state["opt"]
    for i in range(2, 4):
        p2, o2, m = step(p2, o2, batch(i), i)
        assert abs(float(m["loss"]) - losses[i]) < 1e-4  # identical replay


def test_serving_prefill_then_decode_loop():
    cfg = reduced_config(ARCHS["stablelm-1.6b"])
    params = api.init_model(cfg, 0)
    B, S = 2, 16
    batch = api.concrete_batch(cfg, InputShape("p", S, B, "prefill"), seed=5)
    cache_len = S + 8
    logits, cache = api.make_prefill_fn(cfg, cache_len=cache_len)(params, batch)
    dec = jax.jit(api.make_decode_fn(cfg))
    toks = np.argmax(np.asarray(logits), -1).astype(np.int32)
    for _ in range(4):
        logits, cache = dec(params, cache, toks)
        assert np.isfinite(np.asarray(logits)).all()
        toks = np.argmax(np.asarray(logits), -1).astype(np.int32)
    assert int(cache["pos"]) == S + 4
