"""Whole-query join plan subsystem: CandidateTable sort-order propagation
and cached sorted runs, the cost-based join ordering (planner.JoinPlan /
ConnectionPlan), overflow-resume retries, and engine plan_mode parity."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import make_engine, JoinEstimator, JoinTelemetry
from repro.core.matching import (Table, join_tables, planned_join,
                                 cross_join, filter_rows, injective_filter,
                                 single_node_table, CapacityOverflow, _pow2)
from repro.core.planner import (plan_table_joins, plan_connections,
                                simulate_join_order, _reusable)
from repro.data import DATASETS, random_graph, random_query


def mk_table(cols, data):
    data = np.asarray(data, np.int32).reshape(-1, len(cols))
    cap = _pow2(len(data))
    rows = np.full((cap, len(cols)), -1, np.int32)
    rows[: len(data)] = data
    return Table(cols=tuple(cols), rows=jnp.asarray(rows), count=len(data))


def rows_multiset(t):
    return sorted(tuple(int(x) for x in r) for r in t.numpy())


# --------------------- sort-order propagation ------------------------- #
def test_sorted_join_tags_output_order():
    rng = np.random.default_rng(0)
    a = mk_table((0, 1), rng.integers(0, 40, (400, 2)))
    b = mk_table((1, 2), rng.integers(0, 40, (300, 2)))
    t = join_tables(a, b, impl="sorted")
    assert t.sort_order == (1,)
    vals = t.numpy()[:, t.cols.index(1)]
    assert (np.diff(vals) >= 0).all()       # really ordered by the key


def test_is_sorted_by_prefix_semantics():
    t = mk_table((3, 5), np.zeros((4, 2)))
    t.sort_order = (5, 3)
    assert t.is_sorted_by((5,))
    assert t.is_sorted_by((5, 3))
    assert not t.is_sorted_by((3,))
    assert not t.is_sorted_by((5, 3, 7))


def test_filter_and_cross_preserve_order():
    rng = np.random.default_rng(1)
    a = mk_table((0, 1), rng.integers(0, 30, (300, 2)))
    b = mk_table((1, 2), rng.integers(0, 30, (300, 2)))
    t = join_tables(a, b, impl="sorted")
    keep = np.zeros(t.cap, bool)
    keep[: t.count] = rng.random(t.count) < 0.5
    f = filter_rows(t, keep)
    assert f.sort_order == t.sort_order
    vals = f.numpy()[:, f.cols.index(1)]
    assert (np.diff(vals) >= 0).all()
    c = mk_table((7,), rng.integers(0, 5, (3, 1)))
    x = cross_join(f, c)
    assert x.sort_order == f.sort_order


def test_single_node_table_is_sorted():
    t = single_node_table(4, 10, 30, None)
    assert t.sort_order == (4,)


def test_chained_joins_avoid_resort():
    """Joining a sorted-join output again on the same key must not re-sort
    the carried side; cached runs make repeat joins sort-free."""
    rng = np.random.default_rng(2)
    a = mk_table((0, 1), rng.integers(0, 50, (500, 2)))
    b = mk_table((1, 2), rng.integers(0, 50, (400, 2)))
    c = mk_table((1, 3), rng.integers(0, 50, (300, 2)))
    tel = JoinTelemetry()
    t1 = join_tables(a, b, impl="sorted", telemetry=tel)
    assert tel == JoinTelemetry(sorts_performed=2, sorts_avoided=0)
    join_tables(t1, c, impl="sorted", telemetry=tel)
    assert tel.sorts_avoided == 1           # t1 arrived ordered by (1,)
    # a, b and c now hold cached runs for key (1,): repeating both joins
    # performs zero new sorts
    before = tel.sorts_performed
    join_tables(a, b, impl="sorted", telemetry=tel)
    join_tables(t1, c, impl="sorted", telemetry=tel)
    assert tel.sorts_performed == before
    assert tel.sorts_avoided == 5
    # parity with fresh tables (no caches)
    fresh = join_tables(mk_table((0, 1), a.numpy()),
                        mk_table((1, 2), b.numpy()), impl="sorted")
    assert rows_multiset(fresh) == rows_multiset(t1)


def test_multi_col_key_order_permutes_to_reuse_run():
    """A table sorted by (1, 0) joined on shared cols {0, 1} should flip
    the key order to (1, 0) and skip its sort."""
    rng = np.random.default_rng(3)
    a = mk_table((0, 1), rng.integers(0, 6, (400, 2)))
    d = mk_table((1, 0), rng.integers(0, 6, (300, 2)))
    tel = JoinTelemetry()
    x1 = join_tables(a, d, impl="sorted", telemetry=tel)
    assert tel.sorts_performed == 2
    # both sides now carry cached runs for the chosen key order
    x2 = join_tables(a, d, impl="sorted", telemetry=tel)
    assert tel.sorts_performed == 2 and tel.sorts_avoided == 2
    assert rows_multiset(x1) == rows_multiset(x2)


def test_overflow_resume_skips_rework():
    """planned_join's exact-size retry must reuse the first attempt's
    sort+probe (carried on CapacityOverflow.resume): same result, no
    additional sorts."""
    a = mk_table((0,), np.zeros((400, 1)))
    b = mk_table((0, 1), np.column_stack([np.zeros(400), np.arange(400)]))
    tel = JoinTelemetry()
    out = planned_join(a, b, est=10, impl="sorted", telemetry=tel)
    assert out.count == 160_000
    assert tel.sorts_performed == 2         # retry performed zero sorts
    err = None
    try:
        join_tables(mk_table((0,), np.zeros((300, 1))),
                    mk_table((0, 1), np.column_stack(
                        [np.zeros(300), np.arange(300)])),
                    impl="sorted", cap=64)
    except CapacityOverflow as e:
        err = e
    assert err is not None and err.resume is not None
    assert err.needed == 90_000


def test_cross_expand_xla_remainder_regression():
    """The seed's `t % bc` index math miscompiled under XLA CPU at some
    shape combinations (every output row gathered b-row 0).  Pin the
    failing shapes: |A|=10 cap 16, |B|=200 cap 256."""
    a = mk_table((0, 1), np.column_stack([np.arange(10),
                                          100 + np.arange(10)]))
    b_dat = np.column_stack([200 + np.arange(200), 400 + np.arange(200),
                             600 + np.arange(200), 800 + np.arange(200)])
    b = mk_table((2, 3, 4, 5), b_dat)
    out = cross_join(a, b)
    assert out.count == 2000
    arr = out.numpy()
    assert len({tuple(r) for r in arr}) == 2000
    # spot-check the exact pairing semantics (a-major)
    np.testing.assert_array_equal(arr[1], [0, 100, 201, 401, 601, 801])
    np.testing.assert_array_equal(arr[201], [1, 101, 201, 401, 601, 801])


def test_cross_expand_oracle_shape_grid():
    """Loud guard for the subtraction-form index math in _cross_expand:
    full numpy-oracle comparison across a grid of (|A|, |B|) shape
    combinations bracketing the one that miscompiled (the reduced
    remainder form no longer reproduces standalone on this jax build,
    but the fused remainder+gather did in the full kernel — so the real
    cross_join path is pinned exhaustively instead of by spot-check)."""
    for na, nb in [(1, 1), (3, 7), (10, 200), (200, 10), (16, 16),
                   (13, 257), (100, 100), (1, 300), (300, 1)]:
        a = mk_table((0, 1), np.column_stack(
            [np.arange(na), 1000 + np.arange(na)]))
        b = mk_table((2, 3), np.column_stack(
            [2000 + np.arange(nb), 3000 + np.arange(nb)]))
        out = cross_join(a, b)
        assert out.count == na * nb, (na, nb)
        arr = out.numpy()
        want = np.array([[i, 1000 + i, 2000 + j, 3000 + j]
                         for i in range(na) for j in range(nb)], np.int32)
        np.testing.assert_array_equal(arr, want, err_msg=f"{(na, nb)}")


# ----------------------- canonical result sets ------------------------ #
def test_result_set_canonical_across_join_orders():
    """a JOIN b and b JOIN a produce permuted column layouts; result_set
    must canonicalize so both compare equal (regression: it used raw row
    order before)."""
    rng = np.random.default_rng(4)
    a = mk_table((0, 1), rng.integers(0, 10, (60, 2)))
    b = mk_table((1, 2), rng.integers(0, 10, (50, 2)))
    ab = join_tables(a, b)
    ba = join_tables(b, a)
    assert ab.cols != ba.cols
    assert ab.result_set() == ba.result_set()


# ------------------------- cost-based plans --------------------------- #
def test_plan_table_joins_is_permutation_and_never_worse():
    rng = np.random.default_rng(5)
    for trial in range(6):
        n = int(rng.integers(2, 6))
        node_sets = []
        for i in range(n):                      # chain-ish overlap
            node_sets.append({i, i + 1, int(rng.integers(0, n + 1))})
        counts = [int(rng.integers(1, 10_000)) for _ in range(n)]
        cand = {q: int(rng.integers(1, 500)) for q in range(n + 2)}
        est = JoinEstimator(None, cand)
        plan = plan_table_joins(node_sets, counts, est, nested_max=256)
        assert sorted(plan.order) == list(range(n))
        assert plan.est_cost <= plan.greedy_cost + 1e-6
        # DP result is no worse than random sampled orders
        for _ in range(5):
            perm = list(rng.permutation(n))
            c, _steps = simulate_join_order(perm, node_sets, counts, est,
                                            256)
            assert plan.est_cost <= c + 1e-6


def test_plan_table_joins_beats_greedy_on_skew():
    """Small-table-first (the seed heuristic) explodes when the small
    table joins through a low-V(key) node; the DP must route around it."""
    node_sets = [{0, 1}, {1, 2}, {2, 3}]
    counts = [500, 1000, 1000]
    est = JoinEstimator(None, {0: 100, 1: 1, 2: 1000, 3: 100})
    greedy = [0, 1, 2]                       # smallest-count-first
    plan = plan_table_joins(node_sets, counts, est, nested_max=16,
                            greedy_order=greedy)
    assert plan.est_cost < plan.greedy_cost
    assert plan.order[0] != 0                # starts with the cheap pair
    assert all(s.est_rows >= 0 for s in plan.steps)


def test_plan_models_sort_reuse():
    """With identical cardinalities, an order that can reuse a side's
    existing sort order must cost less."""
    node_sets = [{0, 1}, {1, 2}]
    counts = [5000, 5000]
    est = JoinEstimator(None, {0: 10, 1: 10, 2: 10})
    c_sorted, _ = simulate_join_order([0, 1], node_sets, counts, est, 256,
                                      sort_orders=[(1,), (1,)])
    c_unsorted, _ = simulate_join_order([0, 1], node_sets, counts, est, 256,
                                        sort_orders=[None, None])
    assert c_sorted < c_unsorted
    assert _reusable((1, 0), (0, 1)) and not _reusable((0,), (0, 1))


def test_plan_connections_orders_by_selectivity():
    """Greedy smallest-product first is wrong when a bigger product has a
    far more selective connection; the planner must reorder."""
    sizes = [10, 1000, 1000]
    endpoints = [(0, 1), (1, 2)]
    sels = [0.9, 1e-4]
    plan = plan_connections(sizes, endpoints, sels)
    assert sorted(plan.order) == [0, 1]
    assert plan.order == [1, 0]
    assert plan.est_cost < plan.greedy_cost


def test_plan_connections_single_edge_trivial():
    plan = plan_connections([5, 7], [(0, 1)], [0.5])
    assert plan.order == [0]
    assert plan.est_cost == plan.greedy_cost


# ----------------------- engine integration --------------------------- #
def test_engine_sorts_avoided_on_multi_join_template():
    g = DATASETS["lubm"](scale=0.03, seed=1)
    eng = make_engine(g, "stwig+", impl="ref")
    eng.cfg.join_impl = "sorted"            # all joins on the merge path
    r = eng.execute(random_query(g, size=6, seed=31))
    assert r.stats.sorts_performed > 0
    assert r.stats.sorts_avoided > 0
    assert r.stats.plan_mode == "cost"


def test_engine_plan_modes_identical_results():
    g = DATASETS["lubm"](scale=0.03, seed=1)
    q = random_query(g, size=6, seed=7)
    rs = {}
    for pm in ("cost", "greedy"):
        eng = make_engine(g, "stwig+", impl="ref")
        eng.cfg.plan_mode = pm
        r = eng.execute(q)
        rs[pm] = r.result_set()
        assert r.stats.plan_mode == pm
    assert rs["cost"] == rs["greedy"]


def test_engine_plan_modes_identical_with_connections():
    for seed in range(3):
        g = random_graph(n_nodes=70, n_edges=220, n_preds=3,
                         n_literals=18, seed=seed)
        q = random_query(g, size=5, seed=seed + 1, n_connection=2, d_c=3)
        rs = []
        for pm in ("cost", "greedy"):
            eng = make_engine(g, "h2", impl="ref")
            eng.cfg.plan_mode = pm
            rs.append(eng.execute(q).result_set())
        assert rs[0] == rs[1], seed


def test_engine_records_plan_costs():
    g = DATASETS["lubm"](scale=0.03, seed=1)
    eng = make_engine(g, "stwig+", impl="ref")
    r = eng.execute(random_query(g, size=6, seed=7))
    qs = r.stats
    assert qs.plan_cost >= 0.0
    assert qs.greedy_plan_cost >= qs.plan_cost - 1e-6
