"""Sort-merge join subsystem: randomized parity against nested-loop and a
brute-force numpy oracle, LIMIT semantics, capacity retries, planner
strategy selection, and engine-level equivalence across join impls."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import make_engine, CapacityOverflow, resolve_join_impl
from repro.core.matching import Table, join_tables, cross_join, _pow2
from repro.data import DATASETS, random_graph, random_query

RNG = np.random.default_rng(1234)


def mk_table(cols, data):
    data = np.asarray(data, np.int32).reshape(-1, len(cols))
    cap = _pow2(len(data))
    rows = np.full((cap, len(cols)), -1, np.int32)
    rows[: len(data)] = data
    return Table(cols=tuple(cols), rows=jnp.asarray(rows), count=len(data))


def oracle_join(a, b):
    """Brute-force equi-join on shared cols -> sorted multiset of rows."""
    shared = [c for c in a.cols if c in b.cols]
    new = [j for j, c in enumerate(b.cols) if c not in a.cols]
    out = []
    for ra in a.numpy():
        for rb in b.numpy():
            if all(ra[a.cols.index(c)] == rb[b.cols.index(c)]
                   for c in shared):
                out.append(tuple(int(x) for x in ra)
                           + tuple(int(rb[j]) for j in new))
    return sorted(out)


def rows_multiset(t):
    return sorted(tuple(int(x) for x in r) for r in t.numpy())


# ------------------------- randomized parity -------------------------- #
@pytest.mark.parametrize("seed", range(8))
def test_join_random_parity(seed):
    rng = np.random.default_rng(seed)
    na, nb = rng.integers(0, 60, 2)
    ncols = rng.integers(1, 4)
    a_cols = tuple(rng.choice(6, ncols, replace=False))
    b_cols = tuple(rng.choice(6, rng.integers(1, 4), replace=False))
    a = mk_table(a_cols, rng.integers(0, 5, (na, len(a_cols))))
    b = mk_table(b_cols, rng.integers(0, 5, (nb, len(b_cols))))
    want = oracle_join(a, b)
    for impl in ("nested", "sorted", "auto"):
        got = rows_multiset(join_tables(a, b, impl=impl))
        assert got == want, impl


def test_join_many_shared_cols_rank_packing():
    """>2 shared columns exercises the hierarchical dense-rank packing."""
    rng = np.random.default_rng(3)
    a = mk_table((0, 1, 2, 3), rng.integers(0, 3, (80, 4)))
    b = mk_table((3, 2, 1, 0), rng.integers(0, 3, (70, 4)))
    assert rows_multiset(join_tables(a, b, impl="sorted")) == oracle_join(a, b)


def test_join_self_loop_single_col():
    a = mk_table((0,), [[1], [2], [2], [5]])
    b = mk_table((0, 1), [[2, 9], [2, 8], [5, 7], [6, 1]])
    want = oracle_join(a, b)
    for impl in ("nested", "sorted"):
        assert rows_multiset(join_tables(a, b, impl=impl)) == want


def test_join_empty_sides():
    empty = mk_table((1, 2), np.zeros((0, 2)))
    full = mk_table((0, 1), [[1, 2], [3, 4]])
    for impl in ("nested", "sorted"):
        assert join_tables(full, empty, impl=impl).count == 0
        assert join_tables(empty, full, impl=impl).count == 0


def test_no_shared_cols_is_cross_join():
    a = mk_table((0,), [[1], [2]])
    b = mk_table((1,), [[7], [8], [9]])
    t = join_tables(a, b)
    assert t.cols == (0, 1)
    assert rows_multiset(t) == sorted(
        (int(x), int(y)) for x in [1, 2] for y in [7, 8, 9])
    assert rows_multiset(cross_join(a, b)) == rows_multiset(t)


# -------------------------- LIMIT semantics --------------------------- #
@pytest.mark.parametrize("impl", ["nested", "sorted"])
def test_row_limit_clamps_exactly(impl):
    """Regression: the nested path used to check the limit *before* adding
    a chunk, overshooting by up to a chunk and truncating a chunk late."""
    a = mk_table((0,), np.zeros((50, 1)))
    b = mk_table((0, 1), np.column_stack([np.zeros(50), np.arange(50)]))
    t = join_tables(a, b, impl=impl, row_limit=100, chunk=8)
    assert t.count == 100
    assert t.truncated
    # under the limit: full result, not truncated
    t = join_tables(a, b, impl=impl, row_limit=5000, chunk=8)
    assert t.count == 2500
    assert not t.truncated


def test_row_limit_exact_boundary_not_truncated_sorted():
    a = mk_table((0,), np.zeros((10, 1)))
    b = mk_table((0, 1), np.column_stack([np.zeros(10), np.arange(10)]))
    t = join_tables(a, b, impl="sorted", row_limit=100)
    assert t.count == 100 and not t.truncated


# ------------------------- capacity overflow -------------------------- #
@pytest.mark.parametrize("impl", ["nested", "sorted"])
def test_capacity_overflow_carries_exact_need(impl):
    a = mk_table((0,), np.zeros((40, 1)))
    b = mk_table((0, 1), np.column_stack([np.zeros(40), np.arange(40)]))
    with pytest.raises(CapacityOverflow) as ei:
        join_tables(a, b, impl=impl, cap=64)
    assert ei.value.needed == 1600
    # exact-size retry (what Engine._join does) succeeds
    t = join_tables(a, b, impl=impl, cap=_pow2(ei.value.needed))
    assert t.count == 1600


# ------------------------- planner selection -------------------------- #
def test_resolve_join_impl_thresholds():
    assert resolve_join_impl(10, 256) == "nested"
    assert resolve_join_impl(10, 257) == "sorted"
    assert resolve_join_impl(5000, 3, "auto", nested_max=64) == "sorted"
    assert resolve_join_impl(5000, 3, "nested") == "nested"
    # radix: large probe side, single shared column, cheaper than
    # re-sorting both sides; multi-column keys fall back to sort-merge
    assert resolve_join_impl(1 << 16, 1 << 12) == "radix"
    assert resolve_join_impl(1 << 16, 1 << 12, n_shared=2) == "sorted"
    assert resolve_join_impl(100, 1 << 12) == "sorted"  # below min probe
    assert resolve_join_impl(10, 10, "radix") == "radix"  # forced


def test_engine_records_join_strategies_and_estimates():
    g = DATASETS["lubm"](scale=0.03, seed=1)
    eng = make_engine(g, "stwig+", impl="ref")
    r = eng.execute(random_query(g, size=5, seed=31))
    qs = r.stats
    assert sum(qs.join_strategies.values()) > 0
    assert qs.n_estimated_joins > 0
    assert qs.join_actual_rows >= 0 and qs.join_est_rows > 0


# --------------------- engine-level equivalence ----------------------- #
@pytest.mark.parametrize("variant", ["stwig+", "spath_ni2", "h2", "h3",
                                     "hvc", "rdf_h"])
def test_engine_variants_sorted_equals_nested(variant):
    """All engine variants must return identical result sets under the
    sort-merge and the seed nested-loop join implementations."""
    g = DATASETS["lubm"](scale=0.025, seed=2)
    results = {}
    for ji in ("nested", "sorted", "radix"):
        eng = make_engine(g, variant, impl="ref")
        eng.cfg.join_impl = ji
        results[ji] = eng.execute(
            random_query(g, size=5, seed=77)).result_set()
    assert results["nested"] == results["sorted"] == results["radix"]


def test_engine_random_graphs_join_impl_equivalence():
    for seed in range(3):
        g = random_graph(n_nodes=60, n_edges=200, n_preds=3,
                         n_literals=15, seed=seed)
        q = random_query(g, size=4, seed=seed * 3 + 1)
        rs = []
        for ji in ("nested", "sorted", "radix", "auto"):
            eng = make_engine(g, "rdf_h", impl="ref")
            eng.cfg.join_impl = ji
            rs.append(eng.execute(q).result_set())
        assert rs[0] == rs[1] == rs[2] == rs[3]
