"""End-to-end elastic recovery: train on a 2x2x2 (pod,data,model) mesh,
checkpoint, lose the pod axis, reshard onto the surviving 2x2 mesh and
continue — losses must continue finite and the restart must replay the
checkpointed step exactly (deterministic pipeline)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def test_elastic_restart_after_pod_loss(tmp_path):
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(f"""
    import json
    import numpy as np, jax
    from jax.sharding import NamedSharding, PartitionSpec as PS
    from repro.configs import ARCHS, reduced_config
    from repro.configs.base import InputShape, TrainConfig
    from repro.models import api
    from repro.optim import adamw_init
    from repro.checkpoint import Checkpointer
    from repro.runtime import shrink_mesh, reshard
    from repro.data.lm_data import TokenPipeline

    cfg = reduced_config(ARCHS["qwen2-0.5b"])
    tcfg = TrainConfig(lr=1e-3, warmup=1, total_steps=20)
    pipe = TokenPipeline(cfg.vocab_size, 32, 8, seed=4)
    ck = Checkpointer({json.dumps(str(tmp_path))})

    def batch(i):
        b = pipe.global_batch_at(i)
        return {{"tokens": b["tokens"], "labels": b["labels"]}}

    def ns(mesh, t):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, PS))

    # phase 1: multi-pod mesh (2,2,2)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    params = api.init_model(cfg, 0)
    opt = adamw_init(params)
    with mesh:
        step = jax.jit(api.make_train_step(cfg, tcfg, mesh))
        losses = []
        for i in range(3):
            if i == 2:   # checkpoint BEFORE the step we will replay
                ck.save(2, {{"params": params, "opt": opt}},
                        meta={{"step": 2}}, async_=False)
            params, opt, m = step(params, opt, batch(i), i)
            losses.append(float(m["loss"]))

    # phase 2: pod axis lost -> shrink, reshard from checkpoint, resume
    small = shrink_mesh(mesh, "pod")
    state, meta = ck.restore(template={{"params": params, "opt": opt}})
    pspec = api.model_pspecs(cfg, small)
    ospec = api.opt_pspecs(cfg, small)
    with small:
        p2 = reshard(state["params"], small, pspec)
        o2 = reshard(state["opt"], small, ospec)
        step2 = jax.jit(api.make_train_step(cfg, tcfg, small))
        p2, o2, m2 = step2(p2, o2, batch(2), 2)   # replay step 2
    print(json.dumps({{
        "replay_loss": float(m2["loss"]),
        "orig_loss": losses[2],
        "finite": bool(np.isfinite(float(m2["loss"]))),
        "new_mesh": list(small.devices.shape),
    }}))
    """))
    out = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["finite"]
    assert r["new_mesh"] == [2, 2]
    # same global batch + restored state -> identical replayed loss
    assert abs(r["replay_loss"] - r["orig_loss"]) < 1e-4, r
