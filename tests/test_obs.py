"""Observability: tracing spans, the metrics registry, and EXPLAIN.

Three contracts under test:

  * schema pins — the Chrome trace event shape, the metrics snapshot
    shape, and `telemetry()["metrics"]` are consumed by external
    tooling, so their key sets are asserted exactly;
  * zero-cost-when-off — the NULL_TRACER path allocates nothing and a
    traced server returns byte-identical results to an untraced one;
  * end-to-end attribution — a governed + batched + fault-injected run
    produces one trace per query whose spans (submit → prepare →
    execute → governor routing → engine joins) all carry that query's
    trace id, and every ServingError names the trace that explains it.
"""
import json
import time

import pytest

from repro.core import Thresholds, make_engine
from repro.core.engine import EngineConfig
from repro.data import random_graph, random_query
from repro.obs import (HISTOGRAM_FIELDS, MetricsRegistry, NULL_SPAN,
                       NULL_TRACER, Tracer, render_explain)
from repro.serve import (DegradationExhausted, GovernorConfig,
                         QueryServer)
from repro.testing import Fault, FaultInjector


# --------------------------- fixtures ---------------------------------- #
@pytest.fixture(scope="module")
def graph():
    return random_graph(n_nodes=80, n_edges=220, n_preds=3,
                        n_literals=20, seed=1)


@pytest.fixture(scope="module")
def pool(graph):
    return [random_query(graph, size=4, seed=40 + i, n_connection=i % 2,
                         d_c=2) for i in range(4)]


def _forcing_cfg():
    """Route joins through sort-merge and connections through reach so
    injected kernel faults actually land (as in test_chaos.py)."""
    return EngineConfig(check_policy="selective", d_check=2, impl="ref",
                        thresholds=Thresholds(nested_join_max=1),
                        join_impl="sorted", connection_impl="reach")


# ------------------------------ metrics -------------------------------- #
def test_metrics_counter_gauge_histogram_basics():
    m = MetricsRegistry()
    m.counter("c").inc()
    m.counter("c").inc(3)
    assert m.counter("c").value == 4
    m.gauge("g").set(2.5)
    assert m.gauge("g").value == 2.5
    h = m.histogram("h")
    for v in (1.0, 2.0, 4.0, 0.0):
        h.observe(v)
    assert h.count == 4 and h.sum == 7.0
    assert h.min == 0.0 and h.max == 4.0
    assert h.zeros == 1


def test_histogram_percentile_within_bucket_resolution():
    from repro.obs.metrics import HISTOGRAM_BASE, Histogram
    h = Histogram()
    vals = [0.001 * (1 + i) for i in range(1000)]       # 1ms .. 1s
    for v in vals:
        h.observe(v)
    for q in (50, 90, 99):
        exact = vals[int(len(vals) * q / 100) - 1]
        est = h.percentile(q)
        assert exact / HISTOGRAM_BASE <= est <= exact * HISTOGRAM_BASE
    # clamped to the observed range, 0.0 when empty
    assert Histogram().percentile(99) == 0.0
    assert h.percentile(100) <= h.max


def test_metrics_snapshot_schema_pinned():
    m = MetricsRegistry()
    m.counter("a").inc()
    m.gauge("b").set(1.0)
    m.histogram("c").observe(0.5)
    snap = m.snapshot()
    assert sorted(snap) == ["counters", "gauges", "histograms"]
    assert snap["counters"] == {"a": 1}
    assert snap["gauges"] == {"b": 1.0}
    assert sorted(snap["histograms"]["c"]) == sorted(HISTOGRAM_FIELDS)
    json.dumps(snap)                     # JSON-serializable end to end


def test_metric_name_type_conflict_raises():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(ValueError):
        m.histogram("x")
    with pytest.raises(ValueError):
        m.gauge("x")


# ------------------------------ tracer --------------------------------- #
def test_tracer_nesting_and_parent_links():
    tr = Tracer()
    tid = tr.start(kind="unit")
    with tr.segment("root", tid) as root:
        with tr.span("child", k=1) as child:
            with tr.span("grandchild") as gc:
                assert gc.parent is child
            assert child.parent is root
    trace = tr.finish(tid)
    assert trace is not None and trace.trace_id == tid
    assert [s.name for s in trace.spans] == ["root", "child",
                                             "grandchild"]
    assert trace.roots() == [trace.spans[0]]
    assert all(s.end is not None and s.end >= s.start
               for s in trace.spans)


def test_span_error_stamped_and_exception_propagates():
    tr = Tracer()
    tid = tr.start()
    with pytest.raises(RuntimeError):
        with tr.segment("seg", tid):
            with tr.span("inner"):
                raise RuntimeError("boom")
    trace = tr.finish(tid)
    inner, = [s for s in trace.spans if s.name == "inner"]
    assert inner.error == "RuntimeError"
    assert not tr._stack                 # stack unwound through the raise


def test_null_paths_return_shared_null_span():
    tr = Tracer()
    assert tr.segment("s", None) is NULL_SPAN
    assert tr.segment("s", "t999999") is NULL_SPAN   # unknown id
    assert tr.span("orphan") is NULL_SPAN            # no open segment
    assert NULL_TRACER.start() is None
    assert NULL_TRACER.segment("s", "t000001") is NULL_SPAN
    assert NULL_TRACER.span("s") is NULL_SPAN
    assert NULL_SPAN.set(a=1) is NULL_SPAN
    assert not NULL_SPAN.live


def test_trace_bounds_ring_buffer_and_span_cap():
    tr = Tracer(max_traces=2, max_spans_per_trace=3)
    for _ in range(4):
        tid = tr.start()
        with tr.segment("seg", tid):
            for _ in range(5):
                with tr.span("s"):
                    pass
        tr.finish(tid)
    assert len(tr.finished) == 2         # ring buffer keeps the newest
    assert all(len(t.spans) == 3 for t in tr.finished)
    assert tr.dropped_spans == 4 * 3     # 5 nested + 1 root, cap 3


def test_chrome_event_schema_pinned(tmp_path):
    tr = Tracer()
    tid = tr.start()
    with tr.segment("seg", tid, who="q"):
        with tr.span("inner", rows=7):
            pass
    tr.finish(tid)
    path = tmp_path / "trace.json"
    info = tr.export_chrome(path)
    assert info["traces"] == 1 and info["events"] == 3
    doc = json.loads(path.read_text())
    assert sorted(doc) == ["displayTimeUnit", "traceEvents"]
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(meta) == 1 and meta[0]["name"] == "thread_name"
    assert len(spans) == 2
    for ev in spans:                     # the pinned complete-event shape
        assert sorted(ev) == ["args", "dur", "name", "ph", "pid",
                              "tid", "ts"]
        assert ev["pid"] == 1 and ev["args"]["trace_id"] == tid
        assert ev["ts"] >= 0.0 and ev["dur"] >= 0.0
    assert spans[1]["args"]["rows"] == 7


def test_null_tracer_overhead_is_negligible():
    """The disabled path is a constant method returning a shared
    singleton — no allocation, no clock read.  50k span entries must be
    far under any serving-visible cost (bound is ~100x headroom)."""
    t0 = time.perf_counter()
    for _ in range(50_000):
        with NULL_TRACER.span("x") as sp:
            if sp.live:                  # the guard callers use
                sp.set(rows=1)
    assert time.perf_counter() - t0 < 0.5


# --------------------------- serving e2e ------------------------------- #
def test_traced_and_untraced_servers_agree(graph, pool):
    srv_a = QueryServer(graph, impl="ref")
    srv_b = QueryServer(graph, impl="ref", tracer=Tracer())
    for q in pool:
        assert srv_a.query(q).result_set() == srv_b.query(q).result_set()
    assert len(srv_b.tracer.finished) == len(pool)
    assert len(NULL_TRACER.finished) == 0


def test_end_to_end_chaos_trace_export(graph, pool, tmp_path):
    """Governed + batched + fault-injected serving exports a Chrome
    trace where every query's spans — submit, prepare, execute or
    fanout, governor routing (ladder rungs under the injected fault),
    and the engine's per-join spans — share that query's trace id."""
    tr = Tracer()
    srv = QueryServer(graph, cfg=_forcing_cfg(), tracer=tr,
                      governor=GovernorConfig())
    stream = pool * 2
    with FaultInjector(Fault("kernel_dispatch", "raise", every=1)):
        futs = srv.submit_many(stream, wait=True)
    degraded = 0
    for f in futs:
        assert f.trace_id is not None
        if f.done() and f._error is None:
            degraded += bool(f.result().stats.degraded_steps)
    assert degraded, "persistent kernel fault should force the ladder"

    path = tmp_path / "chaos_trace.json"
    info = tr.export_chrome(path)
    assert info["traces"] == len(stream)
    doc = json.loads(path.read_text())
    by_tid: dict = {}
    for ev in doc["traceEvents"]:
        if ev["ph"] == "X":
            by_tid.setdefault(ev["tid"], []).append(ev)
    assert len(by_tid) == len(stream)
    names_by_trace = {}
    for evs in by_tid.values():
        ids = {ev["args"]["trace_id"] for ev in evs}
        assert len(ids) == 1             # one query per thread lane
        names_by_trace[ids.pop()] = [ev["name"] for ev in evs]
    for tid_, names in names_by_trace.items():
        assert names[0] == "submit" and "prepare" in names
        assert "execute" in names or "fanout" in names
    all_names = {n for names in names_by_trace.values() for n in names}
    # governor + engine spans land inside the right query's trace
    assert {"breaker", "ladder", "rung", "join"} <= all_names


def test_serving_errors_carry_trace_id_and_rung_history(graph, pool):
    """DegradationExhausted (and every ServingError) names the trace
    holding its attempts, and renders the per-rung failure history."""
    tr = Tracer()
    srv = QueryServer(graph, cfg=_forcing_cfg(), tracer=tr,
                      governor=GovernorConfig(max_rows=0))
    f = srv.submit(pool[0])
    srv.flush()
    with pytest.raises(DegradationExhausted) as ei:
        f.result()
    exc = ei.value
    assert exc.trace_id == f.trace_id
    assert f"[trace {f.trace_id}]" in str(exc)
    history = exc.attempt_history.splitlines()
    assert len(history) == len(exc.attempts) >= 2
    assert any("primary" in line for line in history)
    # the named trace really holds the rung attempts
    trace = tr.get(f.trace_id)
    assert trace is not None
    rungs = [s for s in trace.spans if s.name == "rung"]
    assert len(rungs) >= 1
    assert all(s.attrs.get("outcome") == "failed" for s in rungs)


def test_telemetry_metrics_and_latency_schema_pinned(graph, pool):
    srv = QueryServer(graph, impl="ref", governor=GovernorConfig())
    for f in srv.submit_many(pool * 2, wait=True):
        f.result()
    t = srv.telemetry()
    assert sorted(t["latency"]) == ["cold_p50", "cold_p99", "n_cold",
                                    "n_warm", "p50", "p99", "warm_p50",
                                    "warm_p99"]
    assert t["latency"]["n_cold"] + t["latency"]["n_warm"] == len(pool) * 2
    m = t["metrics"]
    assert sorted(m) == ["counters", "gauges", "histograms"]
    assert m["counters"]["queries_served"] == len(pool) * 2
    for name in ("latency_s", "latency_cold_s", "latency_warm_s",
                 "prepare_s", "result_rows", "batch_bucket_size"):
        assert sorted(m["histograms"][name]) == sorted(HISTOGRAM_FIELDS)
    for name in ("pending", "plan_cache_entries", "reach_cache_bytes"):
        assert name in m["gauges"]
    json.dumps(t["metrics"])


def test_slow_query_log_captures_explain(graph, pool):
    srv = QueryServer(graph, impl="ref", slow_query_s=0.0,
                      slow_log_max=3)
    for f in srv.submit_many(pool, wait=True):
        f.result()
    log = srv.slow_queries()
    assert len(log) == 3                 # bounded, newest retained
    for entry in log:
        assert sorted(entry) == ["explain", "fingerprint", "latency_s",
                                 "trace_id", "warm"]
        assert entry["explain"].startswith("EXPLAIN template ")
    assert srv.telemetry()["metrics"]["counters"]["slow_queries"] == \
        len(pool)


# ------------------------------ EXPLAIN -------------------------------- #
def test_explain_golden_three_join_template(graph):
    """EXPLAIN on a fixed 3-join template is deterministic: two fresh
    servers render byte-identical reports (modulo the wall-clock
    prepare_time header line), with the pinned section structure and
    the §4.3 τ comparisons."""
    q = random_query(graph, size=4, seed=41, n_connection=0)

    def rendered():
        srv = QueryServer(graph, impl="ref", calibrate=False)
        cold = srv.explain(q)            # pre-execution plan state
        assert "(unlearned — cold execution pending" in cold
        srv.query(q)
        return srv.explain(q)

    a, b = rendered(), rendered()
    strip = [ln for ln in a.splitlines() if "prepare_time" not in ln]
    assert strip == [ln for ln in b.splitlines()
                     if "prepare_time" not in ln]
    text = "\n".join(strip)
    assert text.startswith("EXPLAIN template ")
    for section in ("candidates (IDMap intervals):",
                    "check decision (§4.3):",
                    "components: ",
                    "join order (Selinger DP over per-tree tables):",
                    "connection edges:",
                    "learned join sequence"):
        assert section in text
    for term in ("complex/iterations", "complex/join_product",
                 "power/max_selectivity", "=> use_check"):
        assert term in text
    # the learned join sequence renders est vs observed per join
    assert "impl=" in text and "est=" in text and "rows=" in text


def test_explain_renders_without_thresholds_or_decision(graph):
    """Duck-typed renderer: a policy-forced plan (decision None) and a
    thresholds-free call both render without the τ block."""
    cfg = EngineConfig(check_policy="never", d_check=2, impl="ref")
    eng = make_engine(graph, "rdf_h", impl="ref")
    q = random_query(graph, size=3, seed=42, n_connection=0)
    pq = eng.prepare(q)
    text = render_explain(pq)            # no thresholds given
    assert "est_iterations=" in text     # raw decision inputs instead
    srv = QueryServer(graph, cfg=cfg)
    forced = srv.explain(q)
    assert "forced by check_policy" in forced
