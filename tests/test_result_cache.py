"""ResultCache + server delta migration: exact repeats must be served
without engine execution, and never across a dataset change they can't
prove themselves immune to.

Monkeypatch-proof in the test_snapshot.py style: the engine execution
entry points are poisoned, so a "hit" that secretly re-executes fails
loudly rather than silently passing on equal results.
"""
import numpy as np
import pytest

from repro.core import Dataset, interval_footprint_hit, make_engine
from repro.data import random_graph, random_query
from repro.serve import QueryServer, ResultCache, SnapshotError


# --------------------------- fixtures ---------------------------------- #
@pytest.fixture()
def dataset():
    g = random_graph(n_nodes=150, n_edges=450, n_preds=5,
                     n_literals=25, seed=3)
    return Dataset.build(g, variant="rdf_h")


def _server(ds, **kw):
    kw.setdefault("result_cache_size", 32)
    kw.setdefault("calibrate", False)
    return QueryServer(ds, impl="ref", **kw)


def _recombine_delta(ds, rng, n_ins=4, n_del=4):
    g = ds.graph
    lab, prd = g.labels, g.predicates
    subj = np.bincount(g.src, minlength=g.num_nodes)
    ment = subj + np.bincount(g.dst, minlength=g.num_nodes)
    safe = np.flatnonzero((subj[g.src] >= 2) & (ment[g.src] >= 3)
                          & (ment[g.dst] >= 3))
    dels = rng.choice(safe, size=min(n_del, safe.size), replace=False)
    deletes = [(lab[g.src[i]], prd[g.pred[i]], lab[g.dst[i]])
               for i in dels]
    picks = rng.choice(g.num_edges, size=2 * n_ins, replace=False)
    inserts = [(lab[g.src[i]], prd[g.pred[i]], lab[g.dst[j]])
               for i, j in zip(picks, np.roll(picks, 1))
               if g.pred[i] == g.pred[j]]
    return inserts, deletes


def _poison_execution(monkeypatch, srv):
    def _boom(*a, **k):
        raise AssertionError("engine execution re-entered on a repeat "
                             "the result cache should have served")
    monkeypatch.setattr(srv.engine, "execute_prepared", _boom)


# ------------------------- unit: the cache ------------------------------ #
def test_result_cache_lru_and_bytes_bounds():
    rc = ResultCache(max_entries=2, max_bytes=10_000)
    rows = np.zeros((100, 3), dtype=np.int32)
    iv = [(0, 10), (20, 30), (40, 50)]
    rc.put("ds:v0", "a", (0, 1, 2), rows, False, iv)
    rc.put("ds:v0", "b", (0, 1, 2), rows, False, iv)
    rc.put("ds:v0", "c", (0, 1, 2), rows, False, iv)   # evicts "a"
    assert len(rc) == 2 and rc.evictions == 1
    assert rc.get("ds:v0", "a") is None
    cols, got = rc.get("ds:v0", "b")
    assert cols == (0, 1, 2)
    np.testing.assert_array_equal(got, rows)
    assert rc.hits == 1 and rc.misses == 1
    # an oversized row block stays as a cache-of-one, no thrash
    rc2 = ResultCache(max_entries=8, max_bytes=100)
    rc2.put("ds:v0", "big", (0,), rows, False, iv)
    assert len(rc2) == 1 and rc2.total_bytes == rows.nbytes


def test_result_cache_migrate_footprint_rules():
    rc = ResultCache(max_entries=8)
    rows = np.zeros((4, 2), dtype=np.int32)
    rc.put("d:v0", "clean", (0, 1), rows, False, [(0, 5)])
    rc.put("d:v0", "hit", (0, 1), rows, False, [(10, 20)])
    rc.put("d:v0", "conn", (0, 1), rows, True, [(0, 5)])
    touched = np.array([12, 40], dtype=np.int64)
    kept, dropped = rc.migrate("d:v0", "d:v1", touched)
    assert (kept, dropped) == (1, 2)
    assert rc.get("d:v1", "clean") is not None
    assert rc.get("d:v1", "hit") is None       # interval contains 12
    assert rc.get("d:v1", "conn") is None      # connection edges drop
    assert rc.get("d:v0", "clean") is None     # old id unreachable
    # rebuild (touched None) drops everything
    rc.put("d:v1", "x", (0, 1), rows, False, [(0, 5)])
    kept, dropped = rc.migrate("d:v1", "d:v2", None)
    assert kept == 0 and dropped >= 1


# ------------------ serving: repeats skip the engine -------------------- #
def test_repeat_served_without_execution(dataset, monkeypatch):
    srv = _server(dataset)
    q = random_query(dataset.graph, size=4, seed=11)
    first = srv.query(q)
    assert not first.stats.result_cache_hit
    _poison_execution(monkeypatch, srv)
    again = srv.query(q)
    assert again.stats.result_cache_hit and again.stats.cache_hit
    assert again.cols == first.cols
    np.testing.assert_array_equal(again.rows, first.rows)
    t = srv.telemetry()
    assert t["result_cache"]["hits"] == 1
    assert t["metrics"]["counters"]["result_cache_hits"] == 1


def test_isomorphic_renumbering_hits_and_remaps(dataset, monkeypatch):
    """The cache keys on the canonical fingerprint: a renumbered
    isomorphic template is a hit, with columns remapped per caller."""
    from repro.core.query import QueryTemplate, QueryEdge
    q = random_query(dataset.graph, size=4, seed=21)
    perm = [2, 0, 3, 1][:q.num_nodes]
    perm += list(range(len(perm), q.num_nodes))
    inv = {orig: new for new, orig in enumerate(perm)}
    q2 = QueryTemplate(
        keywords=[q.keywords[perm[i]] for i in range(q.num_nodes)],
        edges=[QueryEdge(inv[e.src], inv[e.dst], e.pred)
               for e in q.edges],
        connections=list(q.connections))
    oracle = make_engine(dataset, "rdf_h", impl="ref")
    want = oracle.execute(q2).result_set()
    srv = _server(dataset)
    srv.query(q)
    _poison_execution(monkeypatch, srv)
    r2 = srv.query(q2)
    assert r2.stats.result_cache_hit
    assert r2.result_set() == want


def test_result_cache_off_by_default(dataset):
    srv = QueryServer(dataset, impl="ref", calibrate=False)
    q = random_query(dataset.graph, size=4, seed=11)
    srv.query(q)
    r = srv.query(q)
    assert srv.result_cache is None
    assert not r.stats.result_cache_hit
    assert srv.telemetry()["result_cache"] is None


# ----------------------- delta migration -------------------------------- #
def test_delta_invalidates_and_repeat_is_correct(dataset):
    """After a delta, a repeat must reflect the NEW data — either via a
    provably-clean migrated entry or by re-execution — and exact repeats
    on the new version hit again."""
    srv = _server(dataset)
    rng = np.random.default_rng(5)
    q = random_query(dataset.graph, size=4, seed=31)
    srv.query(q)
    inserts, deletes = _recombine_delta(dataset, rng)
    info = srv.apply_delta(inserts, deletes)
    assert info["mode"] == "incremental"
    assert srv.dataset.version == 1
    want = make_engine(srv.dataset, "rdf_h",
                       impl="ref").execute(q).result_set()
    r1 = srv.query(q)
    assert r1.result_set() == want
    r2 = srv.query(q)
    assert r2.stats.result_cache_hit and r2.result_set() == want


def test_footprint_clean_entry_survives_delta(monkeypatch):
    """An entry whose candidate intervals provably miss the delta's
    touched set keeps serving without execution across the version bump;
    a connection-edge entry never does."""
    # a sparse graph + exact-label keywords → width-1 intervals, so
    # plenty of single-edge deltas have a provably-disjoint footprint
    g = random_graph(n_nodes=800, n_edges=1600, n_preds=6,
                     n_literals=40, seed=7)
    ds = Dataset.build(g, variant="rdf_h")
    srv = _server(ds)
    q = random_query(g, size=4, seed=31, exact_nodes=True)
    qc = random_query(g, size=4, seed=32, n_connection=1, d_c=2)
    srv.query(q)
    srv.query(qc)
    from repro.serve import canonicalize
    _, _, fp = canonicalize(q)
    pq = srv.plan_cache.peek(srv.dataset_id, fp)
    iv = [(int(lo), int(hi)) for lo, hi in pq.iv]
    # find a single-edge delete whose touched set misses every interval
    subj = np.bincount(g.src, minlength=g.num_nodes)
    ment = subj + np.bincount(g.dst, minlength=g.num_nodes)
    safe = np.flatnonzero((subj[g.src] >= 2) & (ment[g.src] >= 3)
                          & (ment[g.dst] >= 3))
    chosen = None
    for i in safe:
        trial = ds.apply_delta(
            deletes=[(g.labels[g.src[i]], g.predicates[g.pred[i]],
                      g.labels[g.dst[i]])])
        if trial.delta_info["mode"] == "incremental" \
                and not interval_footprint_hit(iv, trial.touched):
            chosen = [(g.labels[g.src[i]], g.predicates[g.pred[i]],
                       g.labels[g.dst[i]])]
            break
    assert chosen is not None, "expected a footprint-clean delta"
    info = srv.apply_delta(deletes=chosen)
    assert info["mode"] == "incremental"
    assert info["results_kept"] >= 1
    _poison_execution(monkeypatch, srv)
    r = srv.query(q)                      # survived entry, no execution
    assert r.stats.result_cache_hit
    with pytest.raises(Exception):        # connection entry was dropped
        srv.query(qc)


def test_plans_revalidated_not_reprepared_after_delta(dataset,
                                                     monkeypatch):
    """Unaffected PlanCache entries migrate across the delta: the next
    request neither misses the cache nor re-enters Engine.prepare."""
    srv = QueryServer(dataset, impl="ref", calibrate=False)
    pool = [random_query(dataset.graph, size=4, seed=41 + i)
            for i in range(3)]
    for q in pool:
        srv.query(q)
    misses0 = srv.plan_cache.snapshot()["misses"]
    rng = np.random.default_rng(9)
    inserts, deletes = _recombine_delta(dataset, rng)
    info = srv.apply_delta(inserts, deletes)
    assert info["mode"] == "incremental"
    from repro.serve import canonicalize
    assert info["plans_kept"] + info["plans_invalidated"] == len(
        {canonicalize(q)[2] for q in pool})
    oracle = make_engine(srv.dataset, "rdf_h", impl="ref")
    want = [oracle.execute(q).result_set() for q in pool]

    def _boom(*a, **k):
        raise AssertionError("Engine.prepare re-entered for a migrated "
                             "plan-cache entry")
    monkeypatch.setattr(srv.engine, "prepare", _boom)
    for q, w in zip(pool, want):
        r = srv.query(q)
        assert r.result_set() == w
    t = srv.plan_cache.snapshot()
    assert t["misses"] == misses0          # no post-delta cold misses
    assert t["revalidations"] >= len(pool)


def test_rebuild_delta_drops_all_plans_and_results(dataset):
    srv = _server(dataset)
    q = random_query(dataset.graph, size=4, seed=51)
    srv.query(q)
    info = srv.apply_delta(
        inserts=[("Zz/brand-new-node", dataset.graph.predicates[0],
                  dataset.graph.labels[0])])
    assert info["mode"] == "rebuild"
    assert info["plans_dropped"] >= 1 and info["plans_kept"] == 0
    assert info["results_dropped"] >= 1 and info["results_kept"] == 0
    want = make_engine(srv.dataset, "rdf_h",
                       impl="ref").execute(q).result_set()
    assert srv.query(q).result_set() == want


# ----------------------- snapshot versioning ---------------------------- #
def test_snapshot_rejects_version_mismatch(dataset, tmp_path):
    srv = _server(dataset)
    q = random_query(dataset.graph, size=4, seed=61)
    srv.query(q)
    path = tmp_path / "v0.snap"
    manifest = srv.save_snapshot(path)
    assert manifest["dataset_version"] == 0
    rng = np.random.default_rng(13)
    inserts, deletes = _recombine_delta(dataset, rng)
    srv.apply_delta(inserts, deletes)
    with pytest.raises(SnapshotError) as ei:
        srv.restore_snapshot(path)
    assert ei.value.reason == "version"
    # same-version server restores fine
    srv2 = _server(dataset)
    srv2.restore_snapshot(path)
    assert srv2.plan_cache.snapshot()["entries"] >= 1
