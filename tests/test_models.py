"""Model zoo: per-arch smoke (reduced config, forward/train step, shapes,
no NaNs) + numerical equivalences (chunked vs step forms, flash vs naive
attention, chunked vs full CE)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.configs.base import InputShape, TrainConfig, supported_shapes
from repro.models import api
from repro.models.nn_ops import flash_attention, chunked_cross_entropy
from repro.models import rwkv6 as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.optim import adamw_init

SMOKE = InputShape("smoke", 64, 2, "train")


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_train_step(name):
    cfg = reduced_config(ARCHS[name])
    params = api.init_model(cfg, 0)
    tcfg = TrainConfig(microbatch=2, total_steps=10, warmup=2)
    step = api.make_train_step(cfg, tcfg)
    batch = api.concrete_batch(cfg, SMOKE, seed=1)
    opt = adamw_init(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch, 2)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0
    for leaf in jax.tree.leaves(params2):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("name", ["qwen2-0.5b", "rwkv6-7b", "hymba-1.5b",
                                  "granite-moe-1b-a400m"])
def test_arch_loss_decreases(name):
    cfg = reduced_config(ARCHS[name])
    params = api.init_model(cfg, 0)
    tcfg = TrainConfig(lr=3e-3, microbatch=1, total_steps=30, warmup=1)
    step = jax.jit(api.make_train_step(cfg, tcfg))
    batch = api.concrete_batch(cfg, SMOKE, seed=1)   # fixed batch: memorize
    opt = adamw_init(params)
    losses = []
    for i in range(8):
        params, opt, m = step(params, opt, batch, i)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_supported_shapes_skip_rules():
    assert [s.name for s in supported_shapes(ARCHS["rwkv6-7b"])] == \
        ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert [s.name for s in supported_shapes(ARCHS["hymba-1.5b"])] == \
        ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert [s.name for s in supported_shapes(ARCHS["hubert-xlarge"])] == \
        ["train_4k", "prefill_32k"]
    assert [s.name for s in supported_shapes(ARCHS["starcoder2-15b"])] == \
        ["train_4k", "prefill_32k", "decode_32k"]
    total = sum(len(supported_shapes(c)) for c in ARCHS.values())
    assert total == 31


# ------------------------------------------------------------------ #
def test_flash_attention_matches_naive():
    rng = np.random.default_rng(0)
    b, h, s, hd = 2, 4, 96, 16
    q = rng.normal(size=(b, h, s, hd)).astype(np.float32)
    k = rng.normal(size=(b, 2, s, hd)).astype(np.float32)
    v = rng.normal(size=(b, 2, s, hd)).astype(np.float32)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=True, kv_chunk=32)
    # naive
    qg = q.reshape(b, 2, 2, s, hd)
    scores = np.einsum("bkgqd,bksd->bkgqs", qg, k) / np.sqrt(hd)
    mask = np.tril(np.ones((s, s), bool))
    scores = np.where(mask, scores, -np.inf)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bkgqs,bksd->bkgqd", p, v).reshape(b, h, s, hd)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_flash_attention_sliding_window_with_meta():
    rng = np.random.default_rng(1)
    b, h, s, hd, w, m = 1, 2, 64, 8, 16, 4
    q = rng.normal(size=(b, h, s, hd)).astype(np.float32)
    k = rng.normal(size=(b, h, s, hd)).astype(np.float32)
    v = rng.normal(size=(b, h, s, hd)).astype(np.float32)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=True, window=w, n_meta=m, kv_chunk=16)
    qpos = np.arange(s)[:, None]
    kpos = np.arange(s)[None, :]
    ok = (qpos >= kpos) & (((qpos - kpos) < w) | (kpos < m))
    scores = np.einsum("bhqd,bhsd->bhqs", q, k) / np.sqrt(hd)
    scores = np.where(ok, scores, -np.inf)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhqs,bhsd->bhqd", p, v)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_chunked_ce_matches_full():
    rng = np.random.default_rng(2)
    b, s, d, v = 2, 32, 16, 50
    x = rng.normal(size=(b, s, d)).astype(np.float32)
    emb = rng.normal(size=(v, d)).astype(np.float32)
    labels = rng.integers(0, v, (b, s)).astype(np.int32)
    got = float(chunked_cross_entropy(jnp.asarray(x), jnp.asarray(emb),
                                      jnp.asarray(labels), chunk=8))
    logits = x @ emb.T
    lse = np.log(np.exp(logits - logits.max(-1, keepdims=True))
                 .sum(-1)) + logits.max(-1)
    nll = lse - np.take_along_axis(logits, labels[..., None], -1)[..., 0]
    np.testing.assert_allclose(got, nll.mean(), rtol=1e-5)


def test_rwkv_chunked_equals_stepwise():
    cfg = reduced_config(ARCHS["rwkv6-7b"])
    defs = rwkv_mod.time_mix_defs(cfg)
    from repro.models.param import init_params
    p = init_params(defs, jax.random.PRNGKey(0))
    b, s, d = 2, 24, cfg.d_model
    h = rwkv_mod.rwkv_heads(cfg)
    hd = cfg.rwkv_head_dim
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    st0 = (jnp.zeros((b, h, hd, hd), jnp.float32), jnp.zeros((b, d)))
    y_chunk, (S_c, _) = rwkv_mod.time_mix_chunked(cfg, p, x, st0, chunk=8)
    # stepwise
    st = st0
    ys = []
    for t in range(s):
        y, st = rwkv_mod.time_mix_step(cfg, p, x[:, t], st)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(st[0]),
                               rtol=2e-3, atol=2e-3)


def test_ssm_scan_equals_stepwise():
    cfg = reduced_config(ARCHS["hymba-1.5b"])
    defs = ssm_mod.ssm_defs(cfg)
    from repro.models.param import init_params
    p = init_params(defs, jax.random.PRNGKey(0))
    b, s, d = 2, 20, cfg.d_model
    h, n = cfg.ssm_heads, cfg.ssm_state
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    h0 = jnp.zeros((b, h, d // h, n), jnp.float32)
    y_scan, h_fin = ssm_mod.ssm_scan(cfg, p, x, h0, chunk=8)
    hc = h0
    ys = []
    for t in range(s):
        y, hc = ssm_mod.ssm_step(cfg, p, x[:, t], hc)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_scan),
                               np.asarray(jnp.stack(ys, 1)),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(hc),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ["qwen2-0.5b", "rwkv6-7b", "hymba-1.5b",
                                  "paligemma-3b"])
def test_decode_matches_prefill(name):
    cfg = reduced_config(ARCHS[name])
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = api.init_model(cfg, 0)
    B, S = 2, 24
    batch = api.concrete_batch(cfg, InputShape("t", S, B, "prefill"), seed=3)
    cache_len = api.decode_cache_len(cfg, InputShape("d", S + 8, B, "decode"))
    _, cache = api.make_prefill_fn(cfg, cache_len=cache_len)(params, batch)
    nxt = np.full(B, 7, np.int32)
    logits2, _ = api.make_decode_fn(cfg)(params, cache, jnp.asarray(nxt))
    b2 = dict(batch)
    b2["tokens"] = np.concatenate([np.asarray(batch["tokens"]),
                                   nxt[:, None]], 1)
    ref, _ = api.make_prefill_fn(cfg, cache_len=cache_len)(params, b2)
    err = float(jnp.max(jnp.abs(logits2.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < 2e-2 * max(float(jnp.max(jnp.abs(ref))), 1.0)
