"""Property-based chaos (hypothesis): for ANY schedule of injected
faults — arbitrary points, kinds, and trigger indices — every future on
the governed server either resolves with a result identical to a fresh
fault-free engine, or raises its own typed ``ServingError``.  Wrong
results are never acceptable; silent hangs are never acceptable."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import make_engine, Thresholds  # noqa: E402
from repro.core.engine import EngineConfig  # noqa: E402
from repro.data import random_graph, random_query  # noqa: E402
from repro.serve import QueryServer, GovernorConfig, ServingError  # noqa: E402
from repro.testing import Fault, FaultInjector, INJECTION_POINTS  # noqa: E402
from repro.testing.faults import FAULT_KINDS  # noqa: E402

_GRAPH = random_graph(n_nodes=80, n_edges=220, n_preds=3,
                      n_literals=20, seed=1)
_POOL = [random_query(_GRAPH, size=4, seed=40 + i, n_connection=i % 2,
                      d_c=2) for i in range(4)]
_FRESH = make_engine(_GRAPH, "rdf_h", impl="ref")
_ORACLE = [_FRESH.execute(q).result_set() for q in _POOL]

# Same forcing config as tests/test_chaos.py: route every join through
# the STAGED sort-merge kernels (fuse_joins=False — the fused chain
# bypasses the merge_probe/_merge_expand seams) and every connection
# through the reach-join so the injected seams actually dispatch on this
# small workload.  Faults sampled at fused_probe/radix_probe simply
# never fire here — the property tolerates un-exercised points.
_CFG = EngineConfig(check_policy="selective", d_check=2, impl="ref",
                    thresholds=Thresholds(nested_join_max=1),
                    join_impl="sorted", fuse_joins=False,
                    connection_impl="reach")

_fault_st = st.builds(
    lambda point, kind, at: Fault(point, kind, at=at, delay_s=0.002),
    st.sampled_from(sorted(INJECTION_POINTS)),
    st.sampled_from(FAULT_KINDS),
    st.integers(min_value=1, max_value=6),
)


@settings(max_examples=10, deadline=None)
@given(schedule=st.lists(_fault_st, min_size=1, max_size=3))
def test_any_fault_schedule_exact_or_typed(schedule):
    # Fresh server per example: breaker / ladder / cache state must not
    # leak between fault schedules.
    srv = QueryServer(_GRAPH, cfg=_CFG, governor=GovernorConfig())
    with FaultInjector(*schedule):
        futures = srv.submit_many(_POOL, wait=True)
        assert all(f.done() for f in futures)   # flush never hangs
        for q_idx, f in enumerate(futures):
            try:
                res = f.result()
            except ServingError:
                continue                        # typed failure: allowed
            assert res.result_set() == _ORACLE[q_idx]
