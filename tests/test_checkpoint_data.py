"""Checkpointer (atomicity, integrity, retention, resharding-shape
restore) and the deterministic data pipeline."""
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data.lm_data import TokenPipeline


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": rng.normal(size=(4, 8)).astype(np.float32),
            "b": {"w": rng.normal(size=(3,)).astype(np.float32),
                  "step": np.int32(7)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(10, t, meta={"cfg": "x"}, async_=False)
    out, meta = ck.restore(template=t)
    assert meta == {"cfg": "x"}
    np.testing.assert_array_equal(out["a"], t["a"])
    np.testing.assert_array_equal(out["b"]["w"], t["b"]["w"])


def test_async_save_and_latest(tmp_path):
    ck = Checkpointer(tmp_path)
    for s in (1, 2, 3):
        ck.save(s, _tree(s))
    ck.wait()
    assert ck.latest_step() == 3


def test_retention_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in range(5):
        ck.save(s, _tree(s), async_=False)
    assert ck.all_steps() == [3, 4]


def test_corruption_detected(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(), async_=False)
    d = next(p for p in tmp_path.iterdir() if p.name.startswith("step_"))
    victim = next(p for p in d.iterdir() if p.suffix == ".npy")
    arr = np.load(victim)
    arr_flat = arr.reshape(-1)
    arr_flat[0] += 1.0
    np.save(victim, arr)
    with pytest.raises(IOError):
        ck.restore(template=_tree())


def test_tmp_dir_never_visible(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _tree(), async_=False)
    assert not any(p.name.endswith(".tmp") for p in tmp_path.iterdir())


# ------------------------------------------------------------------ #
def test_pipeline_deterministic():
    p = TokenPipeline(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    a = p.global_batch_at(5)
    b = p.global_batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.global_batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_shards_cover_global():
    p = TokenPipeline(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    g = p.global_batch_at(2)
    parts = [p.shard_at(2, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), g["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    p = TokenPipeline(vocab_size=50, seq_len=8, global_batch=2, seed=0)
    b = p.global_batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
