"""Reach-join subsystem: connection edges evaluated as set-at-a-time
joins (connectivity.reach_join / reach_filter) must be exactly equivalent
to the cross-product + connectivity_mask path, with peak intermediate
capacity bounded by matches (never |A|*|B|), plus the engine-owned reach
cache, the interval (wildcard) candidate representation, and the planner's
reach-vs-cross strategy choice."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (build_ni_index, connectivity_mask, make_engine,
                        cross_join, filter_rows, ReachCache, ReachJoinInfo,
                        connected_pair_table, reach_join, reach_filter,
                        distinct_column_values, dedup_project, empty_table,
                        ConnFeatures, choose_connection_impl,
                        connection_edge_cost, plan_connections,
                        expected_reach, compute_stats)
from repro.core import connectivity as conn_mod
from repro.core.matching import Table, _pow2, edge_pairs
from repro.data import random_graph, random_query


def mk_table(cols, vals):
    vals = np.asarray(vals, np.int32).reshape(-1, len(cols))
    cap = _pow2(len(vals))
    rows = np.full((cap, len(cols)), -1, np.int32)
    rows[: len(vals)] = vals
    return Table(cols=tuple(cols), rows=jnp.asarray(rows), count=len(vals))


def oracle_join(g, ni, ta, tb, src_col, dst_col, d_c, bidir):
    x = cross_join(ta, tb)
    rows = np.asarray(x.rows[: x.count])
    keep = connectivity_mask(g, ni, rows[:, x.cols.index(src_col)],
                             rows[:, x.cols.index(dst_col)], d_c, bidir)
    return filter_rows(x, keep)


# --------------------------- direct parity ---------------------------- #
@pytest.mark.parametrize("d_max,d_c,bidir", [
    (1, 2, False), (2, 2, False), (2, 3, True), (2, 4, False),
    (1, 3, True), (2, 5, False), (3, 5, True)])
def test_reach_join_matches_cross_filter(d_max, d_c, bidir):
    g = random_graph(n_nodes=90, n_edges=280, n_preds=2,
                     seed=d_max * 7 + d_c)
    ni = build_ni_index(g, d_max=d_max)
    rng = np.random.default_rng(d_c)
    ta = mk_table((0,), rng.integers(0, g.num_nodes, 60))
    tb = mk_table((1,), rng.integers(0, g.num_nodes, 45))
    info = ReachJoinInfo()
    out = reach_join(g, ni, ta, tb, 0, 1, d_c, bidir, info=info)
    want = oracle_join(g, ni, ta, tb, 0, 1, d_c, bidir)
    assert out.result_set() == want.result_set()
    assert info.connected_pairs >= 0 and info.reach_pairs > 0


@pytest.mark.parametrize("d_max,d_c,bidir", [
    (2, 3, False), (2, 4, True), (1, 4, False)])
def test_reach_filter_matches_mask(d_max, d_c, bidir):
    g = random_graph(n_nodes=70, n_edges=220, n_preds=2, seed=d_c + 40)
    ni = build_ni_index(g, d_max=d_max)
    rng = np.random.default_rng(5)
    a = rng.integers(0, g.num_nodes, 64)
    b = rng.integers(0, g.num_nodes, 64)
    t = mk_table((2, 5), np.stack([a, b], axis=1))
    got = reach_filter(g, ni, t, 2, 5, d_c, bidir)
    want = filter_rows(t, connectivity_mask(g, ni, a, b, d_c, bidir))
    assert got.result_set() == want.result_set()


def test_reach_join_multi_column_tables():
    """Endpoint columns embedded in wider tables (the engine case)."""
    g = random_graph(n_nodes=80, n_edges=260, n_preds=2, seed=3)
    ni = build_ni_index(g, d_max=2)
    rng = np.random.default_rng(9)
    ta = mk_table((0, 1), rng.integers(0, g.num_nodes, (40, 2)))
    tb = mk_table((2, 3), rng.integers(0, g.num_nodes, (35, 2)))
    out = reach_join(g, ni, ta, tb, 1, 2, 3, False)
    want = oracle_join(g, ni, ta, tb, 1, 2, 3, False)
    assert out.cols == want.cols
    assert out.result_set() == want.result_set()


def test_reach_join_empty_sides():
    g = random_graph(n_nodes=40, n_edges=100, n_preds=2, seed=1)
    ni = build_ni_index(g, d_max=2)
    ta = mk_table((0,), np.arange(5))
    out = reach_join(g, ni, ta, empty_table((1,)), 0, 1, 2)
    assert out.count == 0 and out.cols == (0, 1)
    out = reach_join(g, ni, empty_table((0,)), ta, 0, 0, 2)
    assert out.count == 0


def test_connected_pair_table_is_exact_and_distinct():
    """The connected-pair table holds exactly the distinct endpoint pairs
    the per-pair oracle accepts — nothing more, nothing less."""
    g = random_graph(n_nodes=60, n_edges=200, n_preds=2, seed=12)
    ni = build_ni_index(g, d_max=2)
    rng = np.random.default_rng(1)
    ta = mk_table((0,), rng.integers(0, g.num_nodes, 30))
    tb = mk_table((1,), rng.integers(0, g.num_nodes, 30))
    a_vals = distinct_column_values(ta, 0)
    b_vals = distinct_column_values(tb, 1)
    assert (np.diff(a_vals) > 0).all()          # sorted distinct
    cp = connected_pair_table(g, ni, a_vals, b_vals, 3, False, (0, 1))
    got = {tuple(r) for r in cp.numpy()}
    want = set()
    for a in a_vals:
        keep = connectivity_mask(g, ni, np.full(len(b_vals), a), b_vals, 3)
        want |= {(int(a), int(b)) for b, k in zip(b_vals, keep) if k}
    assert got == want
    assert cp.count == len(got)                 # deduplicated


# ----------------------- capacity boundedness ------------------------- #
def test_reach_join_capacity_bounded_by_matches():
    """The acceptance property: with the reach impl no intermediate is
    proportional to |A|*|B| — peak table capacity tracks matches + pair
    tables.  4096x4096 rows (16.7M-pair product) over a sparse graph
    where only a handful of endpoint pairs connect."""
    g = random_graph(n_nodes=20_000, n_edges=40_000, n_preds=2, seed=8)
    ni = build_ni_index(g, d_max=1)
    rng = np.random.default_rng(2)
    pa = rng.choice(g.num_nodes, 1024, replace=False)
    pb = rng.choice(g.num_nodes, 1024, replace=False)
    ta = mk_table((0,), rng.choice(pa, 4096))
    tb = mk_table((1,), rng.choice(pb, 4096))
    info = ReachJoinInfo()
    out = reach_join(g, ni, ta, tb, 0, 1, 2, info=info)
    product = ta.count * tb.count                  # 16.7M
    # peak capacity is bounded by matches + pair-table sizes, and every
    # intermediate stays orders of magnitude below the cross product
    assert info.peak_cap <= max(_pow2(out.count), _pow2(info.reach_pairs))
    assert info.peak_cap < product // 64
    assert out.cap == _pow2(out.count)
    # spot-check correctness on a slice against the per-pair oracle
    sub_a, sub_b = mk_table((0,), ta.numpy()[:256]), \
        mk_table((1,), tb.numpy()[:256])
    sub = reach_join(g, ni, sub_a, sub_b, 0, 1, 2)
    want = oracle_join(g, ni, sub_a, sub_b, 0, 1, 2, False)
    assert sub.result_set() == want.result_set()


# --------------------------- reach cache ------------------------------ #
def test_reach_cache_shared_across_edges(monkeypatch):
    """Satellite: reach sets computed for one connection edge are reused
    by later edges sharing endpoints (per-query engine-owned cache), for
    both the per-pair mask path and the reach-join path."""
    g = random_graph(n_nodes=60, n_edges=180, n_preds=2, seed=4)
    ni = build_ni_index(g, d_max=1)
    calls = {"n": 0}
    real = conn_mod._bfs_within

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)
    monkeypatch.setattr(conn_mod, "_bfs_within", counting)
    rng = np.random.default_rng(0)
    a = rng.integers(0, g.num_nodes, 32)
    b = rng.integers(0, g.num_nodes, 32)
    cache = ReachCache()
    connectivity_mask(g, ni, a, b, 5, cache=cache)   # d_c=5 > d_max: BFS
    first = calls["n"]
    assert first > 0
    connectivity_mask(g, ni, a, b, 5, cache=cache)   # all memoized
    assert calls["n"] == first
    # the array-side consumer hits the same cache entries
    ta, tb = mk_table((0,), a), mk_table((1,), b)
    out = reach_join(g, ni, ta, tb, 0, 1, 5, cache=cache)
    assert calls["n"] == first
    assert out.result_set() == oracle_join(g, ni, ta, tb, 0, 1, 5,
                                           False).result_set()


def test_engine_conn_telemetry_and_parity():
    """connection_impl x plan_mode A/B grid: identical result sets, and
    QueryStats.conn_strategies records the executed strategy."""
    g = random_graph(n_nodes=120, n_edges=400, n_preds=3, seed=11)
    q = random_query(g, size=5, seed=23, n_connection=2, d_c=3)
    if not q.connections:
        pytest.skip("sampled query has no connection edges")
    results = {}
    for ci in ("reach", "cross", "auto"):
        for pm in ("cost", "greedy"):
            eng = make_engine(g, "h2", impl="ref")
            eng.cfg.connection_impl = ci
            eng.cfg.plan_mode = pm
            r = eng.execute(q)
            results[(ci, pm)] = r.result_set()
            n_edges = sum(r.stats.conn_strategies.values())
            assert n_edges == len(q.connections)
            if ci != "auto":
                assert set(r.stats.conn_strategies) == {ci}
            if ci == "reach":
                assert r.stats.conn_reach_pairs > 0
                assert r.stats.conn_endpoint_distinct > 0
    first = next(iter(results.values()))
    assert all(v == first for v in results.values())


# ------------------- wildcard interval candidates --------------------- #
def test_edge_pairs_interval_spec_matches_mask():
    g = random_graph(n_nodes=80, n_edges=250, n_preds=3, seed=6)
    n = g.num_nodes
    lo_s, hi_s, lo_d, hi_d = 10, 50, 20, 70
    m_s = np.zeros(n, bool); m_s[lo_s:hi_s] = True
    m_d = np.zeros(n, bool); m_d[lo_d:hi_d] = True
    t_mask = edge_pairs(g, 1, jnp.asarray(m_s), jnp.asarray(m_d), (0, 1))
    t_iv = edge_pairs(g, 1, (jnp.int32(lo_s), jnp.int32(hi_s)),
                      (jnp.int32(lo_d), jnp.int32(hi_d)), (0, 1))
    assert t_mask.result_set() == t_iv.result_set()
    # mixed specs too
    t_mix = edge_pairs(g, 1, jnp.asarray(m_s),
                       (jnp.int32(lo_d), jnp.int32(hi_d)), (0, 1))
    assert t_mix.result_set() == t_mask.result_set()


def test_engine_wildcard_candidates_need_no_masks():
    """check_policy='never' (interval representation) must agree with
    'always' (materialized masks) end to end."""
    g = random_graph(n_nodes=100, n_edges=350, n_preds=3, seed=15)
    q = random_query(g, size=4, seed=31, n_connection=1, d_c=3)
    r_never = make_engine(g, "stwig+", impl="ref").execute(q)
    eng = make_engine(g, "h2", impl="ref")
    eng.cfg.check_policy = "always"
    r_always = eng.execute(q)
    assert r_never.result_set() == r_always.result_set()
    assert not r_never.stats.used_check


# ------------------------ dedup_project ------------------------------- #
def test_dedup_project_distinct_sorted():
    rng = np.random.default_rng(0)
    t = mk_table((3, 1, 2), rng.integers(0, 6, (200, 3)))
    d = dedup_project(t, (1, 2))
    rows = d.numpy()
    want = sorted({(int(r[1]), int(r[2])) for r in t.numpy()})
    assert [tuple(r) for r in rows] == want
    assert d.sort_order == (1, 2)
    assert d.cols == (1, 2)


def test_dedup_project_tolerates_scattered_padding():
    """Valid rows need not form a prefix (union-of-buffers input)."""
    rows = np.full((16, 2), -1, np.int32)
    rows[3] = (5, 2)
    rows[9] = (5, 2)
    rows[12] = (1, 7)
    t = Table(cols=(0, 1), rows=jnp.asarray(rows), count=3)
    d = dedup_project(t, (0, 1))
    assert d.count == 2
    assert {tuple(r) for r in d.numpy()} == {(5, 2), (1, 7)}


# ------------------------ planner choice ------------------------------ #
def test_choose_connection_impl_regimes():
    feat_few = ConnFeatures(distinct_a=20, distinct_b=20,
                            reach_fwd=8.0, reach_bwd=4.0)
    # big tables, few distinct endpoints: reach-join wins
    assert choose_connection_impl(20_000, 20_000, feat_few, 1e-3,
                                  100_000) == "reach"
    # tiny tables: the cross product is cheaper than pair-table setup
    assert choose_connection_impl(4, 4, feat_few, 1e-3, 100_000) == "cross"
    # forcing wins over the model
    assert choose_connection_impl(4, 4, feat_few, 1e-3, 100_000,
                                  impl="reach") == "reach"
    cross, reach = connection_edge_cost(20_000, 20_000, feat_few, 1e-3,
                                        100_000)
    assert reach < cross


def test_plan_connections_with_features():
    """The feature-aware model still produces a valid plan and never
    prices an edge above its cross cost under 'auto'."""
    sizes = [1000, 2000, 50]
    endpoints = [(0, 1), (1, 2)]
    sels = [1e-3, 1e-2]
    feats = [ConnFeatures(10, 10, 4.0, 4.0), ConnFeatures(50, 5, 4.0, 4.0)]
    plan = plan_connections(sizes, endpoints, sels, feats=feats,
                            num_nodes=10_000, impl="auto")
    legacy = plan_connections(sizes, endpoints, sels)
    assert sorted(plan.order) == [0, 1]
    assert plan.est_cost <= legacy.est_cost + 1e-9


def test_expected_reach_monotone_capped():
    g = random_graph(n_nodes=60, n_edges=300, n_preds=2, seed=2)
    st_ = compute_stats(g)
    vals = [expected_reach(st_, g.num_nodes, h) for h in range(6)]
    assert vals[0] == 1.0
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert vals[-1] <= g.num_nodes
