"""Warm-restart durability: snapshot round trips and their safety rails.

The contract — `restore_snapshot` either yields a server whose first
execution per cached template runs the WARM path (no prepare, no
planning DP, no §4.3 decide, no signature check) with results identical
to a fresh engine, or raises a typed SnapshotError and leaves an exact
cold start.  Never a silently wrong or stale answer.
"""
import os
import struct
import time

import numpy as np
import pytest

import repro.core.engine as engine_mod
from repro.core import make_engine
from repro.core.engine import EngineConfig
from repro.data import random_graph, random_query
from repro.serve import (QueryServer, GovernorConfig, SnapshotError,
                         template_fingerprint)
from repro.serve.snapshot import MAGIC, FORMAT_VERSION


@pytest.fixture(scope="module")
def graph():
    return random_graph(n_nodes=80, n_edges=220, n_preds=3,
                        n_literals=20, seed=7)


@pytest.fixture(scope="module")
def pool(graph):
    return [random_query(graph, size=4, seed=60 + i, n_connection=i % 2,
                         d_c=2) for i in range(4)]


@pytest.fixture(scope="module")
def oracle(graph, pool):
    eng = make_engine(graph, "rdf_h", impl="ref")
    return [eng.execute(q).result_set() for q in pool]


def _server(graph, **kw):
    kw.setdefault("governor", GovernorConfig())
    return QueryServer(graph, impl="ref", **kw)


def _warm_server(graph, pool):
    srv = _server(graph)
    for _ in range(2):                   # cold pass + warm pass
        for q in pool:
            srv.query(q)
    return srv


def _canon_rows(res):
    """Canonical byte-comparable form of a MatchResult: rows projected
    into sorted-column order, then lexicographically sorted."""
    order = np.argsort(res.cols)
    rows = np.asarray(res.rows)[:, order]
    if rows.shape[0] > 1:
        rows = rows[np.lexsort(rows.T[::-1])]
    return rows


# --------------------------- happy path -------------------------------- #
def test_roundtrip_restores_warm_path_byte_identical(graph, pool, oracle,
                                                     tmp_path, monkeypatch):
    """The tentpole proof: a restored server's FIRST execution per
    cached template runs the warm path — prepare / plan / decide /
    check are monkeypatch-poisoned and never re-entered — and the
    results are byte-identical to the pre-crash server's and to the
    fault-free oracle."""
    srv = _warm_server(graph, pool)
    before = [srv.query(q) for q in pool]
    path = tmp_path / "serve.snap"
    manifest = srv.save_snapshot(path)
    assert manifest["plans"] == len(pool)
    assert manifest["format_version"] == FORMAT_VERSION

    srv2 = _server(graph)                # the "restarted process"
    srv2.restore_snapshot(path)

    def _boom(*a, **k):
        raise AssertionError("cold path re-entered after restore")
    for fn in ("plan_table_joins", "plan_connections", "decide",
               "check_interval_candidates", "connection_selectivity",
               "endpoint_reach", "choose_connection_impl"):
        monkeypatch.setattr(engine_mod, fn, _boom)
    monkeypatch.setattr(srv2.engine, "prepare", _boom)

    for q, res_before, want in zip(pool, before, oracle):
        res = srv2.query(q)
        assert res.stats.cache_hit       # first post-restore run is WARM
        assert res.stats.join_retries == 0
        assert res.result_set() == want
        assert res.cols == res_before.cols
        assert np.array_equal(_canon_rows(res), _canon_rows(res_before))
    t = srv2.telemetry()
    assert t["plan_cache"]["misses"] == 0
    assert t["governor"]["snapshot"]["action"] == "restored"


def test_roundtrip_with_signature_masks(graph, tmp_path, monkeypatch):
    """check_policy='always' plans carry real [N] bool candidate masks;
    they must round-trip in host form and be rebuilt on-device without
    re-running the check."""
    cfg = EngineConfig(check_policy="always", d_check=2, impl="ref")
    q = random_query(graph, size=4, seed=64, n_connection=0)
    srv = QueryServer(graph, cfg=cfg, governor=GovernorConfig())
    want = srv.query(q).result_set()
    srv.query(q)                         # warm: masks cached on the plan
    path = tmp_path / "masks.snap"
    srv.save_snapshot(path)

    srv2 = QueryServer(graph, cfg=cfg, governor=GovernorConfig())
    srv2.restore_snapshot(path)
    calls = []
    monkeypatch.setattr(
        engine_mod, "check_interval_candidates",
        lambda *a, **k: calls.append(1) or (_ for _ in ()).throw(
            AssertionError("signature check re-entered after restore")))
    res = srv2.query(q)
    assert res.stats.cache_hit and not calls
    assert res.result_set() == want
    assert res.stats.used_check          # stats still attribute the check


def test_restore_preserves_learned_state(graph, pool, tmp_path):
    """Calibrator scales/τ, governor rung memory, breaker entries, and
    plan-cache join_seq survive the round trip (clocks rebased)."""
    srv = _warm_server(graph, pool)
    # plant distinctive learned state
    srv.calibrator.cost_model.join_est_scale = 0.37
    srv.calibrator.thresholds.tau_sel = 2.5
    srv.calibrator.version += 3
    gov = srv.governor
    gov.breaker.record("bad-fp", ok=False, now=0.0)
    gov.breaker.record("bad-fp", ok=False, now=0.0)
    gov.rung_memory.record_degraded("deg-fp", "greedy_plan", now=0.0)
    path = tmp_path / "state.snap"
    srv.save_snapshot(path)

    srv2 = _server(graph)
    srv2.restore_snapshot(path)
    assert srv2.calibrator.cost_model.join_est_scale == 0.37
    assert srv2.calibrator.thresholds.tau_sel == 2.5
    assert srv2.calibrator.version == srv.calibrator.version
    assert srv2.governor.rung_memory.rung("deg-fp") == "greedy_plan"
    assert srv2.governor.breaker._st["bad-fp"]["failures"] == 2
    # restored plans carry the learned join_seq (not re-learned)
    fp = template_fingerprint(pool[0])
    pq = srv2.plan_cache.get(srv2.dataset_id, fp)
    assert pq is not None and pq.warm and pq.join_seq
    # the plan keeps its prepare-time version: the restored calibrator
    # moved past it (we bumped it above), so the first use revalidates
    # through Engine.revalidate instead of trusting a stale decision
    assert pq.version == 0 != srv2._version()


# ------------------------- typed failure modes ------------------------- #
def _assert_cold_start_still_exact(graph, pool, oracle, srv):
    assert len(srv.plan_cache) == 0      # untouched: clean cold start
    for q, want in zip(pool, oracle):
        assert srv.query(q).result_set() == want


def test_missing_snapshot_raises_io(graph, pool, oracle, tmp_path):
    srv = _server(graph)
    with pytest.raises(SnapshotError) as ei:
        srv.restore_snapshot(tmp_path / "nope.snap")
    assert ei.value.reason == "io"
    _assert_cold_start_still_exact(graph, pool, oracle, srv)


def test_truncated_snapshot_raises(graph, pool, oracle, tmp_path):
    path = tmp_path / "trunc.snap"
    _warm_server(graph, pool).save_snapshot(path)
    raw = path.read_bytes()
    path.write_bytes(raw[:20])           # shorter than the header
    srv = _server(graph)
    with pytest.raises(SnapshotError) as ei:
        srv.restore_snapshot(path)
    assert ei.value.reason == "truncated"
    _assert_cold_start_still_exact(graph, pool, oracle, srv)


def test_bad_magic_raises(graph, pool, oracle, tmp_path):
    path = tmp_path / "magic.snap"
    _warm_server(graph, pool).save_snapshot(path)
    raw = path.read_bytes()
    path.write_bytes(b"NOTASNAP" + raw[len(MAGIC):])
    srv = _server(graph)
    with pytest.raises(SnapshotError) as ei:
        srv.restore_snapshot(path)
    assert ei.value.reason == "magic"
    _assert_cold_start_still_exact(graph, pool, oracle, srv)


def test_format_version_mismatch_raises(graph, pool, oracle, tmp_path):
    path = tmp_path / "ver.snap"
    _warm_server(graph, pool).save_snapshot(path)
    raw = bytearray(path.read_bytes())
    raw[len(MAGIC):len(MAGIC) + 4] = struct.pack("<I", FORMAT_VERSION + 1)
    path.write_bytes(bytes(raw))
    srv = _server(graph)
    with pytest.raises(SnapshotError) as ei:
        srv.restore_snapshot(path)
    assert ei.value.reason == "format_version"
    _assert_cold_start_still_exact(graph, pool, oracle, srv)


def test_corrupt_payload_raises_checksum(graph, pool, oracle, tmp_path):
    path = tmp_path / "corrupt.snap"
    _warm_server(graph, pool).save_snapshot(path)
    raw = bytearray(path.read_bytes())
    raw[-10] ^= 0xFF                     # flip one payload byte
    path.write_bytes(bytes(raw))
    srv = _server(graph)
    with pytest.raises(SnapshotError) as ei:
        srv.restore_snapshot(path)
    assert ei.value.reason == "checksum"
    _assert_cold_start_still_exact(graph, pool, oracle, srv)


def test_garbage_with_valid_checksum_raises_undecodable(graph, pool,
                                                        oracle, tmp_path):
    """A checksum-valid file whose payload isn't a pickle: the checksum
    rail can't catch it, the decode rail must."""
    import hashlib
    payload = b"\x80\x04 this is not a valid pickle stream"
    head = MAGIC + struct.pack("<I", FORMAT_VERSION) \
        + hashlib.sha256(payload).digest()
    path = tmp_path / "garbage.snap"
    path.write_bytes(head + payload)
    srv = _server(graph)
    with pytest.raises(SnapshotError) as ei:
        srv.restore_snapshot(path)
    assert ei.value.reason == "undecodable"
    _assert_cold_start_still_exact(graph, pool, oracle, srv)


def test_wrong_dataset_raises(graph, pool, oracle, tmp_path):
    """A snapshot from a different graph must never replay its masks or
    join sizes here — dataset_key is a content digest, so a lookalike
    graph with equal node/edge counts is still rejected."""
    other = random_graph(n_nodes=80, n_edges=220, n_preds=3,
                         n_literals=20, seed=99)
    path = tmp_path / "other.snap"
    srv_other = _server(other)
    srv_other.query(random_query(other, size=3, seed=61))
    srv_other.save_snapshot(path)
    srv = _server(graph)
    with pytest.raises(SnapshotError) as ei:
        srv.restore_snapshot(path)
    assert ei.value.reason == "dataset"
    _assert_cold_start_still_exact(graph, pool, oracle, srv)


def test_stale_snapshot_raises(graph, pool, oracle, tmp_path):
    path = tmp_path / "stale.snap"
    _warm_server(graph, pool).save_snapshot(path)
    time.sleep(0.05)
    srv = _server(graph)
    with pytest.raises(SnapshotError) as ei:
        srv.restore_snapshot(path, max_age_s=0.01)
    assert ei.value.reason == "stale"
    _assert_cold_start_still_exact(graph, pool, oracle, srv)
    # the same file within its age budget restores fine
    srv2 = _server(graph)
    srv2.restore_snapshot(path, max_age_s=3600.0)
    assert len(srv2.plan_cache) == len(pool)


def test_save_is_atomic_no_tmp_left_behind(graph, pool, tmp_path):
    srv = _warm_server(graph, pool)
    path = tmp_path / "atomic.snap"
    srv.save_snapshot(path)
    srv.save_snapshot(path)              # overwrite in place
    assert sorted(os.listdir(tmp_path)) == ["atomic.snap"]


# ----------------------- format compatibility -------------------------- #
def test_restore_pre_observability_payload(graph, pool, oracle, tmp_path):
    """A snapshot whose PreparedQuery blobs predate `join_est_seq` (the
    shape written before the observability PR, same format version)
    still restores: the missing field defaults to an empty estimate
    history instead of failing the whole restore, and the first
    execution per template is still warm and byte-identical."""
    import hashlib
    import pickle

    srv = _warm_server(graph, pool)
    path = tmp_path / "old.snap"
    srv.save_snapshot(path)
    raw = path.read_bytes()
    hdr = len(MAGIC) + 4 + hashlib.sha256().digest_size
    data = pickle.loads(raw[hdr:])
    for _, blob in data["plans"]:
        assert "join_est_seq" in blob    # guard: strip something real
        del blob["join_est_seq"]
    payload = pickle.dumps(data, protocol=4)
    path.write_bytes(MAGIC + struct.pack("<I", FORMAT_VERSION)
                     + hashlib.sha256(payload).digest() + payload)

    srv2 = _server(graph)
    manifest = srv2.restore_snapshot(path)
    assert manifest["plans"] == len(pool)
    for q, want in zip(pool, oracle):
        res = srv2.query(q)
        assert res.stats.cache_hit       # warm path survives the compat
        assert res.result_set() == want
    for _, pq in srv2.plan_cache.entries():
        assert pq.join_est_seq == []     # defaulted, not invented
