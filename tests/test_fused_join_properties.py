"""Property-based join-strategy identity (hypothesis): for ANY pair of
random tables — arbitrary shared-column overlap, duplicate-heavy key
distributions, empty sides — nested-loop, sort-merge (fused and staged)
and radix hash join return the same result multiset, and capacity
overflows resume to the identical answer."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.matching import (  # noqa: E402
    Table, CapacityOverflow, join_tables, _pow2,
)


def mk_table(cols, data):
    data = np.asarray(data, np.int32).reshape(-1, len(cols))
    cap = _pow2(len(data))
    rows = np.full((cap, len(cols)), -1, np.int32)
    rows[: len(data)] = data
    return Table(cols=tuple(cols), rows=jnp.asarray(rows), count=len(data))


def rows_multiset(t):
    return sorted(tuple(int(x) for x in r) for r in t.numpy())


@st.composite
def table_pair(draw):
    """Two tables guaranteed ≥1 shared column; small value alphabet so
    duplicate keys and multi-match segments are the common case."""
    rng = np.random.default_rng(draw(st.integers(0, 10_000)))
    nca = draw(st.integers(1, 3))
    ncb = draw(st.integers(1, 3))
    a_cols = tuple(int(c) for c in rng.choice(4, nca, replace=False))
    rest = [c for c in range(4) if c not in a_cols]
    b_cols = (a_cols[0],) + tuple(
        int(c) for c in rng.choice(rest, min(ncb - 1, len(rest)),
                                   replace=False))
    na = draw(st.integers(0, 80))
    nb = draw(st.integers(0, 80))
    vmax = draw(st.sampled_from([2, 4, 9]))
    a = mk_table(a_cols, rng.integers(0, vmax, (na, len(a_cols))))
    b = mk_table(b_cols, rng.integers(0, vmax, (nb, len(b_cols))))
    return a, b


@settings(max_examples=25, deadline=None)
@given(table_pair())
def test_all_strategies_identical(pair):
    a, b = pair
    want = rows_multiset(join_tables(a, b, impl="nested"))
    assert rows_multiset(join_tables(a, b, impl="sorted", fuse=True)) == want
    assert rows_multiset(join_tables(a, b, impl="sorted", fuse=False)) == want
    assert rows_multiset(join_tables(a, b, impl="radix")) == want


@settings(max_examples=10, deadline=None)
@given(table_pair(), st.sampled_from(["sorted", "radix"]))
def test_overflow_resume_identity(pair, impl):
    """Starving the capacity forces the overflow path; the resumed retry
    must still equal the straight-through answer."""
    a, b = pair
    want = rows_multiset(join_tables(a, b, impl=impl))
    if len(want) <= 1:
        return                                   # no overflow to force
    cap = _pow2(max(len(want) // 2, 1))
    if cap >= len(want):
        return                                   # pow2 rounding absorbed it
    try:
        out = join_tables(a, b, impl=impl, cap=cap)
    except CapacityOverflow as e:
        out = join_tables(a, b, impl=impl, cap=_pow2(e.needed),
                          _resume=getattr(e, "resume", None))
    assert rows_multiset(out) == want
