"""Property-based tests (hypothesis): any planner-chosen join order yields
the identical canonical result set — over random join DAGs at the table
level and over random small graphs at the engine level."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import make_engine
from repro.core.matching import Table, join_tables, _pow2
from repro.data import random_graph, random_query


def mk_table(cols, data):
    data = np.asarray(data, np.int32).reshape(-1, len(cols))
    cap = _pow2(len(data))
    rows = np.full((cap, len(cols)), -1, np.int32)
    rows[: len(data)] = data
    return Table(cols=tuple(cols), rows=jnp.asarray(rows), count=len(data))


@st.composite
def join_problem(draw):
    """3-4 tables over overlapping column sets (chain overlap guarantees
    every left-to-right order stays connected enough to terminate)."""
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    n = draw(st.integers(3, 4))
    tables = []
    for i in range(n):
        cols = (i, i + 1) if draw(st.booleans()) else (i + 1, i)
        rows = int(rng.integers(0, 40))
        tables.append(mk_table(cols, rng.integers(0, 6, (rows, 2))))
    return tables, seed


@settings(max_examples=12, deadline=None)
@given(join_problem())
def test_any_join_order_same_result_set(problem):
    tables, seed = problem
    rng = np.random.default_rng(seed + 1)
    want = None
    for trial in range(3):
        perm = rng.permutation(len(tables))
        acc = tables[perm[0]]
        for i in perm[1:]:
            acc = join_tables(acc, tables[i],
                              impl="sorted" if trial % 2 else "auto")
        got = acc.result_set()
        if want is None:
            want = got
        assert got == want, f"order {perm} diverged"


@st.composite
def graph_and_query(draw):
    seed = draw(st.integers(0, 5_000))
    n = draw(st.integers(20, 60))
    g = random_graph(n_nodes=n, n_edges=draw(st.integers(n, 3 * n)),
                     n_preds=3, n_literals=max(3, n // 5), seed=seed)
    q = random_query(g, size=draw(st.integers(3, 5)), seed=seed + 1,
                     n_connection=draw(st.integers(0, 1)), d_c=3)
    return g, q


@settings(max_examples=8, deadline=None)
@given(graph_and_query())
def test_engine_plan_order_invariance(gq):
    g, q = gq
    want = None
    for pm in ("cost", "greedy"):
        for ji in ("sorted", "nested", "radix"):
            eng = make_engine(g, "rdf_h", impl="ref")
            eng.cfg.plan_mode = pm
            eng.cfg.join_impl = ji
            got = eng.execute(q).result_set()
            if want is None:
                want = got
            assert got == want, (pm, ji)
