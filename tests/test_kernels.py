"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes.  Integer kernels -> exact equality."""
import numpy as np
import pytest

from repro.kernels import ops, ref


RNG = np.random.default_rng(42)


def _ragged_sorted_ids(c, b, hi=1000):
    ids = np.full((c, b), -1, np.int32)
    for i in range(c):
        k = RNG.integers(0, b + 1)
        ids[i, :k] = np.sort(RNG.integers(0, hi, k))
    return ids


@pytest.mark.parametrize("c,b,j", [(1, 1, 1), (7, 13, 3), (64, 128, 8),
                                   (130, 70, 5), (256, 257, 16),
                                   (1000, 33, 2)])
def test_interval_count_sweep(c, b, j):
    ids = _ragged_sorted_ids(c, b)
    lo = RNG.integers(0, 900, j).astype(np.int32)
    hi = lo + RNG.integers(0, 200, j).astype(np.int32)
    want = np.asarray(ref.interval_count_ref(ids, lo, hi))
    got = np.asarray(ops.interval_count(ids, lo, hi, impl="interpret"))
    np.testing.assert_array_equal(got, want)


def test_interval_count_empty_interval():
    ids = _ragged_sorted_ids(10, 8)
    lo = np.asarray([5], np.int32)
    hi = np.asarray([5], np.int32)          # empty
    got = np.asarray(ops.interval_count(ids, lo, hi, impl="interpret"))
    assert (got == 0).all()


def test_interval_count_padding_never_counts():
    ids = np.full((4, 16), -1, np.int32)    # all padding
    lo = np.asarray([0], np.int32)
    hi = np.asarray([10 ** 6], np.int32)
    got = np.asarray(ops.interval_count(ids, lo, hi, impl="interpret"))
    assert (got == 0).all()


@pytest.mark.parametrize("c,w", [(1, 1), (9, 3), (64, 8), (200, 17),
                                 (513, 4)])
def test_bitmask_contains_sweep(c, w):
    cand = RNG.integers(0, 2 ** 32, (c, w), dtype=np.uint32)
    q = RNG.integers(0, 2 ** 32, w, dtype=np.uint32)
    want = np.asarray(ref.bitmask_contains_ref(cand, q))
    got = np.asarray(ops.bitmask_contains(cand, q, impl="interpret"))
    np.testing.assert_array_equal(got, want)


def test_bitmask_self_contained():
    cand = RNG.integers(0, 2 ** 32, (16, 4), dtype=np.uint32)
    got = np.asarray(ops.bitmask_contains(cand, cand[3], impl="interpret"))
    assert got[3] == 1


@pytest.mark.parametrize("p,a,b", [(1, 1, 1), (5, 7, 11), (64, 32, 64),
                                   (257, 16, 8), (100, 130, 20)])
def test_intersect_any_sweep(p, a, b):
    x = np.where(RNG.random((p, a)) < 0.7,
                 RNG.integers(0, 50, (p, a)), -1).astype(np.int32)
    y = np.where(RNG.random((p, b)) < 0.7,
                 RNG.integers(0, 50, (p, b)), -1).astype(np.int32)
    want = np.asarray(ref.intersect_any_ref(x, y))
    got = np.asarray(ops.intersect_any(x, y, impl="interpret"))
    np.testing.assert_array_equal(got, want)


def test_intersect_padding_not_a_hit():
    x = np.full((3, 4), -1, np.int32)
    y = np.full((3, 4), -1, np.int32)
    got = np.asarray(ops.intersect_any(x, y, impl="interpret"))
    assert (got == 0).all()


def _sorted_keys(n, hi=500, sentinel=None, frac_pad=0.2):
    ks = RNG.integers(0, hi, n).astype(np.int32)
    if sentinel is not None and n:
        ks[: max(int(n * frac_pad), 1)] = sentinel
    return np.sort(ks)


@pytest.mark.parametrize("na,nb", [(1, 1), (7, 130), (128, 128),
                                   (300, 77), (1000, 513), (257, 8)])
def test_merge_probe_sweep(na, nb):
    a = _sorted_keys(na, sentinel=(1 << 31) - 1)       # a-side invalid pads
    b = _sorted_keys(nb, sentinel=(1 << 31) - 2)       # b-side invalid pads
    ws, wc = (np.asarray(x) for x in ref.merge_probe_ref(a, b))
    for impl in ("sorted", "interpret"):
        gs, gc = (np.asarray(x) for x in ops.merge_probe(a, b, impl=impl))
        np.testing.assert_array_equal(gs, ws)
        np.testing.assert_array_equal(gc, wc)


def test_merge_probe_ranges_are_consistent():
    """start/cnt must delimit exactly the equal-key run in b."""
    a = _sorted_keys(64, hi=30)
    b = _sorted_keys(96, hi=30)
    s, c = (np.asarray(x) for x in ops.merge_probe(a, b, impl="interpret"))
    for i, key in enumerate(a):
        np.testing.assert_array_equal(b[s[i]: s[i] + c[i]],
                                      np.full(c[i], key))
        assert s[i] == np.searchsorted(b, key, side="left")


def test_merge_probe_invalid_rows_never_match():
    """The join's per-side sentinels must produce zero-count ranges."""
    a = np.sort(np.asarray([3, 7, (1 << 31) - 1] * 4, np.int32))
    b = np.sort(np.asarray([7, 9, (1 << 31) - 2] * 4, np.int32))
    for impl in ("sorted", "interpret"):
        _, c = (np.asarray(x) for x in ops.merge_probe(a, b, impl=impl))
        assert (c[a == (1 << 31) - 1] == 0).all()
        assert (c[a == 7] == 4).all()


def test_auto_dispatch_cpu_is_ref():
    ids = _ragged_sorted_ids(8, 8)
    lo = np.asarray([0], np.int32)
    hi = np.asarray([100], np.int32)
    a = np.asarray(ops.interval_count(ids, lo, hi, impl="auto"))
    b = np.asarray(ops.interval_count(ids, lo, hi, impl="ref"))
    np.testing.assert_array_equal(a, b)
