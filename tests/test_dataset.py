"""Dataset facade + delta ingest: incremental maintenance must be
indistinguishable from a from-scratch rebuild.

The oracle for every delta test is `Dataset.build` on the post-delta
triple list: `apply_delta`'s incremental path must reproduce its digest,
edge arrays, CSRs, NI entries, and stats bit-for-bit, and engines over
both must return byte-identical results across the §4.3 check grid.
"""
import warnings

import numpy as np
import pytest

from repro.core import (Dataset, Engine, ENGINE_VARIANTS, content_digest,
                        interval_footprint_hit, make_engine, csr_patch)
from repro.data import random_graph, random_query


# --------------------------- helpers ----------------------------------- #
def _mk(seed=3, n_nodes=150, n_edges=450, n_preds=5):
    g = random_graph(n_nodes=n_nodes, n_edges=n_edges, n_preds=n_preds,
                     n_literals=25, seed=seed)
    return Dataset.build(g, variant="rdf_h")


def _recombine_delta(ds, rng, n_ins=4, n_del=4):
    """A delta the incremental path can absorb: inserts recombine
    subject/object pairs within one predicate (kinds stay legal), and
    deletes only hit edges whose endpoints stay mentioned afterwards."""
    g = ds.graph
    lab, prd = g.labels, g.predicates
    subj = np.bincount(g.src, minlength=g.num_nodes)
    ment = subj + np.bincount(g.dst, minlength=g.num_nodes)
    safe = np.flatnonzero((subj[g.src] >= 2) & (ment[g.src] >= 3)
                          & (ment[g.dst] >= 3))
    dels = rng.choice(safe, size=min(n_del, safe.size), replace=False)
    deletes = [(lab[g.src[i]], prd[g.pred[i]], lab[g.dst[i]])
               for i in dels]
    picks = rng.choice(g.num_edges, size=2 * n_ins, replace=False)
    inserts = [(lab[g.src[i]], prd[g.pred[i]], lab[g.dst[j]])
               for i, j in zip(picks, np.roll(picks, 1))
               if g.pred[i] == g.pred[j]]
    return inserts, deletes


def _oracle(ds, inserts, deletes):
    """From-scratch Dataset on the post-delta triples, in the exact edge
    order apply_delta's incremental path must reproduce."""
    post = ds._post_triples(inserts, deletes)
    return Dataset.from_triples(
        post, literal_objects=ds.literal_forced, variant="rdf_h")


# ------------------------- construction API ----------------------------- #
def test_build_owns_all_derived_state():
    ds = _mk()
    assert ds.version == 0
    assert ds.digest == content_digest(ds.graph)
    assert ds.cache_key == f"{ds.digest}:v0"
    assert ds.ni.d_max == ENGINE_VARIANTS["rdf_h"]["d"]
    assert ds.stats is not None and ds.idmap is not None


def test_engine_accepts_dataset_and_rejects_sidecar_state():
    ds = _mk()
    eng = Engine(ds)
    assert eng.dataset is ds and eng.graph is ds.graph
    with pytest.raises(ValueError, match="Dataset"):
        make_engine(ds, "rdf_h", stats=ds.stats)
    # variant demanding a deeper NI than the dataset carries
    with pytest.raises(ValueError, match="hops"):
        make_engine(ds, "h3")


def test_make_engine_graph_shim_warns_and_matches():
    g = random_graph(n_nodes=100, n_edges=300, n_preds=4, seed=7)
    ds = Dataset.build(g, variant="rdf_h")
    q = random_query(g, size=4, seed=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = make_engine(g, "rdf_h")
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert (legacy.execute(q).result_set()
            == make_engine(ds, "rdf_h").execute(q).result_set())


# --------------------------- csr_patch --------------------------------- #
def test_csr_patch_matches_full_rebuild():
    rng = np.random.default_rng(0)
    g = random_graph(n_nodes=80, n_edges=240, n_preds=4, seed=11)
    from repro.core.graph import _csr
    dels = rng.choice(g.num_edges, size=10, replace=False)
    keep = np.setdiff1d(np.arange(g.num_edges), dels)
    n_ins = 12
    ins_src = rng.integers(0, g.num_nodes, n_ins).astype(np.int32)
    ins_dst = rng.integers(0, g.num_nodes, n_ins).astype(np.int32)
    ins_pred = rng.integers(0, 4, n_ins).astype(np.int32)
    new_src = np.concatenate([g.src[keep], ins_src])
    new_dst = np.concatenate([g.dst[keep], ins_dst])
    new_pred = np.concatenate([g.pred[keep], ins_pred])
    want = _csr(g.num_nodes, new_src, new_dst, new_pred)
    got = csr_patch(g.out_csr, g.num_nodes, 4,
                    g.src[dels], g.dst[dels], g.pred[dels],
                    ins_src, ins_dst, ins_pred)
    assert got is not None
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)


def test_csr_patch_declines_on_pack_overflow():
    g = random_graph(n_nodes=40, n_edges=80, n_preds=2, seed=5)
    huge = 2 ** 33
    out = csr_patch(g.out_csr, huge, huge,
                    g.src[:1], g.dst[:1], g.pred[:1],
                    g.src[:0], g.dst[:0], g.pred[:0])
    assert out is None


# ------------------------ delta == rebuild ------------------------------ #
def test_apply_delta_incremental_matches_rebuild_bitwise():
    ds = _mk(seed=9)
    rng = np.random.default_rng(1)
    inserts, deletes = _recombine_delta(ds, rng, n_ins=5, n_del=5)
    new = ds.apply_delta(inserts, deletes)
    assert new.delta_info["mode"] == "incremental"
    assert new.version == 1 and new.cache_key.endswith(":v1")
    want = _oracle(ds, inserts, deletes)
    assert new.digest == want.digest
    g1, g2 = new.graph, want.graph
    np.testing.assert_array_equal(g1.src, g2.src)
    np.testing.assert_array_equal(g1.dst, g2.dst)
    np.testing.assert_array_equal(g1.pred, g2.pred)
    np.testing.assert_array_equal(g1.pred_kind, g2.pred_kind)
    for csr1, csr2 in ((g1.out_csr, g2.out_csr), (g1.in_csr, g2.in_csr)):
        for a, b in zip(csr1, csr2):
            np.testing.assert_array_equal(a, b)
    s1, s2 = new.stats, want.stats
    np.testing.assert_array_equal(s1.pred_selectivity, s2.pred_selectivity)
    assert s1.coherence == s2.coherence
    assert s1.specialty == s2.specialty
    assert s1.diversity == s2.diversity
    assert s1.literal_selectivity.keys() == s2.literal_selectivity.keys()
    for k in s1.literal_selectivity:
        np.testing.assert_array_equal(s1.literal_selectivity[k],
                                      s2.literal_selectivity[k])
    for key, e2 in want.ni.entries.items():
        e1 = new.ni.entries[key]
        np.testing.assert_array_equal(e1.count, e2.count)
        np.testing.assert_array_equal(e1.overflow, e2.overflow)
        for r in range(e1.ids.shape[0]):
            if not e1.overflow[r]:
                assert (set(e1.ids[r][:e1.count[r]].tolist())
                        == set(e2.ids[r][:e2.count[r]].tolist()))


@pytest.mark.parametrize("policy", ["always", "never", "selective"])
@pytest.mark.parametrize("plan_mode", ["cost", "greedy"])
def test_delta_query_parity_grid(policy, plan_mode):
    """Randomized oracle: engines over apply_delta and over a rebuilt
    Dataset return byte-identical results across check x plan modes."""
    ds = _mk(seed=21, n_nodes=120, n_edges=380)
    rng = np.random.default_rng(7)
    inserts, deletes = _recombine_delta(ds, rng)
    new = ds.apply_delta(inserts, deletes)
    assert new.delta_info["mode"] == "incremental"
    want = _oracle(ds, inserts, deletes)

    def eng(d):
        e = make_engine(d, "rdf_h", impl="ref")
        e.cfg.check_policy = policy
        e.cfg.plan_mode = plan_mode
        return e
    ea, eb = eng(new), eng(want)
    for i in range(4):
        q = random_query(new.graph, size=4, seed=400 + i,
                         n_connection=i % 2, d_c=2)
        ra, rb = ea.execute(q), eb.execute(q)
        assert ra.cols == rb.cols
        np.testing.assert_array_equal(
            np.sort(ra.rows, axis=0) if ra.rows.size else ra.rows,
            np.sort(rb.rows, axis=0) if rb.rows.size else rb.rows)


def test_apply_delta_is_pure_snapshot_isolation():
    """apply_delta returns fresh objects: a query against the OLD
    Dataset after the delta still sees pre-delta results."""
    ds = _mk(seed=13)
    q = random_query(ds.graph, size=4, seed=77)
    before = make_engine(ds, "rdf_h").execute(q).result_set()
    digest0 = ds.digest
    edges0 = ds.graph.num_edges
    rng = np.random.default_rng(3)
    inserts, deletes = _recombine_delta(ds, rng)
    new = ds.apply_delta(inserts, deletes)
    assert new is not ds and new.graph is not ds.graph
    assert ds.version == 0 and ds.digest == digest0
    assert ds.graph.num_edges == edges0
    after_old = make_engine(ds, "rdf_h").execute(q).result_set()
    assert after_old == before
    assert new.digest != digest0


# ------------------------- rebuild fallbacks ---------------------------- #
def test_fallback_new_label():
    ds = _mk()
    new = ds.apply_delta(inserts=[("Zz/new-subject-404",
                                   ds.graph.predicates[0],
                                   ds.graph.labels[0])])
    assert new.delta_info["mode"] == "rebuild"
    assert new.delta_info["reason"] == "new-label"
    assert new.version == 1 and new.touched is None


def test_fallback_churn_threshold():
    ds = _mk()
    g = ds.graph
    lab, prd = g.labels, g.predicates
    picks = np.arange(g.num_edges)
    inserts = [(lab[g.src[i]], prd[g.pred[i]], lab[g.dst[j]])
               for i, j in zip(picks, np.roll(picks, 1))
               if g.pred[i] == g.pred[j]][:100]
    new = ds.apply_delta(inserts=inserts, churn_threshold=0.01)
    assert new.delta_info["mode"] == "rebuild"
    assert new.delta_info["reason"] == "churn"
    # the same delta under a permissive threshold goes incremental and
    # still matches the rebuild bit-for-bit
    inc = ds.apply_delta(inserts=inserts, churn_threshold=1.0)
    assert inc.delta_info["mode"] == "incremental"
    assert inc.digest == new.digest


def test_fallback_label_dropped():
    ds = _mk()
    g = ds.graph
    # delete every edge touching the node with the fewest mentions so
    # its label vanishes (= id renumbering territory)
    ment = (np.bincount(g.src, minlength=g.num_nodes)
            + np.bincount(g.dst, minlength=g.num_nodes))
    ment[ment == 0] = np.iinfo(ment.dtype).max
    victim = int(np.argmin(ment))
    idx = np.flatnonzero((g.src == victim) | (g.dst == victim))
    deletes = [(g.labels[g.src[i]], g.predicates[g.pred[i]],
                g.labels[g.dst[i]]) for i in idx]
    new = ds.apply_delta(deletes=deletes)
    assert new.delta_info["mode"] == "rebuild"
    assert new.delta_info["reason"] in ("label-dropped", "node-kind")
    q = random_query(new.graph, size=3, seed=5)
    want = _oracle(ds, [], deletes)
    assert (make_engine(new, "rdf_h").execute(q).result_set()
            == make_engine(want, "rdf_h").execute(q).result_set())


def test_delete_unknown_triple_is_noop_insert_existing_duplicates():
    ds = _mk()
    g = ds.graph
    new = ds.apply_delta(deletes=[("No/such", "no-pred", "No/where")])
    assert new.graph.num_edges == g.num_edges
    assert new.version == 1
    t0 = (g.labels[g.src[0]], g.predicates[g.pred[0]], g.labels[g.dst[0]])
    dup = ds.apply_delta(inserts=[t0])
    assert dup.graph.num_edges == g.num_edges + 1   # multigraph append


# ---------------------- footprint predicate ----------------------------- #
def test_interval_footprint_hit():
    touched = np.array([5, 17, 40], dtype=np.int64)
    assert interval_footprint_hit(None, touched)          # unknown -> hit
    assert not interval_footprint_hit([], touched)
    assert interval_footprint_hit([(15, 20)], touched)
    assert not interval_footprint_hit([(18, 40)], touched)  # hi exclusive
    assert interval_footprint_hit([(0, 1), (40, 41)], touched)
