"""Serving subsystem: plan cache, fingerprints, batching, calibration.

The core guarantee is *identity*: every serving path — cold vs. warm plan
cache, batched vs. one-at-a-time, calibrated vs. default thresholds —
returns byte-identical result sets to a fresh single-query engine run.
"""
import numpy as np
import pytest

import repro.core.engine as engine_mod
from repro.core import (make_engine, brute_force_match, Thresholds,
                        CostModel, JoinEstimator, ReplayEstimator,
                        QueryStats, ReachCache)
from repro.core.query import QueryTemplate, QueryEdge, ConnectionEdge
from repro.data import random_graph, random_query
from repro.serve import (QueryServer, PlanCache, ShapeBatcher, Calibrator,
                         template_fingerprint, prepare_cached, dataset_key)


# --------------------------- fixtures ---------------------------------- #
@pytest.fixture(scope="module")
def graph():
    return random_graph(n_nodes=120, n_edges=360, n_preds=4,
                        n_literals=30, seed=3)


@pytest.fixture(scope="module")
def pool(graph):
    return [random_query(graph, size=4, seed=10 + i, n_connection=i % 2,
                         d_c=2) for i in range(4)]


def _fresh_results(graph, queries):
    eng = make_engine(graph, "rdf_h", impl="ref")
    return [eng.execute(q).result_set() for q in queries]


def _permute(query, perm):
    """Renumber a template's nodes: original node i becomes perm[i]."""
    inv = {}
    for i, p in enumerate(perm):
        inv[p] = i
    kws = [query.keywords[inv[j]] for j in range(len(perm))]
    return QueryTemplate(
        keywords=kws,
        edges=[QueryEdge(perm[e.src], perm[e.dst], e.pred)
               for e in query.edges],
        connections=[ConnectionEdge(perm[c.src], perm[c.dst], c.max_dist,
                                    c.bidirectional)
                     for c in query.connections])


# ----------------------- canonical fingerprints ------------------------ #
def test_fingerprint_invariant_under_renumbering(graph, pool):
    rng = np.random.default_rng(0)
    for q in pool:
        fp = template_fingerprint(q)
        for _ in range(4):
            perm = rng.permutation(q.num_nodes).tolist()
            assert template_fingerprint(_permute(q, perm)) == fp


def test_fingerprint_distinguishes_templates(graph, pool):
    fps = {template_fingerprint(q) for q in pool}
    assert len(fps) == len(pool)


def test_fingerprint_distinguishes_edge_direction():
    a = QueryTemplate(keywords=["X/", "Y/"], edges=[QueryEdge(0, 1, 2)])
    b = QueryTemplate(keywords=["X/", "Y/"], edges=[QueryEdge(1, 0, 2)])
    assert template_fingerprint(a) != template_fingerprint(b)


def test_fingerprint_bidirectional_connection_symmetric():
    """A bidirectional connection is a symmetric constraint: swapping its
    endpoints must not change the fingerprint (a directed one must)."""
    a = QueryTemplate(keywords=["X/", "Y/"],
                      connections=[ConnectionEdge(0, 1, 3, True)])
    b = QueryTemplate(keywords=["X/", "Y/"],
                      connections=[ConnectionEdge(1, 0, 3, True)])
    assert template_fingerprint(a) == template_fingerprint(b)
    da = QueryTemplate(keywords=["X/", "Y/"],
                       connections=[ConnectionEdge(0, 1, 3, False)])
    db = QueryTemplate(keywords=["X/", "Y/"],
                       connections=[ConnectionEdge(1, 0, 3, False)])
    assert template_fingerprint(da) != template_fingerprint(db)


def test_canonicalize_degenerate_symmetric_template_is_fast():
    """Fully symmetric templates (n! automorphisms) must not blow up the
    individualization search — the branch budget degrades it to greedy,
    which stays deterministic for a given numbering."""
    import time
    n = 10
    q = QueryTemplate(keywords=["A/"] * n)
    t0 = time.perf_counter()
    fp = template_fingerprint(q)
    assert time.perf_counter() - t0 < 2.0
    assert template_fingerprint(q) == fp          # deterministic


def test_canonicalize_symmetric_template_stable():
    """Fully symmetric templates (automorphic nodes) still canonicalize
    identically from any input numbering."""
    base = QueryTemplate(keywords=["A/", "A/", "B/"],
                         edges=[QueryEdge(0, 2, 1), QueryEdge(1, 2, 1)])
    fp = template_fingerprint(base)
    for perm in ([1, 0, 2], [2, 1, 0], [0, 2, 1]):
        assert template_fingerprint(_permute(base, perm)) == fp


def test_permuted_template_hits_cache_and_remaps(graph, pool):
    q = pool[1]
    srv = QueryServer(graph, impl="ref")
    assert srv.query(q).result_set() == _fresh_results(graph, [q])[0]
    perm = list(reversed(range(q.num_nodes)))
    qp = _permute(q, perm)
    # the permuted template shares the cache entry but its result set is
    # expressed in ITS node numbering — compare against a fresh run of qp
    assert srv.query(qp).result_set() == _fresh_results(graph, [qp])[0]
    pc = srv.telemetry()["plan_cache"]
    assert pc["hits"] >= 1 and pc["entries"] == 1


# --------------------------- plan cache -------------------------------- #
def test_plan_cache_lru_eviction():
    cache = PlanCache(max_entries=2)

    class _PQ:
        version = 0
    a, b, c = _PQ(), _PQ(), _PQ()
    cache.put("d", "a", a)
    cache.put("d", "b", b)
    assert cache.get("d", "a") is a       # touch a -> b is now LRU
    cache.put("d", "c", c)
    assert len(cache) == 2 and cache.evictions == 1
    assert cache.get("d", "b") is None    # evicted
    assert cache.get("d", "a") is a and cache.get("d", "c") is c


def test_prepare_cached_revalidates_on_version_change(graph, pool):
    eng = make_engine(graph, "rdf_h", impl="ref")
    cache = PlanCache()
    did = dataset_key(graph)
    q = pool[0]
    pq1, _, hit1 = prepare_cached(eng, q, cache, did, version=0)
    eng.execute_prepared(pq1)             # learn the execution state
    assert not hit1 and pq1.executions == 1
    pq2, _, hit2 = prepare_cached(eng, q, cache, did, version=1)
    assert hit2 and pq2 is pq1
    assert pq2.version == 1               # revalidated in place
    assert cache.revalidations == 1
    # unchanged decision -> learned state survived
    assert pq2.executions == 1


def test_revalidate_flip_resets_learned_state(graph, pool):
    eng = make_engine(graph, "rdf_h", impl="ref")
    eng.cfg.thresholds = Thresholds(tau_iter=0.0, tau_join=0.0,
                                    tau_sel=0.0)   # force check ON
    q = pool[0]
    pq = eng.prepare(q)
    assert pq.use_check
    eng.execute_prepared(pq)
    assert pq.masks is not None and pq.executions == 1
    eng.cfg.thresholds = Thresholds(tau_iter=1e18, tau_join=1e18,
                                    tau_sel=1e18)  # force check OFF
    kept = eng.revalidate(pq, version=1)
    assert not kept and not pq.use_check
    assert pq.masks is None and pq.executions == 0 and pq.join_seq == []
    # and the reset plan still executes correctly
    assert eng.execute_prepared(pq).result_set() == \
        _fresh_results(graph, [q])[0]


def test_reach_cache_lru_bound():
    rc = ReachCache(max_entries=3)
    for i in range(5):
        rc.put_array(i, 1, 1, np.asarray([i], np.int32))
    assert len(rc) == 3 and rc.evictions == 2
    assert rc.get_array(0, 1, 1) is None
    assert rc.get_array(4, 1, 1) is not None


# ---------------------- serving identity grid --------------------------- #
@pytest.mark.parametrize("batching", [False, True])
@pytest.mark.parametrize("calibrate", [False, True])
def test_serving_identity_grid(graph, pool, batching, calibrate):
    """cold pass + warm pass x {batched, serial} x {calibrated, default}:
    result sets byte-identical to a fresh single-query engine."""
    want = _fresh_results(graph, pool)
    srv = QueryServer(graph, impl="ref", batching=batching,
                      calibrate=calibrate)
    stream = pool + pool[::-1] + pool     # repeats in varied order
    refs = want + want[::-1] + want
    futs = srv.submit_many(stream, wait=True)
    for f, ref in zip(futs, refs):
        assert f.result().result_set() == ref
    t = srv.telemetry()
    assert t["plan_cache"]["entries"] == len(pool)
    assert t["plan_cache"]["hits"] >= len(pool)      # repeats hit
    assert t["queries_served"] == len(stream)


def test_warm_execution_skips_planning_and_check(graph, pool, monkeypatch):
    """A warm plan-cache execution never re-enters plan_table_joins /
    plan_connections / decide, and replays cached candidate masks."""
    q = pool[1]                           # has a connection edge
    srv = QueryServer(graph, impl="ref", calibrate=False)
    r_cold = srv.query(q)
    assert not r_cold.stats.cache_hit

    def _boom(*a, **k):
        raise AssertionError("planning re-entered on warm execution")
    monkeypatch.setattr(engine_mod, "plan_table_joins", _boom)
    monkeypatch.setattr(engine_mod, "plan_connections", _boom)
    monkeypatch.setattr(engine_mod, "decide", _boom)
    monkeypatch.setattr(engine_mod, "check_interval_candidates", _boom)
    # warm replays must not re-enter the connection cost model either
    monkeypatch.setattr(engine_mod, "connection_selectivity", _boom)
    monkeypatch.setattr(engine_mod, "endpoint_reach", _boom)
    monkeypatch.setattr(engine_mod, "choose_connection_impl", _boom)
    r_warm = srv.query(q)
    assert r_warm.stats.cache_hit
    assert r_warm.stats.join_retries == 0
    assert r_warm.result_set() == r_cold.result_set()


def test_calibrated_thresholds_never_change_results(graph, pool):
    """Drive the calibrator hard (miscalibrated start) — results must
    stay identical to the default engine on every query."""
    want = _fresh_results(graph, pool)
    srv = QueryServer(graph, impl="ref", calibrate=True,
                      thresholds=Thresholds(tau_iter=0.1, tau_join=0.1,
                                            tau_sel=0.01))
    for _ in range(3):
        for q, ref in zip(pool, want):
            assert srv.query(q).result_set() == ref
    assert srv.calibrator.observed > 0


# ----------------------------- batching -------------------------------- #
def test_shape_batcher_dedups_identical_fingerprints():
    batcher = ShapeBatcher()
    calls = []

    def execute(item):
        calls.append(item)
        return f"r{item}"
    batcher.add(1, "fpA", 64)
    batcher.add(2, "fpA", 64)
    batcher.add(3, "fpB", 64)
    out = dict(batcher.flush(execute))
    assert len(calls) == 2                # one execution per fingerprint
    assert out == {1: "r1", 2: "r1", 3: "r3"}
    t = batcher.telemetry
    assert t.queries == 3 and t.executions == 2 and t.dedup_saved == 1


def test_batched_dedup_still_remaps_columns(graph, pool):
    """Two renumberings of one template submitted in one batch share one
    execution but each future gets its own column mapping."""
    q = pool[1]
    perm = list(reversed(range(q.num_nodes)))
    qp = _permute(q, perm)
    srv = QueryServer(graph, impl="ref", batching=True)
    f1, f2 = srv.submit_many([q, qp], wait=True)
    assert f1.result().result_set() == _fresh_results(graph, [q])[0]
    assert f2.result().result_set() == _fresh_results(graph, [qp])[0]
    assert srv.batcher.telemetry.executions == 1
    assert srv.batcher.telemetry.dedup_saved == 1


def test_failed_bucket_does_not_orphan_other_futures(graph, pool,
                                                     monkeypatch):
    """An execution error resolves only its own futures with the error;
    the rest of the flush still completes."""
    srv = QueryServer(graph, impl="ref", batching=False)
    boom = RuntimeError("engine exploded")
    real = srv.engine.execute_prepared

    def flaky(pq):
        if pq.fingerprint == template_fingerprint(pool[0]):
            raise boom
        return real(pq)
    monkeypatch.setattr(srv.engine, "execute_prepared", flaky)
    f_bad, f_ok = srv.submit_many([pool[0], pool[1]], wait=True)
    assert f_bad.done() and f_ok.done()
    with pytest.raises(RuntimeError, match="engine exploded"):
        f_bad.result()
    assert f_ok.result().result_set() == _fresh_results(graph, [pool[1]])[0]
    assert srv.query_errors == 1
    assert srv.telemetry()["query_errors"] == 1


def test_warm_replay_pins_connection_strategy(graph, pool):
    """The per-edge reach/cross choice recorded by the cold run is
    replayed warm even if the live cost model has moved since, so the
    join-size replay cannot desync."""
    q = pool[1]                           # has a connection edge
    srv = QueryServer(graph, impl="ref", calibrate=False)
    r_cold = srv.query(q)
    assert sum(r_cold.stats.conn_strategies.values()) >= 1
    # shove the cost model to extremes that would flip any auto choice
    srv.engine.cfg.cost_model.reach_scale = 1e9
    srv.engine.cfg.cost_model.cross_scale = 1e-9
    r_warm = srv.query(q)
    assert r_warm.stats.cache_hit
    assert r_warm.stats.conn_strategies == r_cold.stats.conn_strategies
    assert r_warm.stats.join_retries == 0
    assert r_warm.result_set() == r_cold.result_set()


def test_result_future_lazy_flush(graph, pool):
    srv = QueryServer(graph, impl="ref")
    f = srv.submit(pool[0])
    assert not f.done()
    res = f.result()                      # triggers the flush
    assert f.done() and f.latency is not None
    assert res.result_set() == _fresh_results(graph, [pool[0]])[0]


# ---------------------------- calibrator ------------------------------- #
def _mk_stats(**kw):
    qs = QueryStats()
    for k, v in kw.items():
        setattr(qs, k, v)
    return qs


def test_calibrator_join_bias_direction():
    th, cm = Thresholds(), CostModel()
    cal = Calibrator(th, cm, alpha=1.0)
    # estimates 10x too high -> scale shrinks below 1
    cal.observe(_mk_stats(n_estimated_joins=2,
                          join_est_log_bias=2 * np.log(10.0)))
    assert cm.join_est_scale < 1.0
    # estimates 10x too low -> scale grows above 1 (and is clipped)
    for _ in range(20):
        cal.observe(_mk_stats(n_estimated_joins=1,
                              join_est_log_bias=-np.log(1000.0)))
    assert 1.0 < cm.join_est_scale <= Calibrator.SCALE_BOUND


def test_calibrator_tau_sel_separates_observed_selectivities():
    from repro.core.planner import PlanDecision

    def plan(sel):
        return PlanDecision(use_check=True, complex_query=True,
                            max_selectivity=sel, est_iterations=1e6,
                            est_join_product=1e12)
    th, cm = Thresholds(tau_sel=0.01), CostModel()
    cal = Calibrator(th, cm)
    # selectivity 4.0 failed to prune -> tau_sel jumps past it
    cal.observe(_mk_stats(used_check=True, candidates_before=100,
                          candidates_after=99, plan=plan(4.0)))
    assert th.tau_sel > 4.0
    assert cal.version == 1
    # selectivity 12.0 pruned hard -> tau_sel drops below it
    cal.observe(_mk_stats(used_check=True, candidates_before=100,
                          candidates_after=10, plan=plan(12.0)))
    assert 4.0 < th.tau_sel < 12.0
    # warm repeats are not new evidence
    v = cal.version
    cal.observe(_mk_stats(used_check=True, cache_hit=True,
                          candidates_before=100, candidates_after=99,
                          plan=plan(4.0)))
    assert cal.version == v


def test_calibrator_ignores_warm_observations_entirely():
    """Warm replays are the cold run's observation over again — no EWMA
    may move on them (a hot template would dominate by repetition)."""
    th, cm = Thresholds(), CostModel()
    cal = Calibrator(th, cm, alpha=1.0)
    cal.observe(_mk_stats(cache_hit=True, n_estimated_joins=2,
                          join_est_log_bias=5.0, conn_est_pairs=100.0,
                          conn_connected_pairs=1, conn_reach_pairs=5,
                          conn_est_reach_pairs=500.0))
    assert (cm.join_est_scale, cm.conn_sel_scale, cm.reach_scale) \
        == (1.0, 1.0, 1.0)
    assert cal.version == 0


def test_cross_impl_edges_do_not_accrue_conn_predictions(graph, pool):
    """The cross path never measures connected/reach pairs, so it must
    not contribute predictions either — otherwise every cross edge looks
    like 'predicted N, observed 0' and poisons conn_sel_scale."""
    q = pool[1]                           # has a connection edge
    eng = make_engine(graph, "rdf_h", impl="ref")
    eng.cfg.connection_impl = "cross"
    qs = eng.execute(q).stats
    assert sum(qs.conn_strategies.values()) >= 1
    assert qs.conn_est_pairs == 0.0
    assert qs.conn_est_reach_pairs == 0.0
    eng2 = make_engine(graph, "rdf_h", impl="ref")
    eng2.cfg.connection_impl = "reach"
    qs2 = eng2.execute(q).stats
    assert qs2.conn_est_pairs > 0.0


def test_calibrator_join_scale_converges_to_full_correction():
    """The recorded bias is measured on already-scaled estimates; the
    calibrator must divide the applied scale back out, or a raw c-fold
    over-estimate converges to 1/sqrt(c) instead of 1/c."""
    th, cm = Thresholds(), CostModel()
    cal = Calibrator(th, cm, alpha=1.0)
    c = 4.0                               # raw model over-estimates 4x
    for _ in range(10):
        # bias as the engine would record it: raw bias + applied scale
        bias = np.log(c) + np.log(cm.join_est_scale)
        cal.observe(_mk_stats(n_estimated_joins=1, join_est_log_bias=bias))
    assert np.isclose(cm.join_est_scale, 1.0 / c, rtol=1e-6)


def test_calibrator_ignores_policy_forced_checks():
    """check_policy='always' runs the check with no decide() decision
    (plan=None): no τ evidence, no version bump."""
    th, cm = Thresholds(), CostModel()
    cal = Calibrator(th, cm)
    cal.observe(_mk_stats(used_check=True, plan=None,
                          candidates_before=100, candidates_after=100))
    assert th.tau_sel == Thresholds().tau_sel and cal.version == 0


def test_server_does_not_mutate_caller_thresholds(graph, pool):
    th = Thresholds(tau_iter=0.1, tau_join=0.1, tau_sel=0.01)
    srv = QueryServer(graph, impl="ref", calibrate=True, thresholds=th)
    for _ in range(2):
        for q in pool:
            srv.query(q)
    assert (th.tau_iter, th.tau_join, th.tau_sel) == (0.1, 0.1, 0.01)
    assert srv.calibrator.thresholds is not th


def test_dataset_key_is_content_based():
    ga = random_graph(n_nodes=60, n_edges=150, seed=1)
    gb = random_graph(n_nodes=60, n_edges=150, seed=2)   # same shape
    assert dataset_key(ga) != dataset_key(gb)
    assert dataset_key(ga) == dataset_key(ga)


def test_server_rejects_cfg_plus_thresholds(graph):
    from repro.core import EngineConfig
    with pytest.raises(ValueError, match="cfg"):
        QueryServer(graph, cfg=EngineConfig(),
                    thresholds=Thresholds(tau_sel=0.01))
    with pytest.raises(ValueError, match="cfg"):
        QueryServer(graph, cfg=EngineConfig(), impl="ref")


def test_calibrator_bounds_anchor_to_reference_defaults():
    from repro.core.planner import PlanDecision
    plan = PlanDecision(use_check=True, complex_query=True,
                        max_selectivity=1e9, est_iterations=1e6,
                        est_join_product=1e12)
    th = Thresholds(tau_iter=1.0, tau_join=1.0, tau_sel=0.01)
    cal = Calibrator(th, CostModel())
    ref = Thresholds()
    for _ in range(100):
        cal.observe(_mk_stats(used_check=True, plan=plan,
                              candidates_before=100,
                              candidates_after=100))
    # separator evidence says tau > 1e9, but the cage anchored at the
    # reference defaults caps it
    assert th.tau_sel == ref.tau_sel * Calibrator.TAU_BOUND


# --------------------------- replay estimator --------------------------- #
def test_replay_estimator_replays_then_falls_back():
    base = JoinEstimator(None, {0: 10, 1: 10})
    # recorded entries are (rows, executed capacity) pairs
    rep = ReplayEstimator(base, [(7, 64), (42, 128)])
    e = rep.edge_join(5, None, True, 3)
    assert e == 7 and e.cap == 64
    e = rep.table_join(4, 4, (0,))
    assert e == 42 and e.cap == 128
    # cursor exhausted -> analytic fallback (no pinned capacity)
    fb = rep.table_join(4, 4, (0,))
    assert fb == base.table_join(4, 4, (0,))
    assert getattr(fb, "cap", None) is None
    # bare-int legacy entries still replay as plain row counts
    rep2 = ReplayEstimator(base, [9])
    assert rep2.table_join(4, 4, (0,)) == 9


# ------------------------- QueryStats.to_dict --------------------------- #
def test_query_stats_to_dict_schema_pinned():
    expected = {
        "used_check", "truncated", "cache_hit", "result_cache_hit",
        "candidates_before", "candidates_after",
        "prepare_time", "check_time", "match_time", "conn_time",
        "total_time", "join_work", "dtree_work",
        "join_retries", "n_estimated_joins",
        "join_est_rows", "join_actual_rows",
        "join_est_log_err", "join_est_log_bias",
        "plan_mode", "sorts_performed", "sorts_avoided",
        "plan_cost", "greedy_plan_cost",
        "conn_reach_pairs", "conn_connected_pairs",
        "conn_endpoint_rows", "conn_endpoint_distinct",
        "conn_est_pairs", "conn_est_reach_pairs",
        "budget_checks", "degraded_steps",
        "join_strategies", "conn_strategies", "plan",
    }
    d = QueryStats().to_dict()
    assert set(d) == expected
    import json
    json.dumps(d)                         # JSON-serializable as-is


def test_governor_telemetry_schema_pinned(graph):
    """The governor section of QueryServer.telemetry() is a consumed
    wire format (dashboards, BENCH json): pin its flat key set, the
    breaker/rung-memory sub-schemas, and JSON-serializability."""
    import json
    from repro.serve import GovernorConfig
    srv = QueryServer(graph, impl="ref", governor=GovernorConfig())
    srv.query(random_query(graph, size=3, seed=50))
    gov = srv.telemetry()["governor"]
    assert set(gov) == {
        "limits", "shed_submit", "shed_flush", "budget_exceeded",
        "degraded_queries", "degraded_by_rung", "exhausted",
        "transient_retries", "transient_recoveries", "ladder_entries",
        "breaker", "rung_memory", "snapshot",
    }
    assert set(gov["breaker"]) == {
        "tracked", "trips", "denials", "probes", "recoveries",
        "evictions", "open", "half_open",
    }
    assert set(gov["rung_memory"]) == {
        "tracked", "hits", "jumps", "probes", "probe_recoveries",
        "probe_failures", "chronic", "evictions",
    }
    assert gov["snapshot"] is None      # nothing saved/restored yet
    json.dumps(gov)
    # after a snapshot round-trip the age/version block appears
    import tempfile, os
    path = os.path.join(tempfile.mkdtemp(), "t.snap")
    srv.save_snapshot(path)
    snap = srv.telemetry()["governor"]["snapshot"]
    assert set(snap) == {"action", "format_version", "age_s"}
    assert snap["action"] == "saved" and snap["age_s"] >= 0.0
    json.dumps(snap)


def test_query_stats_to_dict_from_execution(graph, pool):
    import json
    eng = make_engine(graph, "rdf_h", impl="ref")
    d = eng.execute(pool[1]).stats.to_dict()
    json.dumps(d)
    assert d["plan"] is not None and "max_selectivity" in d["plan"]
    assert d["join_strategies"] and isinstance(d["conn_strategies"], dict)


# ------------------------- brute-force anchor --------------------------- #
def test_server_matches_brute_force(graph):
    q = random_query(graph, size=4, seed=77, n_connection=1, d_c=2)
    want = {tuple(t[c] for c in sorted(range(q.num_nodes)))
            for t in brute_force_match(graph, q)}
    srv = QueryServer(graph, impl="ref")
    assert srv.query(q).result_set() == want    # cold
    assert srv.query(q).result_set() == want    # warm replay
