"""Property-based tests (hypothesis) for the core invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (IDMap, build_ni_index, brute_force_match,
                        make_engine, vertex_cover_2approx)
from repro.data import random_graph, random_query


@st.composite
def small_graph(draw):
    seed = draw(st.integers(0, 10_000))
    n = draw(st.integers(10, 60))
    e = draw(st.integers(n, 4 * n))
    return random_graph(n_nodes=n, n_edges=e, n_preds=3,
                        n_literals=max(3, n // 5), seed=seed)


@settings(max_examples=15, deadline=None)
@given(small_graph(), st.text(alphabet="Rl/it 0123456789", max_size=4))
def test_idmap_prefix_interval(g, prefix):
    """Every label in [lo,hi) starts with the prefix; none outside do."""
    idm = IDMap(g)
    lo, hi = idm.interval(prefix)
    labels = g.labels
    inside = labels[lo:hi]
    assert all(str(s).startswith(prefix) for s in inside)
    outside = np.concatenate([labels[:lo], labels[hi:]])
    assert not any(str(s).startswith(prefix) for s in outside)


@settings(max_examples=10, deadline=None)
@given(small_graph(), st.integers(1, 3))
def test_ni_index_exact_khop(g, d_max):
    """NI entry at distance d == exact BFS d-hop frontier (unless overflow)."""
    ni = build_ni_index(g, d_max=d_max)
    indptr, nbr, _ = g.out_csr
    rng = np.random.default_rng(0)
    for n in rng.integers(0, g.num_nodes, size=min(10, g.num_nodes)):
        # BFS with exact distances.  A self-loop makes a node its own
        # 1-hop neighbor (shortest path of length >= 1), matching the
        # index semantics.
        dist = {int(n): 0}
        frontier = [int(n)]
        self_loop = int(n) in set(
            int(v) for v in nbr[indptr[n]:indptr[n + 1]])
        for d in range(1, d_max + 1):
            nxt = []
            for u in frontier:
                for v in nbr[indptr[u]:indptr[u + 1]]:
                    v = int(v)
                    if v not in dist:
                        dist[v] = d
                        nxt.append(v)
            frontier = nxt
            want = sorted(v for v, dd in dist.items() if dd == d)
            if d == 1 and self_loop:
                want = sorted(set(want) | {int(n)})
            e = ni.entries[d]
            if e.overflow[n]:
                continue
            got = sorted(int(x) for x in e.ids[n] if x >= 0)
            assert got == want, (n, d)


@settings(max_examples=10, deadline=None)
@given(small_graph())
def test_vertex_cover_covers_all_edges(g):
    vc = vertex_cover_2approx(g)
    assert all(vc[s] or vc[d] for s, d in zip(g.src, g.dst))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 500), st.integers(3, 5))
def test_pruning_soundness_and_equivalence(seed, size):
    """All engine variants return exactly the brute-force match set —
    i.e. signature pruning never removes a true match (soundness) and the
    full pipeline adds none (completeness)."""
    g = random_graph(n_nodes=50, n_edges=150, n_preds=3, n_literals=15,
                     seed=seed)
    q = random_query(g, size=size, seed=seed * 7 + 1)
    want = {tuple(t[c] for c in sorted(range(q.num_nodes)))
            for t in brute_force_match(g, q)}
    for variant in ("stwig+", "spath_ni2", "h2", "h3", "hvc"):
        got = make_engine(g, variant, impl="ref").execute(q).result_set()
        assert got == want, variant


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 300))
def test_connection_edge_equivalence(seed):
    g = random_graph(n_nodes=40, n_edges=130, n_preds=2, n_literals=10,
                     seed=seed)
    q = random_query(g, size=4, seed=seed + 11, n_connection=1, d_c=3)
    if not q.connections:
        return
    want = {tuple(t[c] for c in sorted(range(q.num_nodes)))
            for t in brute_force_match(g, q)}
    for variant in ("stwig+", "h3"):
        got = make_engine(g, variant, impl="ref").execute(q).result_set()
        assert got == want, variant
