"""QueryServer: the user-facing serving API.

Wraps one Engine over one immutable dataset with:

  * a plan cache (`plan_cache.PlanCache`, LRU) of PreparedQuery objects
    keyed by canonical template fingerprint — repeat templates skip
    planning and recompilation;
  * a server-owned LRU-bounded reach cache installed on the engine, so
    connection edges of *different* queries sharing endpoint nodes reuse
    reach sets;
  * shape-batched execution (`batching.ShapeBatcher`): submitted queries
    are bucketed by (fingerprint, pow2 capacity class) at flush time,
    each bucket executed once, results fanned out (renumbered clients get
    their own column mapping);
  * online calibration (`calibrate.Calibrator`) of the τ thresholds and
    cost-model constants from the executed queries' own stats;
  * resource governance (`governor`): admission control with load
    shedding, per-execution deadline/row/capacity budgets, a degradation
    ladder that retries failed or over-budget queries on exact-but-
    cheaper settings, and a per-fingerprint circuit breaker that
    quarantines repeatedly failing templates;
  * latency/cache telemetry: p50/p99 overall and split cold vs. warm,
    plan/reach cache hit rates, batch dedup factor, governor counters,
    and a rollup of QueryStats.to_dict() sums.

Submission is future-based: `submit` enqueues and returns a
`ResultFuture`; execution happens at `flush()` (called explicitly, by
`submit_many(..., wait=True)`, or lazily by the first `.result()`).
`query()` is the synchronous one-call convenience.

Failure containment invariant: a flush NEVER leaves a submitted future
unresolved and NEVER lets one query's failure leak into another's
result.  Every future resolves with either an exact result or its own
typed error; `ResultFuture.result()` re-raises serving errors as-is and
wraps engine exceptions in `QueryError` carrying the template
fingerprint and the failing phase (prepare vs. execute vs.
degraded-retry) with the original as __cause__.
"""
from __future__ import annotations

import random
import time
from collections import deque
from dataclasses import replace

from ..core.engine import (Engine, EngineConfig, MatchResult, QueryStats,
                           make_engine)
from ..core.connectivity import ReachCache
from ..core.dataset import Dataset
from ..core.matching import _pow2
from ..core.query import QueryTemplate
from ..obs.trace import NULL_TRACER
from ..obs.metrics import MetricsRegistry
from ..obs.explain import render_explain
from .plan_cache import (PlanCache, canonicalize, dataset_key,  # noqa: F401
                         prepare_cached, remap_result)
from .result_cache import ResultCache
from .batching import ShapeBatcher
from .calibrate import Calibrator
from .governor import (Governor, GovernorConfig, BudgetExceeded,
                       ServingError, RejectedError, QuarantinedError,
                       QueryError, IncompleteFlushError,
                       DegradationExhausted)


class ResultFuture:
    """Handle for one submitted query.  `result()` drains the server's
    pending batch if this future is still unresolved (lazy flush), so
    async submission needs no background thread.  An execution failure
    resolves the future with the error (re-raised by `result()`) instead
    of aborting the flush — one poisoned bucket cannot orphan the rest
    of the batch.

    A failed future is terminal: the error is stored at resolution time,
    so repeated `.result()` calls re-raise it without draining the
    server again."""

    def __init__(self, server: "QueryServer", query: QueryTemplate):
        self._server = server
        self.query = query
        self._result: MatchResult | None = None
        self._error: BaseException | None = None
        self._phase: str = "execute"        # phase the stored error hit
        self.fingerprint: str | None = None
        self.latency: float | None = None   # seconds, set at resolution
        self.cache_hit: bool = False        # plan-cache hit at flush time
        self.trace_id: str | None = None    # obs trace id (None when off)

    def done(self) -> bool:
        return self._result is not None or self._error is not None

    def result(self) -> MatchResult:
        if not self.done():
            self._server.flush()
            if not self.done():
                # flush() guarantees resolution; if that invariant ever
                # breaks, surface a typed terminal error instead of
                # asserting — and never re-drain on the next call
                self._fail(IncompleteFlushError(
                    "flush completed without resolving this future"),
                    phase="flush")
        if self._error is not None:
            err = self._error
            if isinstance(err, ServingError):
                raise err
            raise QueryError(self.fingerprint, self._phase, err,
                             trace_id=self.trace_id) from err
        return self._result

    def _resolve(self, result: MatchResult, latency: float) -> None:
        self._result = result
        self.latency = latency

    def _fail(self, error: BaseException, phase: str = "execute") -> None:
        self._error = error
        self._phase = phase


class QueryServer:
    """Serve template queries over one `repro.core.Dataset`.

    Construct from a Dataset (`QueryServer(Dataset.build(graph, ...))`);
    passing a bare graph still works as a deprecated shim that wraps it
    in a version-0 Dataset.  `apply_delta` moves the server to the next
    dataset version in place, migrating warm state (see its docstring).
    `result_cache_size > 0` enables the exact-repeat ResultCache: a
    repeated template on an unchanged dataset version is answered from
    stored rows without any engine execution.

    calibrate=False freezes the thresholds/cost model at their configured
    values (A/B baseline); batching=False executes submissions one at a
    time in arrival order (still through the plan cache).  `cfg`, when
    given, is the complete engine configuration — `variant` is then
    ignored and passing thresholds/impl alongside raises.  `governor`
    (a GovernorConfig) enables resource governance: admission control,
    per-execution budgets, the degradation ladder, and the circuit
    breaker; None (the default) keeps the ungoverned behavior.

    `tracer` (an obs.trace.Tracer) enables per-query tracing: every
    submission gets a trace id and its submit/prepare/governor/engine
    spans, exportable via `tracer.export_chrome(path)`; None keeps the
    ~zero-cost NULL_TRACER.  `slow_query_s` retains any query slower
    than the threshold in a bounded slow-query log with its rendered
    EXPLAIN (`slow_queries()`).  `latency_window` is accepted for
    API compatibility; latency percentiles now come from the metrics
    registry's O(1)-memory log-bucketed histograms."""

    def __init__(self, dataset, variant: str = "rdf_h", ni=None, stats=None,
                 thresholds=None, cfg: EngineConfig | None = None,
                 impl: str = "auto",
                 plan_cache_size: int = 64,
                 reach_cache_size: int = 200_000,
                 reach_cache_bytes: int | None = None,
                 result_cache_size: int = 0,
                 result_cache_bytes: int | None = None,
                 calibrate: bool = True, batching: bool = True,
                 latency_window: int = 4096,
                 governor: GovernorConfig | None = None,
                 tracer=None, slow_query_s: float | None = None,
                 slow_log_max: int = 32):
        if cfg is not None:
            # cfg is the complete engine configuration: silently dropping
            # a tuned thresholds/impl next to it would corrupt A/B runs
            if thresholds is not None or impl != "auto":
                raise ValueError("pass either cfg or thresholds/impl, "
                                 "not both (cfg already carries them)")
            if isinstance(dataset, Dataset):
                if ni is not None or stats is not None:
                    raise ValueError("pass ni/stats via the Dataset, "
                                     "not alongside it")
                self.engine = Engine(dataset, cfg)
            else:
                if ni is None:
                    from ..core.ni_index import build_ni_index
                    ni = build_ni_index(dataset, d_max=cfg.d_check)
                self.engine = Engine(dataset, ni, cfg, stats=stats)
        else:
            self.engine = make_engine(dataset, variant, ni=ni, stats=stats,
                                      thresholds=thresholds, impl=impl)
        self.dataset = self.engine.dataset
        # the calibrator mutates Thresholds/CostModel in place so every
        # later plan sees calibrated values — give the engine private
        # copies first, so a caller-supplied (possibly shared or tuned)
        # object is never corrupted by this server's online calibration
        if calibrate:
            self.engine.cfg.thresholds = replace(self.engine.cfg.thresholds)
            self.engine.cfg.cost_model = replace(self.engine.cfg.cost_model)
        self.calibrator = (Calibrator(self.engine.cfg.thresholds,
                                      self.engine.cfg.cost_model)
                           if calibrate else None)
        self.plan_cache = PlanCache(plan_cache_size)
        # the result cache is opt-in (size 0 disables): serving rows
        # without execution also skips calibration observations and the
        # governor, which a tuning-focused deployment may not want
        self.result_cache = (ResultCache(result_cache_size,
                                         result_cache_bytes)
                             if result_cache_size else None)
        self.engine.reach_cache = ReachCache(max_entries=reach_cache_size,
                                             max_bytes=reach_cache_bytes)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.engine.tracer = self.tracer
        self.metrics = MetricsRegistry()
        self.slow_query_s = slow_query_s
        self._slow_log: deque = deque(maxlen=int(slow_log_max))
        self.batcher = ShapeBatcher(metrics=self.metrics)
        self.batching = batching
        self.governor = Governor(governor) if governor is not None else None
        self.dataset_id = self.dataset.cache_key
        self._pending: list[ResultFuture] = []
        self._rollup: dict = {}
        self.queries_served = 0
        self.query_errors = 0
        self.queries_shed = 0
        # (action, format version, monotonic stamp) of the last
        # save_snapshot/restore_snapshot, for telemetry age reporting
        self._snapshot_meta: tuple[str, int, float] | None = None

    # ------------------------------------------------------------------ #
    def submit(self, query: QueryTemplate) -> ResultFuture:
        f = ResultFuture(self, query)
        f.trace_id = self.tracer.start()
        gov = self.governor
        with self.tracer.segment("submit", f.trace_id) as sp:
            if gov is not None and gov.cfg.max_pending is not None \
                    and len(self._pending) >= gov.cfg.max_pending:
                # admission control: shed at submit time, before any
                # engine work — the future resolves immediately with
                # RejectedError
                gov.shed_submit += 1
                self.queries_shed += 1
                self.metrics.counter("queries_shed").inc()
                err = RejectedError(
                    f"pending queue full ({gov.cfg.max_pending}), "
                    "load shed at admission")
                err.trace_id = f.trace_id
                f._fail(err, phase="admit")
                sp.set(outcome="shed", pending=len(self._pending))
                self.tracer.finish(f.trace_id)
                return f
            self._pending.append(f)
            sp.set(outcome="admitted", pending=len(self._pending))
        return f

    def submit_many(self, queries, wait: bool = False) -> list[ResultFuture]:
        futures = [self.submit(q) for q in queries]
        if wait:
            self.flush()
        return futures

    def query(self, query: QueryTemplate) -> MatchResult:
        """Synchronous single-query convenience."""
        return self.submit(query).result()

    # ------------------------------------------------------------------ #
    def _version(self) -> int:
        return self.calibrator.version if self.calibrator is not None else 0

    def flush(self) -> None:
        """Execute every pending submission (batched or serial).  Every
        popped future is resolved by the time this returns — with a
        result, a typed serving error, or its own engine error — even if
        the flush body itself raises unexpectedly."""
        pending, self._pending = self._pending, []
        if not pending:
            return
        try:
            self._flush_body(pending)
        finally:
            # failure-containment backstop: a bug escaping the per-future
            # error handling must not leave siblings hanging (a hung
            # future would re-drain the server from .result() forever)
            for f in pending:
                if not f.done():
                    f._fail(IncompleteFlushError(
                        "flush aborted before this future ran"),
                        phase="flush")
                    self.query_errors += 1

    def _flush_body(self, pending: list[ResultFuture]) -> None:
        t_flush = time.perf_counter()
        # canonicalize + plan-cache lookup per future; a failure here
        # resolves that future with the error and spares the rest
        prepped = []
        for f in pending:
            t0 = time.perf_counter()
            failed = None
            with self.tracer.segment("prepare", f.trace_id) as sp:
                try:
                    pq, order, hit = prepare_cached(self.engine, f.query,
                                                    self.plan_cache,
                                                    self.dataset_id,
                                                    self._version())
                except Exception as e:       # noqa: BLE001
                    failed = e
                    sp.set(outcome="error", error_type=type(e).__name__)
                else:
                    f.cache_hit = hit
                    f.fingerprint = pq.fingerprint
                    sp.set(outcome="ok", cache_hit=hit,
                           fingerprint=(pq.fingerprint or "")[:40])
            prep_s = time.perf_counter() - t0
            if failed is not None:
                f._fail(failed, phase="prepare")
                self.query_errors += 1
                self.metrics.counter("query_errors").inc()
                self.tracer.finish(f.trace_id)
                continue
            self.metrics.histogram("prepare_s").observe(prep_s)
            if self.result_cache is not None:
                cached = self.result_cache.get(self.dataset_id,
                                               pq.fingerprint)
                if cached is not None:
                    # exact repeat on the current dataset version: serve
                    # the stored canonical rows without any engine work
                    # (no batcher, no governor, no calibration observe —
                    # nothing executed, so there is nothing to learn from)
                    cols, rows = cached
                    qs = QueryStats(used_check=pq.use_check,
                                    cache_hit=True, result_cache_hit=True,
                                    plan=pq.decision)
                    qs.candidates_before = sum(pq.cand_sizes.values())
                    self.metrics.counter("result_cache_hits").inc()
                    self._observe_stats(qs)
                    self._finish(f, MatchResult(cols=cols, rows=rows,
                                                stats=qs),
                                 order, time.perf_counter() - t0)
                    continue
            prepped.append((f, pq, order, prep_s))
        stopper = self._flush_stopper(t_flush)
        if self.batching:
            for f, pq, order, prep_s in prepped:
                cap_class = _pow2(sum(pq.cand_sizes.values()))
                self.batcher.add((f, pq, order, prep_s),
                                 pq.fingerprint, cap_class)
            # the batcher pairs every member of a bucket with the SAME
            # result tuple (one execution, fanned out); the first future
            # seen per result object is the representative whose trace
            # carries the execute spans — the rest get a "fanout"
            # segment pointing at it
            rep_trace: dict[int, str | None] = {}
            for (f, pq, order, prep_s), res in \
                    self.batcher.flush(self._execute_item,
                                       should_stop=stopper):
                if isinstance(res, BaseException):
                    # bucket shed by the flush wall budget: the batcher
                    # pairs unexecuted items with the stop exception
                    self._finish(f, res, order, prep_s)
                    continue
                out, lat = res
                rid = id(res)
                if rid in rep_trace:
                    with self.tracer.segment("fanout", f.trace_id) as sp:
                        sp.set(executed_in=rep_trace[rid])
                else:
                    rep_trace[rid] = f.trace_id
                self._finish(f, out, order, prep_s + lat)
        else:
            for f, pq, order, prep_s in prepped:
                shed = stopper() if stopper is not None else None
                if shed is not None:
                    self._finish(f, shed, order, prep_s)
                    continue
                res, lat = self._execute_item((f, pq, order, prep_s))
                self._finish(f, res, order, prep_s + lat)

    def _flush_stopper(self, t0: float):
        """None, or a callable returning None (continue) / a
        RejectedError (shed the rest of this flush) once the per-flush
        wall budget is spent."""
        gov = self.governor
        if gov is None or gov.cfg.flush_wall_s is None:
            return None

        def stop():
            spent = time.perf_counter() - t0
            if spent > gov.cfg.flush_wall_s:
                gov.shed_flush += 1
                return RejectedError(
                    f"flush wall budget ({gov.cfg.flush_wall_s:.3f}s) "
                    f"exhausted after {spent:.3f}s, tail shed")
            return None

        return stop

    # ------------------------------------------------------------------ #
    def _execute_item(self, item):
        """Execute one bucket representative.  Returns (MatchResult |
        exception, latency) — failures are values so that one bad bucket
        resolves only its own futures with the error.  The circuit
        breaker gates the execution per template fingerprint; the
        degradation ladder runs inside `_execute_governed`."""
        f, pq, _, _ = item
        gov = self.governor
        t0 = time.perf_counter()
        with self.tracer.segment("execute", f.trace_id,
                                 fingerprint=(pq.fingerprint or "")[:40]
                                 ) as seg:
            if gov is not None:
                with self.tracer.span("breaker") as sp:
                    verdict = gov.breaker.admit(pq.fingerprint,
                                                now=gov.clock())
                    sp.set(verdict=verdict)
                if verdict == "deny":
                    seg.set(outcome="quarantined")
                    return QuarantinedError(
                        pq.fingerprint or "?",
                        gov.breaker.retry_after(pq.fingerprint,
                                                now=gov.clock())), \
                        time.perf_counter() - t0
            try:
                res = self._execute_governed(pq)
            except Exception as e:           # noqa: BLE001
                if gov is not None:
                    gov.breaker.record(pq.fingerprint, ok=False,
                                       now=gov.clock())
                seg.set(outcome="error", error_type=type(e).__name__)
                return e, time.perf_counter() - t0
            lat = time.perf_counter() - t0
            if gov is not None:
                gov.breaker.record(pq.fingerprint, ok=True,
                                   now=gov.clock())
            if self.calibrator is not None:
                self.calibrator.observe(res.stats)
            self._observe_stats(res.stats)
            if self.result_cache is not None and not res.stats.truncated \
                    and not res.stats.degraded_steps:
                # only clean primary results are cached: truncated rows
                # are not THE answer, and degraded-rung results came from
                # a sibling plan we don't want to pin as the repeat answer
                self.result_cache.put(self.dataset_id, pq.fingerprint,
                                      res.cols, res.rows,
                                      bool(pq.query.connections), pq.iv)
            seg.set(outcome="ok", warm=bool(res.stats.cache_hit),
                    rows=res.count)
        return res, lat

    def _execute_governed(self, pq) -> MatchResult:
        """Primary execution under the configured budget; on any failure
        (budget abort, capacity blow-up, kernel error) walk the
        degradation ladder instead of failing outright.

        Rung memory routes repeat traffic first: a fingerprint known to
        be degraded jumps straight to its last-good rung (no primary
        attempt, no intermediate rungs); once per re-probe interval the
        primary config is probed instead — success claws full quality
        back, failure falls straight back to the remembered rung.
        Probes skip the transient retry (at most ONE primary attempt
        per interval is the contract)."""
        gov = self.governor
        if gov is None:
            return self.engine.execute_prepared(pq)
        mem = gov.rung_memory
        if mem is not None and pq.fingerprint is not None:
            with self.tracer.span("route") as sp:
                verdict, rung = mem.route(pq.fingerprint, gov.clock())
                sp.set(verdict=verdict, rung=rung)
            if verdict == "jump":
                return self._degraded_retry(pq, None, start=rung)
            if verdict == "probe":
                try:
                    res = self._attempt_primary(pq, retry=False)
                except Exception as primary:     # noqa: BLE001
                    if isinstance(primary, BudgetExceeded):
                        gov.budget_exceeded += 1
                    mem.record_probe_failed(pq.fingerprint)
                    return self._degraded_retry(pq, primary, start=rung)
                mem.record_primary_ok(pq.fingerprint)
                return res
        try:
            return self._attempt_primary(pq, retry=gov.cfg.transient_retry)
        except Exception as primary:             # noqa: BLE001
            if isinstance(primary, BudgetExceeded):
                gov.budget_exceeded += 1
            return self._degraded_retry(pq, primary)

    def _attempt_primary(self, pq, retry: bool) -> MatchResult:
        """One primary execution under a fresh budget; with `retry`,
        a failure that is NOT budget/capacity-typed gets exactly one
        jittered-backoff retry on the primary config with a FRESH
        prepare and a fresh budget — a transient kernel blip costs
        neither a ladder walk, nor a degraded-result stamp, nor a
        breaker strike.  A budget abort is deterministic (re-running
        can only re-blow the same bound), so it goes straight to the
        ladder; so does a repeat failure."""
        gov = self.governor
        budget = gov.make_budget()
        try:
            with self.tracer.span("primary") as sp:
                res = (self.engine.execute_prepared(pq) if budget is None
                       else self.engine.execute_prepared(pq, budget=budget))
                sp.set(outcome="ok")
                return res
        except BudgetExceeded:
            raise
        except Exception:                        # noqa: BLE001
            if not retry:
                raise
            gov.transient_retries += 1
            with self.tracer.span("transient_retry") as sp:
                backoff = gov.cfg.retry_backoff_s
                if backoff > 0:
                    time.sleep(backoff * (1.0 + gov.cfg.retry_jitter
                                          * random.random()))
                fresh = self.engine.prepare(pq.query,
                                            fingerprint=pq.fingerprint,
                                            version=pq.version)
                budget = gov.make_budget()
                res = (self.engine.execute_prepared(fresh)
                       if budget is None
                       else self.engine.execute_prepared(fresh,
                                                         budget=budget))
                gov.transient_recoveries += 1
                sp.set(outcome="recovered")
                return res

    def _degraded_retry(self, pq, primary: BaseException | None,
                        start: str | None = None) -> MatchResult:
        """Walk the ladder: each rung gets a sibling engine with the
        rung's exact-but-cheaper config, a FRESH prepare (the primary
        plan may be the thing that failed) and a fresh budget.  The plan
        cache is never polluted with degraded plans, and degraded stats
        carry `degraded_steps` so the Calibrator ignores them.  Raises
        DegradationExhausted (primary error as __cause__) if every rung
        fails.

        `start` (a rung name from rung memory) begins the walk at that
        rung — intermediate rungs are never attempted on a jump; an
        unknown name falls back to a full walk.  `primary is None`
        marks a memory jump (no primary failure happened), so it is
        counted as a jump, not a ladder entry."""
        gov = self.governor
        mem = gov.rung_memory
        attempts: list[tuple[str, BaseException]] = \
            [] if primary is None else [("primary", primary)]
        steps: list[str] = []
        ladder = gov.cfg.ladder
        first = 0
        if start is not None:
            for i, rung in enumerate(ladder):
                if rung.name == start:
                    first = i
                    break
        if primary is not None:
            gov.ladder_entries += 1
        with self.tracer.span(
                "ladder",
                entry="jump" if primary is None else "failure",
                start=start) as lsp:
            for rung in ladder[first:]:
                steps.append(rung.name)
                with self.tracer.span("rung", rung=rung.name) as rsp:
                    eng = self.engine.with_config(
                        rung.apply(self.engine.cfg, gov.cfg))
                    budget = gov.make_budget()
                    try:
                        dpq = eng.prepare(pq.query,
                                          fingerprint=pq.fingerprint)
                        res = (eng.execute_prepared(dpq)
                               if budget is None
                               else eng.execute_prepared(dpq,
                                                         budget=budget))
                    except Exception as e:   # noqa: BLE001
                        attempts.append((rung.name, e))
                        rsp.set(outcome="failed",
                                error_type=type(e).__name__)
                        continue
                    rsp.set(outcome="ok")
                res.stats.degraded_steps = list(steps)
                gov.note_degraded(rung.name)
                if mem is not None and pq.fingerprint is not None:
                    if mem.record_degraded(pq.fingerprint, rung.name,
                                           gov.clock()):
                        self._note_chronic(pq)
                lsp.set(outcome="degraded", rung=rung.name)
                return res
            lsp.set(outcome="exhausted")
        gov.exhausted += 1
        if mem is not None and pq.fingerprint is not None:
            # even the remembered rung failed: forget it so the next
            # request re-walks (the fault moved out from under us)
            mem.clear(pq.fingerprint)
        err = DegradationExhausted(pq.fingerprint, attempts,
                                   trace_id=self.tracer.current_trace_id())
        if primary is not None:
            raise err from primary
        raise err

    def _note_chronic(self, pq) -> None:
        """A fingerprint stayed degraded past `chronic_after`: surface
        it for RE-PLANNING instead of re-trying — drop its cached plan,
        tell the Calibrator, and forget the rung so the next request
        plans fresh against the calibrated thresholds."""
        self.plan_cache.drop(self.dataset_id, pq.fingerprint)
        if self.calibrator is not None:
            self.calibrator.note_chronic(pq.fingerprint)
        self.governor.rung_memory.clear(pq.fingerprint)

    def _finish(self, f: ResultFuture, res, order, latency: float) -> None:
        if isinstance(res, BaseException):
            phase = ("degraded-retry" if isinstance(res,
                                                    DegradationExhausted)
                     else "execute")
            if isinstance(res, ServingError) and res.trace_id is None:
                # stamp the trace id so the raised error names the trace
                # holding its rung-attempt spans (shed errors shared
                # across futures keep the first future's id)
                res.trace_id = f.trace_id
            f._fail(res, phase=phase)
            self.query_errors += 1
            self.metrics.counter("query_errors").inc()
            self.tracer.finish(f.trace_id)
            return
        warm = bool(res.stats.cache_hit)
        f._resolve(remap_result(res, order), latency)
        self.queries_served += 1
        m = self.metrics
        m.counter("queries_served").inc()
        m.histogram("latency_s").observe(latency)
        m.histogram("latency_warm_s" if warm
                    else "latency_cold_s").observe(latency)
        m.histogram("result_rows").observe(res.count)
        if self.slow_query_s is not None and latency >= self.slow_query_s:
            m.counter("slow_queries").inc()
            pq = (self.plan_cache.peek(self.dataset_id, f.fingerprint)
                  if f.fingerprint is not None else None)
            self._slow_log.append({
                "fingerprint": f.fingerprint,
                "trace_id": f.trace_id,
                "latency_s": latency,
                "warm": warm,
                "explain": (None if pq is None else
                            render_explain(pq,
                                           self.engine.cfg.thresholds)),
            })
        self.tracer.finish(f.trace_id)

    def _observe_stats(self, qs) -> None:
        for k, v in qs.to_dict().items():
            if isinstance(v, bool):
                self._rollup[k] = self._rollup.get(k, 0) + int(v)
            elif isinstance(v, (int, float)):
                self._rollup[k] = self._rollup.get(k, 0) + v
            elif isinstance(v, dict) and k in ("join_strategies",
                                               "conn_strategies"):
                d = self._rollup.setdefault(k, {})
                for kk, vv in v.items():
                    d[kk] = d.get(kk, 0) + vv

    # ------------------------------------------------------------------ #
    def apply_delta(self, inserts=(), deletes=(),
                    churn_threshold: float = 0.05) -> dict:
        """Absorb a triple delta into the served dataset WITHOUT a cold
        start: pending work is flushed, `Dataset.apply_delta` produces
        the next immutable dataset version (incremental when the delta is
        small, full rebuild past the churn threshold), and every warm
        structure is migrated rather than thrown away:

          * device-resident NI tensors and the bloom prefilter carry over
            for every NI entry the incremental path left untouched
            (shared by object identity with the old dataset);
          * reach-cache entries survive unless their stored reach set (or
            seed node) intersects the delta's edge endpoints; a rebuild
            clears the cache;
          * plan-cache entries are re-keyed to the new versioned dataset
            id, their learned state kept when the delta provably missed
            their candidate intervals AND the recomputed §4.3 decision is
            unchanged (otherwise the entry stays cached but its learned
            masks/orders reset); a rebuild drops all plans — node ids may
            have been renumbered;
          * result-cache entries survive only with an untouched interval
            footprint and no connection edges (see ResultCache.migrate);
          * governor rung memory and breaker state are fingerprint-keyed
            and survive as-is (worst case the next probe re-learns).

        The previous Dataset object is untouched — anything still holding
        it keeps getting pre-delta answers (snapshot isolation).  Returns
        an info dict: the delta mode/reason plus per-cache migration
        counts."""
        self.flush()
        old_ds, old_engine = self.dataset, self.engine
        old_id = self.dataset_id
        new_ds = old_ds.apply_delta(inserts, deletes,
                                    churn_threshold=churn_threshold)
        # same cfg object: the Calibrator keeps mutating the live
        # thresholds/cost model the new engine plans with
        eng = Engine(new_ds, old_engine.cfg)
        eng.tracer = self.tracer
        for (sign, d), dev in old_engine._dev_cache.items():
            if new_ds.ni.entries.get(sign * d) is \
                    old_ds.ni.entries.get(sign * d):
                eng._dev_cache[(sign, d)] = dev
        if new_ds.ni.entries.get(1) is old_ds.ni.entries.get(1):
            eng._bloom = old_engine._bloom
        rc = old_engine.reach_cache
        if new_ds.touched is None:
            reach_dropped = rc.clear()
        else:
            reach_dropped = rc.invalidate_delta(new_ds.delta_endpoints)
        eng.reach_cache = rc
        if self.calibrator is not None:
            self.calibrator.note_delta()
        new_version = self._version()
        new_id = new_ds.cache_key
        plans_kept = plans_invalidated = 0
        if new_ds.touched is None:
            _, plans_dropped = self.plan_cache.migrate(
                old_id, new_id, revalidate=lambda pq: False)
        else:
            touched = new_ds.touched

            def _reval(pq):
                nonlocal plans_kept, plans_invalidated
                ok = eng.revalidate_delta(pq, touched)
                ok = eng.revalidate(pq, new_version) and ok
                self.plan_cache.revalidations += 1
                if ok:
                    plans_kept += 1
                else:
                    plans_invalidated += 1
                    self.plan_cache.invalidations += 1
                return True

            _, plans_dropped = self.plan_cache.migrate(old_id, new_id,
                                                       revalidate=_reval)
        results_kept = results_dropped = 0
        if self.result_cache is not None:
            results_kept, results_dropped = self.result_cache.migrate(
                old_id, new_id, new_ds.touched)
        self.dataset = new_ds
        self.dataset_id = new_id
        self.engine = eng
        self.metrics.counter("deltas_applied").inc()
        self.metrics.gauge("dataset_version").set(new_ds.version)
        info = dict(new_ds.delta_info)
        info.update({
            "version": new_ds.version,
            "dataset_id": new_id,
            "plans_kept": plans_kept,
            "plans_invalidated": plans_invalidated,
            "plans_dropped": plans_dropped,
            "reach_dropped": reach_dropped,
            "results_kept": results_kept,
            "results_dropped": results_dropped,
        })
        return info

    # ------------------------------------------------------------------ #
    def save_snapshot(self, path) -> dict:
        """Serialize every piece of learned serving state (calibrator
        separators/scales, governor rung memory + breaker, plan-cache
        entries with their learned join/connection plans) to `path`.
        Returns the snapshot manifest.  See repro.serve.snapshot."""
        from .snapshot import save_snapshot as _save
        manifest = _save(self, path)
        self._snapshot_meta = ("saved", manifest["format_version"],
                               time.monotonic())
        return manifest

    def restore_snapshot(self, path, max_age_s: float | None = None) -> dict:
        """Load learned serving state saved by `save_snapshot`.  A
        corrupt, version-mismatched, stale, or wrong-dataset snapshot
        raises SnapshotError and leaves this server untouched (a clean
        cold start) — never a wrong or stale answer.  Returns the
        restored manifest."""
        from .snapshot import restore_snapshot as _restore
        manifest = _restore(self, path, max_age_s=max_age_s)
        self._snapshot_meta = ("restored", manifest["format_version"],
                               time.monotonic())
        return manifest

    def _snapshot_info(self) -> dict | None:
        if self._snapshot_meta is None:
            return None
        action, version, stamp = self._snapshot_meta
        return {"action": action, "format_version": version,
                "age_s": time.monotonic() - stamp}

    # ------------------------------------------------------------------ #
    def explain(self, query: QueryTemplate) -> str:
        """Rendered EXPLAIN report for `query`'s plan: the §4.3 check
        decision with its τ terms, per-node candidate intervals, D-tree
        decomposition, learned join/connection orders, and the recorded
        join sequence (estimated vs. observed rows).  Uses the cached
        plan when present (without perturbing LRU order or hit/miss
        telemetry); a never-seen template is prepared — and cached — so
        EXPLAIN shows exactly the plan the next execution will run."""
        _, _, fingerprint = canonicalize(query)
        pq = self.plan_cache.peek(self.dataset_id, fingerprint)
        if pq is None:
            pq, _, _ = prepare_cached(self.engine, query, self.plan_cache,
                                      self.dataset_id, self._version())
        return render_explain(pq, self.engine.cfg.thresholds)

    def slow_queries(self) -> list[dict]:
        """The bounded slow-query log (oldest first): one dict per query
        slower than `slow_query_s`, carrying fingerprint, trace id,
        latency, warm/cold, and the rendered EXPLAIN of the plan that
        ran it."""
        return list(self._slow_log)

    def telemetry(self) -> dict:
        """One JSON-serializable snapshot of everything the server knows
        about itself: latency percentiles (seconds), cache hit rates,
        batching dedup, calibration state, governance counters, the
        metrics-registry snapshot, and the QueryStats rollup."""
        rc = self.engine.reach_cache
        gov_t = None
        if self.governor is not None:
            gov_t = self.governor.snapshot()
            gov_t["snapshot"] = self._snapshot_info()
        m = self.metrics
        m.gauge("pending").set(len(self._pending))
        m.gauge("plan_cache_entries").set(len(self.plan_cache))
        m.gauge("reach_cache_bytes").set(rc.total_bytes)
        lat = m.histogram("latency_s")
        cold = m.histogram("latency_cold_s")
        warm = m.histogram("latency_warm_s")
        out = {
            "queries_served": self.queries_served,
            "query_errors": self.query_errors,
            "queries_shed": self.queries_shed,
            "latency": {
                "p50": lat.percentile(50),
                "p99": lat.percentile(99),
                "cold_p50": cold.percentile(50),
                "cold_p99": cold.percentile(99),
                "warm_p50": warm.percentile(50),
                "warm_p99": warm.percentile(99),
                "n_cold": cold.count,
                "n_warm": warm.count,
            },
            "metrics": m.snapshot(),
            "dataset": {
                "id": self.dataset_id,
                "digest": self.dataset.digest,
                "version": self.dataset.version,
                "nodes": self.dataset.num_nodes,
                "edges": self.dataset.num_edges,
            },
            "plan_cache": self.plan_cache.snapshot(),
            "result_cache": (None if self.result_cache is None
                             else self.result_cache.snapshot()),
            "reach_cache": {
                "entries": len(rc), "hits": rc.hits, "misses": rc.misses,
                "evictions": rc.evictions,
                "bytes": rc.total_bytes, "max_bytes": rc.max_bytes,
            },
            "batch": self.batcher.telemetry.snapshot(),
            "calibration": (None if self.calibrator is None
                            else self.calibrator.snapshot()),
            "governor": gov_t,
            "stats_rollup": dict(self._rollup),
        }
        return out
