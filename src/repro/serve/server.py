"""QueryServer: the user-facing serving API.

Wraps one Engine over one immutable dataset with:

  * a plan cache (`plan_cache.PlanCache`, LRU) of PreparedQuery objects
    keyed by canonical template fingerprint — repeat templates skip
    planning and recompilation;
  * a server-owned LRU-bounded reach cache installed on the engine, so
    connection edges of *different* queries sharing endpoint nodes reuse
    reach sets;
  * shape-batched execution (`batching.ShapeBatcher`): submitted queries
    are bucketed by (fingerprint, pow2 capacity class) at flush time,
    each bucket executed once, results fanned out (renumbered clients get
    their own column mapping);
  * online calibration (`calibrate.Calibrator`) of the τ thresholds and
    cost-model constants from the executed queries' own stats;
  * latency/cache telemetry: p50/p99 overall and split cold vs. warm,
    plan/reach cache hit rates, batch dedup factor, and a rollup of
    QueryStats.to_dict() sums.

Submission is future-based: `submit` enqueues and returns a
`ResultFuture`; execution happens at `flush()` (called explicitly, by
`submit_many(..., wait=True)`, or lazily by the first `.result()`).
`query()` is the synchronous one-call convenience.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import replace

import numpy as np

from ..core.engine import Engine, EngineConfig, MatchResult, make_engine
from ..core.connectivity import ReachCache
from ..core.matching import _pow2
from ..core.query import QueryTemplate
from .plan_cache import PlanCache, dataset_key, prepare_cached, remap_result
from .batching import ShapeBatcher
from .calibrate import Calibrator


class ResultFuture:
    """Handle for one submitted query.  `result()` drains the server's
    pending batch if this future is still unresolved (lazy flush), so
    async submission needs no background thread.  An execution failure
    resolves the future with the error (re-raised by `result()`) instead
    of aborting the flush — one poisoned bucket cannot orphan the rest
    of the batch."""

    def __init__(self, server: "QueryServer", query: QueryTemplate):
        self._server = server
        self.query = query
        self._result: MatchResult | None = None
        self._error: BaseException | None = None
        self.latency: float | None = None   # seconds, set at resolution
        self.cache_hit: bool = False        # plan-cache hit at flush time

    def done(self) -> bool:
        return self._result is not None or self._error is not None

    def result(self) -> MatchResult:
        if not self.done():
            self._server.flush()
        if self._error is not None:
            raise self._error
        assert self._result is not None, "flush did not resolve future"
        return self._result

    def _resolve(self, result: MatchResult, latency: float) -> None:
        self._result = result
        self.latency = latency

    def _fail(self, error: BaseException) -> None:
        self._error = error


class QueryServer:
    """Serve template queries over one RDF graph.

    calibrate=False freezes the thresholds/cost model at their configured
    values (A/B baseline); batching=False executes submissions one at a
    time in arrival order (still through the plan cache).  `cfg`, when
    given, is the complete engine configuration — `variant` is then
    ignored and passing thresholds/impl alongside raises."""

    def __init__(self, graph, variant: str = "rdf_h", ni=None, stats=None,
                 thresholds=None, cfg: EngineConfig | None = None,
                 impl: str = "auto",
                 plan_cache_size: int = 64,
                 reach_cache_size: int = 200_000,
                 calibrate: bool = True, batching: bool = True,
                 latency_window: int = 4096):
        if cfg is not None:
            # cfg is the complete engine configuration: silently dropping
            # a tuned thresholds/impl next to it would corrupt A/B runs
            if thresholds is not None or impl != "auto":
                raise ValueError("pass either cfg or thresholds/impl, "
                                 "not both (cfg already carries them)")
            if ni is None:
                from ..core.ni_index import build_ni_index
                ni = build_ni_index(graph, d_max=cfg.d_check)
            self.engine = Engine(graph, ni, cfg, stats=stats)
        else:
            self.engine = make_engine(graph, variant, ni=ni, stats=stats,
                                      thresholds=thresholds, impl=impl)
        # the calibrator mutates Thresholds/CostModel in place so every
        # later plan sees calibrated values — give the engine private
        # copies first, so a caller-supplied (possibly shared or tuned)
        # object is never corrupted by this server's online calibration
        if calibrate:
            self.engine.cfg.thresholds = replace(self.engine.cfg.thresholds)
            self.engine.cfg.cost_model = replace(self.engine.cfg.cost_model)
        self.calibrator = (Calibrator(self.engine.cfg.thresholds,
                                      self.engine.cfg.cost_model)
                           if calibrate else None)
        self.plan_cache = PlanCache(plan_cache_size)
        self.engine.reach_cache = ReachCache(max_entries=reach_cache_size)
        self.batcher = ShapeBatcher()
        self.batching = batching
        self.dataset_id = dataset_key(graph)
        self._pending: list[ResultFuture] = []
        self._lat_all: deque = deque(maxlen=latency_window)
        self._lat_cold: deque = deque(maxlen=latency_window)
        self._lat_warm: deque = deque(maxlen=latency_window)
        self._rollup: dict = {}
        self.queries_served = 0
        self.query_errors = 0

    # ------------------------------------------------------------------ #
    def submit(self, query: QueryTemplate) -> ResultFuture:
        f = ResultFuture(self, query)
        self._pending.append(f)
        return f

    def submit_many(self, queries, wait: bool = False) -> list[ResultFuture]:
        futures = [self.submit(q) for q in queries]
        if wait:
            self.flush()
        return futures

    def query(self, query: QueryTemplate) -> MatchResult:
        """Synchronous single-query convenience."""
        return self.submit(query).result()

    # ------------------------------------------------------------------ #
    def _version(self) -> int:
        return self.calibrator.version if self.calibrator is not None else 0

    def flush(self) -> None:
        """Execute every pending submission (batched or serial)."""
        pending, self._pending = self._pending, []
        if not pending:
            return
        # canonicalize + plan-cache lookup per future; a failure here
        # resolves that future with the error and spares the rest
        prepped = []
        for f in pending:
            t0 = time.perf_counter()
            try:
                pq, order, hit = prepare_cached(self.engine, f.query,
                                                self.plan_cache,
                                                self.dataset_id,
                                                self._version())
            except Exception as e:           # noqa: BLE001
                f._fail(e)
                self.query_errors += 1
                continue
            f.cache_hit = hit
            prepped.append((f, pq, order, time.perf_counter() - t0))
        if self.batching:
            for f, pq, order, prep_s in prepped:
                cap_class = _pow2(sum(pq.cand_sizes.values()))
                self.batcher.add((f, pq, order, prep_s),
                                 pq.fingerprint, cap_class)
            for (f, pq, order, prep_s), (res, lat) in \
                    self.batcher.flush(self._execute_item):
                self._finish(f, res, order, prep_s + lat)
        else:
            for f, pq, order, prep_s in prepped:
                res, lat = self._execute_item((f, pq, order, prep_s))
                self._finish(f, res, order, prep_s + lat)

    def _execute_item(self, item):
        """Execute one bucket representative.  Returns (MatchResult |
        exception, latency) — failures are values so that one bad bucket
        resolves only its own futures with the error."""
        _, pq, _, _ = item
        t0 = time.perf_counter()
        try:
            res = self.engine.execute_prepared(pq)
        except Exception as e:               # noqa: BLE001
            return e, time.perf_counter() - t0
        lat = time.perf_counter() - t0
        if self.calibrator is not None:
            self.calibrator.observe(res.stats)
        self._observe_stats(res.stats)
        return res, lat

    def _finish(self, f: ResultFuture, res, order, latency: float) -> None:
        if isinstance(res, BaseException):
            f._fail(res)
            self.query_errors += 1
            return
        f._resolve(remap_result(res, order), latency)
        self.queries_served += 1
        self._lat_all.append(latency)
        (self._lat_warm if res.stats.cache_hit
         else self._lat_cold).append(latency)

    def _observe_stats(self, qs) -> None:
        for k, v in qs.to_dict().items():
            if isinstance(v, bool):
                self._rollup[k] = self._rollup.get(k, 0) + int(v)
            elif isinstance(v, (int, float)):
                self._rollup[k] = self._rollup.get(k, 0) + v
            elif isinstance(v, dict) and k in ("join_strategies",
                                               "conn_strategies"):
                d = self._rollup.setdefault(k, {})
                for kk, vv in v.items():
                    d[kk] = d.get(kk, 0) + vv

    # ------------------------------------------------------------------ #
    @staticmethod
    def _pct(lat, q) -> float:
        return float(np.percentile(np.asarray(lat), q)) if lat else 0.0

    def telemetry(self) -> dict:
        """One JSON-serializable snapshot of everything the server knows
        about itself: latency percentiles (seconds), cache hit rates,
        batching dedup, calibration state, and the QueryStats rollup."""
        rc = self.engine.reach_cache
        out = {
            "queries_served": self.queries_served,
            "query_errors": self.query_errors,
            "latency": {
                "p50": self._pct(self._lat_all, 50),
                "p99": self._pct(self._lat_all, 99),
                "cold_p50": self._pct(self._lat_cold, 50),
                "cold_p99": self._pct(self._lat_cold, 99),
                "warm_p50": self._pct(self._lat_warm, 50),
                "warm_p99": self._pct(self._lat_warm, 99),
                "n_cold": len(self._lat_cold),
                "n_warm": len(self._lat_warm),
            },
            "plan_cache": self.plan_cache.snapshot(),
            "reach_cache": {
                "entries": len(rc), "hits": rc.hits, "misses": rc.misses,
                "evictions": rc.evictions,
            },
            "batch": self.batcher.telemetry.snapshot(),
            "calibration": (None if self.calibrator is None
                            else self.calibrator.snapshot()),
            "stats_rollup": dict(self._rollup),
        }
        return out
