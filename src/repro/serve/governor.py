"""Resource governance and failure containment for the serving tier.

Worst-case subgraph matching is exponential in the template, and the
serving layer makes pruning/plan decisions online — so a mispredicted
plan, an adversarial template, or a capacity blow-up must be *bounded*,
*shed*, *degraded*, or *quarantined* instead of stalling every query
behind it in the flush.  Four mechanisms compose:

  * `Budget` — a cooperative per-execution budget (wall deadline, total
    materialized join rows, largest single-table capacity) threaded
    through `Engine.execute_prepared` and checked at every join and
    connection-edge boundary.  A blown budget raises `BudgetExceeded`
    carrying the partial QueryStats, so telemetry survives the abort.
  * admission control (in `QueryServer`) — a bounded pending queue that
    sheds submissions with `RejectedError` when full, plus a per-flush
    wall budget that sheds the *tail* of an over-long flush instead of
    letting one flush absorb the server.
  * the degradation ladder — a failed or over-budget query is retried on
    exact-but-cheaper settings, one rung at a time: skip the signature
    check, force the greedy plan, force the nested/cross join impls
    (avoiding the sort-merge kernel and reach-gather machinery
    entirely), and finally re-run under a reduced row cap with the
    truncation explicitly flagged.  Every rung except the last returns
    exact results; `QueryStats.degraded_steps` records the walk.
  * a per-fingerprint `CircuitBreaker` — templates that keep failing
    even through the ladder are quarantined (fail-fast
    `QuarantinedError`) for a cooldown, then probed half-open; a
    successful probe closes the breaker, a failed one re-opens it with
    exponential backoff.  One poisoned template cannot re-poison every
    flush.
  * a per-fingerprint `RungMemory` — once a template has succeeded on a
    ladder rung, repeat traffic jumps straight to that rung instead of
    re-walking the ladder from the top; a periodic primary re-probe
    (breaker-style half-open, but for *quality* rather than admission)
    claws full quality back when the underlying fault clears, and a
    template that stays degraded past `chronic_after` consecutive
    requests is surfaced for re-planning (plan-cache drop + calibrator
    notice) instead of being re-tried forever.

Both per-fingerprint state holders are bounded (`max_tracked` LRU) and
serializable (`save_state`/`load_state`) so the learned failure
knowledge survives a warm restart — see `repro.serve.snapshot`.
Cross-process clocks don't compare, so saved deadlines are *relative*
remaining durations, rebased against the restoring process's clock.

The engine depends on none of this: `Budget` is duck-typed (the engine
just calls ``budget.checkpoint(...)``), so ``repro.core`` never imports
``repro.serve``.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field, replace


# ---------------------------------------------------------------------- #
# Typed serving errors.
# ---------------------------------------------------------------------- #
class ServingError(RuntimeError):
    """Base class of every typed serving-layer failure.  Subclasses carry
    their own context and are raised as-is by ResultFuture.result().

    `trace_id` is the query trace id (repro.obs.trace) when tracing was
    enabled — the server stamps it at resolution time, so every chaos-
    suite failure is attributable to one exported trace."""

    trace_id: str | None = None


class RejectedError(ServingError):
    """Load shed: the pending queue was full at submit time, or the
    per-flush wall budget ran out before this query's bucket ran."""


class QuarantinedError(ServingError):
    """The query's template fingerprint is quarantined by the circuit
    breaker; it was failed fast without touching the engine."""

    def __init__(self, fingerprint: str, retry_after_s: float):
        self.fingerprint = fingerprint
        self.retry_after_s = float(retry_after_s)
        super().__init__(
            f"template {fingerprint[:24]!r}... quarantined, "
            f"retry after {retry_after_s:.2f}s")


class QueryError(ServingError):
    """Wrapper re-raised by ResultFuture.result() around non-serving
    exceptions, adding the query fingerprint and the phase that failed
    (prepare vs. execute vs. degraded-retry).  The original exception is
    the __cause__ (``raise ... from``)."""

    def __init__(self, fingerprint: str | None, phase: str,
                 cause: BaseException, trace_id: str | None = None):
        self.fingerprint = fingerprint
        self.phase = phase
        self.trace_id = trace_id
        fp = "?" if fingerprint is None else fingerprint[:24] + "..."
        tr = "" if trace_id is None else f" [trace {trace_id}]"
        super().__init__(f"query {fp} failed during {phase}{tr}: {cause}")


class IncompleteFlushError(ServingError):
    """A flush completed without resolving this future (an internal
    serving bug surfaced as a typed error instead of a hang: the future
    is permanently failed, so repeated .result() calls never re-drain
    the server)."""


class DegradationExhausted(ServingError):
    """The primary execution and every ladder rung failed.  `attempts`
    lists (rung name, error) in order; the primary error is __cause__.
    `attempt_history` is the rendered multi-line walk (one line per
    attempted rung with its full error text) and `trace_id` ties the
    failure to its exported trace."""

    def __init__(self, fingerprint: str | None,
                 attempts: list[tuple[str, BaseException]],
                 trace_id: str | None = None):
        self.fingerprint = fingerprint
        self.attempts = attempts
        self.trace_id = trace_id
        self.attempt_history = "\n".join(
            f"  {name}: {type(err).__name__}: {err}"
            for name, err in attempts)
        steps = ", ".join(f"{name}: {type(err).__name__}"
                          for name, err in attempts)
        tr = "" if trace_id is None else f" [trace {trace_id}]"
        super().__init__(f"degradation ladder exhausted ({steps}){tr}")


class BudgetExceeded(Exception):
    """A cooperative budget check failed mid-execution.

    reason: 'deadline' | 'rows' | 'capacity'; `stats` is the partial
    QueryStats of the aborted execution (telemetry survives the abort),
    `phase` the pipeline phase that tripped the check."""

    def __init__(self, reason: str, phase: str, elapsed_s: float,
                 rows: int, stats=None):
        self.reason = reason
        self.phase = phase
        self.elapsed_s = float(elapsed_s)
        self.rows = int(rows)
        self.stats = stats
        super().__init__(
            f"budget exceeded ({reason}) in phase {phase!r} after "
            f"{elapsed_s * 1e3:.1f}ms / {rows} rows")


# ---------------------------------------------------------------------- #
# Cooperative per-execution budget.
# ---------------------------------------------------------------------- #
@dataclass
class Budget:
    """Per-execution resource budget, checked cooperatively by the engine
    at join and connection-edge boundaries (``checkpoint``).

    deadline_s bounds wall time for ONE execution attempt; max_rows
    bounds the cumulative materialized join output rows; max_capacity
    bounds the largest single table capacity the execution may allocate.
    All three are optional — None disables that bound."""
    deadline_s: float | None = None
    max_rows: int | None = None
    max_capacity: int | None = None
    started: float = field(default_factory=time.perf_counter)
    rows: int = 0
    checks: int = 0

    def elapsed(self) -> float:
        return time.perf_counter() - self.started

    def checkpoint(self, phase: str, rows: int = 0, cap: int = 0,
                   stats=None) -> None:
        """Record `rows` newly materialized rows / a table of capacity
        `cap` and raise BudgetExceeded if any bound is now blown."""
        self.checks += 1
        self.rows += int(rows)
        if self.deadline_s is not None:
            el = self.elapsed()
            if el > self.deadline_s:
                raise BudgetExceeded("deadline", phase, el, self.rows,
                                     stats=stats)
        if self.max_rows is not None and self.rows > self.max_rows:
            raise BudgetExceeded("rows", phase, self.elapsed(), self.rows,
                                 stats=stats)
        if self.max_capacity is not None and cap > self.max_capacity:
            raise BudgetExceeded("capacity", phase, self.elapsed(),
                                 self.rows, stats=stats)


# ---------------------------------------------------------------------- #
# Degradation ladder.
# ---------------------------------------------------------------------- #
@dataclass(frozen=True)
class LadderRung:
    """One exact-but-cheaper retry configuration.  `overrides` are
    EngineConfig field replacements (cumulative by construction — each
    rung's dict includes every earlier rung's overrides); `truncate`
    additionally caps max_rows at GovernorConfig.degraded_row_cap, the
    only rung that may return non-exact (explicitly flagged truncated)
    results."""
    name: str
    overrides: dict
    truncate: bool = False

    def apply(self, cfg, gov_cfg: "GovernorConfig"):
        kw = dict(self.overrides)
        if self.truncate:
            cap = gov_cfg.degraded_row_cap
            kw["max_rows"] = cap if cfg.max_rows is None \
                else min(cfg.max_rows, cap)
        return replace(cfg, **kw)


def default_ladder() -> tuple[LadderRung, ...]:
    """skip signature check -> greedy plan -> forced nested/cross impls
    -> reduced row cap.  Rung 3 avoids the sort-merge kernel, the join
    expand, and the reach-gather machinery entirely (nested joins +
    cross-product connection edges), so a fault localized to any of
    those still has an exact escape hatch."""
    skip = {"check_policy": "never"}
    greedy = {**skip, "plan_mode": "greedy"}
    simple = {**greedy, "join_impl": "nested", "connection_impl": "cross"}
    return (
        LadderRung("skip_check", skip),
        LadderRung("greedy_plan", greedy),
        LadderRung("force_simple_impls", simple),
        LadderRung("truncate", simple, truncate=True),
    )


# ---------------------------------------------------------------------- #
# Per-fingerprint circuit breaker.
# ---------------------------------------------------------------------- #
class CircuitBreaker:
    """closed -> (threshold consecutive failures) -> open (fail-fast for
    cooldown) -> half-open (one probe) -> closed on success, re-open with
    exponentially backed-off cooldown on failure.

    Failures are counted per template fingerprint and only for queries
    that failed *through* the degradation ladder — a template served
    exactly by a degraded rung is a success.  `now` is injectable for
    deterministic tests; values are clamped to a high-water mark so a
    clock passed backwards can never re-open a recovered breaker or
    resurrect an expired cooldown.

    The per-fingerprint state dict is bounded: at `max_tracked` entries
    the least-recently-touched *closed, fully recovered* entry is
    evicted (open/half-open entries are never evicted — quarantine
    state must not be forgettable under fingerprint churn)."""

    def __init__(self, threshold: int = 3, cooldown_s: float = 5.0,
                 backoff: float = 2.0, max_cooldown_s: float = 300.0,
                 max_tracked: int = 1024):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self.backoff = float(backoff)
        self.max_cooldown_s = float(max_cooldown_s)
        self.max_tracked = int(max_tracked)
        self._st: OrderedDict[str, dict] = OrderedDict()
        self._hwm = 0.0                 # high-water mark of observed `now`
        self.trips = 0
        self.denials = 0
        self.probes = 0
        self.recoveries = 0
        self.evictions = 0

    def _now(self, now: float | None) -> float:
        now = time.monotonic() if now is None else float(now)
        self._hwm = max(self._hwm, now)
        return self._hwm

    def _touch(self, fp: str) -> None:
        self._st.move_to_end(fp)

    def _evict(self) -> None:
        """Drop LRU closed entries until under `max_tracked`.  Entries
        with residual failure counts go only after fully-recovered ones;
        open/half-open entries are never dropped."""
        if len(self._st) <= self.max_tracked:
            return
        for want_clean in (True, False):
            for fp in list(self._st):
                st = self._st[fp]
                if st["state"] != "closed":
                    continue
                if want_clean and st["failures"] != 0:
                    continue
                del self._st[fp]
                self.evictions += 1
                if len(self._st) <= self.max_tracked:
                    return

    def admit(self, fp: str, now: float | None = None) -> str:
        """'allow' | 'deny' | 'probe' for one execution of `fp`."""
        st = self._st.get(fp)
        if st is None or st["state"] == "closed":
            return "allow"
        self._touch(fp)
        now = self._now(now)
        if st["state"] == "open":
            if now < st["until"]:
                self.denials += 1
                return "deny"
            st["state"] = "half_open"
        self.probes += 1
        return "probe"

    def retry_after(self, fp: str, now: float | None = None) -> float:
        st = self._st.get(fp)
        if st is None or st["state"] != "open":
            return 0.0
        return max(0.0, st["until"] - self._now(now))

    def record(self, fp: str, ok: bool, now: float | None = None) -> None:
        st = self._st.setdefault(fp, {"state": "closed", "failures": 0,
                                      "cooldown": self.cooldown_s,
                                      "until": 0.0})
        self._touch(fp)
        self._evict()
        if ok:
            if st["state"] != "closed":
                self.recoveries += 1
            st.update(state="closed", failures=0, cooldown=self.cooldown_s)
            return
        st["failures"] += 1
        if st["state"] == "half_open":
            # failed probe: re-open, back the cooldown off
            st["cooldown"] = min(st["cooldown"] * self.backoff,
                                 self.max_cooldown_s)
            st["failures"] = 0
        elif st["failures"] < self.threshold:
            return
        else:
            st["failures"] = 0
        st["state"] = "open"
        st["until"] = self._now(now) + st["cooldown"]
        self.trips += 1

    def state(self, fp: str) -> str:
        st = self._st.get(fp)
        return "closed" if st is None else st["state"]

    def snapshot(self) -> dict:
        by_state: dict[str, int] = {}
        for st in self._st.values():
            by_state[st["state"]] = by_state.get(st["state"], 0) + 1
        return {
            "tracked": len(self._st),
            "trips": self.trips,
            "denials": self.denials,
            "probes": self.probes,
            "recoveries": self.recoveries,
            "evictions": self.evictions,
            "open": by_state.get("open", 0),
            "half_open": by_state.get("half_open", 0),
        }

    def save_state(self, now: float | None = None) -> dict:
        """Serializable state.  ``time.monotonic`` values are meaningless
        across processes, so open-cooldown deadlines are stored as
        *remaining* durations and rebased at ``load_state``."""
        now = self._now(now)
        entries = []
        for fp, st in self._st.items():        # LRU order preserved
            entries.append({
                "fp": fp, "state": st["state"],
                "failures": int(st["failures"]),
                "cooldown": float(st["cooldown"]),
                "until_rel": max(0.0, st["until"] - now),
            })
        return {"entries": entries,
                "counters": {"trips": self.trips, "denials": self.denials,
                             "probes": self.probes,
                             "recoveries": self.recoveries,
                             "evictions": self.evictions}}

    def load_state(self, state: dict, now: float | None = None) -> None:
        now = self._now(now)
        self._st.clear()
        for e in state.get("entries", []):
            self._st[str(e["fp"])] = {
                "state": str(e["state"]),
                "failures": int(e["failures"]),
                "cooldown": float(e["cooldown"]),
                "until": now + float(e.get("until_rel", 0.0)),
            }
        c = state.get("counters", {})
        self.trips = int(c.get("trips", 0))
        self.denials = int(c.get("denials", 0))
        self.probes = int(c.get("probes", 0))
        self.recoveries = int(c.get("recoveries", 0))
        self.evictions = int(c.get("evictions", 0))
        self._evict()


# ---------------------------------------------------------------------- #
# Per-fingerprint rung memory.
# ---------------------------------------------------------------------- #
class RungMemory:
    """Remembers, per template fingerprint, the last degradation rung
    that *succeeded*, so repeat traffic on a known-degraded template
    jumps straight to that rung instead of re-walking the ladder from
    the top on every request.

    ``route(fp)`` returns one of:

      * ``("primary", None)`` — no memory for `fp`: run the primary
        config (walking the ladder only if it actually fails).
      * ``("jump", rung)``    — known degraded: execute `rung` directly,
        zero primary or intermediate-rung attempts.
      * ``("probe", rung)``   — the re-probe interval elapsed: try the
        primary config ONCE; on success the memory is cleared (quality
        clawed back), on failure fall straight back to `rung`.  Routing
        a probe *claims* the interval slot (``next_probe`` advances
        immediately), so concurrent traffic keeps jumping — at most one
        primary attempt per `reprobe_interval_s`.

    ``record_degraded`` returns True exactly once, when a fingerprint
    has stayed degraded for `chronic_after` consecutive requests — the
    caller surfaces it for re-planning (plan-cache drop + calibrator
    notice) rather than re-trying forever.

    Bounded like the breaker: LRU eviction at `max_tracked` (forgetting
    a rung only costs one extra ladder walk).  `now` is injectable."""

    def __init__(self, reprobe_interval_s: float = 30.0,
                 chronic_after: int = 8, max_tracked: int = 1024):
        self.reprobe_interval_s = float(reprobe_interval_s)
        self.chronic_after = int(chronic_after)
        self.max_tracked = int(max_tracked)
        self._st: OrderedDict[str, dict] = OrderedDict()
        self.hits = 0               # routed requests with memory present
        self.jumps = 0              # direct-to-rung executions
        self.probes = 0             # primary re-probe attempts routed
        self.probe_recoveries = 0   # probes that restored full quality
        self.probe_failures = 0     # probes that fell back to the rung
        self.chronic = 0            # fingerprints surfaced for re-plan
        self.evictions = 0

    def _now(self, now: float | None) -> float:
        return time.monotonic() if now is None else float(now)

    def route(self, fp: str, now: float | None = None):
        st = self._st.get(fp)
        if st is None:
            return ("primary", None)
        self._st.move_to_end(fp)
        self.hits += 1
        now = self._now(now)
        if now >= st["next_probe"]:
            st["next_probe"] = now + self.reprobe_interval_s
            self.probes += 1
            return ("probe", st["rung"])
        self.jumps += 1
        return ("jump", st["rung"])

    def record_degraded(self, fp: str, rung: str,
                        now: float | None = None) -> bool:
        """A request on `fp` was served by `rung`.  Returns True exactly
        when the fingerprint crosses the chronic threshold."""
        now = self._now(now)
        st = self._st.get(fp)
        if st is None:
            st = {"rung": rung, "consecutive": 0,
                  "next_probe": now + self.reprobe_interval_s}
            self._st[fp] = st
            self._evict()
        else:
            self._st.move_to_end(fp)
        st["rung"] = rung
        st["consecutive"] += 1
        if st["consecutive"] == self.chronic_after:
            self.chronic += 1
            return True
        return False

    def record_primary_ok(self, fp: str) -> None:
        """Primary config succeeded (a re-probe paid off): forget."""
        if self._st.pop(fp, None) is not None:
            self.probe_recoveries += 1

    def record_probe_failed(self, fp: str) -> None:
        self.probe_failures += 1

    def clear(self, fp: str) -> None:
        self._st.pop(fp, None)

    def rung(self, fp: str) -> str | None:
        st = self._st.get(fp)
        return None if st is None else st["rung"]

    def _evict(self) -> None:
        while len(self._st) > self.max_tracked:
            self._st.popitem(last=False)
            self.evictions += 1

    def snapshot(self) -> dict:
        return {
            "tracked": len(self._st),
            "hits": self.hits,
            "jumps": self.jumps,
            "probes": self.probes,
            "probe_recoveries": self.probe_recoveries,
            "probe_failures": self.probe_failures,
            "chronic": self.chronic,
            "evictions": self.evictions,
        }

    def save_state(self, now: float | None = None) -> dict:
        now = self._now(now)
        entries = [{"fp": fp, "rung": st["rung"],
                    "consecutive": int(st["consecutive"]),
                    "next_probe_rel": max(0.0, st["next_probe"] - now)}
                   for fp, st in self._st.items()]
        return {"entries": entries,
                "counters": {"hits": self.hits, "jumps": self.jumps,
                             "probes": self.probes,
                             "probe_recoveries": self.probe_recoveries,
                             "probe_failures": self.probe_failures,
                             "chronic": self.chronic,
                             "evictions": self.evictions}}

    def load_state(self, state: dict, now: float | None = None) -> None:
        now = self._now(now)
        self._st.clear()
        for e in state.get("entries", []):
            self._st[str(e["fp"])] = {
                "rung": str(e["rung"]),
                "consecutive": int(e["consecutive"]),
                "next_probe": now + float(e.get("next_probe_rel", 0.0)),
            }
        c = state.get("counters", {})
        self.hits = int(c.get("hits", 0))
        self.jumps = int(c.get("jumps", 0))
        self.probes = int(c.get("probes", 0))
        self.probe_recoveries = int(c.get("probe_recoveries", 0))
        self.probe_failures = int(c.get("probe_failures", 0))
        self.chronic = int(c.get("chronic", 0))
        self.evictions = int(c.get("evictions", 0))
        self._evict()


# ---------------------------------------------------------------------- #
# Governor: configuration + runtime state.
# ---------------------------------------------------------------------- #
@dataclass
class GovernorConfig:
    """Resource-governance policy for one QueryServer.  Every bound is
    optional — a default-constructed config enables only the degradation
    ladder and the circuit breaker (no budgets, no shedding)."""
    deadline_s: float | None = None     # per-execution-attempt wall budget
    max_rows: int | None = None         # cumulative join output rows
    max_capacity: int | None = None     # largest single table capacity
    max_pending: int | None = None      # admission: pending-queue bound
    flush_wall_s: float | None = None   # per-flush wall budget
    breaker_threshold: int = 3
    breaker_cooldown_s: float = 5.0
    breaker_backoff: float = 2.0
    breaker_max_cooldown_s: float = 300.0
    breaker_max_tracked: int = 1024     # bound on per-fp breaker states
    degraded_row_cap: int = 1 << 14     # 'truncate' rung row cap
    ladder: tuple = field(default_factory=default_ladder)
    # --- rung memory (fault memory for the ladder) ---
    rung_memory: bool = True            # remember last-good rung per fp
    reprobe_interval_s: float = 30.0    # primary re-probe cadence
    chronic_after: int = 8              # consecutive degraded -> re-plan
    rung_memory_max: int = 1024         # bound on remembered fps
    # --- transient-fault classification ---
    transient_retry: bool = True        # one retry before the ladder
    retry_backoff_s: float = 0.01       # base backoff before the retry
    retry_jitter: float = 1.0           # backoff *= 1 + U(0,jitter)


class Governor:
    """Runtime state for one server's governance policy: the circuit
    breaker plus counters for shedding, budget aborts, and ladder use."""

    def __init__(self, cfg: GovernorConfig):
        self.cfg = cfg
        self.clock = time.monotonic     # injectable for deterministic tests
        self.breaker = CircuitBreaker(cfg.breaker_threshold,
                                      cfg.breaker_cooldown_s,
                                      cfg.breaker_backoff,
                                      cfg.breaker_max_cooldown_s,
                                      max_tracked=cfg.breaker_max_tracked)
        self.rung_memory = RungMemory(cfg.reprobe_interval_s,
                                      cfg.chronic_after,
                                      cfg.rung_memory_max) \
            if cfg.rung_memory else None
        self.shed_submit = 0            # submissions rejected at admission
        self.shed_flush = 0             # futures shed by the flush budget
        self.budget_exceeded = 0        # primary attempts aborted by Budget
        self.degraded: dict[str, int] = {}   # successful rung -> count
        self.degraded_queries = 0
        self.exhausted = 0              # ladder walked fully, still failed
        self.transient_retries = 0      # primary retried after a blip
        self.transient_recoveries = 0   # retries that succeeded exactly
        self.ladder_entries = 0         # requests that entered the ladder

    def make_budget(self) -> Budget | None:
        c = self.cfg
        if c.deadline_s is None and c.max_rows is None \
                and c.max_capacity is None:
            return None
        return Budget(deadline_s=c.deadline_s, max_rows=c.max_rows,
                      max_capacity=c.max_capacity)

    def note_degraded(self, rung: str) -> None:
        self.degraded_queries += 1
        self.degraded[rung] = self.degraded.get(rung, 0) + 1

    def snapshot(self) -> dict:
        c = self.cfg
        return {
            "limits": {
                "deadline_s": c.deadline_s, "max_rows": c.max_rows,
                "max_capacity": c.max_capacity,
                "max_pending": c.max_pending,
                "flush_wall_s": c.flush_wall_s,
            },
            "shed_submit": self.shed_submit,
            "shed_flush": self.shed_flush,
            "budget_exceeded": self.budget_exceeded,
            "degraded_queries": self.degraded_queries,
            "degraded_by_rung": dict(self.degraded),
            "exhausted": self.exhausted,
            "transient_retries": self.transient_retries,
            "transient_recoveries": self.transient_recoveries,
            "ladder_entries": self.ladder_entries,
            "breaker": self.breaker.snapshot(),
            "rung_memory": (None if self.rung_memory is None
                            else self.rung_memory.snapshot()),
        }

    def save_state(self, now: float | None = None) -> dict:
        return {
            "breaker": self.breaker.save_state(now),
            "rung_memory": (None if self.rung_memory is None
                            else self.rung_memory.save_state(now)),
            "counters": {
                "shed_submit": self.shed_submit,
                "shed_flush": self.shed_flush,
                "budget_exceeded": self.budget_exceeded,
                "degraded": dict(self.degraded),
                "degraded_queries": self.degraded_queries,
                "exhausted": self.exhausted,
                "transient_retries": self.transient_retries,
                "transient_recoveries": self.transient_recoveries,
                "ladder_entries": self.ladder_entries,
            },
        }

    def load_state(self, state: dict, now: float | None = None) -> None:
        self.breaker.load_state(state.get("breaker", {}), now)
        rm = state.get("rung_memory")
        if self.rung_memory is not None and rm is not None:
            self.rung_memory.load_state(rm, now)
        c = state.get("counters", {})
        self.shed_submit = int(c.get("shed_submit", 0))
        self.shed_flush = int(c.get("shed_flush", 0))
        self.budget_exceeded = int(c.get("budget_exceeded", 0))
        self.degraded = {str(k): int(v)
                         for k, v in c.get("degraded", {}).items()}
        self.degraded_queries = int(c.get("degraded_queries", 0))
        self.exhausted = int(c.get("exhausted", 0))
        self.transient_retries = int(c.get("transient_retries", 0))
        self.transient_recoveries = int(c.get("transient_recoveries", 0))
        self.ladder_entries = int(c.get("ladder_entries", 0))
