"""Template plan cache: canonical fingerprints + LRU over PreparedQuery.

A serving workload repeats templates — often renumbered by the client
(node 0 of one request is node 3 of the next).  The cache therefore keys
on a *canonical* form of the template: nodes are relabeled by an
individualization-refinement canonical ordering (1-WL color refinement
over keywords / incident predicate edges / connection constraints, with
exhaustive branching on tied color cells — templates have <= ~10 nodes,
so the worst case is tiny).  Two isomorphic templates map to the same
fingerprint and share one `PreparedQuery`; results are mapped back to the
caller's node numbering through the canonicalization permutation.

`PreparedQuery` itself lives in `repro.core.engine` (it is the engine's
prepare/execute state machine) and is re-exported here as its public
serving-layer home.
"""
from __future__ import annotations

from collections import OrderedDict

from ..core.engine import Engine, MatchResult, PreparedQuery  # noqa: F401
from ..core.query import QueryTemplate, QueryEdge, ConnectionEdge


# ---------------------------------------------------------------------- #
# Canonical form.
# ---------------------------------------------------------------------- #
def _initial_colors(query: QueryTemplate) -> list:
    """Per-node invariant: keyword plus incident-edge/connection shape."""
    n = query.num_nodes
    sigs = []
    for q in range(n):
        out_e = tuple(sorted(-1 if e.pred is None else e.pred
                             for e in query.edges if e.src == q))
        in_e = tuple(sorted(-1 if e.pred is None else e.pred
                            for e in query.edges if e.dst == q))
        # a bidirectional connection is symmetric (a->b or b->a both
        # satisfy it), so its endpoints play one undistinguished role
        conn = tuple(sorted(("u" if c.bidirectional
                             else ("s" if c.src == q else "d"),
                             c.max_dist, bool(c.bidirectional))
                            for c in query.connections
                            if q in (c.src, c.dst)))
        sigs.append((query.keywords[q], out_e, in_e, conn))
    return sigs


def _compress(sigs: list) -> list[int]:
    """Map arbitrary hashable signatures to dense ints by sorted order
    (stable across processes — no hash() involved)."""
    ranks = {s: i for i, s in enumerate(sorted(set(sigs)))}
    return [ranks[s] for s in sigs]


def _refine(query: QueryTemplate, colors: list[int]) -> list[int]:
    """1-WL refinement until the color partition is stable."""
    n = query.num_nodes
    while True:
        sigs = []
        for q in range(n):
            nb = []
            for e in query.edges:
                p = -1 if e.pred is None else e.pred
                if e.src == q:
                    nb.append(("e>", p, colors[e.dst]))
                if e.dst == q:
                    nb.append(("e<", p, colors[e.src]))
            for c in query.connections:
                if c.src == q:
                    role = "c=" if c.bidirectional else "c>"
                    nb.append((role, c.max_dist, bool(c.bidirectional),
                               colors[c.dst]))
                if c.dst == q:
                    role = "c=" if c.bidirectional else "c<"
                    nb.append((role, c.max_dist, bool(c.bidirectional),
                               colors[c.src]))
            sigs.append((colors[q], tuple(sorted(nb))))
        new = _compress(sigs)
        if new == colors:
            return colors
        colors = new


def _encode(query: QueryTemplate, order: list[int]):
    """Canonical encoding of `query` relabeled so order[i] becomes node i.
    `order` lists original node ids in canonical sequence."""
    pos = {orig: i for i, orig in enumerate(order)}
    kws = tuple(query.keywords[orig] for orig in order)
    edges = tuple(sorted((pos[e.src], pos[e.dst],
                          -1 if e.pred is None else e.pred)
                         for e in query.edges))
    # bidirectional connections are symmetric: canonical endpoint order
    conns = tuple(sorted(
        ((min(pos[c.src], pos[c.dst]), max(pos[c.src], pos[c.dst]),
          c.max_dist, True) if c.bidirectional
         else (pos[c.src], pos[c.dst], c.max_dist, False))
        for c in query.connections))
    return (kws, edges, conns)


# Individualization branch budget: exhaustive branching is factorial on
# fully symmetric templates (n identical unconnected nodes => n!
# encodings), and canonicalization runs on every submission.  Realistic
# templates discriminate almost immediately; past this many branch
# expansions the search degrades to greedy first-member
# individualization — still deterministic for a GIVEN numbering (same
# query object always maps to the same fingerprint, so repeats still
# hit), merely no longer guaranteed to unify every exotic renumbering of
# a highly symmetric template (those become separate cache entries,
# never wrong results).
_CANON_BUDGET = 64


def _canonical_order(query: QueryTemplate, colors: list[int],
                     budget: list[int] | None = None) -> list[int]:
    """Individualization-refinement canonical node order: refine, then
    branch on every member of the first tied color cell and keep the
    lexicographically smallest encoding.  Exact — isomorphic templates
    produce identical encodings regardless of input numbering — while
    the branch budget lasts (see _CANON_BUDGET)."""
    if budget is None:
        budget = [_CANON_BUDGET]
    colors = _refine(query, colors)
    n = query.num_nodes
    cells: dict[int, list[int]] = {}
    for q, c in enumerate(colors):
        cells.setdefault(c, []).append(q)
    tied = [m for _, m in sorted(cells.items()) if len(m) > 1]
    if not tied:
        return sorted(range(n), key=lambda q: colors[q])
    members = tied[0] if budget[0] > 0 else tied[0][:1]
    budget[0] -= len(members)
    best = None
    for v in members:
        # individualize v: a fresh color below its cell, preserving the
        # relative order of all other colors
        ind = [2 * c + (0 if q == v else 1) for q, c in enumerate(colors)]
        order = _canonical_order(query, _compress(ind), budget)
        enc = _encode(query, order)
        if best is None or enc < best[0]:
            best = (enc, order)
    return best[1]


def canonicalize(query: QueryTemplate
                 ) -> tuple[QueryTemplate, list[int], str]:
    """(canonical query, order, fingerprint).

    `order[i]` is the original node id that became canonical node i; the
    fingerprint is a stable string of the canonical encoding (keywords,
    predicate edges, connection edges)."""
    order = _canonical_order(query, _compress(_initial_colors(query)))
    kws, edges, conns = _encode(query, order)
    canon = QueryTemplate(
        keywords=list(kws),
        edges=[QueryEdge(s, d, None if p < 0 else p) for s, d, p in edges],
        connections=[ConnectionEdge(s, d, md, bd)
                     for s, d, md, bd in conns])
    return canon, order, repr((kws, edges, conns))


def template_fingerprint(query: QueryTemplate) -> str:
    """Canonical template fingerprint: equal for isomorphic templates."""
    return canonicalize(query)[2]


def dataset_key(dataset) -> str:
    """Cache key component identifying one loaded dataset by CONTENT.

    Keying on id(graph) would be a wrong-results trap for caches that
    outlive a graph (CPython recycles ids, and a recycled id plus equal
    node/edge counts would replay another graph's cached masks and join
    sizes).  The digest covers the FULL edge arrays — a sampled digest
    would re-open the same trap for graphs differing only outside the
    sample — at ~tens of ms per GB of edges, paid once per server.
    Equal datasets sharing cache entries is a bonus.

    A `repro.core.Dataset` additionally carries a delta version, so its
    key is the versioned ``digest:vN`` form (`Dataset.cache_key`): two
    states of one mutable dataset never share cache entries.  A bare
    graph keys to the plain content digest."""
    from ..core.dataset import Dataset, content_digest
    if isinstance(dataset, Dataset):
        return dataset.cache_key
    return content_digest(dataset)


# ---------------------------------------------------------------------- #
# LRU plan cache.
# ---------------------------------------------------------------------- #
class PlanCache:
    """LRU cache of PreparedQuery keyed by (dataset id, fingerprint).

    Entries carry the calibration `version` they were prepared under: the
    τ thresholds feed the §4.3 check decision baked into the plan, so a
    stale entry must not be served as-is.  But discarding it would throw
    away the learned execution state (masks, join orders, exact join
    sizes) every time the Calibrator nudges a threshold — so
    `prepare_cached` instead *revalidates* stale entries through
    `Engine.revalidate`, which re-runs only the cheap §4.3 decision and
    keeps everything learned whenever the decision is unchanged."""

    def __init__(self, max_entries: int = 64):
        self.max_entries = int(max_entries)
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.revalidations = 0          # stale entries re-decided
        self.invalidations = 0          # ... whose decision flipped
        self.drops = 0                  # chronic-degradation re-plans

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, dataset_id: str, fingerprint: str) -> PreparedQuery | None:
        key = (dataset_id, fingerprint)
        pq = self._entries.get(key)
        if pq is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return pq

    def peek(self, dataset_id: str,
             fingerprint: str) -> PreparedQuery | None:
        """`get` without side effects: no LRU touch, no hit/miss count.
        Observability reads (EXPLAIN, the slow-query log) use this so
        inspecting a plan never perturbs cache telemetry or eviction
        order."""
        return self._entries.get((dataset_id, fingerprint))

    def put(self, dataset_id: str, fingerprint: str,
            pq: PreparedQuery) -> None:
        key = (dataset_id, fingerprint)
        self._entries[key] = pq
        self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1

    def drop(self, dataset_id: str, fingerprint: str) -> bool:
        """Remove one entry (chronic-degradation re-planning: the next
        request on this fingerprint prepares fresh).  Returns whether an
        entry was present."""
        if self._entries.pop((dataset_id, fingerprint), None) is None:
            return False
        self.drops += 1
        return True

    def entries(self):
        """((dataset_id, fingerprint), PreparedQuery) pairs in LRU order
        (least recent first) — snapshot serialization preserves it."""
        return list(self._entries.items())

    def migrate(self, old_id: str, new_id: str,
                revalidate=None) -> tuple[int, int]:
        """Dataset-delta migration: move every entry keyed under `old_id`
        to `new_id`, preserving their relative LRU order.  `revalidate`
        (if given) is called with each PreparedQuery before the move and
        may return False to drop the entry instead (counted in `drops`).
        Returns (moved, dropped)."""
        moved = dropped = 0
        for (dsid, fp), pq in list(self._entries.items()):
            if dsid != old_id:
                continue
            del self._entries[(dsid, fp)]
            if revalidate is not None and revalidate(pq) is False:
                self.drops += 1
                dropped += 1
                continue
            self._entries[(new_id, fp)] = pq
            moved += 1
        return moved, dropped

    def snapshot(self) -> dict:
        total = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "evictions": self.evictions,
            "revalidations": self.revalidations,
            "invalidations": self.invalidations,
            "drops": self.drops,
        }


def prepare_cached(engine: Engine, query: QueryTemplate, cache: PlanCache,
                   dataset_id: str, version: int = 0
                   ) -> tuple[PreparedQuery, list[int], bool]:
    """Canonicalize `query`, look its plan up in `cache` (preparing and
    inserting on miss, revalidating on a calibration-version mismatch).
    Returns (prepared canonical query, order, hit) where `order[i]` is
    the caller's node id of canonical node i — `remap_result` uses it to
    translate executed results back."""
    canon, order, fingerprint = canonicalize(query)
    pq = cache.get(dataset_id, fingerprint)
    hit = pq is not None
    if pq is None:
        pq = engine.prepare(canon, fingerprint=fingerprint, version=version)
        cache.put(dataset_id, fingerprint, pq)
    elif pq.version != version:
        cache.revalidations += 1
        if not engine.revalidate(pq, version):
            cache.invalidations += 1
    return pq, order, hit


def remap_result(result: MatchResult, order: list[int]) -> MatchResult:
    """Translate a canonical-template MatchResult back to the caller's
    node numbering (rows are shared, only the column labels change)."""
    cols = tuple(order[c] for c in result.cols)
    return MatchResult(cols=cols, rows=result.rows, stats=result.stats)
