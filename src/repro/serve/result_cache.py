"""Exact-repeat result cache: (dataset id, template fingerprint) → rows.

The long-blocked serving win: a repeat of an already-executed template can
be answered without touching the engine at all — not even the warm replay
path — IF the system can prove the stored rows are still the answer.  With
an immutable dataset that proof was trivial but the cache was pointless to
scope; with `Dataset.apply_delta` it becomes possible to keep entries
*across* deltas:

  * entries are keyed by the server's versioned dataset id
    (``Dataset.cache_key`` = ``digest:vN``), so a delta never serves stale
    rows by accident — unmigrated entries simply stop matching;
  * on a delta, `migrate` re-keys the entries that provably survived: a
    connection-free template's matches live entirely inside its candidate
    intervals (every matched node is interval-constrained, and any
    changed edge's endpoints are in the delta's touched set), so the
    result is unchanged iff no touched node falls in any interval
    (`interval_footprint_hit`).  Templates WITH connection edges always
    drop — connectivity paths may run through nodes outside every
    interval, which the footprint can't see.

Results are stored in canonical-template form (cols + row array straight
from the engine); the server remaps per caller at fan-out time, so one
entry serves every isomorphic renumbering.  Bounded LRU by entry count
and (optionally) accounted row bytes, same discipline as ReachCache.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from ..core.dataset import interval_footprint_hit


class ResultCache:
    """LRU cache of exact query results keyed (dataset id, fingerprint)."""

    def __init__(self, max_entries: int = 256,
                 max_bytes: int | None = None):
        self.max_entries = int(max_entries)
        self.max_bytes = max_bytes
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0      # entries dropped by delta migration
        self.insertions = 0
        self.total_bytes = 0

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------------ #
    def get(self, dataset_id: str, fingerprint: str):
        """(cols, rows) in canonical-template form, or None."""
        key = (dataset_id, fingerprint)
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return e["cols"], e["rows"]

    def put(self, dataset_id: str, fingerprint: str, cols, rows,
            has_connections: bool, iv) -> None:
        """Store one canonical result.  `iv` is the prepared query's
        [Q, 2] candidate-interval array — the migration footprint."""
        key = (dataset_id, fingerprint)
        if key in self._entries:
            self.total_bytes -= self._entries.pop(key)["bytes"]
        rows = np.asarray(rows)
        nbytes = int(rows.nbytes)
        e = {"cols": tuple(int(c) for c in cols), "rows": rows,
             "has_connections": bool(has_connections),
             "iv": np.array(iv, copy=True), "bytes": nbytes}
        self._entries[key] = e
        self.insertions += 1
        self.total_bytes += nbytes
        while len(self._entries) > self.max_entries:
            self._evict_lru()
        if self.max_bytes is not None:
            # never evict the just-inserted entry: an oversized result
            # stays as a cache-of-one rather than thrashing
            while self.total_bytes > self.max_bytes \
                    and len(self._entries) > 1:
                self._evict_lru()

    def _evict_lru(self) -> None:
        _, e = self._entries.popitem(last=False)
        self.total_bytes -= e["bytes"]
        self.evictions += 1

    # ------------------------------------------------------------------ #
    def migrate(self, old_id: str, new_id: str,
                touched: np.ndarray | None) -> tuple[int, int]:
        """Delta migration: re-key surviving entries from `old_id` to
        `new_id`, drop the rest.  `touched` is the delta's sorted
        touched-node array (None = full rebuild = drop everything).
        Returns (kept, dropped)."""
        kept = dropped = 0
        for (dsid, fp), e in list(self._entries.items()):
            if dsid != old_id:
                continue
            del self._entries[(dsid, fp)]
            iv_pairs = [(int(lo), int(hi)) for lo, hi in e["iv"]]
            if touched is None or e["has_connections"] \
                    or interval_footprint_hit(iv_pairs, touched):
                self.total_bytes -= e["bytes"]
                self.invalidations += 1
                dropped += 1
                continue
            self._entries[(new_id, fp)] = e
            kept += 1
        return kept, dropped

    # ------------------------------------------------------------------ #
    def snapshot(self) -> dict:
        total = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "insertions": self.insertions,
            "bytes": self.total_bytes,
            "max_bytes": self.max_bytes,
        }
