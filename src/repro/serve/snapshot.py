"""Warm-restart durability: versioned, checksummed serving-state snapshots.

Everything the serving tier *learns* — the Calibrator's τ separators and
cost scales, the Governor's rung memory and breaker states, and the
PlanCache's PreparedQuery entries with their learned join/connection
plans (`join_seq`, `conn_impls`, component/connection orders, candidate
masks) — evaporates on process restart, forcing a full re-learn from
cold.  `save_snapshot`/`restore_snapshot` round-trip that state through
one file, so a restarted server's first execution per cached template
runs the warm path: no prepare, no planning DP, no §4.3 decide, no
signature check.

File format (everything after the header is one pickle payload):

    bytes  0..7   MAGIC  b"REPROSNP"
    bytes  8..11  format version (little-endian uint32)
    bytes 12..43  sha256 of the payload
    bytes 44..    payload (pickle protocol, stdlib only)

Safety invariants:

  * A corrupt, truncated, version-mismatched, stale (``max_age_s``), or
    wrong-dataset snapshot raises a typed `SnapshotError` — the server
    is left exactly as it was (a clean cold start), never serving a
    wrong or stale answer.  Restore is all-or-nothing: every object is
    rebuilt and validated BEFORE any server state is touched.
  * The dataset is identified by its content digest (`Dataset.digest`,
    over the full edge arrays) PLUS its delta version, so a snapshot can
    never replay another graph's masks or join sizes onto a lookalike
    graph, nor onto a same-origin dataset that has since absorbed
    `apply_delta` updates (reason 'version').
  * Device arrays are never serialized: candidate masks travel in host
    (numpy) form and `Engine._candidate_masks` rebuilds the device side
    lazily on first post-restore use.
  * Clocks don't compare across processes: breaker cooldowns and rung
    re-probe deadlines are stored as *remaining* durations and rebased
    against the restoring process's monotonic clock (see
    `governor.CircuitBreaker.save_state`).
"""
from __future__ import annotations

import hashlib
import os
import pickle
import struct
import time

from ..core.engine import PreparedQuery
from .governor import ServingError

MAGIC = b"REPROSNP"
FORMAT_VERSION = 1


class SnapshotError(ServingError):
    """A snapshot could not be written or safely restored.  `reason` is
    one of: 'io', 'truncated', 'magic', 'format_version', 'checksum',
    'undecodable', 'dataset', 'version', 'stale', 'payload'."""

    def __init__(self, reason: str, detail: str):
        self.reason = reason
        super().__init__(f"snapshot {reason}: {detail}")


# ---------------------------------------------------------------------- #
# PreparedQuery <-> host-only blob.
# ---------------------------------------------------------------------- #
_PQ_FIELDS = ("query", "iv", "cand_sizes", "comps", "trees_per_comp",
              "decision", "use_check", "fingerprint", "version",
              "prepare_time", "executions", "comp_orders", "comp_costs",
              "conn_order", "conn_costs", "conn_impls", "join_seq",
              "join_est_seq")

# Fields ADDED to PreparedQuery after snapshot format v1 shipped, with
# the default a pre-addition snapshot restores to.  Listing a field here
# (instead of bumping FORMAT_VERSION) keeps older payloads restorable:
# the learned plan state they carry is still exactly valid, only the new
# observability field is absent.  join_est_seq added in the tracing PR —
# an empty history merely renders EXPLAIN's est column as "-" until the
# next cold run repopulates it.
_PQ_FIELD_DEFAULTS = {"join_est_seq": list}


def _pq_to_blob(pq: PreparedQuery) -> dict:
    """Host-only dict of one PreparedQuery.  Device-resident masks are
    lowered to their numpy form (`masks_host`); everything else is plain
    Python / numpy already."""
    blob = {k: getattr(pq, k) for k in _PQ_FIELDS}
    if pq.masks is not None:
        _, pass_np, after = pq.masks
        blob["masks_host"] = (pass_np, after)
    else:
        blob["masks_host"] = pq.masks_host
    # join_seq caps may be CapEstimate (an int subclass carrying a jit
    # shape hint) — normalize to plain tuples of builtins so the blob
    # survives refactors of estimator-internal types
    blob["join_seq"] = [(int(r), int(c), str(i))
                        for r, c, i in pq.join_seq]
    blob["join_est_seq"] = [None if e is None else int(e)
                            for e in pq.join_est_seq]
    return blob


def _pq_from_blob(blob: dict) -> PreparedQuery:
    kwargs = {}
    for k in _PQ_FIELDS:
        if k in blob:
            kwargs[k] = blob[k]
        elif k in _PQ_FIELD_DEFAULTS:
            kwargs[k] = _PQ_FIELD_DEFAULTS[k]()
        else:
            raise KeyError(k)            # caller wraps as payload error
    pq = PreparedQuery(**kwargs)
    pq.masks = None
    pq.masks_host = blob.get("masks_host")
    return pq


# ---------------------------------------------------------------------- #
# Save / restore.
# ---------------------------------------------------------------------- #
def _collect(server) -> dict:
    plans = []
    for (ds, fp), pq in server.plan_cache.entries():   # LRU order
        if ds != server.dataset_id:
            continue
        plans.append((fp, _pq_to_blob(pq)))
    return {
        "dataset_key": server.dataset.digest,
        "dataset_version": server.dataset.version,
        "saved_at": time.time(),
        "calibration_version": server._version(),
        "calibrator": (None if server.calibrator is None
                       else server.calibrator.save_state()),
        "governor": (None if server.governor is None
                     else server.governor.save_state()),
        "plans": plans,
    }


def save_snapshot(server, path) -> dict:
    """Write every piece of learned serving state to `path` (atomic:
    tmp file + rename).  Returns a manifest dict."""
    path = os.fspath(path)
    data = _collect(server)
    payload = pickle.dumps(data, protocol=4)
    digest = hashlib.sha256(payload).digest()
    head = MAGIC + struct.pack("<I", FORMAT_VERSION) + digest
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            f.write(head)
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except OSError as e:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise SnapshotError("io", str(e)) from e
    return {"path": path, "format_version": FORMAT_VERSION,
            "dataset_key": server.dataset.digest,
            "dataset_version": server.dataset.version,
            "plans": len(data["plans"]),
            "bytes": len(head) + len(payload)}


def _read_payload(path) -> dict:
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError as e:
        raise SnapshotError("io", str(e)) from e
    hdr_len = len(MAGIC) + 4 + hashlib.sha256().digest_size
    if len(raw) < hdr_len:
        raise SnapshotError("truncated",
                            f"{len(raw)} bytes < {hdr_len}-byte header")
    if raw[:len(MAGIC)] != MAGIC:
        raise SnapshotError("magic", f"{raw[:len(MAGIC)]!r}")
    (version,) = struct.unpack_from("<I", raw, len(MAGIC))
    if version != FORMAT_VERSION:
        raise SnapshotError(
            "format_version",
            f"snapshot v{version}, this build reads v{FORMAT_VERSION}")
    digest = raw[len(MAGIC) + 4:hdr_len]
    payload = raw[hdr_len:]
    if hashlib.sha256(payload).digest() != digest:
        raise SnapshotError("checksum", "payload sha256 mismatch")
    try:
        data = pickle.loads(payload)
    except Exception as e:               # noqa: BLE001
        raise SnapshotError("undecodable", str(e)) from e
    if not isinstance(data, dict) or "dataset_key" not in data:
        raise SnapshotError("payload", "missing dataset_key")
    return data


def restore_snapshot(server, path, max_age_s: float | None = None) -> dict:
    """Load a snapshot into `server`.  All-or-nothing: every restored
    object is built and validated before any server state is mutated, so
    a failed restore leaves an exact cold start.  Raises SnapshotError
    on any corruption, format/version mismatch, wrong dataset, or
    staleness past `max_age_s`."""
    path = os.fspath(path)
    data = _read_payload(path)
    # The delta version is checked before the content digest: once the
    # server's dataset has absorbed apply_delta round-trips the snapshot
    # never saw, "this snapshot predates your deltas" is the actionable
    # error even though the content digest (which tracks the edge set)
    # has necessarily moved too.  A digest mismatch at the SAME version
    # means a genuinely different dataset.  (Pre-version payloads carry
    # no dataset_version: they could only have been taken at version 0.)
    snap_version = int(data.get("dataset_version", 0))
    if snap_version != server.dataset.version:
        raise SnapshotError(
            "version",
            f"snapshot at dataset version {snap_version}, server is at "
            f"v{server.dataset.version}")
    if data["dataset_key"] != server.dataset.digest:
        raise SnapshotError(
            "dataset",
            f"snapshot for {data['dataset_key']!r}, server is on "
            f"{server.dataset.digest!r}")
    age = time.time() - float(data.get("saved_at", 0.0))
    if max_age_s is not None and age > max_age_s:
        raise SnapshotError("stale",
                            f"snapshot is {age:.1f}s old > {max_age_s}s")
    # ---- build everything before touching the server ----------------- #
    try:
        plans = [(fp, _pq_from_blob(blob)) for fp, blob in data["plans"]]
        cal_state = data.get("calibrator")
        gov_state = data.get("governor")
    except Exception as e:               # noqa: BLE001
        raise SnapshotError("payload", str(e)) from e
    # ---- apply -------------------------------------------------------- #
    if server.calibrator is not None and cal_state is not None:
        server.calibrator.load_state(cal_state)
    if server.governor is not None and gov_state is not None:
        server.governor.load_state(gov_state, server.governor.clock())
    for fp, pq in plans:                 # LRU order preserved
        server.plan_cache.put(server.dataset_id, fp, pq)
    return {"path": path, "format_version": FORMAT_VERSION,
            "dataset_key": data["dataset_key"], "plans": len(plans),
            "age_s": age,
            "calibration_version": int(data.get("calibration_version", 0))}
