"""Shape-batched execution: bucket a stream of admitted queries by
(template fingerprint, pow2 capacity class) and execute each bucket once
through shared padded shapes.

Two effects compound:

  * queries with the SAME fingerprint against an immutable dataset are
    the same computation — one execution serves the whole bucket (result
    fan-out; per-future column remapping handles renumbered clients);
  * buckets are drained in capacity-class order, so executions whose
    padded table shapes coincide run consecutively and XLA's jit cache
    stays hot across adjacent buckets instead of thrashing between a
    large and a small shape regime per query.

The batcher is policy only — it owns no engine state.  The server hands
it opaque items plus their (fingerprint, capacity class) and an
`execute(item) -> result` callback for one representative per bucket.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class BatchTelemetry:
    queries: int = 0            # items admitted
    executions: int = 0         # engine executions actually run
    buckets: int = 0            # distinct (fingerprint, class) buckets
    dedup_saved: int = 0        # executions avoided by result fan-out
    flushes: int = 0
    shed: int = 0               # items shed by a flush-time stop signal

    def snapshot(self) -> dict:
        return dict(vars(self))


class ShapeBatcher:
    """Admit items, then `flush(execute)` them bucket-at-a-time.

    Items sharing a bucket key get the result of ONE execution of the
    bucket's first (representative) item; buckets run in ascending
    (capacity class, fingerprint) order."""

    def __init__(self, metrics=None):
        # optional obs.metrics.MetricsRegistry: per-bucket size histogram
        # (how much dedup/shape-sharing each flush actually found)
        self.telemetry = BatchTelemetry()
        self.metrics = metrics
        self._pending: list[tuple[str, int, object]] = []

    def __len__(self) -> int:
        return len(self._pending)

    def add(self, item, fingerprint: str, cap_class: int) -> None:
        self._pending.append((fingerprint, int(cap_class), item))
        self.telemetry.queries += 1

    def flush(self, execute,
              should_stop=None) -> list[tuple[object, object]]:
        """Run all pending items; returns [(item, result), ...] in bucket
        order.  `execute(item)` is called once per bucket.

        `should_stop`, when given, is consulted before each bucket: it
        returns None to continue or an Exception instance to shed the
        remaining buckets — every not-yet-executed item is paired with
        that exception instead of a result (the server resolves each
        affected future with it), so an exhausted per-flush wall budget
        sheds the tail of the flush instead of hanging it."""
        pending, self._pending = self._pending, []
        if not pending:
            return []
        self.telemetry.flushes += 1
        buckets: dict[tuple[int, str], list[object]] = {}
        for fingerprint, cap_class, item in pending:
            buckets.setdefault((cap_class, fingerprint), []).append(item)
        out = []
        stopped: Exception | None = None
        for key in sorted(buckets):
            items = buckets[key]
            self.telemetry.buckets += 1
            if self.metrics is not None:
                self.metrics.histogram("batch_bucket_size").observe(
                    len(items))
            if stopped is None and should_stop is not None:
                stopped = should_stop()
            if stopped is not None:
                self.telemetry.shed += len(items)
                for item in items:
                    out.append((item, stopped))
                continue
            self.telemetry.executions += 1
            self.telemetry.dedup_saved += len(items) - 1
            result = execute(items[0])
            for item in items:
                out.append((item, result))
        return out
