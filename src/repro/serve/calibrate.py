"""Self-calibrating pruning and cost-model constants.

The paper's §4.3 decision — run the neighborhood check or not — hinges on
the τ1–τ3 thresholds, and the planner's join/connection cost models hinge
on analytic cardinality estimates.  Both are tuned offline in the paper;
in a serving setting the system observes its own executions, so the
Calibrator closes the loop online:

  * join_est_scale   from the signed join-estimate log bias
                     (QueryStats.join_est_log_bias): a planner that
                     systematically over-estimates join sizes gets its
                     estimates shrunk, and vice versa.
  * conn_sel_scale   from observed vs. predicted connected-pair counts
                     (conn_connected_pairs vs conn_est_pairs): corrects
                     connection_selectivity on datasets whose reach
                     structure the geometric-fanout model misses.
  * reach_scale      from observed vs. predicted reach-pair-table rows
                     (conn_reach_pairs vs conn_est_reach_pairs): corrects
                     the reach-join side of connection_edge_cost, i.e.
                     the per-edge reach-vs-cross strategy choice.
  * τ1–τ3            rule-based bounded steps: a check that ran but
                     barely pruned while costing real time raises τ3
                     (demand more selectivity); a skipped check followed
                     by join work far above τ2 lowers τ1/τ2 (classify
                     such templates as complex next time).

All updates are multiplicative, EWMA-smoothed, and clipped to bounded
ranges around the defaults, and none of them can change query *results*
— thresholds and cost constants only steer pruning/strategy/order
choices, every one of which is exact.  `version` increments whenever a
threshold moves; the PlanCache uses it to invalidate prepared decisions
made under stale thresholds.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from ..core.engine import QueryStats
from ..core.planner import Thresholds, CostModel


@dataclass
class Ewma:
    """Exponentially weighted running mean (None until first update)."""
    alpha: float = 0.25
    value: float | None = None
    n: int = 0

    def update(self, x: float) -> float:
        self.value = x if self.value is None \
            else (1 - self.alpha) * self.value + self.alpha * x
        self.n += 1
        return self.value

    def get(self, default: float = 0.0) -> float:
        return default if self.value is None else self.value


def _clip(x: float, lo: float, hi: float) -> float:
    return max(lo, min(hi, x))


class Calibrator:
    """Aggregates per-query QueryStats into per-dataset running telemetry
    and feeds calibrated thresholds / cost-model constants back into the
    planner.  Mutates the `Thresholds` and `CostModel` objects it is
    handed IN PLACE — hand it the engine's own cfg objects and every
    later plan sees the calibrated values without further plumbing."""

    TAU_BOUND = 16.0            # each τ stays within default / x bound
    SCALE_BOUND = 8.0           # join/reach scales stay within 1/x .. x
    SEL_BOUND = 64.0            # selectivity correction range

    def __init__(self, thresholds: Thresholds, cost_model: CostModel,
                 alpha: float = 0.25,
                 bounds_ref: Thresholds | None = None):
        self.thresholds = thresholds
        self.cost_model = cost_model
        # τ movement is bounded around a reference grid, NOT around the
        # starting values: a miscalibrated start (the situation
        # calibration exists to repair) must not anchor its own cage.
        # The default reference is the paper's canonical thresholds.
        ref = bounds_ref if bounds_ref is not None else Thresholds()
        self._tau_defaults = (ref.tau_iter, ref.tau_join, ref.tau_sel)
        self.version = 0
        self.observed = 0
        self.degraded_skipped = 0
        self.chronic_notices = 0
        self.chronic_fps: list[str] = []
        self._join_bias = Ewma(alpha)
        self._conn_sel = Ewma(alpha)
        self._reach = Ewma(alpha)

    # ------------------------------------------------------------------ #
    def observe(self, qs: QueryStats) -> None:
        """Fold one executed query's stats into the running calibration.
        Only cold executions carry new evidence — warm ones replay the
        cold run's decisions and sizes verbatim."""
        self.observed += 1
        if qs.cache_hit:
            # cold-run evidence only, uniformly: a warm repeat replays
            # the first run's masks, join sizes, and connection
            # strategies, so every one of its ratios is the same
            # observation folded in again — a hot template would
            # otherwise dominate the EWMAs by repetition count
            return
        if qs.degraded_steps:
            # degraded-ladder executions ran under forced non-default
            # settings (check off, forced impls, reduced caps) — their
            # estimate/observation ratios describe the degraded config,
            # not the primary one the thresholds and cost model govern
            self.degraded_skipped += 1
            return
        cm = self.cost_model
        b = self.SCALE_BOUND
        if qs.n_estimated_joins:
            # the recorded bias was measured on estimates that already
            # had join_est_scale applied — divide it back out so the
            # EWMA tracks the RAW model's bias.  (Setting the scale
            # absolutely from the post-scale bias converges to only half
            # the correction in log space: a 16x raw over-estimate would
            # settle at scale 1/4 instead of 1/16.)
            raw = (qs.join_est_log_bias / qs.n_estimated_joins
                   - math.log(max(cm.join_est_scale, 1e-12)))
            bias = self._join_bias.update(raw)
            cm.join_est_scale = _clip(math.exp(-bias), 1.0 / b, b)
        if qs.conn_est_pairs > 0:
            r = self._conn_sel.update(
                math.log((qs.conn_connected_pairs + 1.0)
                         / (qs.conn_est_pairs + 1.0)))
            cm.conn_sel_scale = _clip(math.exp(r), 1.0 / self.SEL_BOUND,
                                      self.SEL_BOUND)
        if qs.conn_est_reach_pairs > 0 and qs.conn_reach_pairs > 0:
            r = self._reach.update(
                math.log((qs.conn_reach_pairs + 1.0)
                         / (qs.conn_est_reach_pairs + 1.0)))
            cm.reach_scale = _clip(math.exp(r), 1.0 / b, b)
        self._update_thresholds(qs)

    def _update_thresholds(self, qs: QueryStats) -> None:
        th = self.thresholds
        d_iter, d_join, d_sel = self._tau_defaults
        bound = self.TAU_BOUND
        before = (th.tau_iter, th.tau_join, th.tau_sel)
        if qs.used_check and qs.plan is None:
            # check forced by policy ('always'), not decided by the τ
            # thresholds — no decide() evidence, nothing to learn from
            pass
        elif qs.used_check:
            # pruning power is measured by the candidate ratio alone —
            # wall times are useless for this rule online (cold runs are
            # compile-dominated, warm runs replay cached masks at zero
            # check cost), but the ratio is exact on every cold run.
            # τ3 is maintained as a running *separator* between observed
            # selectivities: a template whose selectivity S failed to
            # prune is direct evidence that τ3 must exceed S, and a
            # template that pruned well is evidence τ3 must not — one
            # observation per template moves τ3 past it, instead of
            # creeping multiplicatively.
            prune = qs.candidates_after / max(qs.candidates_before, 1)
            s = qs.plan.max_selectivity if qs.plan is not None else None
            if prune > 0.9:
                target = s * 1.1 if s is not None else th.tau_sel * 1.5
                th.tau_sel = _clip(max(th.tau_sel, target), d_sel / bound,
                                   d_sel * bound)
            elif prune < 0.5:
                target = s * 0.95 if s is not None else th.tau_sel / 1.1
                th.tau_sel = _clip(min(th.tau_sel, target), d_sel / bound,
                                   d_sel * bound)
        elif qs.plan is not None and not qs.plan.complex_query:
            work = qs.join_work + qs.dtree_work
            if work > 4.0 * th.tau_join:
                # "not complex" misclassification: actual join work blew
                # past τ2 — tighten both complexity gates
                th.tau_iter = _clip(th.tau_iter / 1.25, d_iter / bound,
                                    d_iter * bound)
                th.tau_join = _clip(th.tau_join / 1.25, d_join / bound,
                                    d_join * bound)
        if (th.tau_iter, th.tau_join, th.tau_sel) != before:
            self.version += 1

    # ------------------------------------------------------------------ #
    def note_chronic(self, fingerprint: str) -> None:
        """A template stayed degraded past the governor's chronic
        threshold: its plan keeps failing under the primary config, so
        re-plan rather than re-try.  The version bump forces every
        cached decision through `Engine.revalidate` (cheap — pure
        template arithmetic), and the fingerprint is kept (bounded) for
        telemetry/offline analysis."""
        self.chronic_notices += 1
        if fingerprint not in self.chronic_fps:
            self.chronic_fps.append(fingerprint)
            del self.chronic_fps[:-64]
        self.version += 1

    def note_delta(self) -> None:
        """The dataset absorbed a delta: stats shifted, so the §4.3
        decision baked into every cached plan may have flipped even
        though no threshold moved.  Bumping the version routes each
        cached entry through `Engine.revalidate` on its next use (and
        lets the server re-decide eagerly during plan-cache migration)."""
        self.version += 1

    def save_state(self) -> dict:
        """Serializable learned state (thresholds, scales, EWMAs) for
        warm-restart snapshots; restored by `load_state`."""
        th, cm = self.thresholds, self.cost_model
        return {
            "version": self.version,
            "observed": self.observed,
            "degraded_skipped": self.degraded_skipped,
            "chronic_notices": self.chronic_notices,
            "chronic_fps": list(self.chronic_fps),
            "thresholds": {"tau_iter": th.tau_iter,
                           "tau_join": th.tau_join,
                           "tau_sel": th.tau_sel,
                           "nested_join_max": th.nested_join_max},
            "cost_model": {"join_est_scale": cm.join_est_scale,
                           "conn_sel_scale": cm.conn_sel_scale,
                           "reach_scale": cm.reach_scale,
                           "cross_scale": cm.cross_scale},
            "ewma": {name: {"alpha": e.alpha, "value": e.value, "n": e.n}
                     for name, e in (("join_bias", self._join_bias),
                                     ("conn_sel", self._conn_sel),
                                     ("reach", self._reach))},
        }

    def load_state(self, state: dict) -> None:
        """Restore `save_state` output IN PLACE on the same Thresholds /
        CostModel objects the engine plans with."""
        th, cm = self.thresholds, self.cost_model
        for k, v in state.get("thresholds", {}).items():
            setattr(th, k, v)
        for k, v in state.get("cost_model", {}).items():
            setattr(cm, k, v)
        for name, e in (("join_bias", self._join_bias),
                        ("conn_sel", self._conn_sel),
                        ("reach", self._reach)):
            s = state.get("ewma", {}).get(name)
            if s is not None:
                e.alpha = float(s["alpha"])
                e.value = None if s["value"] is None else float(s["value"])
                e.n = int(s["n"])
        self.version = int(state.get("version", 0))
        self.observed = int(state.get("observed", 0))
        self.degraded_skipped = int(state.get("degraded_skipped", 0))
        self.chronic_notices = int(state.get("chronic_notices", 0))
        self.chronic_fps = [str(f) for f in state.get("chronic_fps", [])]

    def snapshot(self) -> dict:
        th, cm = self.thresholds, self.cost_model
        return {
            "observed": self.observed,
            "degraded_skipped": self.degraded_skipped,
            "chronic_notices": self.chronic_notices,
            "version": self.version,
            "tau_iter": th.tau_iter,
            "tau_join": th.tau_join,
            "tau_sel": th.tau_sel,
            "join_est_scale": cm.join_est_scale,
            "conn_sel_scale": cm.conn_sel_scale,
            "reach_scale": cm.reach_scale,
            "cross_scale": cm.cross_scale,
            "join_bias_ewma": self._join_bias.get(),
            "conn_sel_ewma": self._conn_sel.get(),
            "reach_ewma": self._reach.get(),
        }
