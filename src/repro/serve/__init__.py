"""Query serving subsystem: template plan cache, shape-batched execution,
and self-calibrating pruning decisions.

RDF-ℏ's thesis — signature pruning should be applied *selectively* per
dataset and per query template (§4.3) — only pays off in a serving
setting where the same templates arrive repeatedly and the system can
learn from its own executions.  This package is that setting:

  * `plan_cache`  — canonical template fingerprints and an LRU cache of
                    `PreparedQuery` objects (the engine's prepare/execute
                    split), so repeat templates skip planning and
                    recompilation entirely.
  * `batching`    — shape-batched execution: queries bucketed by template
                    fingerprint and pow2 capacity class, each bucket
                    executed once through shared padded shapes.
  * `calibrate`   — online calibration of the τ1–τ3 pruning thresholds
                    and the planner cost-model constants from per-query
                    QueryStats telemetry.
  * `governor`    — resource governance and failure containment:
                    per-execution deadline/row/capacity budgets, the
                    exact-but-cheaper degradation ladder, admission
                    control, and the per-fingerprint circuit breaker.
  * `server`      — the user-facing `QueryServer` (submit / submit_many,
                    sync + async result futures, LRU-bounded plan and
                    reach caches, p50/p99 latency + cache-hit telemetry,
                    and `apply_delta` for in-place dataset version bumps
                    with warm-state migration).
  * `result_cache`— opt-in exact-repeat result rows keyed by versioned
                    dataset id + template fingerprint, migrated across
                    deltas by interval-footprint proof.
  * `snapshot`    — warm-restart durability: versioned, checksummed
                    serialization of all learned serving state
                    (calibration, rung memory, breaker, cached plans),
                    restored all-or-nothing with typed `SnapshotError`
                    fallbacks to a clean cold start.
"""
from .plan_cache import (PreparedQuery, PlanCache, template_fingerprint,
                         canonicalize, prepare_cached, dataset_key)
from .result_cache import ResultCache
from .batching import ShapeBatcher, BatchTelemetry
from .calibrate import Calibrator, Ewma
from .governor import (Budget, BudgetExceeded, CircuitBreaker,
                       DegradationExhausted, Governor, GovernorConfig,
                       IncompleteFlushError, LadderRung, QueryError,
                       QuarantinedError, RejectedError, RungMemory,
                       ServingError, default_ladder)
from .server import QueryServer, ResultFuture
from .snapshot import SnapshotError, save_snapshot, restore_snapshot

__all__ = [
    "PreparedQuery", "PlanCache", "template_fingerprint", "canonicalize",
    "prepare_cached", "dataset_key", "ResultCache",
    "ShapeBatcher", "BatchTelemetry",
    "Calibrator", "Ewma", "QueryServer", "ResultFuture",
    "Budget", "BudgetExceeded", "CircuitBreaker", "DegradationExhausted",
    "Governor", "GovernorConfig", "IncompleteFlushError", "LadderRung",
    "QueryError", "QuarantinedError", "RejectedError", "RungMemory",
    "ServingError", "default_ladder",
    "SnapshotError", "save_snapshot", "restore_snapshot",
]
