"""Dataset features (§4.1) and dataset evaluation metrics (§5).

  * predicate selectivity s(p) = |p| / |E|
  * literal selectivity  f_{n,p_a} = m_{n,p_a} / |l(p_a)|
  * dataset coherence (Duan et al. structuredness, coverage-weighted)
  * relationship specialty (occurrence-kurtosis, weighted by |r|)
  * literal diversity (unique words in an M-sample of attribute literals)
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .graph import RDFGraph, ATTR, REL, RESOURCE


@dataclass
class DatasetStats:
    pred_selectivity: np.ndarray              # [P] float64
    literal_selectivity: dict[int, dict[int, float]]  # pa -> n -> f
    coherence: float
    specialty: float
    diversity: int
    type_pred: int | None = None
    # join-cardinality features: edges of p per distinct subject/object —
    # the expected fanout when a candidate table is joined with p's edge
    # table on the subject (src) or object (dst) side.
    src_fanout: np.ndarray | None = None      # [P] float64
    dst_fanout: np.ndarray | None = None      # [P] float64
    avg_fanout: float = 1.0                   # fallback for wildcard preds
    # per-node degrees: the first hop of a reach expansion from a known
    # candidate set uses the candidates' actual degrees instead of the
    # global average — on hub-heavy graphs the two differ by orders of
    # magnitude, and connection-edge cost estimates inherit the gap.
    out_degree: np.ndarray | None = None      # [N] float64
    in_degree: np.ndarray | None = None       # [N] float64

    def lit_sel(self, pa: int, n: int) -> float:
        table = self.literal_selectivity.get(pa)
        if not table:
            return 1.0
        if n in table:
            return table[n]
        ks = sorted(table)
        if n < ks[0]:
            return table[ks[0]]
        return table[ks[-1]]


def expected_reach(stats: DatasetStats, num_nodes: int, hops: int) -> float:
    """Expected reach-set size within `hops` hops of a random node: the
    geometric fanout series sum_{i<=h} avg_fanout^i, capped at |N|.
    Shared by connection_selectivity and the planner's reach-join cost
    model (pair-table sizes ~= distinct_endpoints * expected_reach)."""
    fan = max(float(stats.avg_fanout), 1.0)
    n = float(max(num_nodes, 1))
    return min(n, float(sum(fan ** i for i in range(max(hops, 0) + 1))))


def endpoint_reach(stats: DatasetStats, num_nodes: int, hops: int,
                   nodes: np.ndarray | None = None,
                   sign: int = +1) -> float:
    """Candidate-aware expected reach-set size: the first expansion hop
    uses the *actual* mean out-degree (sign=+1) or in-degree (sign=-1) of
    the given endpoint candidate nodes; later hops fall back to the global
    average fanout.

      R(h) = 1 + d1 * sum_{i<h} fan^i      (capped at |N|)

    With d1 == avg_fanout this collapses to expected_reach exactly, so
    callers without candidate values lose nothing.  On hub-heavy graphs a
    hub endpoint (d1 >> avg) gets the large reach estimate it deserves and
    a leaf endpoint a small one — which is what lets ConnectionPlan order
    hub edges after selective ones."""
    n = float(max(num_nodes, 1))
    if hops <= 0:
        return 1.0
    fan = max(float(stats.avg_fanout), 1.0)
    deg = stats.out_degree if sign > 0 else stats.in_degree
    if nodes is None or deg is None or len(nodes) == 0:
        d1 = fan
    else:
        d1 = float(np.mean(deg[np.asarray(nodes, dtype=np.int64)]))
    d1 = max(d1, 0.0)
    series = float(sum(fan ** i for i in range(hops)))   # 1 + fan + ...
    return min(n, 1.0 + d1 * series)


def connection_selectivity(stats: DatasetStats, num_nodes: int, d_c: int,
                           bidirectional: bool = False,
                           a_nodes: np.ndarray | None = None,
                           b_nodes: np.ndarray | None = None) -> float:
    """P(random node pair is connected within d_c hops) — the cardinality
    feature the whole-query join plan uses to order connection edges.

    Mirrors Algorithm 3's split: a forward reach set within ceil(d_c/2)
    hops must intersect a backward reach set within the remaining hops.
    Expected reach-set sizes come from endpoint_reach: candidate-aware
    (mean degree of the actual endpoint candidates for the first hop) when
    a_nodes/b_nodes are given, the global geometric fanout series
    otherwise.  Two independent uniform sets of sizes R_f, R_b over n
    nodes intersect with probability ~= R_f * R_b / n."""
    from .connectivity import hop_split
    h_fwd, h_bwd = hop_split(d_c)
    n = max(num_nodes, 1)
    sel = min(1.0, endpoint_reach(stats, n, h_fwd, a_nodes, +1)
              * endpoint_reach(stats, n, h_bwd, b_nodes, -1) / n)
    if bidirectional:
        sel = min(1.0, 2.0 * sel)
    return max(sel, 1.0 / (float(n) * n))


def predicate_selectivity(graph: RDFGraph) -> np.ndarray:
    counts = np.bincount(graph.pred, minlength=graph.num_predicates)
    return counts / max(graph.num_edges, 1)


def predicate_fanout(graph: RDFGraph) -> tuple[np.ndarray, np.ndarray, float]:
    """Per-predicate join fanout: |edges(p)| / #distinct src(p) (and dst).

    Feeds the sort-merge join planner's cardinality estimates: joining a
    table on node column q with the edge table of p multiplies its size by
    roughly this factor."""
    p_count = graph.num_predicates
    counts = np.bincount(graph.pred, minlength=p_count).astype(np.float64)
    src_fan = np.ones(p_count)
    dst_fan = np.ones(p_count)
    for ends, fan in ((graph.src, src_fan), (graph.dst, dst_fan)):
        pairs = np.unique(graph.pred.astype(np.int64) * (graph.num_nodes + 1)
                          + ends.astype(np.int64))
        distinct = np.bincount((pairs // (graph.num_nodes + 1)).astype(int),
                               minlength=p_count).astype(np.float64)
        np.divide(counts, np.maximum(distinct, 1.0), out=fan,
                  where=counts > 0)
    avg = float(graph.num_edges / max(graph.num_nodes, 1))
    return src_fan, dst_fan, max(avg, 1.0)


def node_degrees(graph: RDFGraph) -> tuple[np.ndarray, np.ndarray]:
    """Per-node (out_degree, in_degree) over all edges — the first-hop
    branching factors endpoint_reach uses for candidate-aware reach."""
    out_deg = np.bincount(graph.src, minlength=graph.num_nodes)
    in_deg = np.bincount(graph.dst, minlength=graph.num_nodes)
    return out_deg.astype(np.float64), in_deg.astype(np.float64)


def literal_selectivity(graph: RDFGraph, ns=(1, 2, 3, 4, 5, 6, 8),
                        sample: int = 20000,
                        seed: int = 0,
                        preds=None) -> dict[int, dict[int, float]]:
    """f_{n,pa}: avg #literals of pa matching a prefix n-gram, over the set
    of prefix n-grams of pa's literals, normalized by #unique literals.

    preds: optional predicate-id subset to compute.  The sampling rng is
    seeded per predicate, so a single predicate's table is identical
    whether computed alone (delta patching) or in a full pass.
    """
    out: dict[int, dict[int, float]] = {}
    for pa in (range(graph.num_predicates) if preds is None else preds):
        if graph.pred_kind[pa] != ATTR:
            continue
        mask = graph.pred == pa
        lits = np.unique(graph.dst[mask])
        labels = graph.labels[lits]
        if len(labels) > sample:
            rng = np.random.default_rng((seed, int(pa)))
            labels = rng.choice(labels, size=sample, replace=False)
        if len(labels) == 0:
            continue
        table = {}
        for n in ns:
            prefixes = np.asarray([s[:n] for s in labels])
            uniq, counts = np.unique(prefixes, return_counts=True)
            # avg #literals matching a prefix n-gram
            m = counts.mean()
            table[n] = float(m / len(labels))
        out[pa] = table
    return out


def _find_type_predicate(graph: RDFGraph) -> int | None:
    for name in ("type", "rdf:type", "a", "isA"):
        hits = np.nonzero(graph.predicates == name)[0]
        if len(hits):
            return int(hits[0])
    return None


def coherence_terms(graph: RDFGraph, type_pred: int,
                    types=None) -> dict[int, tuple[float, float]]:
    """Per-type coherence terms {type_id: (weight, coverage)}.

    ``types`` restricts computation to a subset (delta patching); types with
    no members or no member edges contribute no term, matching the skips of
    the historical single-pass loop."""
    tmask = graph.pred == type_pred
    inst, typ = graph.src[tmask], graph.dst[tmask]
    # predicates set per instance (excluding type edges)
    emask = ~tmask
    esrc, epred = graph.src[emask], graph.pred[emask]

    terms: dict[int, tuple[float, float]] = {}
    for t in (np.unique(typ) if types is None else types):
        members = inst[typ == t]
        if len(members) == 0:
            continue
        sel = np.isin(esrc, members)
        if not sel.any():
            continue
        ps, pinv = np.unique(epred[sel], return_inverse=True)
        ss = esrc[sel]
        # OC(p, T): #instances of T with >=1 edge of p
        pairs = np.unique(np.stack([pinv, ss]), axis=1)
        oc = np.bincount(pairs[0], minlength=len(ps))
        cv = oc.sum() / (len(ps) * len(members))
        w = len(ps) + len(members)
        terms[int(t)] = (float(w), float(cv))
    return terms


def coherence_from_terms(terms: dict[int, tuple[float, float]]) -> float:
    """Weighted sum over terms in ascending type order — the same
    accumulation order (np.unique is sorted) and arithmetic as the
    single-pass loop, so patched and from-scratch coherence agree
    bit-for-bit."""
    total_w = 0.0
    score = 0.0
    for t in sorted(terms):
        w, cv = terms[t]
        score += w * cv
        total_w += w
    return float(score / total_w) if total_w else 0.0


def coherence(graph: RDFGraph, type_pred: int | None = None) -> float:
    """Duan et al. SIGMOD'11 structuredness: coverage CV(T) = fraction of
    (instance, predicate) slots filled, weighted by (|P(T)| + |I(T)|)."""
    if type_pred is None:
        type_pred = _find_type_predicate(graph)
    if type_pred is None:
        return 0.0
    return coherence_from_terms(coherence_terms(graph, type_pred))


def _pearson_kurtosis(x: np.ndarray) -> float:
    x = np.asarray(x, dtype=np.float64)
    if len(x) < 2:
        return 1.0
    m = x.mean()
    v = ((x - m) ** 2).mean()
    if v <= 1e-12:
        return 1.0
    m4 = ((x - m) ** 4).mean()
    return float(m4 / (v * v))


def specialty_terms(graph: RDFGraph,
                    preds=None) -> dict[int, tuple[float, float]]:
    """Per-REL-predicate specialty terms {pred_id: (count, kurtosis)}.

    ``preds`` restricts computation to a subset (delta patching); non-REL
    or empty predicates contribute no term."""
    terms: dict[int, tuple[float, float]] = {}
    for p in (range(graph.num_predicates) if preds is None else preds):
        if graph.pred_kind[p] != REL:
            continue
        mask = graph.pred == p
        cnt = int(mask.sum())
        if cnt == 0:
            continue
        ks = _pearson_kurtosis(np.bincount(graph.src[mask]).astype(float)[
            np.bincount(graph.src[mask]) > 0])
        ko = _pearson_kurtosis(np.bincount(graph.dst[mask]).astype(float)[
            np.bincount(graph.dst[mask]) > 0])
        terms[int(p)] = (float(cnt), max(ks, ko))
    return terms


def specialty_from_terms(terms: dict[int, tuple[float, float]]) -> float:
    """Weighted mean over terms in ascending predicate order — same
    accumulation order and arithmetic as the single-pass loop."""
    total = 0.0
    wsum = 0.0
    for p in sorted(terms):
        cnt, kurt = terms[p]
        total += cnt * kurt
        wsum += cnt
    return float(total / wsum) if wsum else 0.0


def relationship_specialty(graph: RDFGraph) -> float:
    """Weighted Pearson-kurtosis of per-node occurrence counts of each
    relationship predicate.  Hubs can sit on either end (e.g. a prolific
    author is the *object* of many `author` edges), so we take the max of
    subject-side and object-side kurtosis per predicate."""
    return specialty_from_terms(specialty_terms(graph))


def literal_diversity(graph: RDFGraph, m_sample: int = 100_000,
                      seed: int = 0) -> int:
    """#unique whitespace words among literals of M sampled attribute edges."""
    attr_mask = graph.pred_kind[graph.pred] == ATTR
    idx = np.nonzero(attr_mask)[0]
    if len(idx) == 0:
        return 0
    rng = np.random.default_rng(seed)
    if len(idx) > m_sample:
        idx = rng.choice(idx, size=m_sample, replace=False)
    words = set()
    for lab in graph.labels[graph.dst[idx]]:
        words.update(lab.split())
    return len(words)


def compute_stats(graph: RDFGraph, m_sample: int = 100_000) -> DatasetStats:
    tp = _find_type_predicate(graph)
    src_fan, dst_fan, avg_fan = predicate_fanout(graph)
    out_deg, in_deg = node_degrees(graph)
    return DatasetStats(
        pred_selectivity=predicate_selectivity(graph),
        literal_selectivity=literal_selectivity(graph),
        coherence=coherence(graph, tp),
        specialty=relationship_specialty(graph),
        diversity=literal_diversity(graph, m_sample),
        type_pred=tp,
        src_fanout=src_fan,
        dst_fanout=dst_fan,
        avg_fanout=avg_fan,
        out_degree=out_deg,
        in_degree=in_deg,
    )
