"""Neighborhood containment check (paper Algorithm 1) — vectorized.

Host side derives, for one query node q, the *requirements*: for each
direction (forward/backward) and each distance d <= d_check, the set of
keyword id-intervals that must appear among a candidate's <=d-hop neighbors,
each with a minimum count.  Counts aggregate nested intervals (the paper's
"uniquely contains" rule): if interval I' is contained in I, matches of I'
also satisfy I, so required counts accumulate over contained intervals.

Device side gathers the candidates' NI rows per exact distance, counts ids
per interval with the interval_count kernel, cumulative-sums over distance,
and compares against the requirements.  Overflowed NI entries auto-pass
(prune only on certain information).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from .graph import RDFGraph
from .ni_index import NIIndex
from .query import QueryTemplate
from ..kernels import ops


@dataclass
class DirectionReqs:
    """Requirements in one direction for one query node."""
    # union of intervals referenced at any distance
    lo: np.ndarray          # [J] int64
    hi: np.ndarray          # [J] int64
    # per distance d (1-indexed -> row d-1): required count per interval
    # (0 = no requirement at that distance)
    need: np.ndarray        # [d_check, J] int32


@dataclass
class NodeReqs:
    fwd: DirectionReqs | None
    bwd: DirectionReqs | None

    @property
    def empty(self) -> bool:
        def e(r):
            return r is None or r.need.sum() == 0
        return e(self.fwd) and e(self.bwd)


def _query_distances(query: QueryTemplate, comp: set[int], q: int,
                     forward: bool) -> dict[int, int]:
    """Directed BFS distances from q inside one component."""
    adj: dict[int, list[int]] = {}
    for e in query.edges:
        if e.src in comp and e.dst in comp:
            if forward:
                adj.setdefault(e.src, []).append(e.dst)
            else:
                adj.setdefault(e.dst, []).append(e.src)
    dist = {q: 0}
    frontier = [q]
    d = 0
    while frontier:
        d += 1
        nxt = []
        for u in frontier:
            for v in adj.get(u, ()):
                if v not in dist:
                    dist[v] = d
                    nxt.append(v)
        frontier = nxt
    dist.pop(q)
    return dist


def build_requirements(query: QueryTemplate, comp: list[int], q: int,
                       d_check: int, intervals: np.ndarray) -> NodeReqs:
    """intervals: [Q, 2] keyword intervals from IDMap."""
    comp_set = set(comp)

    def one_direction(forward: bool) -> DirectionReqs | None:
        dist = _query_distances(query, comp_set, q, forward)
        within = [(u, d) for u, d in dist.items() if d <= d_check]
        if not within:
            return None
        ivs = sorted({(int(intervals[u][0]), int(intervals[u][1]))
                      for u, _ in within})
        lo = np.asarray([i[0] for i in ivs], dtype=np.int64)
        hi = np.asarray([i[1] for i in ivs], dtype=np.int64)
        need = np.zeros((d_check, len(ivs)), dtype=np.int32)
        # appearance count per (interval, distance)
        appear = np.zeros((d_check, len(ivs)), dtype=np.int32)
        idx = {iv: j for j, iv in enumerate(ivs)}
        for u, d in within:
            appear[d - 1, idx[(int(intervals[u][0]), int(intervals[u][1]))]] += 1
        cum = np.cumsum(appear, axis=0)          # within distance <= d
        # nested aggregation: need(I, d) = sum over I' contained in I
        for j, (l, h) in enumerate(ivs):
            contained = [j2 for j2, (l2, h2) in enumerate(ivs)
                         if l <= l2 and h2 <= h]
            need[:, j] = cum[:, contained].sum(axis=1)
        return DirectionReqs(lo=lo, hi=hi, need=need)

    return NodeReqs(fwd=one_direction(True), bwd=one_direction(False))


# ---------------------------------------------------------------------- #
import functools

import jax


@functools.partial(jax.jit, static_argnames=("use_sorted",))
def _gather_count(ids_dev, cands, lo_b, hi_b, use_sorted=True):
    """Device-fused gather + interval count: rows never leave the device.

    ids_dev [N, cap] (sorted rows, -1 pad); cands [C]; lo_b/hi_b [J]."""
    rows = ids_dev[cands]
    if use_sorted:
        big = jnp.iinfo(jnp.int32).max
        r = jnp.where(rows < 0, big, rows)
        bounds = jnp.concatenate([lo_b, hi_b])
        idx = jax.vmap(lambda row: jnp.searchsorted(row, bounds))(r)
        j = lo_b.shape[0]
        return idx[:, j:] - idx[:, :j]
    def one(bounds):
        l, h = bounds
        return jnp.sum((rows >= l) & (rows < h), axis=1, dtype=jnp.int32)
    return jax.lax.map(one, (lo_b, hi_b)).T


def _pow2(x, lo=256):
    return max(lo, 1 << (max(int(x), 1) - 1).bit_length())


def check_interval_candidates(ni: NIIndex, reqs: NodeReqs,
                              lo: int, hi: int, d_check: int,
                              *, impl: str = "auto",
                              chunk: int = 8192,
                              device_cache: dict | None = None) -> np.ndarray:
    """Pass mask (bool [hi-lo]) for candidates lo..hi-1 of one query node.

    device_cache: persistent {(sign, d): jnp ids} so the NI tensors are
    uploaded once per engine, not per query."""
    n_cand = hi - lo
    out = np.ones(n_cand, dtype=bool)
    if reqs.empty or n_cand == 0:
        return out
    d_check = min(d_check, ni.d_max)
    cache = device_cache if device_cache is not None else {}

    def dev_ids(sign, d):
        key = (sign, d)
        if key not in cache:
            cache[key] = jnp.asarray(ni.entries[sign * d].ids)
        return cache[key]

    # pad candidate ids to a pow2 bucket for jit shape stability
    c_pad = min(_pow2(n_cand), max(chunk, 256))
    for start in range(0, n_cand, c_pad):
        stop = min(start + c_pad, n_cand)
        cands = np.full(c_pad, lo, dtype=np.int32)
        cands[: stop - start] = np.arange(lo + start, lo + stop)
        cands_dev = jnp.asarray(cands)
        ok = np.ones(stop - start, dtype=bool)
        for sign, dreq in ((+1, reqs.fwd), (-1, reqs.bwd)):
            if dreq is None or not dreq.need.any():
                continue
            j = dreq.lo.shape[0]
            j_pad = max(4, 1 << (j - 1).bit_length())
            lo_b = np.zeros(j_pad, np.int32)
            hi_b = np.zeros(j_pad, np.int32)
            lo_b[:j] = dreq.lo
            hi_b[:j] = dreq.hi
            lo_dev, hi_dev = jnp.asarray(lo_b), jnp.asarray(hi_b)
            cum = np.zeros((stop - start, j), dtype=np.int64)
            over = np.zeros(stop - start, dtype=bool)
            max_d = int(np.max(np.nonzero(dreq.need.any(axis=1))[0]) + 1)
            for d in range(1, min(d_check, max_d) + 1):
                entry = ni.entries[sign * d]
                cnt = np.asarray(_gather_count(
                    dev_ids(sign, d), cands_dev, lo_dev, hi_dev))
                cum += cnt[: stop - start, :j]
                over |= entry.overflow[cands[: stop - start]]
                if dreq.need[d - 1].sum() > 0:
                    sat = (cum >= dreq.need[d - 1][None, :]).all(axis=1)
                    ok &= sat | over
        out[start:stop] = ok
    return out


# ---------------------------------------------------------------------- #
# Bloom/bitstring signature prefilter (gStore-style; uses the
# bitmask_contains kernel).  Sound one-sided filter for EXACT-keyword
# neighborhoods: if a required neighbor id's bits are not contained in a
# candidate's signature, the candidate cannot have that neighbor.
# ---------------------------------------------------------------------- #
BLOOM_WORDS = 8      # 256-bit signatures
_BLOOM_K = 2


def _bloom_bits(ids: np.ndarray, words: int = BLOOM_WORDS):
    """Bit positions (k hashes) for each id; ids int64 array."""
    n_bits = 32 * words
    h1 = (ids.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)) \
        >> np.uint64(40)
    h2 = (ids.astype(np.uint64) * np.uint64(0xBF58476D1CE4E5B9)) \
        >> np.uint64(40)
    return (h1 % n_bits).astype(np.int64), (h2 % n_bits).astype(np.int64)


def build_bloom(entry, words: int = BLOOM_WORDS) -> np.ndarray:
    """[N, words] uint32 signatures of each node's neighbor-id set."""
    n, cap = entry.ids.shape
    sig = np.zeros((n, words), np.uint32)
    ids = entry.ids
    valid = ids >= 0
    rows = np.repeat(np.arange(n), cap).reshape(n, cap)[valid]
    flat = ids[valid].astype(np.int64)
    for bits in _bloom_bits(flat, words):
        word, bit = bits // 32, bits % 32
        np.bitwise_or.at(sig, (rows, word.astype(np.int64)),
                         (np.uint32(1) << bit.astype(np.uint32)))
    return sig


def bloom_query_sig(required_ids: np.ndarray,
                    words: int = BLOOM_WORDS) -> np.ndarray:
    sig = np.zeros(words, np.uint32)
    for bits in _bloom_bits(required_ids.astype(np.int64), words):
        word, bit = bits // 32, bits % 32
        np.bitwise_or.at(sig, word.astype(np.int64),
                         np.uint32(1) << bit.astype(np.uint32))
    return sig


def bloom_prefilter(sigs: np.ndarray, entry, reqs: NodeReqs,
                    lo: int, hi: int, *, impl: str = "auto") -> np.ndarray:
    """Pass mask over candidates lo..hi using 1-hop bloom signatures.

    Only exact keywords (interval width 1) participate; wider intervals
    cannot be expressed as bits (the reason the paper's NI generalizes
    gStore-style signatures).  Overflowed entries auto-pass."""
    n_cand = hi - lo
    dreq = reqs.fwd
    if dreq is None or not dreq.need.any():
        return np.ones(n_cand, dtype=bool)
    exact = [(int(l),) for l, h, need in
             zip(dreq.lo, dreq.hi, dreq.need[0])
             if h - l == 1 and need > 0] if dreq.need.shape[0] else []
    if not exact:
        return np.ones(n_cand, dtype=bool)
    required = np.asarray([e[0] for e in exact], np.int64)
    qsig = bloom_query_sig(required)
    ok = np.asarray(ops.bitmask_contains(sigs[lo:hi], qsig, impl=impl),
                    dtype=bool)
    return ok | entry.overflow[lo:hi]
