"""Query templates (paper §1.1).

A template is a small directed graph whose nodes carry *partial keywords*
(prefixes of RDF labels; '' = wildcard) and whose edges are either predicate
edges (pred id, or None for wildcard predicate) or *connection edges* with a
distance constraint.

Matching semantics: **subgraph isomorphism** (injective node mapping), per
the paper's §1 ("graph template matching (based on subgraph isomorphism)").
Injectivity is what makes the count-based neighborhood check (Algorithm 1's
{Distance, Count} pairs) a sound pruning rule: c distinct query nodes with
keyword p within d hops force >= c distinct matching neighbors.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .graph import RDFGraph, IDMap


@dataclass(frozen=True)
class QueryEdge:
    src: int
    dst: int
    pred: int | None = None      # None = wildcard predicate


@dataclass(frozen=True)
class ConnectionEdge:
    src: int
    dst: int
    max_dist: int                # E: distance (shortest path) <= max_dist
    bidirectional: bool = False  # if True, also accept dst ->* src


@dataclass
class QueryTemplate:
    keywords: list[str]                       # per query node
    edges: list[QueryEdge] = field(default_factory=list)
    connections: list[ConnectionEdge] = field(default_factory=list)

    @property
    def num_nodes(self) -> int:
        return len(self.keywords)

    @property
    def size(self) -> int:
        """Paper's "query size" = number of template nodes."""
        return self.num_nodes

    # -------------------------------------------------------------- #
    def components(self) -> list[list[int]]:
        """Connected components after removing connection edges."""
        parent = list(range(self.num_nodes))

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for e in self.edges:
            a, b = find(e.src), find(e.dst)
            if a != b:
                parent[a] = b
        comps: dict[int, list[int]] = {}
        for v in range(self.num_nodes):
            comps.setdefault(find(v), []).append(v)
        return list(comps.values())

    def component_edges(self, comp: list[int]) -> list[QueryEdge]:
        s = set(comp)
        return [e for e in self.edges if e.src in s and e.dst in s]

    def intervals(self, idmap: IDMap) -> np.ndarray:
        """[Q, 2] keyword id-intervals (lo inclusive, hi exclusive)."""
        return np.asarray([idmap.interval(k) for k in self.keywords],
                          dtype=np.int64)


# ---------------------------------------------------------------------- #
# Brute-force oracle (host, exponential) — ground truth for tests.
# ---------------------------------------------------------------------- #
def brute_force_match(graph: RDFGraph, query: QueryTemplate,
                      limit: int = 1_000_000) -> set[tuple[int, ...]]:
    """All homomorphisms query -> graph satisfying keyword, predicate-edge
    and connection-edge constraints.  Exponential; small inputs only."""
    idmap = IDMap(graph)
    iv = query.intervals(idmap)
    n_q = query.num_nodes

    # adjacency dicts for the small-graph oracle
    out_adj: dict[int, list[tuple[int, int]]] = {}
    for s, d, p in zip(graph.src, graph.dst, graph.pred):
        out_adj.setdefault(int(s), []).append((int(d), int(p)))

    def bfs_within(a: int, h: int) -> set[int]:
        seen = {a}
        frontier = {a}
        for _ in range(h):
            nxt = set()
            for u in frontier:
                for v, _ in out_adj.get(u, ()):
                    if v not in seen:
                        seen.add(v)
                        nxt.add(v)
            frontier = nxt
        return seen

    def conn_ok(a: int, b: int, c: ConnectionEdge) -> bool:
        if b in bfs_within(a, c.max_dist):
            return True
        if c.bidirectional and a in bfs_within(b, c.max_dist):
            return True
        return False

    # order query nodes: connected-first greedy for pruning
    order = list(range(n_q))
    results: set[tuple[int, ...]] = set()
    assign: list[int | None] = [None] * n_q

    edges_by_node: dict[int, list[QueryEdge]] = {}
    for e in query.edges:
        edges_by_node.setdefault(e.src, []).append(e)
        edges_by_node.setdefault(e.dst, []).append(e)

    def edge_ok(e: QueryEdge) -> bool:
        s, d = assign[e.src], assign[e.dst]
        if s is None or d is None:
            return True
        for v, p in out_adj.get(s, ()):
            if v == d and (e.pred is None or p == e.pred):
                return True
        return False

    def rec(i: int):
        if len(results) >= limit:
            return
        if i == n_q:
            for c in query.connections:
                if not conn_ok(assign[c.src], assign[c.dst], c):
                    return
            results.add(tuple(assign))  # type: ignore[arg-type]
            return
        q = order[i]
        lo, hi = iv[q]
        taken = {assign[order[k]] for k in range(i)}
        for cand in range(int(lo), int(hi)):
            if cand in taken:     # injectivity (subgraph isomorphism)
                continue
            assign[q] = cand
            if all(edge_ok(e) for e in edges_by_node.get(q, ())):
                rec(i + 1)
            assign[q] = None

    rec(0)
    return results
