"""D-tree decomposition of a query component (paper Algorithm 2, step 1).

A D-tree is a height-1 directed tree: a root query node plus the query
edges incident to it that are still uncovered.  The decomposition is the
CLRS 2-approximation vertex cover driven by the selectivity function
S(q) = deg(q) / |C_q| — prefer high degree (covers more edges) and small
candidate sets (fewer D-tree candidates).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .query import QueryTemplate, QueryEdge


@dataclass
class DTree:
    root: int
    # edges incident to root: (pred, child, outgoing?) — outgoing means
    # root -> child in the template.
    edges: list[tuple[int | None, int, bool]] = field(default_factory=list)

    @property
    def nodes(self) -> list[int]:
        return [self.root] + [c for _, c, _ in self.edges]


def decompose(query: QueryTemplate, comp: list[int],
              cand_sizes: dict[int, int]) -> list[DTree]:
    """Decompose one component into D-trees covering all its edges."""
    remaining = list(query.component_edges(comp))
    if not remaining:
        return [DTree(root=comp[0])] if len(comp) == 1 else \
               [DTree(root=v) for v in comp]

    def degree(v: int) -> int:
        return sum(1 for e in remaining if v in (e.src, e.dst))

    def S(v: int) -> float:
        return degree(v) / max(cand_sizes.get(v, 1), 1)

    trees: list[DTree] = []
    while remaining:
        # pick edge maximizing S(src) + S(dst)
        best = max(remaining, key=lambda e: S(e.src) + S(e.dst))
        for root in (best.src, best.dst):
            mine = [e for e in remaining if root in (e.src, e.dst)]
            if not mine:
                continue
            t = DTree(root=root)
            for e in mine:
                if e.src == root:
                    t.edges.append((e.pred, e.dst, True))
                else:
                    t.edges.append((e.pred, e.src, False))
            trees.append(t)
            remaining = [e for e in remaining if e not in mine]
    return trees


def join_order(trees: list[DTree], cand_counts: list[int]) -> list[int]:
    """Paper's join order: start from the smallest candidate set, repeatedly
    add the smallest-candidate tree that shares a query node with the
    already-joined set (fall back to global smallest if disconnected).

    This is the seed heuristic, kept as the `plan_mode="greedy"` baseline
    and as the comparison order the cost-based planner
    (`planner.plan_table_joins`) evaluates under its own cost model; the
    engine executes the planner's order by default."""
    n = len(trees)
    order = []
    used = [False] * n
    joined_nodes: set[int] = set()
    for _ in range(n):
        best, best_connected = None, False
        for i in range(n):
            if used[i]:
                continue
            connected = bool(joined_nodes.intersection(trees[i].nodes))
            key = (connected, -cand_counts[i])
            if best is None or key > ((best_connected, -cand_counts[best])):
                best, best_connected = i, connected
        order.append(best)
        used[best] = True
        joined_nodes.update(trees[best].nodes)
    return order
