"""RDF-ℏ query engine (paper Fig. 2 pipeline), split into prepare/execute
phases for the serving layer.

Pipeline per query: separate connection edges → IDMap candidate intervals →
(policy-dependent) neighborhood check → per-component D-tree decomposition →
edge-parallel D-tree candidate generation → cost-based whole-query join plan
(planner.plan_table_joins over System-R estimates, sort-run-reuse aware) →
connection-edge evaluation (intra-table filters first, then cross-component
connectivity joins in planner.plan_connections order) → final match table.
EngineConfig.plan_mode='greedy' keeps the seed's smallest-first heuristics
for A/B comparison.

Prepare/execute split (`Engine.prepare` / `Engine.execute_prepared`):
everything that depends only on (dataset, template) — candidate intervals,
D-tree decomposition, the §4.3 check decision — is computed once into a
`PreparedQuery`.  The first execution additionally *learns* the
data-determined parts of the plan into it: per-component join orders, the
connection-edge order, the candidate masks, and the exact join output
sizes (`join_seq`).  Repeat executions replay all of that — no planning
DP, no signature check, no capacity-overflow retries, and byte-identical
jit shapes (so XLA's compilation cache always hits).  The serving layer
(`repro.serve`) caches PreparedQuery objects keyed by canonical template
fingerprint; `Engine.execute` keeps the one-shot behavior by preparing
fresh per call.

Engine variants (paper §6):
  STWIG+      check_policy='never',     any index (1-hop suffices)
  SPath(NI2)  check_policy='always',    d_check=2
  ℏ-2Hops     check_policy='selective', d_check=2
  ℏ-3Hops     check_policy='selective', d_check=3
  ℏ-VC        check_policy='selective', d_check=2, NI variant='vc'
"""
from __future__ import annotations

import math
import time
import warnings
from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from .graph import RDFGraph
from .ni_index import NIIndex
from .dataset import Dataset, ENGINE_VARIANTS, interval_footprint_hit
from .query import QueryTemplate, ConnectionEdge
from .signature import (build_requirements, check_interval_candidates,
                        build_bloom, bloom_prefilter)
from .decompose import decompose, join_order, DTree
from .matching import (Table, CapacityOverflow, dtree_candidates,
                       cross_join, single_node_table, filter_rows,
                       injective_filter, planned_join, _pow2,
                       JoinTelemetry)
from .connectivity import (connectivity_mask, reach_join, reach_filter,
                           ReachCache, ReachJoinInfo,
                           distinct_column_values, hop_split)
from .planner import (Thresholds, CostModel, PlanDecision, decide,
                      JoinEstimator, ReplayEstimator,
                      plan_table_joins, plan_connections, ConnFeatures,
                      choose_connection_impl)
from .stats import DatasetStats, connection_selectivity, endpoint_reach
from ..obs.trace import NULL_TRACER


@dataclass
class EngineConfig:
    check_policy: str = "selective"     # never | always | selective
    d_check: int = 2                    # hops used by the neighborhood check
    impl: str = "auto"                  # kernel impl (auto|pallas|interpret|ref)
    thresholds: Thresholds = field(default_factory=Thresholds)
    chunk: int = 8192
    max_rows: int | None = 1 << 20   # LIMIT guard for explosive joins
    use_bloom: bool = False          # gStore-style 1-hop bitstring prefilter
    join_impl: str = "auto"     # auto (planner per-join) | sorted | radix | nested
    plan_mode: str = "cost"          # whole-query join order: cost | greedy
    # fused sort-merge chain (kernels.fused_join: pack→sort→probe→expand
    # in one dispatch).  False = staged per-op dispatches (A/B baseline,
    # also what the chaos harness uses to exercise the staged seams).
    fuse_joins: bool = True
    # connection-edge strategy: 'reach' = device-resident reach-join
    # (distinct endpoints -> reach-set pair tables -> one sort-merge join
    # on reach_id -> equi-joins back; O(matches) output work), 'cross' =
    # the seed cross-product + per-pair connectivity_mask filter
    # (O(|A|*|B|), kept for A/B), 'auto' = per-edge cost-model choice.
    connection_impl: str = "auto"    # auto | reach | cross
    # calibrated multiplicative corrections to the analytic cost model
    # (serve.Calibrator learns these online; defaults = hardcoded model)
    cost_model: CostModel = field(default_factory=CostModel)


@dataclass
class QueryStats:
    used_check: bool = False
    truncated: bool = False
    plan: PlanDecision | None = None
    candidates_before: int = 0
    candidates_after: int = 0
    prepare_time: float = 0.0           # template planning (0 on cache hits)
    check_time: float = 0.0
    match_time: float = 0.0
    conn_time: float = 0.0
    total_time: float = 0.0
    cache_hit: bool = False             # executed from a warm PreparedQuery
    result_cache_hit: bool = False      # served from the ResultCache
    join_work: int = 0                  # Σ |A|*|B| over joins (work proxy)
    dtree_work: int = 0                 # Σ D-tree candidate rows generated
    # join planner telemetry
    join_strategies: dict = field(default_factory=dict)  # impl -> #joins
    join_retries: int = 0               # capacity-overflow recompiles
    n_estimated_joins: int = 0
    join_est_rows: int = 0              # Σ estimated output rows
    join_actual_rows: int = 0           # Σ actual output rows
    join_est_log_err: float = 0.0       # Σ |ln(est/actual)| (accuracy)
    join_est_log_bias: float = 0.0      # Σ ln(est/actual) (signed bias)
    # whole-query plan telemetry
    plan_mode: str = "cost"             # join order used (cost | greedy)
    sorts_performed: int = 0            # sort-merge sorts actually run
    sorts_avoided: int = 0              # skipped via sort-order/cached runs
    plan_cost: float = 0.0              # Σ est cost of executed join plans
    greedy_plan_cost: float = 0.0       # same cost model, greedy order
    # connection-edge telemetry (reach-join subsystem)
    conn_strategies: dict = field(default_factory=dict)  # impl -> #edges
    conn_reach_pairs: int = 0           # Σ (node, reach_id) pairs gathered
    conn_connected_pairs: int = 0       # Σ deduped connected endpoint pairs
    conn_endpoint_rows: int = 0         # Σ endpoint-column rows seen
    conn_endpoint_distinct: int = 0     # Σ distinct endpoint nodes seen
    conn_est_pairs: float = 0.0         # Σ predicted connected pairs
    conn_est_reach_pairs: float = 0.0   # Σ predicted pair-table rows
    # serving-tier degradation ladder (repro.serve.governor): names of the
    # rungs walked before this execution succeeded, in order — empty for a
    # healthy primary execution.  The Calibrator skips degraded stats.
    degraded_steps: list = field(default_factory=list)
    budget_checks: int = 0              # cooperative budget checkpoints hit

    # Stable flat schema: scalar counters first, then the two strategy
    # dicts and a plan summary.  Server telemetry rollups and benchmarks
    # consume this instead of re-plucking fields ad hoc; a schema test
    # pins the key set, so extend it deliberately.
    _SCALAR_FIELDS = (
        "used_check", "truncated", "cache_hit", "result_cache_hit",
        "candidates_before", "candidates_after",
        "prepare_time", "check_time", "match_time", "conn_time",
        "total_time",
        "join_work", "dtree_work",
        "join_retries", "n_estimated_joins",
        "join_est_rows", "join_actual_rows",
        "join_est_log_err", "join_est_log_bias",
        "plan_mode", "sorts_performed", "sorts_avoided",
        "plan_cost", "greedy_plan_cost",
        "conn_reach_pairs", "conn_connected_pairs",
        "conn_endpoint_rows", "conn_endpoint_distinct",
        "conn_est_pairs", "conn_est_reach_pairs",
        "budget_checks",
    )

    def to_dict(self) -> dict:
        """JSON-serializable snapshot with a stable key set."""
        out = {}
        for k in self._SCALAR_FIELDS:
            v = getattr(self, k)
            if isinstance(v, (bool, str)):
                out[k] = v
            elif isinstance(v, float):
                out[k] = float(v)
            else:
                out[k] = int(v)
        out["degraded_steps"] = [str(s) for s in self.degraded_steps]
        out["join_strategies"] = {str(k): int(v)
                                  for k, v in self.join_strategies.items()}
        out["conn_strategies"] = {str(k): int(v)
                                  for k, v in self.conn_strategies.items()}
        p = self.plan
        out["plan"] = None if p is None else {
            "use_check": bool(p.use_check),
            "complex_query": bool(p.complex_query),
            "max_selectivity": float(p.max_selectivity),
            "est_iterations": float(p.est_iterations),
            "est_join_product": float(p.est_join_product),
        }
        return out


@dataclass
class MatchResult:
    cols: tuple[int, ...]
    rows: np.ndarray                    # [count, num query nodes]
    stats: QueryStats

    @property
    def count(self) -> int:
        return int(self.rows.shape[0])

    def result_set(self) -> set[tuple[int, ...]]:
        order = np.argsort(self.cols)
        return {tuple(int(r[i]) for i in order) for r in self.rows}


@dataclass
class PreparedQuery:
    """Template-level execution state: computed once by `Engine.prepare`,
    enriched by the first `execute_prepared` run, replayed by every later
    one.  `repro.serve.plan_cache.PlanCache` LRU-caches these keyed by
    (dataset id, canonical template fingerprint).

    prepare() fills the template-dependent fields: candidate intervals,
    component split, D-tree decomposition, and the §4.3 pruning decision.
    The first execution learns the data-determined plan — per-component
    join orders (`comp_orders`, from the Selinger DP over *actual* table
    counts), the connection-edge order (`conn_order`), the candidate pass
    masks (`masks`, device-resident), and the exact output size of every
    estimator-sized join in engine call order (`join_seq`).  Execution of
    a fixed template against an immutable dataset is deterministic, so
    replaying them is exact: warm runs skip the planning DP, the
    signature check, and all capacity-overflow retries, and touch only
    jit shapes already compiled."""
    query: QueryTemplate
    iv: np.ndarray                      # [Q, 2] candidate intervals
    cand_sizes: dict[int, int]
    comps: list[list[int]]
    trees_per_comp: list[list[DTree]]
    decision: PlanDecision | None
    use_check: bool
    fingerprint: str | None = None
    version: int = 0                    # calibration version at prepare time
    prepare_time: float = 0.0
    # learned on first execution ------------------------------------- #
    executions: int = 0
    masks: tuple | None = None          # (pass_masks, pass_np, after)
    # host-only serializable form of `masks` — (pass_np, after) with no
    # device arrays.  Written by snapshot serialization
    # (repro.serve.snapshot); `_candidate_masks` rebuilds the device
    # arrays from it lazily on the first post-restore execution, so a
    # restored plan never re-runs the signature check
    masks_host: tuple | None = None
    comp_orders: dict = field(default_factory=dict)   # comp idx -> order
    comp_costs: dict = field(default_factory=dict)    # comp idx -> (c, g)
    conn_order: list[int] | None = None
    conn_costs: tuple[float, float] = (0.0, 0.0)
    # per-edge strategy choices in processing order: replayed on warm
    # runs so a calibrator-moved cost model cannot flip a strategy
    # mid-replay and desync the recorded join_seq
    conn_impls: list[str] | None = None
    # (actual output rows, executed pow2 capacity, join strategy) per
    # estimator-sized join, in engine call order.  Replaying the capacity
    # (not just the row count) means warm run 1 allocates the exact
    # steady-state jit shapes the cold run ended at — including joins
    # whose cold run took an overflow retry, where the final capacity
    # differs from what the row count alone would re-derive.  Replaying
    # the strategy keeps the per-join sorted/radix/nested choice — which
    # depends on sort-run state that only exists mid-execution — stable
    # across warm runs (join_strategies round-trips exactly).
    join_seq: list[tuple[int, int, str]] = field(default_factory=list)
    # planner estimate per join_seq entry (None for unestimated joins),
    # recorded cold alongside join_seq — EXPLAIN renders estimated vs.
    # observed cardinality per join from the two in lockstep
    join_est_seq: list[int | None] = field(default_factory=list)

    @property
    def warm(self) -> bool:
        return self.executions > 0

    def reset_learned(self) -> None:
        """Drop everything the first execution learned (masks, join
        orders, join_seq) while keeping the template-level fields.  Used
        when a revalidation decides the learned state can't be replayed —
        a flipped §4.3 decision, or a delta that touched the template's
        candidate footprint."""
        self.masks = None
        self.masks_host = None
        self.comp_orders = {}
        self.comp_costs = {}
        self.conn_order = None
        self.conn_costs = (0.0, 0.0)
        self.conn_impls = None
        self.join_seq = []
        self.join_est_seq = []
        self.executions = 0


class Engine:
    def __init__(self, dataset: "Dataset | RDFGraph",
                 ni: "NIIndex | EngineConfig | None" = None,
                 cfg: EngineConfig | None = None,
                 stats: DatasetStats | None = None):
        """Primary form: ``Engine(dataset, cfg)`` over a
        ``repro.core.Dataset``.  The legacy ``Engine(graph, ni, cfg,
        stats)`` form still works and wraps its pieces in a version-0
        Dataset."""
        if isinstance(dataset, Dataset):
            if isinstance(ni, EngineConfig) and cfg is None:
                cfg = ni
                ni = None
            if ni is not None or stats is not None:
                raise ValueError(
                    "pass ni/stats via the Dataset, not alongside it")
            ds = dataset
        else:
            if not isinstance(ni, NIIndex):
                raise TypeError("Engine(graph, ...) requires an NI index; "
                                "construct a repro.core.Dataset instead")
            ds = Dataset.build(dataset, ni=ni, stats=stats)
        self.dataset = ds
        self.graph = ds.graph
        self.ni = ds.ni
        self.cfg = cfg or EngineConfig()
        self.idmap = ds.idmap
        self.stats = ds.stats
        self._dev_cache: dict = {}      # device-resident NI tensors
        self._bloom = None              # lazy 1-hop bloom signatures
        # optional server-owned reach cache shared across queries (reach
        # sets go stale only via Dataset.apply_delta, which the serving
        # tier pairs with ReachCache.invalidate_delta); when None each
        # execution gets its own per-query cache as before
        self.reach_cache: ReachCache | None = None
        # observability: the serving layer installs its Tracer here; the
        # default no-op tracer keeps bare-engine hot paths at ~zero cost
        self.tracer = NULL_TRACER

    # -------------------------------------------------------------- #
    def prepare(self, query: QueryTemplate,
                fingerprint: str | None = None,
                version: int = 0) -> PreparedQuery:
        """Template-dependent planning: intervals, decomposition, and the
        §4.3 check decision.  No candidate data is touched."""
        t0 = time.perf_counter()
        cfg = self.cfg
        iv = query.intervals(self.idmap)
        cand_sizes = {q: int(iv[q, 1] - iv[q, 0])
                      for q in range(query.num_nodes)}
        comps = query.components()
        trees_per_comp = [decompose(query, comp, cand_sizes)
                          for comp in comps]
        decision = None
        if cfg.check_policy == "always":
            use_check = True
        elif cfg.check_policy == "never":
            use_check = False
        else:
            decision = decide(query, trees_per_comp, cand_sizes, self.stats,
                              cfg.thresholds, k=cfg.d_check)
            use_check = decision.use_check
        return PreparedQuery(
            query=query, iv=iv, cand_sizes=cand_sizes, comps=comps,
            trees_per_comp=trees_per_comp, decision=decision,
            use_check=use_check, fingerprint=fingerprint, version=version,
            prepare_time=time.perf_counter() - t0)

    def execute(self, query: QueryTemplate) -> MatchResult:
        return self.execute_prepared(self.prepare(query))

    def with_config(self, cfg: EngineConfig) -> "Engine":
        """A sibling engine over the same dataset with a different
        configuration: shares the graph, NI index, IDMap, dataset stats,
        device tensor cache, and bloom signatures (all immutable or
        append-only caches), but NOT the server-owned reach cache — a
        degraded retry (repro.serve.governor) must execute in isolation
        from state a faulty primary run may have touched, so the sibling
        falls back to per-query reach caches."""
        eng = object.__new__(Engine)
        eng.dataset = self.dataset
        eng.graph = self.graph
        eng.ni = self.ni
        eng.cfg = cfg
        eng.idmap = self.idmap
        eng.stats = self.stats
        eng._dev_cache = self._dev_cache
        eng._bloom = self._bloom
        eng.reach_cache = None
        eng.tracer = self.tracer    # degraded-rung spans land in the
        return eng                  # same trace as the primary attempt

    def revalidate(self, pq: PreparedQuery, version: int) -> bool:
        """Refresh a PreparedQuery after the calibrated thresholds moved.

        Only the §4.3 check decision depends on the thresholds, and
        re-deciding is cheap (pure template arithmetic) — so instead of
        discarding the plan, re-run `decide` and keep everything learned
        (masks, join orders, join_seq) whenever the decision is stable.
        A flipped decision changes the candidate masks and hence every
        downstream table, so then the learned execution state is reset
        (the template-level fields stay valid).  Returns True iff the
        learned state survived."""
        cfg = self.cfg
        kept = True
        if cfg.check_policy == "selective":
            decision = decide(pq.query, pq.trees_per_comp, pq.cand_sizes,
                              self.stats, cfg.thresholds, k=cfg.d_check)
            if decision.use_check != pq.use_check:
                pq.reset_learned()
                kept = False
            pq.decision = decision
            pq.use_check = decision.use_check
        pq.version = version
        return kept

    def revalidate_delta(self, pq: PreparedQuery,
                         touched: np.ndarray | None) -> bool:
        """Refresh a PreparedQuery after a Dataset delta (same digest
        lineage, bumped version, stable label space).

        The only learned state a data change can make *wrong* is the
        candidate masks — every pass bit is a function of the NI rows of
        the candidates in the template's intervals, and stale join
        orders/capacities/strategies self-heal (planned_join retries on
        overflow, ReplayEstimator falls back to analytic estimates).  So
        the plan survives intact iff no touched node falls inside any of
        its candidate intervals; otherwise the learned state resets and
        the next execution re-learns against the new data.  Returns True
        iff the learned state survived."""
        iv_pairs = [(int(pq.iv[q, 0]), int(pq.iv[q, 1]))
                    for q in range(pq.query.num_nodes)]
        if interval_footprint_hit(iv_pairs, touched):
            pq.reset_learned()
            return False
        return True

    # -------------------------------------------------------------- #
    def _candidate_masks(self, pq: PreparedQuery) -> tuple:
        """Per-node candidate pass specs.  With the check on, each node
        gets a [N] bool mask.  Without it the candidate set IS the IDMap
        interval — represented as a (lo, hi) pair instead of materializing
        an all-true [N] mask per query node (edge_pairs and
        single_node_table consume both forms), so the wildcard path
        allocates nothing per node.  Deterministic per (dataset,
        template): cached on the PreparedQuery, so warm executions skip
        the whole signature check."""
        if pq.masks is not None:
            return pq.masks
        if pq.masks_host is not None:
            # warm restart: rebuild device arrays from the snapshot's
            # host-form masks — no signature check, no bloom, no NI
            # touch; the restored plan replays exactly like a warm one
            host_np, after = pq.masks_host
            pass_masks = {}
            for comp in pq.comps:
                for q in comp:
                    m = host_np.get(q)
                    if m is not None:
                        pass_masks[q] = jnp.asarray(m)
                    else:
                        lo, hi = int(pq.iv[q, 0]), int(pq.iv[q, 1])
                        pass_masks[q] = (jnp.int32(lo), jnp.int32(hi))
            pq.masks = (pass_masks, host_np, after)
            return pq.masks
        cfg = self.cfg
        query, iv = pq.query, pq.iv
        n = self.graph.num_nodes
        pass_masks: dict[int, object] = {}
        pass_np: dict[int, np.ndarray | None] = {}
        after = 0
        for comp in pq.comps:
            for q in comp:
                lo, hi = int(iv[q, 0]), int(iv[q, 1])
                if pq.use_check:
                    mask = np.zeros(n, dtype=bool)
                    reqs = build_requirements(query, comp, q,
                                              min(cfg.d_check, self.ni.d_max), iv)
                    ok = np.ones(hi - lo, dtype=bool)
                    if cfg.use_bloom and hi > lo:
                        if self._bloom is None:
                            self._bloom = build_bloom(self.ni.entries[1])
                        ok &= bloom_prefilter(self._bloom,
                                              self.ni.entries[1], reqs,
                                              lo, hi, impl=cfg.impl)
                    if ok.any():
                        ok &= check_interval_candidates(
                            self.ni, reqs, lo, hi,
                            min(cfg.d_check, self.ni.d_max),
                            impl=cfg.impl, chunk=cfg.chunk,
                            device_cache=self._dev_cache)
                    mask[lo:hi] = ok
                    pass_np[q] = mask
                    pass_masks[q] = jnp.asarray(mask)
                    after += int(mask.sum())
                else:
                    pass_np[q] = None
                    pass_masks[q] = (jnp.int32(lo), jnp.int32(hi))
                    after += hi - lo
        pq.masks = (pass_masks, pass_np, after)
        return pq.masks

    def execute_prepared(self, pq: PreparedQuery,
                         budget=None) -> MatchResult:
        """`budget` is an optional duck-typed cooperative budget (see
        repro.serve.governor.Budget): the engine calls
        ``budget.checkpoint(phase, rows=..., cap=..., stats=qs)`` at every
        estimator-sized join and at each pipeline phase boundary, and the
        budget raises its own typed error (carrying the partial QueryStats
        it was handed) when a bound is blown.  The core never imports the
        serving layer — any object with that method works."""
        t0 = time.perf_counter()
        qs = QueryStats()
        cfg = self.cfg
        query, iv, cand_sizes = pq.query, pq.iv, pq.cand_sizes
        qs.candidates_before = sum(cand_sizes.values())
        qs.plan = pq.decision
        qs.used_check = pq.use_check
        qs.cache_hit = pq.warm
        qs.prepare_time = 0.0 if pq.warm else pq.prepare_time
        # current pipeline phase, mutated at phase boundaries so the
        # record_join checkpoint attributes budget aborts to the right
        # phase without threading a phase argument through the join stack
        phase = ["check"]

        def checkpoint(rows=0, cap=0):
            if budget is not None:
                qs.budget_checks += 1
                budget.checkpoint(phase[0], rows=rows, cap=cap, stats=qs)

        # ---- candidate masks ------------------------------------------
        t1 = time.perf_counter()
        tracer = self.tracer
        with tracer.span("check") as sp:
            pass_masks, pass_np, after = self._candidate_masks(pq)
            if sp.live:
                sp.set(used_check=pq.use_check,
                       before=qs.candidates_before, after=after,
                       warm=pq.warm)
        qs.candidates_after = after
        qs.check_time = time.perf_counter() - t1
        # deadline-only checkpoint: candidate counts are not join rows,
        # so they don't charge the max_rows budget
        checkpoint()

        # ---- per-component matching -----------------------------------
        t2 = time.perf_counter()
        base_est = JoinEstimator(self.stats, cand_sizes,
                                 scale=cfg.cost_model.join_est_scale)
        # warm runs replay the exact join sizes observed on the first
        # execution; cold runs record them as they happen (restarting the
        # recording, so a previously failed partial run can't corrupt it)
        warm_replay = pq.warm and bool(pq.join_seq)
        if not warm_replay:
            pq.join_seq = []
            pq.join_est_seq = []
        estimator = (ReplayEstimator(base_est, pq.join_seq)
                     if warm_replay else base_est)
        qs.plan_mode = cfg.plan_mode
        tel = JoinTelemetry()

        def record_join(impl, est, actual, retried, cap=0):
            qs.join_strategies[impl] = qs.join_strategies.get(impl, 0) + 1
            qs.join_retries += int(retried)
            if est is not None:
                qs.n_estimated_joins += 1
                qs.join_est_rows += int(est)
                qs.join_actual_rows += int(actual)
                err = math.log((est + 1) / (actual + 1))
                qs.join_est_log_err += abs(err)
                qs.join_est_log_bias += err
                if not warm_replay:
                    pq.join_seq.append((int(actual), int(cap), str(impl)))
                    pq.join_est_seq.append(int(est))
            # every estimator-sized join is a budget boundary: actual
            # output rows charge max_rows, the executed capacity is
            # checked against max_capacity, and the deadline is re-read
            checkpoint(rows=int(actual), cap=int(cap))

        comp_tables: list[Table] = []
        phase[0] = "match"
        for ci, (comp, trees) in enumerate(zip(pq.comps,
                                               pq.trees_per_comp)):
            with tracer.span("component", index=ci) as csp:
                if not query.component_edges(comp):
                    # isolated node(s)
                    tab = None
                    for q in comp:
                        t = single_node_table(q, int(iv[q, 0]),
                                              int(iv[q, 1]), pass_np[q])
                        tab = t if tab is None else injective_filter(
                            self._retry(cross_join, tab, t))
                    comp_tables.append(tab)
                    continue
                cand_tables = []
                for tr in trees:
                    tab = dtree_candidates(
                        self.graph, tr, pass_masks,
                        row_limit=self.cfg.max_rows,
                        join_impl=self.cfg.join_impl,
                        nested_max=self.cfg.thresholds.nested_join_max,
                        probe_impl=self._probe_impl(),
                        estimator=estimator.edge_join, record=record_join,
                        telemetry=tel, fuse=self.cfg.fuse_joins,
                        tracer=tracer)
                    qs.truncated |= tab.truncated
                    qs.dtree_work += tab.count
                    cand_tables.append(injective_filter(tab))
                counts = [t.count for t in cand_tables]
                if cfg.plan_mode == "cost" and len(cand_tables) > 1:
                    if ci in pq.comp_orders:
                        order = pq.comp_orders[ci]
                        pc, gc = pq.comp_costs[ci]
                    else:
                        greedy = join_order(trees, counts)
                        plan = plan_table_joins(
                            [set(tr.nodes) for tr in trees], counts,
                            base_est,
                            cfg.thresholds.nested_join_max,
                            sort_orders=[t.sort_order
                                         for t in cand_tables],
                            greedy_order=greedy)
                        order = plan.order
                        pc, gc = plan.est_cost, plan.greedy_cost
                        pq.comp_orders[ci] = order
                        pq.comp_costs[ci] = (pc, gc)
                    qs.plan_cost += pc
                    qs.greedy_plan_cost += gc
                else:
                    order = join_order(trees, counts)
                tab = cand_tables[order[0]]
                for i in order[1:]:
                    qs.join_work += (max(tab.count, 1)
                                     * max(cand_tables[i].count, 1))
                    tab = injective_filter(self._join(
                        tab, cand_tables[i], estimator,
                        row_limit=self.cfg.max_rows, record=record_join,
                        telemetry=tel))
                    qs.truncated |= tab.truncated
                if csp.live:
                    csp.set(rows=tab.count, trees=len(trees))
                comp_tables.append(tab)
                checkpoint(cap=tab.cap)
        qs.match_time = time.perf_counter() - t2

        # ---- connection edges ------------------------------------------
        t3 = time.perf_counter()
        phase[0] = "connections"
        with tracer.span("connections",
                         edges=len(query.connections)) as sp:
            final = self._process_connections(query, pq.comps,
                                              comp_tables, qs,
                                              record_join, tel, pq=pq,
                                              checkpoint=checkpoint)
            if sp.live:
                sp.set(rows=final.count)
        qs.conn_time = time.perf_counter() - t3
        qs.sorts_performed = tel.sorts_performed
        qs.sorts_avoided = tel.sorts_avoided

        pq.executions += 1
        qs.total_time = time.perf_counter() - t0
        rows = np.asarray(final.rows[: final.count])
        return MatchResult(cols=final.cols, rows=rows, stats=qs)

    # -------------------------------------------------------------- #
    def _probe_impl(self) -> str:
        """merge-probe kernel impl for sort-merge joins.  The 'ref' engine
        impl maps to the semantically identical searchsorted path: the
        O(A*B) probe oracle exists for kernel validation, not for running
        real joins."""
        impl = self.cfg.impl
        return "sorted" if impl == "ref" else impl

    def _join(self, a: Table, b: Table, estimator,
              row_limit: int | None = None, record=None,
              telemetry: JoinTelemetry | None = None) -> Table:
        """Planned equi-join: strategy by table size, capacity pre-sized
        from the stats-driven cardinality estimate, single exact-size
        retry on overflow."""
        shared = tuple(c for c in a.cols if c in b.cols)
        est = estimator.table_join(a.count, b.count, shared)
        return planned_join(a, b, est, row_limit=row_limit,
                            impl=self.cfg.join_impl,
                            nested_max=self.cfg.thresholds.nested_join_max,
                            probe_impl=self._probe_impl(), record=record,
                            telemetry=telemetry, fuse=self.cfg.fuse_joins,
                            tracer=self.tracer)

    def _retry(self, fn, *args, **kw):
        cap = None
        for _ in range(8):
            try:
                return fn(*args, **kw) if cap is None else fn(*args, cap=cap, **kw)
            except CapacityOverflow as e:
                cap = _pow2(e.needed)
        raise RuntimeError("capacity retry loop failed")

    def _process_connections(self, query: QueryTemplate, comps,
                             comp_tables: list[Table],
                             qs: QueryStats, record_join=None,
                             tel: JoinTelemetry | None = None,
                             pq: PreparedQuery | None = None,
                             checkpoint=None) -> Table:
        """Connection-edge evaluation (Alg. 3): intra filters first (linear
        in table size), then cross-component merges.  The merge order comes
        from planner.plan_connections (cost-based with per-edge
        reach-vs-cross pricing) under plan_mode='cost'; plan_mode='greedy'
        keeps the seed's dynamic smallest-current-product rule as an A/B
        baseline.  Each edge is evaluated either by the reach-join (no
        cross product, O(matches) output work) or the seed cross+filter
        path, per EngineConfig.connection_impl / the cost model.  A warm
        PreparedQuery supplies the cached edge order directly."""
        ck = checkpoint if checkpoint is not None else (lambda **kw: None)
        tables = list(comp_tables)
        owner = {}
        for i, comp in enumerate(comps):
            for q in comp:
                owner[q] = i
        group = list(range(len(tables)))       # table index per original comp
        # reach cache: connection edges sharing endpoint nodes (or
        # re-filtered after merges) reuse each other's reach sets; a
        # server-owned bounded cache extends the reuse across queries
        rcache = (self.reach_cache if self.reach_cache is not None
                  else ReachCache())
        n = self.graph.num_nodes
        cost_model = self.cfg.cost_model

        def find(i):
            while group[i] != i:
                group[i] = group[group[i]]
                i = group[i]
            return i

        # distinct endpoint values per (group root, column): one
        # device-to-host column sync + unique each, shared between the
        # plan-time feature pass and execution, invalidated when a
        # group's table is replaced (filter or merge)
        dvals: dict[tuple[int, int], np.ndarray] = {}

        # per-edge strategy: warm runs replay the choices recorded by the
        # first execution (same reason as join_seq — the live calibrated
        # cost model may have moved since, and a flipped strategy would
        # change the join call sequence the replay depends on)
        replay_impls = (pq.conn_impls
                        if pq is not None and pq.executions > 0
                        and pq.conn_impls else None)
        impl_cursor = [0]
        record_impls = ([] if pq is not None and replay_impls is None
                        else None)

        def edge_choice(count_a, count_b, a_vals, b_vals, c, intra):
            """(impl, sel, feat) for one connection edge.  Warm replays
            return the recorded impl without evaluating the cost model at
            all (sel/feat None) — both consumers of those values, the
            strategy choice and the calibration accrual, are disabled on
            the warm path, so computing endpoint_reach per edge there
            would be pure warm-latency overhead."""
            if replay_impls is not None \
                    and impl_cursor[0] < len(replay_impls):
                impl = replay_impls[impl_cursor[0]]
                impl_cursor[0] += 1
                return impl, None, None
            feat = conn_feat(a_vals, b_vals, c)
            sel = sel_of(c, a_vals, b_vals)
            impl = choose_connection_impl(
                count_a, count_b, feat, sel, n,
                impl=self.cfg.connection_impl, intra=intra,
                model=cost_model)
            if record_impls is not None:
                record_impls.append(impl)
            return impl, sel, feat

        def distinct_of(gi: int, col: int) -> np.ndarray:
            key = (gi, col)
            if key not in dvals:
                dvals[key] = distinct_column_values(tables[gi], col)
            return dvals[key]

        def invalidate(*groups: int) -> None:
            for k in [k for k in dvals if k[0] in groups]:
                del dvals[k]

        def conn_feat(a_vals: np.ndarray, b_vals: np.ndarray,
                      c) -> ConnFeatures:
            # candidate-aware reach: the first expansion hop uses the
            # actual degrees of the distinct endpoint candidates
            h_fwd, h_bwd = hop_split(c.max_dist)
            return ConnFeatures(len(a_vals), len(b_vals),
                                endpoint_reach(self.stats, n, h_fwd,
                                               a_vals, +1),
                                endpoint_reach(self.stats, n, h_bwd,
                                               b_vals, -1))

        def record_conn(impl: str, info: ReachJoinInfo,
                        sel: float | None,
                        feat: ConnFeatures | None) -> None:
            qs.conn_strategies[impl] = qs.conn_strategies.get(impl, 0) + 1
            qs.conn_reach_pairs += info.reach_pairs
            qs.conn_connected_pairs += info.connected_pairs
            qs.conn_endpoint_rows += info.rows_a + info.rows_b
            qs.conn_endpoint_distinct += info.distinct_a + info.distinct_b
            # predictions are accrued only for edges whose impl measures
            # the observed side (the cross path never fills
            # connected_pairs/reach_pairs) — otherwise every cross edge
            # would look like "predicted N, observed 0" to the Calibrator
            # and drag conn_sel_scale/reach_scale to the floor.  Warm
            # replays skip the cost model entirely (sel/feat None); the
            # Calibrator ignores warm stats anyway.
            if impl == "reach" and sel is not None:
                qs.conn_est_pairs += sel * info.distinct_a * info.distinct_b
                qs.conn_est_reach_pairs += (
                    info.distinct_a * feat.reach_fwd
                    + info.distinct_b * feat.reach_bwd)

        def sel_of(c, a_vals=None, b_vals=None) -> float:
            return connection_selectivity(self.stats, n, c.max_dist,
                                          c.bidirectional,
                                          a_nodes=a_vals, b_nodes=b_vals)

        tracer = self.tracer

        def intra_filter(gi: int, c) -> None:
            # no early-out on an empty table: both impls handle it, and
            # conn_strategies must count every connection edge processed
            with tracer.span("conn_edge", kind="intra") as sp:
                tab = tables[gi]
                a_vals = distinct_of(gi, c.src)
                b_vals = distinct_of(gi, c.dst)
                info = ReachJoinInfo(rows_a=tab.count, rows_b=tab.count,
                                     distinct_a=len(a_vals),
                                     distinct_b=len(b_vals))
                impl, sel, feat = edge_choice(tab.count, tab.count,
                                              a_vals, b_vals, c,
                                              intra=True)
                if impl == "reach":
                    tables[gi] = reach_filter(
                        self.graph, self.ni, tab, c.src, c.dst,
                        c.max_dist,
                        c.bidirectional, a_vals=a_vals, b_vals=b_vals,
                        impl=self.cfg.join_impl,
                        nested_max=self.cfg.thresholds.nested_join_max,
                        probe_impl=self._probe_impl(), cache=rcache,
                        telemetry=tel, record=record_join, info=info,
                        fuse=self.cfg.fuse_joins, tracer=tracer)
                else:
                    rows = np.asarray(tab.rows[: tab.count])
                    a = rows[:, tab.cols.index(c.src)]
                    b = rows[:, tab.cols.index(c.dst)]
                    keep = connectivity_mask(self.graph, self.ni, a, b,
                                             c.max_dist, c.bidirectional,
                                             impl=self.cfg.impl,
                                             cache=rcache)
                    tables[gi] = filter_rows(tab, keep)
                invalidate(gi)
                record_conn(impl, info, sel, feat)
                if sp.live:
                    sp.set(impl=impl, src=c.src, dst=c.dst,
                           max_dist=c.max_dist, rows=tables[gi].count,
                           reach_pairs=info.reach_pairs,
                           connected_pairs=info.connected_pairs)
                # connection-edge boundary: deadline + capacity re-check
                # (rows=0 — a filter materializes no new join rows)
                ck(cap=tables[gi].cap)

        def apply_connection(c) -> None:
            gi, gj = find(owner[c.src]), find(owner[c.dst])
            if gi == gj:
                # merged by an earlier join: now an intra filter
                intra_filter(gi, c)
                return
            with tracer.span("conn_edge", kind="merge") as sp:
                ta, tb = tables[gi], tables[gj]
                a_vals = distinct_of(gi, c.src)
                b_vals = distinct_of(gj, c.dst)
                info = ReachJoinInfo(rows_a=ta.count, rows_b=tb.count,
                                     distinct_a=len(a_vals),
                                     distinct_b=len(b_vals))
                impl, sel, feat = edge_choice(ta.count, tb.count,
                                              a_vals, b_vals, c,
                                              intra=False)
                if impl == "reach":
                    joined = injective_filter(reach_join(
                        self.graph, self.ni, ta, tb, c.src, c.dst,
                        c.max_dist,
                        c.bidirectional, a_vals=a_vals, b_vals=b_vals,
                        row_limit=self.cfg.max_rows,
                        impl=self.cfg.join_impl,
                        nested_max=self.cfg.thresholds.nested_join_max,
                        probe_impl=self._probe_impl(), cache=rcache,
                        telemetry=tel, record=record_join, info=info,
                        fuse=self.cfg.fuse_joins, tracer=tracer))
                    qs.join_work += info.reach_pairs + joined.count
                    qs.truncated |= joined.truncated
                else:
                    qs.join_work += max(ta.count, 1) * max(tb.count, 1)
                    joined = injective_filter(self._retry(
                        cross_join, ta, tb, row_limit=self.cfg.max_rows))
                    qs.truncated |= joined.truncated
                    # the cross path bypasses record_join, so charge its
                    # materialized rows to the budget here
                    ck(rows=joined.count, cap=joined.cap)
                    if joined.count:
                        rows = np.asarray(joined.rows[: joined.count])
                        a = rows[:, joined.cols.index(c.src)]
                        b = rows[:, joined.cols.index(c.dst)]
                        keep = connectivity_mask(self.graph, self.ni,
                                                 a, b,
                                                 c.max_dist,
                                                 c.bidirectional,
                                                 impl=self.cfg.impl,
                                                 cache=rcache)
                        joined = filter_rows(joined, keep)
                invalidate(gi, gj)
                record_conn(impl, info, sel, feat)
                group[gj] = gi
                tables[gi] = joined
                if sp.live:
                    sp.set(impl=impl, src=c.src, dst=c.dst,
                           max_dist=c.max_dist, rows=joined.count,
                           rows_a=info.rows_a, rows_b=info.rows_b,
                           reach_pairs=info.reach_pairs,
                           connected_pairs=info.connected_pairs)
                ck(cap=joined.cap)

        intra = [c for c in query.connections
                 if find(owner[c.src]) == find(owner[c.dst])]
        inter = [c for c in query.connections
                 if find(owner[c.src]) != find(owner[c.dst])]
        for c in intra:
            intra_filter(find(owner[c.src]), c)

        if inter and self.cfg.plan_mode == "cost":
            if pq is not None and pq.conn_order is not None:
                order, (pc, gc) = pq.conn_order, pq.conn_costs
            else:
                endpoints = [(find(owner[c.src]), find(owner[c.dst]))
                             for c in inter]
                sels = [sel_of(c, distinct_of(gi, c.src),
                               distinct_of(gj, c.dst))
                        for c, (gi, gj) in zip(inter, endpoints)]
                feats = [conn_feat(distinct_of(gi, c.src),
                                   distinct_of(gj, c.dst), c)
                         for c, (gi, gj) in zip(inter, endpoints)]
                plan = plan_connections([t.count for t in tables],
                                        endpoints, sels, feats=feats,
                                        num_nodes=n,
                                        impl=self.cfg.connection_impl,
                                        model=cost_model)
                order, pc, gc = plan.order, plan.est_cost, plan.greedy_cost
                if pq is not None:
                    pq.conn_order = list(order)
                    pq.conn_costs = (pc, gc)
            qs.plan_cost += pc
            qs.greedy_plan_cost += gc
            for k in order:
                apply_connection(inter[k])
        else:
            # seed baseline: smallest current candidate product first
            while inter:
                inter.sort(key=lambda c: tables[find(owner[c.src])].count
                           * tables[find(owner[c.dst])].count)
                apply_connection(inter.pop(0))

        if record_impls is not None:
            pq.conn_impls = record_impls

        # cross-join any remaining disconnected groups
        roots = sorted({find(i) for i in range(len(tables))})
        tab = tables[roots[0]]
        for r in roots[1:]:
            tab = injective_filter(self._retry(
                cross_join, tab, tables[r], row_limit=self.cfg.max_rows))
            qs.truncated |= tab.truncated
            ck(rows=tab.count, cap=tab.cap)
        return tab


# ---------------------------------------------------------------------- #
# Named engine variants (paper §6) — table lives in dataset.ENGINE_VARIANTS
# so Dataset.build can size the NI index without importing this module.
# ---------------------------------------------------------------------- #
def make_engine(dataset: "Dataset | RDFGraph", variant: str = "rdf_h",
                ni: NIIndex | None = None,
                stats: DatasetStats | None = None,
                thresholds: Thresholds | None = None,
                impl: str = "auto") -> Engine:
    """Engine for a named paper variant over a ``Dataset``.

    Passing a bare ``RDFGraph`` is deprecated: it wraps the graph in a
    version-0 Dataset (building the variant's NI index and stats) and
    emits a DeprecationWarning.  Construct the Dataset once and reuse it —
    that is also what unlocks ``apply_delta`` and the version-scoped
    serving caches."""
    if variant not in ENGINE_VARIANTS:
        raise ValueError(f"unknown variant {variant!r}")
    b = ENGINE_VARIANTS[variant]
    th = thresholds or Thresholds()
    cfg = EngineConfig(check_policy=b["policy"], d_check=b["d_check"],
                       impl=impl, thresholds=th)
    if isinstance(dataset, Dataset):
        if ni is not None or stats is not None:
            raise ValueError("pass ni/stats via the Dataset, "
                             "not alongside it")
        if dataset.ni.d_max < b["d_check"]:
            raise ValueError(
                f"variant {variant!r} checks {b['d_check']} hops but the "
                f"Dataset's NI index only stores {dataset.ni.d_max}")
        if b["var"] == "vc" and dataset.ni.variant != "vc":
            raise ValueError(f"variant {variant!r} needs a vertex-cover NI "
                             f"index (Dataset.build(ni_variant='vc'))")
        return Engine(dataset, cfg)
    warnings.warn(
        "make_engine(graph, ...) is deprecated; build a repro.core.Dataset "
        "(Dataset.build(graph, variant=...)) and pass that instead",
        DeprecationWarning, stacklevel=2)
    ds = Dataset.build(dataset, variant=variant, ni=ni, stats=stats)
    return Engine(ds, cfg)
