"""Connection-edge evaluation (paper Algorithm 3).

For a pair (n_i, n_j) with distance constraint d_c: n_i's forward reach set
within ceil(d_c/2) hops must intersect n_j's backward reach set within
d_c - ceil(d_c/2) hops (both include the node itself at distance 0, which
the paper leaves implicit but is required for odd splits and direct edges).

Reach sets come from the NI index.  When the required hop count exceeds the
index's d_max, reach sets are expanded one hop at a time through distance-1
entries — this is exactly the expensive path the paper measures in §6.3
(1-hop index: 92% of query time; 3-hop: 3.6%).

Exactness: unlike the neighborhood *check*, connectivity decides final
results, so truncation cannot be tolerated — any overflowed row falls back
to an exact host-side BFS.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .graph import RDFGraph
from .ni_index import NIIndex
from ..kernels import ops


def _gather_reach(ni: NIIndex, nodes: np.ndarray, hops: int, sign: int):
    """Reach ids within <= min(hops, d_max) via direct NI gathers.

    Returns (ids [P, R], overflow [P], frontier_ids [P, F] at exactly d_max
    or None if hops <= d_max)."""
    parts = [nodes[:, None].astype(np.int32)]          # distance 0: self
    overflow = np.zeros(len(nodes), dtype=bool)
    d_use = min(hops, ni.d_max)
    for d in range(1, d_use + 1):
        e = ni.entries[sign * d]
        parts.append(e.ids[nodes])
        overflow |= e.overflow[nodes]
    ids = np.concatenate(parts, axis=1)
    frontier = None
    if hops > ni.d_max:
        frontier = ni.entries[sign * ni.d_max].ids[nodes]
    return ids, overflow, frontier


def _dedup_rows(ids: np.ndarray, cap: int):
    """Sort rows descending, null out duplicates, truncate to cap.

    Returns (ids [P, <=cap], overflow [P]) — overflow true when valid
    uniques exceeded cap (row then unusable for exact decisions)."""
    s = np.sort(ids, axis=1)[:, ::-1]                  # desc: valid first
    dup = np.zeros_like(s, dtype=bool)
    dup[:, 1:] = s[:, 1:] == s[:, :-1]
    s = np.where(dup, -1, s)
    s = np.sort(s, axis=1)[:, ::-1]
    counts = (s >= 0).sum(axis=1)
    overflow = counts > cap
    return s[:, :cap], overflow


def reach_sets(ni: NIIndex, nodes: np.ndarray, hops: int, sign: int,
               cap: int = 4096):
    """All node ids within <= hops (sign=+1 forward, -1 backward), deduped.

    Returns (ids [P, <=cap] int32 -1-padded, overflow [P] bool)."""
    ids, overflow, frontier = _gather_reach(ni, nodes, hops, sign)
    ids, of2 = _dedup_rows(ids, cap)
    overflow |= of2
    rem = hops - ni.d_max
    e1 = ni.entries[sign * 1]
    # bound the [p, slice, c1] expansion buffer to ~64M int32 (256MB)
    while rem > 0 and frontier is not None:
        p, f = frontier.shape
        slice_w = max(1, (1 << 26) // max(e1.cap * p, 1))
        new_frontier = np.full((p, 1), -1, np.int32)
        for fs in range(0, f, slice_w):
            blk = frontier[:, fs:fs + slice_w]                 # [p, w]
            safe = np.maximum(blk, 0)
            nxt = e1.ids[safe]                                 # [p, w, c1]
            nxt = np.where(blk[:, :, None] >= 0, nxt, -1).reshape(p, -1)
            overflow |= (e1.overflow[safe] & (blk >= 0)).any(axis=1)
            new_frontier, off = _dedup_rows(
                np.concatenate([new_frontier, nxt], axis=1), cap)
            overflow |= off
        frontier = new_frontier
        ids, of3 = _dedup_rows(np.concatenate([ids, frontier], axis=1), cap)
        overflow |= of3
        rem -= 1
    return ids, overflow


def _bfs_within(graph: RDFGraph, start: int, hops: int, forward: bool) -> set:
    indptr, nbr, _ = graph.out_csr if forward else graph.in_csr
    seen = {int(start)}
    frontier = [int(start)]
    for _ in range(hops):
        nxt = []
        for u in frontier:
            for v in nbr[indptr[u]:indptr[u + 1]]:
                v = int(v)
                if v not in seen:
                    seen.add(v)
                    nxt.append(v)
        frontier = nxt
    return seen


def connectivity_mask(graph: RDFGraph, ni: NIIndex,
                      a_nodes: np.ndarray, b_nodes: np.ndarray,
                      d_c: int, bidirectional: bool = False,
                      *, impl: str = "auto", chunk: int = 1024) -> np.ndarray:
    """Exact mask[i] = exists directed path a->b (or b->a if bidirectional)
    of length <= d_c."""
    p = len(a_nodes)
    out = np.zeros(p, dtype=bool)
    h_fwd = -(-d_c // 2)            # ceil
    h_bwd = d_c - h_fwd
    if max(h_fwd, h_bwd) > ni.d_max:
        # Index does not cover the needed hops (the paper's expensive
        # case, §6.3).  On CPU the exact per-node BFS (memoized across
        # pairs) beats the dense frontier expansion, which exists for the
        # TPU-target path; cost is still dominated by traversal — exactly
        # the effect the paper measures.
        fwd_memo: dict[int, set] = {}
        bwd_memo: dict[int, set] = {}
        for i in range(p):
            ai, bi = int(a_nodes[i]), int(b_nodes[i])
            if ai not in fwd_memo:
                fwd_memo[ai] = _bfs_within(graph, ai, h_fwd, True)
            if bi not in bwd_memo:
                bwd_memo[bi] = _bfs_within(graph, bi, h_bwd, False)
            out[i] = bool(fwd_memo[ai] & bwd_memo[bi])
        if bidirectional:
            out |= connectivity_mask(graph, ni, b_nodes, a_nodes, d_c,
                                     False, impl=impl, chunk=chunk)
        return out

    # Index covers the hops: reach sets are pure INDEX READS (no graph
    # traversal) — the paper's fast case.  Memoized per node across pairs.
    def reach_from_index(n: int, hops: int, sign: int) -> set:
        s = {n}
        for d in range(1, hops + 1):
            e = ni.entries[sign * d]
            if e.overflow[n]:
                return _bfs_within(graph, n, hops, sign > 0)
            row = e.ids[n]
            s.update(int(x) for x in row[row >= 0])
        return s

    fwd_memo: dict[int, set] = {}
    bwd_memo: dict[int, set] = {}
    for i in range(p):
        ai, bi = int(a_nodes[i]), int(b_nodes[i])
        if ai not in fwd_memo:
            fwd_memo[ai] = reach_from_index(ai, h_fwd, +1)
        if bi not in bwd_memo:
            bwd_memo[bi] = reach_from_index(bi, h_bwd, -1)
        out[i] = bool(fwd_memo[ai] & bwd_memo[bi])
    if bidirectional:
        rev = connectivity_mask(graph, ni, b_nodes, a_nodes, d_c,
                                False, impl=impl, chunk=chunk)
        out |= rev
    return out


def connectivity_mask_vectorized(graph: RDFGraph, ni: NIIndex,
                                 a_nodes: np.ndarray, b_nodes: np.ndarray,
                                 d_c: int, bidirectional: bool = False,
                                 *, impl: str = "auto",
                                 chunk: int = 1024) -> np.ndarray:
    """TPU-target form: batched reach-set gathers + intersect kernel.
    Exactness guaranteed by BFS fallback on overflow rows."""
    if bidirectional:
        fwd = connectivity_mask_vectorized(graph, ni, a_nodes, b_nodes,
                                           d_c, impl=impl, chunk=chunk)
        rev = connectivity_mask_vectorized(graph, ni, b_nodes, a_nodes,
                                           d_c, impl=impl, chunk=chunk)
        return fwd | rev
    p = len(a_nodes)
    out = np.zeros(p, dtype=bool)
    h_fwd = -(-d_c // 2)
    h_bwd = d_c - h_fwd
    for s in range(0, p, chunk):
        e = min(s + chunk, p)
        a, b = a_nodes[s:e], b_nodes[s:e]
        fa, ofa = reach_sets(ni, a, h_fwd, +1)
        bb, ofb = reach_sets(ni, b, h_bwd, -1)
        hit = np.asarray(ops.intersect_any(fa, bb, impl=impl), dtype=bool)
        of = ofa | ofb
        for i in np.nonzero(of)[0]:
            fs = _bfs_within(graph, a[i], h_fwd, True)
            bs = _bfs_within(graph, b[i], h_bwd, False)
            hit[i] = bool(fs & bs)
        out[s:e] = hit
    return out


def enumerate_shortest_paths(graph: RDFGraph, a: int, b: int, d_c: int,
                             max_paths: int = 1000) -> list[list[int]]:
    """Instantiate a connection edge: all SHORTEST directed paths a -> b of
    length <= d_c (paper Fig. 2, final stage: "connection edges are
    instantiated by enumerating all shortest paths").

    BFS layers record every shortest-predecessor, then paths are rebuilt
    by backtracking.  Returns [] if b is unreachable within d_c.
    """
    if a == b:
        return [[a]]
    indptr, nbr, _ = graph.out_csr
    parents: dict[int, list[int]] = {}
    dist = {a: 0}
    frontier = [a]
    found_at = None
    for d in range(1, d_c + 1):
        nxt = []
        for u in frontier:
            for v in nbr[indptr[u]:indptr[u + 1]]:
                v = int(v)
                if v not in dist:
                    dist[v] = d
                    parents[v] = [u]
                    nxt.append(v)
                elif dist[v] == d:
                    parents[v].append(u)
        if b in dist:
            found_at = d
            break
        frontier = nxt
    if found_at is None:
        return []

    paths: list[list[int]] = []

    def back(node, suffix):
        if len(paths) >= max_paths:
            return
        if node == a:
            paths.append([a] + suffix)
            return
        for p in parents.get(node, ()):
            back(p, [node] + suffix)

    back(b, [])
    return paths


def instantiate_connections(graph: RDFGraph, result, query,
                            max_paths: int = 16) -> list[dict]:
    """For each match row, enumerate the shortest paths realizing every
    connection edge.  Returns one dict per row:
    {(src_q, dst_q): [path, ...], ...}."""
    out = []
    col_of = {c: i for i, c in enumerate(result.cols)}
    for row in result.rows:
        inst = {}
        for c in query.connections:
            pa = enumerate_shortest_paths(
                graph, int(row[col_of[c.src]]), int(row[col_of[c.dst]]),
                c.max_dist, max_paths)
            if not pa and c.bidirectional:
                pa = enumerate_shortest_paths(
                    graph, int(row[col_of[c.dst]]),
                    int(row[col_of[c.src]]), c.max_dist, max_paths)
            inst[(c.src, c.dst)] = pa
        out.append(inst)
    return out
