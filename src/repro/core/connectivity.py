"""Connection-edge evaluation (paper Algorithm 3).

For a pair (n_i, n_j) with distance constraint d_c: n_i's forward reach set
within ceil(d_c/2) hops must intersect n_j's backward reach set within
d_c - ceil(d_c/2) hops (both include the node itself at distance 0, which
the paper leaves implicit but is required for odd splits and direct edges).

Reach sets come from the NI index.  When the required hop count exceeds the
index's d_max, reach sets are expanded one hop at a time through distance-1
entries — this is exactly the expensive path the paper measures in §6.3
(1-hop index: 92% of query time; 3-hop: 3.6%).

Exactness: unlike the neighborhood *check*, connectivity decides final
results, so truncation cannot be tolerated — any overflowed row falls back
to an exact host-side BFS.

Two evaluation forms for a connection edge over candidate tables A, B:

  * cross+filter (the seed path): materialize A x B, then decide each pair
    with per-pair reach-set intersections (`connectivity_mask`) —
    O(|A|*|B|) in both work and peak memory.
  * reach-join (`reach_join` / `reach_filter`): extract the *distinct*
    endpoint nodes of each side (typically << row count), gather their
    exact reach sets once into flat (node, reach_id) pair tables, compute
    connected (a, b) endpoint pairs with ONE sort-merge join on reach_id
    (reusing the merge-probe machinery of matching.py), and equi-join the
    deduplicated pair table back against A and B — output work O(matches),
    no intermediate proportional to |A|*|B|.

Both are exact: reach gathering falls back to per-node BFS for NI-overflow
nodes and for hops beyond the index's d_max.  A `ReachCache` (engine-owned,
per query) memoizes reach sets across connection edges sharing endpoints.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from .graph import RDFGraph
from .ni_index import NIIndex
from .matching import (Table, DEFAULT_NESTED_MAX, join_tables, planned_join,
                       dedup_project, empty_table, filter_rows, _pow2)
from ..obs.trace import NULL_TRACER
from ..kernels import ops


# Synthetic column id for the reach-id column of (node, reach_id) pair
# tables — must never collide with a query-node id (those are >= 0).
REACH_ID_COL = -2


def hop_split(d_c: int) -> tuple[int, int]:
    """Algorithm 3's split of a distance constraint: forward reach within
    ceil(d_c/2) hops must intersect backward reach within the remainder.
    The single source of the split — execution (mask + reach-join), the
    cost model, and the selectivity estimate must all agree on it."""
    h_fwd = -(-d_c // 2)
    return h_fwd, d_c - h_fwd


def _gather_reach(ni: NIIndex, nodes: np.ndarray, hops: int, sign: int):
    """Reach ids within <= min(hops, d_max) via direct NI gathers.

    Returns (ids [P, R], overflow [P], frontier_ids [P, F] at exactly d_max
    or None if hops <= d_max)."""
    parts = [nodes[:, None].astype(np.int32)]          # distance 0: self
    overflow = np.zeros(len(nodes), dtype=bool)
    d_use = min(hops, ni.d_max)
    for d in range(1, d_use + 1):
        e = ni.entries[sign * d]
        parts.append(e.ids[nodes])
        overflow |= e.overflow[nodes]
    ids = np.concatenate(parts, axis=1)
    frontier = None
    if hops > ni.d_max:
        frontier = ni.entries[sign * ni.d_max].ids[nodes]
    return ids, overflow, frontier


def _dedup_rows(ids: np.ndarray, cap: int):
    """Sort rows descending, null out duplicates, truncate to cap.

    Returns (ids [P, <=cap], overflow [P]) — overflow true when valid
    uniques exceeded cap (row then unusable for exact decisions)."""
    s = np.sort(ids, axis=1)[:, ::-1]                  # desc: valid first
    dup = np.zeros_like(s, dtype=bool)
    dup[:, 1:] = s[:, 1:] == s[:, :-1]
    s = np.where(dup, -1, s)
    s = np.sort(s, axis=1)[:, ::-1]
    counts = (s >= 0).sum(axis=1)
    overflow = counts > cap
    return s[:, :cap], overflow


def reach_sets(ni: NIIndex, nodes: np.ndarray, hops: int, sign: int,
               cap: int = 4096):
    """All node ids within <= hops (sign=+1 forward, -1 backward), deduped.

    Returns (ids [P, <=cap] int32 -1-padded, overflow [P] bool)."""
    ids, overflow, frontier = _gather_reach(ni, nodes, hops, sign)
    ids, of2 = _dedup_rows(ids, cap)
    overflow |= of2
    rem = hops - ni.d_max
    e1 = ni.entries[sign * 1]
    # bound the [p, slice, c1] expansion buffer to ~64M int32 (256MB)
    while rem > 0 and frontier is not None:
        p, f = frontier.shape
        slice_w = max(1, (1 << 26) // max(e1.cap * p, 1))
        new_frontier = np.full((p, 1), -1, np.int32)
        for fs in range(0, f, slice_w):
            blk = frontier[:, fs:fs + slice_w]                 # [p, w]
            safe = np.maximum(blk, 0)
            nxt = e1.ids[safe]                                 # [p, w, c1]
            nxt = np.where(blk[:, :, None] >= 0, nxt, -1).reshape(p, -1)
            overflow |= (e1.overflow[safe] & (blk >= 0)).any(axis=1)
            new_frontier, off = _dedup_rows(
                np.concatenate([new_frontier, nxt], axis=1), cap)
            overflow |= off
        frontier = new_frontier
        ids, of3 = _dedup_rows(np.concatenate([ids, frontier], axis=1), cap)
        overflow |= of3
        rem -= 1
    return ids, overflow


def _bfs_within(graph: RDFGraph, start: int, hops: int, forward: bool) -> set:
    indptr, nbr, _ = graph.out_csr if forward else graph.in_csr
    seen = {int(start)}
    frontier = [int(start)]
    for _ in range(hops):
        nxt = []
        for u in frontier:
            for v in nbr[indptr[u]:indptr[u + 1]]:
                v = int(v)
                if v not in seen:
                    seen.add(v)
                    nxt.append(v)
        frontier = nxt
    return seen


@dataclass
class ReachCache:
    """Memo of exact reach sets, keyed (node, hops, sign).

    Engine-owned per query by default (shared across every connection edge
    of one query, so edges with common endpoints never recompute a reach
    set — the caches `connectivity_mask` used to rebuild per call,
    hoisted).  The serving layer instead installs one server-owned cache
    with `max_entries` and/or `max_bytes` set, extending the reuse across
    queries (the dataset is immutable, so entries never go stale) with
    LRU eviction bounding the footprint.  `max_entries` bounds the key
    count; `max_bytes` bounds the accounted payload bytes — entry-count
    bounds alone break on hub-heavy graphs, where one entry holds a reach
    set of up to |N| ids.  Accounting: `arr.nbytes` for the array mirror,
    8 bytes/element for the set mirror (the int32 payload a set entry
    would occupy as an array plus equal slack for set overhead — an
    estimate, not a measurement, but monotone in set size which is what
    eviction needs).  Two mirrored stores (python sets for per-pair
    intersections, np arrays for the reach-join pair tables) convert
    lazily between each other; both stores of an evicted key go together,
    and a key's charge covers whichever mirrors currently exist."""
    sets: dict = field(default_factory=dict)
    arrays: dict = field(default_factory=dict)
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    max_entries: int | None = None      # LRU bound on distinct keys
    max_bytes: int | None = None        # LRU bound on accounted bytes
    total_bytes: int = 0
    _lru: OrderedDict = field(default_factory=OrderedDict, repr=False)
    _nbytes: dict = field(default_factory=dict, repr=False)

    def __len__(self) -> int:
        return len(self._lru)

    def _account(self, key) -> None:
        """Re-derive `key`'s byte charge from its live mirrors."""
        b = 0
        a = self.arrays.get(key)
        if a is not None:
            b += int(a.nbytes)
        s = self.sets.get(key)
        if s is not None:
            b += 8 * len(s)
        self.total_bytes += b - self._nbytes.get(key, 0)
        self._nbytes[key] = b

    def _evict(self, key) -> None:
        self.sets.pop(key, None)
        self.arrays.pop(key, None)
        self.total_bytes -= self._nbytes.pop(key, 0)
        self.evictions += 1

    def _touch(self, key) -> None:
        self._lru[key] = None
        self._lru.move_to_end(key)
        if self.max_entries is not None:
            while len(self._lru) > self.max_entries:
                self._evict(self._lru.popitem(last=False)[0])
        if self.max_bytes is not None:
            # never evict the just-touched key: a single entry larger
            # than the whole budget stays as a cache-of-one (evicting it
            # would thrash the entry currently in use)
            while self.total_bytes > self.max_bytes and len(self._lru) > 1:
                self._evict(self._lru.popitem(last=False)[0])

    def get_set(self, node: int, hops: int, sign: int) -> set | None:
        key = (node, hops, sign)
        s = self.sets.get(key)
        if s is None and key in self.arrays:
            s = self.sets[key] = set(int(x) for x in self.arrays[key])
            self._account(key)
        self.hits += s is not None
        self.misses += s is None
        if s is not None:
            self._touch(key)
        return s

    def put_set(self, node: int, hops: int, sign: int, s: set) -> None:
        key = (node, hops, sign)
        self.sets[key] = s
        self._account(key)
        self._touch(key)

    def get_array(self, node: int, hops: int, sign: int) -> np.ndarray | None:
        key = (node, hops, sign)
        a = self.arrays.get(key)
        if a is None and key in self.sets:
            s = self.sets[key]
            a = self.arrays[key] = np.fromiter(s, np.int32, len(s))
            self._account(key)
        self.hits += a is not None
        self.misses += a is None
        if a is not None:
            self._touch(key)
        return a

    def put_array(self, node: int, hops: int, sign: int,
                  arr: np.ndarray) -> None:
        key = (node, hops, sign)
        self.arrays[key] = arr
        self._account(key)
        self._touch(key)

    # ------------------------------------------------------------------ #
    def clear(self) -> int:
        """Drop every entry (full-rebuild delta: all ids may have moved).
        Returns the number of entries dropped."""
        n = len(self._lru)
        self.sets.clear()
        self.arrays.clear()
        self._lru.clear()
        self._nbytes.clear()
        self.total_bytes = 0
        self.evictions += n
        return n

    def invalidate_delta(self, endpoints: np.ndarray) -> int:
        """Drop entries an incremental Dataset delta may have changed.

        A changed edge u→v can only alter reach(n, h, sign) if the edge's
        near endpoint was already within h-1 hops of n — and anything
        within h-1 hops is in the stored reach set (or is n itself).  So
        an entry is stale only if {n} ∪ stored set intersects the delta's
        edge endpoints; everything else is provably unchanged and stays.
        Returns the number of entries dropped."""
        eps = set(int(x) for x in np.asarray(endpoints).ravel())
        if not eps:
            return 0
        stale = []
        for key in self._lru:
            node = int(key[0])
            if node in eps:
                stale.append(key)
                continue
            s = self.sets.get(key)
            if s is not None:
                if not eps.isdisjoint(s):
                    stale.append(key)
                continue
            a = self.arrays.get(key)
            if a is not None and len(a) and np.isin(a, list(eps)).any():
                stale.append(key)
        for key in stale:
            self._evict(key)
            del self._lru[key]
        return len(stale)


def _exact_reach(graph: RDFGraph, ni: NIIndex, node: int, hops: int,
                 sign: int, cache: ReachCache | None = None) -> set:
    """Exact reach set of one node: pure index reads when the NI index
    covers `hops` and the node's entries did not overflow (the paper's
    fast case), else exact BFS (the expensive case §6.3 measures)."""
    if cache is not None:
        s = cache.get_set(node, hops, sign)
        if s is not None:
            return s
    s = None
    if hops <= ni.d_max:
        s = {node}
        for d in range(1, hops + 1):
            e = ni.entries[sign * d]
            if e.overflow[node]:
                s = None
                break
            row = e.ids[node]
            s.update(int(x) for x in row[row >= 0])
    if s is None:
        s = _bfs_within(graph, node, hops, sign > 0)
    if cache is not None:
        cache.put_set(node, hops, sign, s)
    return s


def connectivity_mask(graph: RDFGraph, ni: NIIndex,
                      a_nodes: np.ndarray, b_nodes: np.ndarray,
                      d_c: int, bidirectional: bool = False,
                      *, impl: str = "auto", chunk: int = 1024,
                      cache: ReachCache | None = None) -> np.ndarray:
    """Exact mask[i] = exists directed path a->b (or b->a if bidirectional)
    of length <= d_c.

    Per-pair decision over memoized exact reach sets (`cache`; a local one
    is created when the caller does not pass an engine-owned cache).  Index
    reads where the NI index covers the hop split, per-node BFS beyond."""
    p = len(a_nodes)
    out = np.zeros(p, dtype=bool)
    h_fwd, h_bwd = hop_split(d_c)
    if cache is None:
        cache = ReachCache()
    for i in range(p):
        fs = _exact_reach(graph, ni, int(a_nodes[i]), h_fwd, +1, cache)
        bs = _exact_reach(graph, ni, int(b_nodes[i]), h_bwd, -1, cache)
        out[i] = not fs.isdisjoint(bs)
    if bidirectional:
        out |= connectivity_mask(graph, ni, b_nodes, a_nodes, d_c,
                                 False, impl=impl, chunk=chunk, cache=cache)
    return out


def connectivity_mask_vectorized(graph: RDFGraph, ni: NIIndex,
                                 a_nodes: np.ndarray, b_nodes: np.ndarray,
                                 d_c: int, bidirectional: bool = False,
                                 *, impl: str = "auto",
                                 chunk: int = 1024) -> np.ndarray:
    """TPU-target form: batched reach-set gathers + intersect kernel.
    Exactness guaranteed by BFS fallback on overflow rows."""
    if bidirectional:
        fwd = connectivity_mask_vectorized(graph, ni, a_nodes, b_nodes,
                                           d_c, impl=impl, chunk=chunk)
        rev = connectivity_mask_vectorized(graph, ni, b_nodes, a_nodes,
                                           d_c, impl=impl, chunk=chunk)
        return fwd | rev
    p = len(a_nodes)
    out = np.zeros(p, dtype=bool)
    h_fwd, h_bwd = hop_split(d_c)
    for s in range(0, p, chunk):
        e = min(s + chunk, p)
        a, b = a_nodes[s:e], b_nodes[s:e]
        fa, ofa = reach_sets(ni, a, h_fwd, +1)
        bb, ofb = reach_sets(ni, b, h_bwd, -1)
        hit = np.asarray(ops.intersect_any(fa, bb, impl=impl), dtype=bool)
        of = ofa | ofb
        for i in np.nonzero(of)[0]:
            fs = _bfs_within(graph, a[i], h_fwd, True)
            bs = _bfs_within(graph, b[i], h_bwd, False)
            hit[i] = bool(fs & bs)
        out[s:e] = hit
    return out


# ---------------------------------------------------------------------- #
# Reach-join: connection edges as set-at-a-time joins (no cross product).
# ---------------------------------------------------------------------- #
@dataclass
class ReachJoinInfo:
    """Execution telemetry of one reach-join / reach-filter (feeds
    QueryStats.conn_* via the engine)."""
    rows_a: int = 0                 # input table rows (side holding src)
    rows_b: int = 0
    distinct_a: int = 0             # distinct endpoint nodes per side
    distinct_b: int = 0
    reach_pairs: int = 0            # flat (node, reach_id) pairs gathered
    connected_pairs: int = 0        # deduped connected endpoint pairs
    peak_cap: int = 0               # largest intermediate table capacity


def reach_pairs(graph: RDFGraph, ni: NIIndex, nodes: np.ndarray, hops: int,
                sign: int, cap: int = 4096,
                cache: ReachCache | None = None
                ) -> tuple[np.ndarray, np.ndarray]:
    """Exact flat (node, reach_id) pairs for the given distinct nodes.

    Set-at-a-time NI gathers (`reach_sets`) where the index covers `hops`;
    per-node exact BFS for overflow rows and for hops > d_max.  Returns
    (pair_nodes [M], pair_reach [M]) int32 — every node contributes its
    full reach set including itself (distance 0)."""
    nodes = np.asarray(nodes)
    if nodes.size == 0:
        return np.empty(0, np.int32), np.empty(0, np.int32)
    per_node: dict[int, np.ndarray] = {}
    misses: list[int] = []
    for v in nodes:
        v = int(v)
        arr = None if cache is None else cache.get_array(v, hops, sign)
        if arr is not None:
            per_node[v] = arr
        else:
            misses.append(v)
    if misses:
        ids = overflow = None
        if hops <= ni.d_max:
            ids, overflow = reach_sets(ni, np.asarray(misses), hops, sign,
                                       cap=cap)
        for i, v in enumerate(misses):
            if ids is not None and not overflow[i]:
                row = ids[i]
                arr = row[row >= 0].astype(np.int32)
            else:                       # NI overflow or hops > d_max
                s = _bfs_within(graph, v, hops, sign > 0)
                arr = np.fromiter(s, np.int32, len(s))
            per_node[v] = arr
            if cache is not None:
                cache.put_array(v, hops, sign, arr)
    arrs = [per_node[int(v)] for v in nodes]
    counts = [a.shape[0] for a in arrs]
    pair_nodes = np.repeat(nodes.astype(np.int32), counts)
    pair_reach = (np.concatenate(arrs) if pair_nodes.size
                  else np.empty(0, np.int32))
    return pair_nodes, pair_reach


def _pair_table(pair_reach: np.ndarray, pair_nodes: np.ndarray,
                node_col: int) -> Table:
    """(node, reach_id) pairs as a 2-column device table keyed by the
    reach id.  Pre-sorted on host by reach id and tagged, so the
    sort-merge join on REACH_ID_COL skips both device sorts."""
    m = int(pair_reach.shape[0])
    order = np.argsort(pair_reach, kind="stable")
    rows = np.full((_pow2(m), 2), -1, np.int32)
    rows[:m, 0] = pair_reach[order]
    rows[:m, 1] = pair_nodes[order]
    return Table(cols=(REACH_ID_COL, node_col), rows=jnp.asarray(rows),
                 count=m, sort_order=(REACH_ID_COL,))


def distinct_column_values(table: Table, col: int) -> np.ndarray:
    """Sorted distinct valid values of one table column (host array —
    these drive the host-side NI gathers)."""
    if table.count == 0:
        return np.empty(0, np.int32)
    vals = np.asarray(table.rows[: table.count, table.cols.index(col)])
    u = np.unique(vals)
    return u[u >= 0].astype(np.int32)


def _directed_pairs(graph: RDFGraph, ni: NIIndex, a_vals, b_vals,
                    h_fwd: int, h_bwd: int, src_col: int, dst_col: int,
                    cap: int, impl: str, probe_impl: str, nested_max: int,
                    cache, telemetry, info: ReachJoinInfo,
                    fuse: bool = True) -> Table:
    """Connected (a, b) pairs for one direction: fwd(a) x bwd(b) joined on
    the shared reach id, deduplicated to distinct endpoint pairs."""
    fn, fr = reach_pairs(graph, ni, a_vals, h_fwd, +1, cap=cap, cache=cache)
    bn, br = reach_pairs(graph, ni, b_vals, h_bwd, -1, cap=cap, cache=cache)
    info.reach_pairs += int(fn.shape[0] + bn.shape[0])
    ta = _pair_table(fr, fn, src_col)
    tb = _pair_table(br, bn, dst_col)
    j = join_tables(ta, tb, impl=impl, nested_max=nested_max,
                    probe_impl=probe_impl, telemetry=telemetry, fuse=fuse)
    out = dedup_project(j, (src_col, dst_col))
    info.peak_cap = max(info.peak_cap, ta.cap, tb.cap, j.cap, out.cap)
    return out


def connected_pair_table(graph: RDFGraph, ni: NIIndex,
                         a_vals: np.ndarray, b_vals: np.ndarray,
                         d_c: int, bidirectional: bool,
                         cols: tuple[int, int], *, cap: int = 4096,
                         impl: str = "auto", probe_impl: str = "auto",
                         nested_max: int = DEFAULT_NESTED_MAX,
                         cache: ReachCache | None = None,
                         telemetry=None,
                         info: ReachJoinInfo | None = None,
                         fuse: bool = True, tracer=None) -> Table:
    """Distinct (a, b) node pairs with a directed path a->b of length
    <= d_c (plus b->a when bidirectional), as a 2-column table over
    `cols` = (src_col, dst_col), sorted by it.

    This is Alg. 3 evaluated set-at-a-time: one sort-merge join on the
    shared reach id replaces the per-pair set intersections."""
    info = info if info is not None else ReachJoinInfo()
    if tracer is None:
        tracer = NULL_TRACER
    with tracer.span("reach_pairs") as sp:
        src_col, dst_col = cols
        h_fwd, h_bwd = hop_split(d_c)
        cp = _directed_pairs(graph, ni, a_vals, b_vals, h_fwd, h_bwd,
                             src_col, dst_col, cap, impl, probe_impl,
                             nested_max, cache, telemetry, info, fuse)
        if bidirectional:
            rev = _directed_pairs(graph, ni, b_vals, a_vals, h_fwd, h_bwd,
                                  dst_col, src_col, cap, impl, probe_impl,
                                  nested_max, cache, telemetry, info, fuse)
            # union: concat the padded buffers (valid rows need not form a
            # prefix — dedup_project tolerates that) and re-dedup
            perm = np.asarray([rev.cols.index(c) for c in cp.cols])
            both = Table(cols=cp.cols,
                         rows=jnp.concatenate([cp.rows, rev.rows[:, perm]]),
                         count=cp.count + rev.count)
            cp = dedup_project(both, cp.cols)
            info.peak_cap = max(info.peak_cap, cp.cap)
        info.connected_pairs = cp.count
        if sp.live:
            sp.set(reach_pairs=info.reach_pairs,
                   connected_pairs=info.connected_pairs,
                   distinct_a=len(a_vals), distinct_b=len(b_vals))
    return cp


def reach_join(graph: RDFGraph, ni: NIIndex, ta: Table, tb: Table,
               src_col: int, dst_col: int, d_c: int,
               bidirectional: bool = False, *,
               a_vals: np.ndarray | None = None,
               b_vals: np.ndarray | None = None,
               row_limit: int | None = None, cap: int = 4096,
               impl: str = "auto", nested_max: int = DEFAULT_NESTED_MAX,
               probe_impl: str = "auto", cache: ReachCache | None = None,
               telemetry=None, record=None,
               info: ReachJoinInfo | None = None,
               fuse: bool = True, tracer=None) -> Table:
    """Join tables `ta` and `tb` on the connection constraint
    dist(ta.src_col -> tb.dst_col) <= d_c, WITHOUT materializing the
    cross product: equivalent to
    filter(cross_join(ta, tb), connectivity_mask) but with output work
    O(matches) and peak intermediate capacity bounded by the match count
    (plus the pair tables), never by |A|*|B|."""
    info = info if info is not None else ReachJoinInfo()
    info.rows_a, info.rows_b = ta.count, tb.count
    if ta.count == 0 or tb.count == 0:
        return empty_table(ta.cols + tb.cols)
    if a_vals is None:
        a_vals = distinct_column_values(ta, src_col)
    if b_vals is None:
        b_vals = distinct_column_values(tb, dst_col)
    info.distinct_a, info.distinct_b = len(a_vals), len(b_vals)
    cp = connected_pair_table(graph, ni, a_vals, b_vals, d_c, bidirectional,
                              (src_col, dst_col), cap=cap, impl=impl,
                              probe_impl=probe_impl, nested_max=nested_max,
                              cache=cache, telemetry=telemetry, info=info,
                              fuse=fuse, tracer=tracer)
    # A |x| pairs on src_col, then |x| B on dst_col: both sized exactly
    # (no estimate: counts are known after each probe, so planned_join
    # allocates the exact pow2 capacity).
    t1 = planned_join(ta, cp, None, row_limit=row_limit, impl=impl,
                      nested_max=nested_max, probe_impl=probe_impl,
                      record=record, telemetry=telemetry, fuse=fuse,
                      tracer=tracer)
    out = planned_join(t1, tb, None, row_limit=row_limit, impl=impl,
                       nested_max=nested_max, probe_impl=probe_impl,
                       record=record, telemetry=telemetry, fuse=fuse,
                       tracer=tracer)
    out.truncated |= t1.truncated
    info.peak_cap = max(info.peak_cap, t1.cap, out.cap)
    return out


def reach_filter(graph: RDFGraph, ni: NIIndex, table: Table,
                 src_col: int, dst_col: int, d_c: int,
                 bidirectional: bool = False, *,
                 a_vals: np.ndarray | None = None,
                 b_vals: np.ndarray | None = None, cap: int = 4096,
                 impl: str = "auto", nested_max: int = DEFAULT_NESTED_MAX,
                 probe_impl: str = "auto", cache: ReachCache | None = None,
                 telemetry=None, record=None,
                 info: ReachJoinInfo | None = None,
                 fuse: bool = True, tracer=None) -> Table:
    """Intra-table connection filter as a reach-SEMI-join: keep rows whose
    (src_col, dst_col) values appear in the connected-pair table.
    Equivalent to filter_rows(table, connectivity_mask(...)) without the
    per-row host loop."""
    info = info if info is not None else ReachJoinInfo()
    info.rows_a = info.rows_b = table.count
    if table.count == 0:
        return table
    if a_vals is None:
        a_vals = distinct_column_values(table, src_col)
    if b_vals is None:
        b_vals = distinct_column_values(table, dst_col)
    info.distinct_a, info.distinct_b = len(a_vals), len(b_vals)
    cp = connected_pair_table(graph, ni, a_vals, b_vals, d_c, bidirectional,
                              (src_col, dst_col), cap=cap, impl=impl,
                              probe_impl=probe_impl, nested_max=nested_max,
                              cache=cache, telemetry=telemetry, info=info,
                              fuse=fuse, tracer=tracer)
    if cp.count == 0:
        return filter_rows(table, np.zeros(table.count, bool), kept=0)
    # shared cols = both endpoint cols, no new cols: the equi-join IS the
    # semi-join (cp rows are distinct, so each table row matches at most
    # one pair).
    out = planned_join(table, cp, None, impl=impl, nested_max=nested_max,
                       probe_impl=probe_impl, record=record,
                       telemetry=telemetry, fuse=fuse, tracer=tracer)
    info.peak_cap = max(info.peak_cap, out.cap)
    return out


def enumerate_shortest_paths(graph: RDFGraph, a: int, b: int, d_c: int,
                             max_paths: int = 1000) -> list[list[int]]:
    """Instantiate a connection edge: all SHORTEST directed paths a -> b of
    length <= d_c (paper Fig. 2, final stage: "connection edges are
    instantiated by enumerating all shortest paths").

    BFS layers record every shortest-predecessor, then paths are rebuilt
    by backtracking.  Returns [] if b is unreachable within d_c.
    """
    if a == b:
        return [[a]]
    indptr, nbr, _ = graph.out_csr
    parents: dict[int, list[int]] = {}
    dist = {a: 0}
    frontier = [a]
    found_at = None
    for d in range(1, d_c + 1):
        nxt = []
        for u in frontier:
            for v in nbr[indptr[u]:indptr[u + 1]]:
                v = int(v)
                if v not in dist:
                    dist[v] = d
                    parents[v] = [u]
                    nxt.append(v)
                elif dist[v] == d:
                    parents[v].append(u)
        if b in dist:
            found_at = d
            break
        frontier = nxt
    if found_at is None:
        return []

    paths: list[list[int]] = []

    def back(node, suffix):
        if len(paths) >= max_paths:
            return
        if node == a:
            paths.append([a] + suffix)
            return
        for p in parents.get(node, ()):
            back(p, [node] + suffix)

    back(b, [])
    return paths


def instantiate_connections(graph: RDFGraph, result, query,
                            max_paths: int = 16) -> list[dict]:
    """For each match row, enumerate the shortest paths realizing every
    connection edge.  Returns one dict per row:
    {(src_q, dst_q): [path, ...], ...}."""
    out = []
    col_of = {c: i for i, c in enumerate(result.cols)}
    for row in result.rows:
        inst = {}
        for c in query.connections:
            pa = enumerate_shortest_paths(
                graph, int(row[col_of[c.src]]), int(row[col_of[c.dst]]),
                c.max_dist, max_paths)
            if not pa and c.bidirectional:
                pa = enumerate_shortest_paths(
                    graph, int(row[col_of[c.dst]]),
                    int(row[col_of[c.src]]), c.max_dist, max_paths)
            inst[(c.src, c.dst)] = pa
        out.append(inst)
    return out
