"""RDF-ℏ core: the paper's contribution as a composable JAX library."""
from .graph import RDFGraph, IDMap, RESOURCE, LITERAL, REL, ATTR, csr_patch
from .ni_index import NIIndex, NIEntry, build_ni_index, \
    vertex_cover_2approx, khop_rows, patch_entry
from .dataset import (Dataset, ENGINE_VARIANTS, content_digest,
                      interval_footprint_hit)
from .query import QueryTemplate, QueryEdge, ConnectionEdge, brute_force_match
from .signature import build_requirements, check_interval_candidates
from .decompose import DTree, decompose, join_order
from .matching import Table, CandidateTable, SortedRun, JoinTelemetry, \
    join_tables, cross_join, edge_pairs, \
    dtree_candidates, CapacityOverflow, resolve_join_impl, filter_rows, \
    injective_filter, dedup_project, empty_table
from .connectivity import (connectivity_mask, reach_sets,
    connectivity_mask_vectorized, enumerate_shortest_paths,
    instantiate_connections, ReachCache, ReachJoinInfo, reach_pairs,
    connected_pair_table, reach_join, reach_filter,
    distinct_column_values, REACH_ID_COL)
from .stats import DatasetStats, compute_stats, predicate_selectivity, \
    literal_selectivity, coherence, relationship_specialty, \
    literal_diversity, connection_selectivity, expected_reach, \
    endpoint_reach, node_degrees, coherence_terms, coherence_from_terms, \
    specialty_terms, specialty_from_terms
from .planner import Thresholds, CostModel, PlanDecision, decide, \
    neighborhood_selectivity, tune_thresholds, JoinEstimator, \
    ReplayEstimator, CapEstimate, JoinPlan, PlannedStep, plan_table_joins, \
    simulate_join_order, ConnectionPlan, plan_connections, ConnFeatures, \
    connection_edge_cost, choose_connection_impl
from .engine import Engine, EngineConfig, MatchResult, PreparedQuery, \
    QueryStats, make_engine
from .distributed import shard_check, gather_candidates
