"""NI (Neighborhood Interval) index — dense TPU-native form.

Paper form: a 5-column table (node, signed distance, label-ID interval,
count, neighbor ids) binned by factor ``m``.  Dense form here: for each
signed distance ``k`` (negative = backward) a padded [N, cap_k] int32 tensor
of the ids of all nodes at shortest-path distance exactly |k|, sorted
ascending, padded with -1.  Because node id == label id (see graph.py), one
tensor serves both roles the paper splits across columns:

  * label-interval containment checks (Algorithm 1) — compare ids against a
    query keyword interval;
  * connectivity ID-list intersection (Algorithm 3) — intersect id lists.

Per-entry [min, max] summaries (the paper's "Label ID interval" column) are
kept per bin of ``m`` ids so the check can skip non-intersecting bins; the
Pallas kernel uses them as a block-skip hint, the jnp reference ignores them.

Soundness under truncation: if a node has more than cap_k neighbors at
distance k the entry is truncated and its ``overflow`` bit set; every check
treats overflow as an automatic pass (prune only on certain information).

The vertex-cover variant (h-VC) indexes distance-2 entries only for nodes in
a 2-approximation vertex cover; other nodes carry overflow=True at |k|=2 so
checks degrade gracefully to 1-hop information.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .graph import RDFGraph, INVALID


@dataclass
class NIEntry:
    """Index tensor for one signed distance."""
    ids: np.ndarray        # [N, cap] int32, sorted, -1 padded
    count: np.ndarray      # [N] int32 true count (may exceed cap)
    overflow: np.ndarray   # [N] bool
    bin_lo: np.ndarray     # [N, nbins] int32 per-bin min id (bin size = m)
    bin_hi: np.ndarray     # [N, nbins] int32 per-bin max id

    @property
    def cap(self) -> int:
        return int(self.ids.shape[1])


@dataclass
class NIIndex:
    d_max: int
    m: int                              # binning factor (paper: 5)
    entries: dict[int, NIEntry]         # signed distance -> entry
    vc_mask: np.ndarray | None = None   # set for the vertex-cover variant
    variant: str = "full"               # "full" | "vc"

    def entry(self, k: int) -> NIEntry:
        return self.entries[k]

    def size_bytes(self) -> int:
        """Space actually used (paper Fig. 3): only real ids + summaries."""
        total = 0
        for k, e in self.entries.items():
            stored = np.minimum(e.count, e.cap).sum()
            nbins = np.ceil(np.minimum(e.count, e.cap) / self.m).sum()
            total += int(stored) * 4 + int(nbins) * 8 + e.count.nbytes // 4
        return total

    def dense_bytes(self) -> int:
        """Padded device footprint."""
        return sum(e.ids.nbytes + e.bin_lo.nbytes + e.bin_hi.nbytes
                   for e in self.entries.values())


# ---------------------------------------------------------------------- #
def _khop_sets(indptr: np.ndarray, nbr: np.ndarray, d_max: int,
               restrict: np.ndarray | None = None):
    """Exact k-hop neighbor id lists per node, per exact distance 1..d_max.

    restrict: optional bool [N]; nodes outside it only get distance-1 lists
    (vertex-cover variant).
    Returns list of lists-of-arrays: hops[d-1][n] = ids at distance exactly d.
    """
    n_nodes = indptr.shape[0] - 1
    hops = [[None] * n_nodes for _ in range(d_max)]
    for n in range(n_nodes):
        d1 = np.unique(nbr[indptr[n]:indptr[n + 1]])
        hops[0][n] = d1
    if d_max == 1:
        return hops
    for n in range(n_nodes):
        if restrict is not None and not restrict[n]:
            for d in range(1, d_max):
                hops[d][n] = np.empty(0, dtype=nbr.dtype)
            continue
        seen = {n}
        seen_arr = np.asarray([n], dtype=nbr.dtype)
        frontier = hops[0][n]
        seen_arr = np.union1d(seen_arr, frontier)
        for d in range(1, d_max):
            if frontier.size == 0:
                hops[d][n] = np.empty(0, dtype=nbr.dtype)
                frontier = hops[d][n]
                continue
            # expand frontier through CSR
            starts, ends = indptr[frontier], indptr[frontier + 1]
            sizes = ends - starts
            if sizes.sum() == 0:
                nxt = np.empty(0, dtype=nbr.dtype)
            else:
                idx = np.concatenate([np.arange(s, e) for s, e in zip(starts, ends)])
                nxt = np.unique(nbr[idx])
                nxt = np.setdiff1d(nxt, seen_arr, assume_unique=True)
            hops[d][n] = nxt
            seen_arr = np.union1d(seen_arr, nxt)
            frontier = nxt
    return hops


def khop_rows(csr, d_max: int, nodes: np.ndarray):
    """Exact k-hop lists for just ``nodes`` — row-for-row what `_khop_sets`
    would compute for them over the same CSR (same unique/union1d/setdiff1d
    pipeline, so a patched entry equals a rebuilt one).

    Returns ``rows[d-1][i]`` = ids at distance exactly d from ``nodes[i]``.
    """
    indptr, nbr, _ = csr
    out = [[None] * len(nodes) for _ in range(d_max)]
    for i, node in enumerate(nodes):
        n = int(node)
        d1 = np.unique(nbr[indptr[n]:indptr[n + 1]])
        out[0][i] = d1
        if d_max == 1:
            continue
        seen_arr = np.union1d(np.asarray([n], dtype=nbr.dtype), d1)
        frontier = d1
        for d in range(1, d_max):
            if frontier.size == 0:
                out[d][i] = np.empty(0, dtype=nbr.dtype)
                frontier = out[d][i]
                continue
            starts, ends = indptr[frontier], indptr[frontier + 1]
            sizes = ends - starts
            if sizes.sum() == 0:
                nxt = np.empty(0, dtype=nbr.dtype)
            else:
                idx = np.concatenate([np.arange(s, e)
                                      for s, e in zip(starts, ends)])
                nxt = np.unique(nbr[idx])
                nxt = np.setdiff1d(nxt, seen_arr, assume_unique=True)
            out[d][i] = nxt
            seen_arr = np.union1d(seen_arr, nxt)
            frontier = nxt
    return out


def patch_entry(entry: "NIEntry", rows: np.ndarray, lists, m: int) -> "NIEntry":
    """Copy-on-write row update: a new NIEntry whose arrays are copies of
    ``entry``'s with row ``rows[i]`` rewritten from ``lists[i]``.

    Capacity is kept fixed — a list longer than the entry's cap truncates
    with overflow=True, which every check treats as an automatic pass
    (sound: prune only on certain information).  Per-row bin summaries are
    recomputed exactly as `_pack` does.
    """
    ids = entry.ids.copy()
    count = entry.count.copy()
    overflow = entry.overflow.copy()
    bl = entry.bin_lo.copy()
    bh = entry.bin_hi.copy()
    cap = entry.cap
    nbins = bl.shape[1]
    i32max = np.iinfo(np.int32).max
    for r, arr in zip(rows, lists):
        r = int(r)
        c = int(arr.shape[0])
        count[r] = c
        overflow[r] = c > cap
        k = min(c, cap)
        ids[r, :k] = arr[:k]
        ids[r, k:] = INVALID
        row = ids[r]
        for b in range(nbins):
            blk = row[b * m:(b + 1) * m]
            valid = blk >= 0
            if valid.any():
                bl[r, b] = blk[valid].min()
                bh[r, b] = blk[valid].max()
            else:
                bl[r, b] = i32max
                bh[r, b] = INVALID
    return NIEntry(ids=ids, count=count, overflow=overflow,
                   bin_lo=bl, bin_hi=bh)


def _pack(lists, cap: int, m: int) -> NIEntry:
    n = len(lists)
    ids = np.full((n, cap), INVALID, dtype=np.int32)
    count = np.zeros(n, dtype=np.int32)
    overflow = np.zeros(n, dtype=bool)
    for i, arr in enumerate(lists):
        c = arr.shape[0]
        count[i] = c
        if c > cap:
            overflow[i] = True
            c = cap
        ids[i, :c] = arr[:c]
    nbins = max(1, -(-cap // m))
    bl = np.full((n, nbins), np.iinfo(np.int32).max, dtype=np.int32)
    bh = np.full((n, nbins), INVALID, dtype=np.int32)
    for b in range(nbins):
        blk = ids[:, b * m:(b + 1) * m]
        valid = blk >= 0
        any_v = valid.any(axis=1)
        bl[any_v, b] = np.where(valid, blk, np.iinfo(np.int32).max).min(axis=1)[any_v]
        bh[any_v, b] = np.where(valid, blk, -1).max(axis=1)[any_v]
    return NIEntry(ids=ids, count=count, overflow=overflow, bin_lo=bl, bin_hi=bh)


def vertex_cover_2approx(graph: RDFGraph) -> np.ndarray:
    """CLRS 2-approximation: repeatedly take both endpoints of an uncovered
    edge.  Deterministic (edge order)."""
    covered = np.zeros(graph.num_nodes, dtype=bool)
    in_cover = np.zeros(graph.num_nodes, dtype=bool)
    for s, d in zip(graph.src, graph.dst):
        if not (in_cover[s] or in_cover[d]):
            in_cover[s] = True
            in_cover[d] = True
    del covered
    return in_cover


def round_cap(x: int, minimum: int = 8) -> int:
    c = max(int(x), minimum)
    return 1 << (c - 1).bit_length()


def build_ni_index(graph: RDFGraph, d_max: int = 2, m: int = 5,
                   variant: str = "full",
                   cap_quantile: float = 1.0,
                   max_cap: int = 4096) -> NIIndex:
    """Build the NI index.

    cap_quantile < 1.0 trades space for overflow (sound; overflowing nodes
    simply cannot be pruned at that distance).
    """
    assert variant in ("full", "vc")
    vc = vertex_cover_2approx(graph) if variant == "vc" else None
    entries: dict[int, NIEntry] = {}
    for direction, csr in ((+1, graph.out_csr), (-1, graph.in_csr)):
        indptr, nbr, _ = csr
        restrict = vc if variant == "vc" else None
        hops = _khop_sets(indptr, nbr, d_max, restrict=restrict)
        for d in range(1, d_max + 1):
            sizes = np.asarray([a.shape[0] for a in hops[d - 1]])
            if sizes.size == 0:
                cap = 8
            elif cap_quantile >= 1.0:
                cap = round_cap(sizes.max() if sizes.size else 1)
            else:
                cap = round_cap(int(np.quantile(sizes, cap_quantile)))
            cap = min(cap, max_cap)
            entry = _pack(hops[d - 1], cap, m)
            if variant == "vc" and d > 1:
                # non-cover nodes have no stored info at this distance:
                # mark overflow so checks auto-pass (cannot prune).
                entry.overflow = entry.overflow | ~vc
            entries[direction * d] = entry
    return NIIndex(d_max=d_max, m=m, entries=entries,
                   vc_mask=vc, variant=variant)
