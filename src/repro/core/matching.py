"""D-tree candidate generation and joins (paper Algorithm 2, steps 2-3).

TPU-native formulation: candidate generation is *edge-parallel* — one pass
over the full edge array produces all (root, child) pairs matching a query
edge (predicate + endpoint pass masks), with no per-node degree padding.

Joins are planned per-pair between three device-resident strategies:

  * ``sorted`` — sort-merge equi-join: shared join columns are packed into
    a single int32 key (fused dense-rank packing, so any number of
    columns fits 31 bits without overflow), both sides are sorted once,
    per-row match ranges come from the merge-probe kernel
    (``kernels.merge_probe``: searchsorted on CPU, Pallas on TPU), and
    matches are expanded with a segment-offset gather.  O((A+B)·log+out)
    work, all intermediates on device.  When neither side has a cached
    sorted run, the whole pack→sort→probe→expand chain runs as ONE fused
    dispatch (``kernels.fused_join``) with a single scalar host sync.
  * ``radix`` — radix-partitioned hash join (``kernels.radix_join``):
    only the build (B) side is partitioned into pow2 hash buckets; probe
    rows stream against their bucket's window with SIMD compares.  Skips
    sorting the probe side entirely and preserves A's row order; the
    cost model prices it in when the probe side is large, keys are
    single-column, and no sorted run is reusable.  Skewed key
    distributions fall back to sort-merge deterministically.
  * ``nested`` — the vectorized nested-loop join (an |A|×|B| compare mask
    per chunk).  O(A·B) but with trivial constants; the planner keeps it
    for small tables where sort/probe setup dominates.

All tables are capacity-padded for jit shape stability; true counts are
tracked, and capacity overflow raises CapacityOverflow carrying the exact
needed size — plus the completed sort+probe state on the sort-merge path —
so the engine's retry re-sizes in one step without redoing the work
(stats-driven estimates pre-size capacities so the retry is the exception).

Tables are first-class: CandidateTable carries sort-order metadata
(`sort_order` — the column tuple its rows are currently ordered by) and a
cache of sorted runs, so a chain of sort-merge joins on the same key sorts
each side at most once.  Join outputs, filters, and cross products tag or
propagate the order they preserve; `JoinTelemetry` counts sorts performed
vs. avoided for QueryStats.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from .graph import RDFGraph
from .decompose import DTree
from ..obs.trace import NULL_TRACER
from ..kernels import ops as kops
from ..kernels import fused_join as kfused
from ..kernels import radix_join as krad
import functools
import math


DEFAULT_NESTED_MAX = 256      # planner: nested-loop below this table size

# Join-key space (defined with the packing kernel): real packed keys live
# in [0, 2^31 - 3]; the top two int32 values are invalid-row sentinels
# (distinct per side so an invalid a-row never matches an invalid b-row).
_A_INVALID = kfused.A_INVALID
_B_INVALID = kfused.B_INVALID


class CapacityOverflow(Exception):
    def __init__(self, needed: int):
        self.needed = int(needed)
        super().__init__(f"capacity overflow, need {needed}")


@dataclass
class SortedRun:
    """One cached sorted materialization of a table.

    rows: the table's rows permuted to be lexicographically nondecreasing
    by `key_cols` (valid rows first, padding last).  keys: the packed
    int32 join keys in that same order, cached only for single-column
    runs tagged with the side role they were built for — single-column
    keys are independent of the partner table, but carry a per-side
    invalid-row sentinel, so an 'a'-side key run cannot be reused on the
    'b' side.  Multi-column rank-packed keys depend on the partner table
    and are never cached (keys is None)."""
    rows: jax.Array
    keys: jax.Array | None = None
    key_side: str | None = None     # 'a' | 'b' (role keys were built for)


@dataclass
class CandidateTable:
    """First-class device-resident match table.

    rows[i] maps cols[j] -> graph node id; rows is capacity-padded
    (pow2) for jit shape stability and `count` tracks the valid prefix.

    Sort-order metadata threads through the whole join pipeline:
    `sort_order` names the column tuple the valid rows are currently
    lexicographically ordered by (None = unknown order), and `_runs`
    caches previously computed sorted materializations keyed by column
    tuple.  `_join_sorted` consults both to skip redundant
    `_sort_rows_by_key` calls, and tags its outputs with the order they
    inherit from the merge, so chains of joins on the same key sort each
    side at most once."""
    cols: tuple[int, ...]
    rows: jax.Array            # [cap, len(cols)] int32, invalid rows = -1
    count: int                 # true number of valid rows
    truncated: bool = False    # row_limit hit (LIMIT semantics)
    sort_order: tuple[int, ...] | None = None   # current row order (or None)
    _runs: dict = field(default_factory=dict, repr=False, compare=False)

    @property
    def cap(self) -> int:
        return int(self.rows.shape[0])

    def numpy(self) -> np.ndarray:
        return np.asarray(self.rows[: self.count])

    def result_set(self) -> set[tuple[int, ...]]:
        """Deduplicated rows in *canonical* column order (columns sorted
        by query-node id), so tables produced by different join orders —
        whose .cols permutations differ — compare equal.  Matches
        MatchResult.result_set."""
        order = np.argsort(self.cols, kind="stable")
        return {tuple(int(r[i]) for i in order) for r in self.numpy()}

    # ---------------- sort-run bookkeeping ------------------------- #
    def is_sorted_by(self, key_cols: tuple[int, ...]) -> bool:
        """True iff rows are already ordered by key_cols (a lexicographic
        sort by a longer tuple is also sorted by any prefix)."""
        return (self.sort_order is not None
                and len(self.sort_order) >= len(key_cols)
                and self.sort_order[: len(key_cols)] == tuple(key_cols))

    def sorted_run(self, key_cols: tuple[int, ...]) -> SortedRun | None:
        """A cached/implicit sorted materialization for key_cols, if any."""
        key_cols = tuple(key_cols)
        if self.is_sorted_by(key_cols):
            run = self._runs.get(key_cols)
            return run if run is not None else SortedRun(rows=self.rows)
        return self._runs.get(key_cols)

    # Each cached run holds a full sorted copy of the rows; cap how many
    # a table retains (FIFO) so a table joined on many distinct keys
    # can't pin unbounded device memory.  Chained joins on one key — the
    # reuse pattern that matters — need exactly one entry, and join
    # *outputs* reuse via their sort_order tag, which costs nothing.
    MAX_CACHED_RUNS = 4

    def cache_run(self, key_cols: tuple[int, ...], rows_sorted: jax.Array,
                  keys_sorted: jax.Array | None = None,
                  key_side: str | None = None) -> None:
        if len(key_cols) != 1:
            keys_sorted = key_side = None   # partner-dependent, not reusable
        key_cols = tuple(key_cols)
        while key_cols not in self._runs \
                and len(self._runs) >= self.MAX_CACHED_RUNS:
            self._runs.pop(next(iter(self._runs)))
        self._runs[key_cols] = SortedRun(
            rows=rows_sorted, keys=keys_sorted, key_side=key_side)


# Historical name: the thin rows+count dataclass this grew out of.  All
# call sites accept/return CandidateTable; the alias keeps the public API.
Table = CandidateTable


@dataclass
class JoinTelemetry:
    """Per-query sort-reuse counters (threaded from the engine down into
    the sort-merge join path)."""
    sorts_performed: int = 0
    sorts_avoided: int = 0


def _pow2(x: int, lo: int = 64) -> int:
    return max(lo, 1 << (max(int(x), 1) - 1).bit_length())


# ---------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("src_iv", "dst_iv"))
def _edge_pairs_mask(src, dst, pred, pred_id, pass_src, pass_dst,
                     src_iv=False, dst_iv=False):
    """Endpoint pass specs are either full-[N] bool masks or (lo, hi)
    interval pairs — wildcard candidate sets (check off) stay intervals
    so no [N] mask is ever materialized for them."""
    if src_iv:
        m = (src >= pass_src[0]) & (src < pass_src[1])
    else:
        m = pass_src[src]
    if dst_iv:
        m = m & (dst >= pass_dst[0]) & (dst < pass_dst[1])
    else:
        m = m & pass_dst[dst]
    return m & jnp.where(pred_id < 0, True, pred == pred_id)


@functools.partial(jax.jit, static_argnames=("cap",))
def _edge_pairs_gather(mask, src, dst, cap):
    e = src.shape[0]
    idx = jnp.nonzero(mask, size=cap, fill_value=e)[0]
    safe = jnp.minimum(idx, e - 1)
    s = jnp.where(idx < e, src[safe], -1)
    d = jnp.where(idx < e, dst[safe], -1)
    return jnp.stack([s, d], axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("size", "has_new"))
def _join_gather(eq, a_rows, b_rows, new_sel, size, has_new):
    ii, jj = jnp.nonzero(eq, size=size, fill_value=-1)
    left = jnp.where(ii[:, None] >= 0, a_rows[jnp.maximum(ii, 0)], -1)
    if has_new:
        right = jnp.where(jj[:, None] >= 0,
                          b_rows[jnp.maximum(jj, 0)][:, new_sel], -1)
        return jnp.concatenate([left, right], axis=1)
    return left


def edge_pairs(graph: RDFGraph, pred_id: int | None,
               pass_src, pass_dst,
               cols: tuple[int, int], cap: int | None = None) -> Table:
    """All edges (s, d) with pred==pred_id (None = any) and both endpoint
    specs satisfied.  A spec is a full-[N] bool mask or a (lo, hi)
    interval pair (wildcard candidates).  Returns a 2-column table."""
    src = jnp.asarray(graph.src)
    dst = jnp.asarray(graph.dst)
    pred = jnp.asarray(graph.pred)
    p = jnp.int32(-1 if pred_id is None else pred_id)
    mask = _edge_pairs_mask(src, dst, pred, p, pass_src, pass_dst,
                            src_iv=isinstance(pass_src, tuple),
                            dst_iv=isinstance(pass_dst, tuple))
    if cols[0] == cols[1]:      # query self-loop: s == d, single column
        mask = mask & (src == dst)
        count = int(mask.sum())
        cap2 = cap or _pow2(count)
        if count > cap2:
            raise CapacityOverflow(count)
        idx = jnp.nonzero(mask, size=cap2, fill_value=graph.num_edges)[0]
        s = jnp.where(idx < graph.num_edges,
                      src[jnp.minimum(idx, graph.num_edges - 1)], -1)
        return Table(cols=(cols[0],), rows=s[:, None].astype(jnp.int32),
                     count=count)
    count = int(mask.sum())
    if cap is None:
        cap = _pow2(count)
    if count > cap:
        raise CapacityOverflow(count)
    rows = _edge_pairs_gather(mask, src, dst, cap)
    return Table(cols=cols, rows=rows, count=count)


# ---------------------------------------------------------------------- #
def _shared_and_new(a_cols, b_cols):
    shared = [(a_cols.index(c), b_cols.index(c)) for c in a_cols if c in b_cols]
    new = [j for j, c in enumerate(b_cols) if c not in a_cols]
    return shared, new


# --------------------- strategy choice / pricing ---------------------- #
# Work-proxy cost constants (1 unit ~ one SIMD element op), calibrated
# against benchmarks/kernel_micro.py on the CPU container: an XLA sort
# touches each element O(log n) times with heavy compare/permute traffic,
# so it is weighted far above the streaming compares of a hash-bucket
# window probe.
SORT_WEIGHT = 8.0         # per-element-per-log2 cost of an XLA sort
RADIX_WINDOW = 4.0        # expected bucket-window width (hash + dup slack)
RADIX_MIN_PROBE = 8192    # radix eligible only at probe sides this large
RADIX_WORK_MAX = 1 << 25  # probe_cap * window elements before skew fallback


def strategy_costs(a_count: int, b_count: int, *, a_sorted: bool = False,
                   b_sorted: bool = False, n_shared: int = 1) -> dict:
    """Work-proxy cost of each join strategy at the given table sizes.

    a_sorted/b_sorted: a sorted run (or matching sort-order tag) already
    exists for the join key, so sort-merge skips that side's sort.  radix
    is only defined for single-column keys — multi-column packing itself
    costs a lexsort, which the fused sorted path gets for free."""
    a, b = max(int(a_count), 1), max(int(b_count), 1)
    costs = {"nested": float(a) * float(b)}
    sort_a = 0.0 if a_sorted else SORT_WEIGHT * a * math.log2(a + 1)
    sort_b = 0.0 if b_sorted else SORT_WEIGHT * b * math.log2(b + 1)
    costs["sorted"] = sort_a + sort_b + float(a + b)
    if n_shared == 1:
        # partition sorts only B (by bucket id); every probe row pays a
        # window of SIMD compares instead of participating in a sort
        costs["radix"] = (SORT_WEIGHT * b * math.log2(b + 1)
                          + RADIX_WINDOW * a + float(b))
    return costs


def choose_join_strategy(a_count: int, b_count: int,
                         nested_max: int = DEFAULT_NESTED_MAX, *,
                         a_sorted: bool = False, b_sorted: bool = False,
                         n_shared: int = 1) -> str:
    """Cheapest strategy under `strategy_costs`, with two hard gates:
    tiny tables always take nested (setup dominates any asymptotics) and
    radix needs a probe side of at least RADIX_MIN_PROBE rows (below
    that the partition/window overhead can't amortize)."""
    if max(a_count, b_count) <= nested_max:
        return "nested"
    c = strategy_costs(a_count, b_count, a_sorted=a_sorted,
                       b_sorted=b_sorted, n_shared=n_shared)
    if "radix" in c and a_count >= RADIX_MIN_PROBE \
            and c["radix"] < c["sorted"]:
        return "radix"
    return "sorted"


def resolve_join_impl(a_count: int, b_count: int, impl: str = "auto",
                      nested_max: int = DEFAULT_NESTED_MAX, *,
                      a_sorted: bool = False, b_sorted: bool = False,
                      n_shared: int = 1) -> str:
    """Per-join strategy choice: nested-loop for small tables (sort/probe
    setup dominates), sort-merge or radix-hash otherwise per the cost
    model (`strategy_costs`)."""
    if impl != "auto":
        return impl
    return choose_join_strategy(a_count, b_count, nested_max,
                                a_sorted=a_sorted, b_sorted=b_sorted,
                                n_shared=n_shared)


def _resolve_for(a: "Table", b: "Table", impl: str, nested_max: int) -> str:
    """Resolve the strategy for a concrete table pair — shared by
    join_tables and planned_join so recording and execution agree."""
    shared, _ = _shared_and_new(a.cols, b.cols)
    if not shared:
        return "cross"
    kc = tuple(a.cols[i] for i, _ in shared)
    return resolve_join_impl(
        a.count, b.count, impl, nested_max,
        a_sorted=a.sorted_run(kc) is not None,
        b_sorted=b.sorted_run(kc) is not None,
        n_shared=len(shared))


# ------------------------- sort-merge path ---------------------------- #
# Fused dense-rank key packing (kernels.fused_join): single-column keys
# take an identity path with no concat/split dispatches; multi-column
# keys come from ONE lexsort over the concatenated sides.
_pack_keys = kfused.pack_keys


@jax.jit
def _sort_rows_by_key(keys, rows):
    order = jnp.argsort(keys)
    return keys[order], rows[order]


@functools.partial(jax.jit, static_argnames=("cap", "new_sel", "has_new"))
def _merge_expand(a_rows_s, b_rows_s, start, cnt, limit, cap, new_sel,
                  has_new):
    """Expand per-a-row match ranges into output rows.

    Output slot t belongs to sorted a-row i = searchsorted(cumsum(cnt), t)
    and pairs it with sorted b-row start[i] + (t - prefix[i]) — a pure
    segment-offset gather, no host round-trip."""
    a_cap = a_rows_s.shape[0]
    csum = jnp.cumsum(cnt)
    t = jnp.arange(cap, dtype=jnp.int32)
    seg = jnp.searchsorted(csum, t, side="right").astype(jnp.int32)
    valid = (t < csum[-1]) & (t < limit)
    i = jnp.minimum(seg, a_cap - 1)
    base = csum[i] - cnt[i]
    j = jnp.clip(start[i] + (t - base), 0, b_rows_s.shape[0] - 1)
    left = jnp.where(valid[:, None], a_rows_s[i], -1)
    if has_new:
        sel = jnp.asarray(new_sel, jnp.int32)
        right = jnp.where(valid[:, None], b_rows_s[j][:, sel], -1)
        return jnp.concatenate([left, right], axis=1)
    return left


@dataclass
class _ProbeResume:
    """Sort+probe results carried on CapacityOverflow so the exact-size
    retry re-runs only the expand — no second sort, probe, or host sync."""
    a_rows_s: jax.Array
    b_rows_s: jax.Array
    start: jax.Array
    cnt: jax.Array
    cnt_np: np.ndarray
    key_cols: tuple[int, ...]


def _reuse_key_order(a: Table, b: Table, shared):
    """Permute the shared-column order — equi-join semantics are
    order-invariant — so that an existing sort order or cached run on
    either side becomes usable.  Prefers reusing the larger side (bigger
    sort skipped)."""
    if len(shared) < 2:
        return shared
    col_set = {a.cols[i] for i, _ in shared}
    best = None
    for t, weight in ((a, a.count), (b, b.count)):
        orders = []
        if t.sort_order is not None and len(t.sort_order) >= len(shared):
            orders.append(tuple(t.sort_order[: len(shared)]))
        orders.extend(k for k in t._runs if len(k) == len(shared))
        for o in orders:
            if set(o) == col_set and len(set(o)) == len(shared):
                if best is None or weight > best[0]:
                    best = (weight, o)
    if best is None:
        return shared
    by_col = {a.cols[i]: (i, j) for i, j in shared}
    return [by_col[c] for c in best[1]]


def _join_sorted(a: Table, b: Table, shared, new, cap, row_limit,
                 probe_impl: str, telemetry: JoinTelemetry | None = None,
                 resume: _ProbeResume | None = None,
                 fuse: bool = True) -> Table:
    out_cols = a.cols + tuple(b.cols[j] for j in new)
    if resume is None:
        shared = _reuse_key_order(a, b, shared)
        a_sel = tuple(s[0] for s in shared)
        b_sel = tuple(s[1] for s in shared)
        key_cols = tuple(a.cols[i] for i in a_sel)

        a_run = a.sorted_run(key_cols)
        b_run = b.sorted_run(key_cols)
        if fuse and a_run is None and b_run is None \
                and a.count * b.count < 1 << 31:
            # No sorted run to reuse on either side: the whole
            # pack→sort→probe(→expand) chain runs as one fused dispatch
            # with a single scalar host sync (the match total).  The
            # sorted sides and match ranges come back as device-resident
            # byproducts for run caching and the overflow-retry contract.
            return _join_sorted_fused(
                a, b, a_sel, b_sel, key_cols, out_cols, new, cap,
                row_limit, probe_impl, telemetry)
        a_rows_in = a_run.rows if a_run is not None else a.rows
        b_rows_in = b_run.rows if b_run is not None else b.rows
        # Packed keys: a cached single-column key run is reused only in
        # the side role it was built for (invalid-row sentinels are
        # per-side); otherwise keys are (re)built from the — possibly
        # pre-sorted — rows, which keeps them in sorted order because
        # the rank packing is order-preserving.
        a_keys = a_run.keys if (a_run is not None and a_run.keys is not None
                                and a_run.key_side == "a") else None
        b_keys = b_run.keys if (b_run is not None and b_run.keys is not None
                                and b_run.key_side == "b") else None
        if a_keys is None or b_keys is None:
            ak, bk = _pack_keys(a_rows_in, b_rows_in, a_sel, b_sel)
            a_keys = ak if a_keys is None else a_keys
            b_keys = bk if b_keys is None else b_keys
        if a_run is not None:
            a_keys_s, a_rows_s = a_keys, a_rows_in
            if telemetry is not None:
                telemetry.sorts_avoided += 1
        else:
            a_keys_s, a_rows_s = _sort_rows_by_key(a_keys, a.rows)
            a.cache_run(key_cols, a_rows_s, a_keys_s, "a")
            if telemetry is not None:
                telemetry.sorts_performed += 1
        if b_run is not None:
            b_keys_s, b_rows_s = b_keys, b_rows_in
            if telemetry is not None:
                telemetry.sorts_avoided += 1
        else:
            b_keys_s, b_rows_s = _sort_rows_by_key(b_keys, b.rows)
            b.cache_run(key_cols, b_rows_s, b_keys_s, "b")
            if telemetry is not None:
                telemetry.sorts_performed += 1
        start, cnt = kops.merge_probe(a_keys_s, b_keys_s, impl=probe_impl)

        # The per-row count vector syncs to host ONCE per join (planning
        # metadata, not row data): summing in int64 avoids the int32 wrap
        # a skewed >2^31-match join would hit on device.  The same array
        # serves the capacity check, the overflow clip below, and — via
        # _ProbeResume on CapacityOverflow — the exact-size retry.
        cnt_np = np.asarray(cnt)
    else:
        a_rows_s, b_rows_s = resume.a_rows_s, resume.b_rows_s
        start, cnt, cnt_np = resume.start, resume.cnt, resume.cnt_np
        key_cols = resume.key_cols
    total = int(cnt_np.sum(dtype=np.int64))
    out_count = total if row_limit is None else min(total, row_limit)
    truncated = row_limit is not None and total > row_limit
    if out_count >= 1 << 31:
        raise RuntimeError(
            f"join result ({total} rows) too large to materialize; "
            "set a row_limit")
    if cap is None:
        cap = _pow2(out_count)
    if out_count > cap:
        err = CapacityOverflow(out_count)
        err.resume = _ProbeResume(a_rows_s, b_rows_s, start, cnt, cnt_np,
                                  key_cols)
        raise err
    if total >= 1 << 31:
        # device cumsum would wrap: clip per-row counts on host so the
        # running total saturates at the row limit, then expand normally
        # (reuses the one cnt_np transfer made above).
        csum = cnt_np.astype(np.int64).cumsum()
        clipped = np.clip(out_count - (csum - cnt_np.astype(np.int64)),
                          0, cnt_np.astype(np.int64))
        cnt = jnp.asarray(clipped.astype(np.int32))
    rows = _merge_expand(a_rows_s, b_rows_s, start, cnt,
                         jnp.int32(out_count), cap=cap,
                         new_sel=tuple(new), has_new=bool(new))
    # The expand emits output slots in sorted-a order: the result is
    # lexicographically ordered by the join key and inherits it.
    return Table(cols=out_cols, rows=rows, count=out_count,
                 truncated=truncated, sort_order=key_cols)


def _join_sorted_fused(a: Table, b: Table, a_sel, b_sel, key_cols,
                       out_cols, new, cap, row_limit, probe_impl: str,
                       telemetry: JoinTelemetry | None) -> Table:
    """Fused sort-merge join (kernels.fused_join): one dispatch, one
    scalar sync.  Same output, telemetry, run-caching, and
    CapacityOverflow contract as the staged path — on overflow the
    resume carries the fused chain's sort+probe byproducts so the retry
    re-runs only the expand."""
    probe = kops._resolve(probe_impl, cpu_default="sorted")
    limit = jnp.int32(min(row_limit, (1 << 31) - 1)
                      if row_limit is not None else (1 << 31) - 1)
    if cap is not None:
        (rows, total_dev, a_keys_s, a_rows_s, b_keys_s, b_rows_s, start,
         cnt) = kfused.sort_probe_expand(
            a.rows, b.rows, limit, a_sel=a_sel, b_sel=b_sel, cap=cap,
            new_sel=tuple(new), has_new=bool(new), probe=probe)
    else:
        a_keys_s, a_rows_s, b_keys_s, b_rows_s, start, cnt, total_dev = \
            kfused.sort_probe(a.rows, b.rows, a_sel=a_sel, b_sel=b_sel,
                              probe=probe)
    if telemetry is not None:
        telemetry.sorts_performed += 2
    a.cache_run(key_cols, a_rows_s, a_keys_s, "a")
    b.cache_run(key_cols, b_rows_s, b_keys_s, "b")
    total = int(total_dev)          # the ONE host sync of this join
    out_count = total if row_limit is None else min(total, row_limit)
    truncated = row_limit is not None and total > row_limit
    if cap is None:
        cap = _pow2(out_count)
        rows = _merge_expand(a_rows_s, b_rows_s, start, cnt,
                             jnp.int32(out_count), cap=cap,
                             new_sel=tuple(new), has_new=bool(new))
    elif out_count > cap:
        err = CapacityOverflow(out_count)
        err.resume = _ProbeResume(a_rows_s, b_rows_s, start, cnt,
                                  np.asarray(cnt), key_cols)
        raise err
    return Table(cols=out_cols, rows=rows, count=out_count,
                 truncated=truncated, sort_order=key_cols)


# ------------------------- radix-hash path ---------------------------- #
@dataclass
class _RadixResume:
    """Partition+window+probe results carried on CapacityOverflow so the
    exact-size retry re-runs only the output assembly."""
    b_rows_p: jax.Array
    lt: jax.Array
    cnt: jax.Array
    win_start: jax.Array
    total: int
    key_cols: tuple[int, ...]


def _radix_bits(b_count: int) -> int:
    """Bucket count ~ 2x the build side (load factor ~0.5), clamped so
    the edge table stays trivial."""
    return max(4, min(16, max(b_count, 1).bit_length()))


def _join_radix(a: Table, b: Table, shared, new, cap, row_limit,
                probe_impl: str, telemetry: JoinTelemetry | None = None,
                resume: _RadixResume | None = None,
                fuse: bool = True) -> Table:
    """Radix-partitioned hash join: partition B by hashed key, stream A
    against per-row bucket windows.  A is never sorted and the output
    preserves A's row order (sort_order carries through).  Degenerate
    distributions — a hot key inflating the max bucket, or a potential
    >2^31 output — fall back to sort-merge deterministically, so warm
    replay re-derives the same decision."""
    out_cols = a.cols + tuple(b.cols[j] for j in new)
    if resume is None:
        # No |A|*|B| product gate here: radix output is bounded by
        # a.cap * lmax, and the work guard below caps that at
        # RADIX_WORK_MAX (<< 2^31), so int32 totals are always safe.
        a_sel = tuple(s[0] for s in shared)
        b_sel = tuple(s[1] for s in shared)
        key_cols = tuple(a.cols[i] for i in a_sel)
        a_keys, b_keys = _pack_keys(a.rows, b.rows, a_sel, b_sel)
        bits = _radix_bits(b.count)
        b_keys_p, b_rows_p, edges, maxlen = krad.radix_partition(
            b_keys, b.rows, bits)
        lmax = _pow2(int(maxlen), lo=8)     # one scalar sync (window size)
        if a.cap * lmax > RADIX_WORK_MAX:
            # skew: the widest bucket would make the window matrix
            # quadratic — sort-merge is strictly better here
            return _join_sorted(a, b, shared, new, cap, row_limit,
                                probe_impl, telemetry=telemetry, fuse=fuse)
        win_keys, win_start = krad.radix_window(a_keys, edges, b_keys_p,
                                                bits, lmax)
        lt, cnt = kops.radix_probe(a_keys, win_keys, impl=probe_impl)
        total = int(jnp.sum(cnt))           # second scalar sync (total)
    else:
        b_rows_p, lt, cnt = resume.b_rows_p, resume.lt, resume.cnt
        win_start = resume.win_start
        total, key_cols = resume.total, resume.key_cols
    out_count = total if row_limit is None else min(total, row_limit)
    truncated = row_limit is not None and total > row_limit
    if cap is None:
        cap = _pow2(out_count)
    if out_count > cap:
        err = CapacityOverflow(out_count)
        err.resume = _RadixResume(b_rows_p, lt, cnt, win_start,
                                  total, key_cols)
        raise err
    rows = krad.radix_scatter(a.rows, b_rows_p, lt, cnt, win_start,
                              jnp.int32(out_count), cap=cap,
                              new_sel=tuple(new), has_new=bool(new))
    # scatter slots are ordered by probe row: A's order is preserved
    return Table(cols=out_cols, rows=rows, count=out_count,
                 truncated=truncated, sort_order=a.sort_order)


# ------------------------- nested-loop path --------------------------- #
@jax.jit
def _join_chunk_mask(a_rows, b_rows, a_sel, b_sel):
    """eq[i, j] = rows valid & all shared cols equal.

    a_sel: [S] indices into a cols; b_sel: [S] indices into b cols."""
    a_k = a_rows[:, a_sel]                          # [A, S]
    b_k = b_rows[:, b_sel]                          # [B, S]
    eq = (a_k[:, None, :] == b_k[None, :, :]).all(-1)
    valid = (a_rows[:, :1] >= 0) & (b_rows[None, :, 0] >= 0)
    return eq & valid


def _assemble(pieces: list[jax.Array], cap: int, ncols: int) -> jax.Array:
    """Stack device-resident row chunks into one padded device buffer."""
    out = jnp.full((cap, ncols), -1, jnp.int32)
    off = 0
    for p in pieces:
        out = jax.lax.dynamic_update_slice(out, p, (off, 0))
        off += int(p.shape[0])
    return out


def _join_nested(a: Table, b: Table, shared, new, cap, chunk, b_chunk,
                 row_limit) -> Table:
    a_sel = jnp.asarray([s[0] for s in shared], jnp.int32)
    b_sel = jnp.asarray([s[1] for s in shared], jnp.int32)
    new_sel = jnp.asarray(new, jnp.int32)
    out_cols = a.cols + tuple(b.cols[j] for j in new)

    pieces, total = [], 0
    truncated = False
    for bs in range(0, max(b.count, 1), b_chunk):
        b_rows_t = b.rows[bs: min(bs + b_chunk,
                                  min(b.cap, _pow2(b.count)))]
        if b_rows_t.shape[0] == 0:
            break
        for start in range(0, max(a.count, 1), chunk):
            a_rows = a.rows[start:start + chunk]
            eq = _join_chunk_mask(a_rows, b_rows_t, a_sel, b_sel)
            cnt = int(eq.sum())
            if cnt == 0:
                continue
            if row_limit is not None:
                remaining = row_limit - total
                if remaining <= 0:
                    truncated = True
                    break
                take = min(cnt, remaining)
                truncated |= take < cnt
            else:
                take = cnt
            rows = _join_gather(eq, a_rows, b_rows_t,
                                new_sel if new else jnp.zeros(0, jnp.int32),
                                _pow2(cnt), bool(new))
            pieces.append(rows[:take])
            total += take
        if truncated:
            break
    if cap is None:
        cap = _pow2(total)
    if total > cap:
        raise CapacityOverflow(total)
    t = Table(cols=out_cols, rows=_assemble(pieces, cap, len(out_cols)),
              count=total)
    t.truncated = truncated
    return t


# ---------------------------------------------------------------------- #
def join_tables(a: Table, b: Table, cap: int | None = None,
                chunk: int = 4096, b_chunk: int = 1 << 16,
                row_limit: int | None = None, impl: str = "auto",
                nested_max: int = DEFAULT_NESTED_MAX,
                probe_impl: str = "auto",
                telemetry: JoinTelemetry | None = None,
                fuse: bool = True,
                _resume=None) -> Table:
    """Equi-join on shared query-node columns.

    impl: 'auto' (planner picks per table sizes and sort state),
    'sorted' (sort-merge), 'radix' (radix-partitioned hash join), or
    'nested' (chunked vectorized nested loop).  With row_limit the join
    stops once the limit is reached (LIMIT semantics — appended rows are
    clamped to the remaining budget and .truncated is set iff matches were
    dropped or scanning stopped early).  telemetry counts sorts performed
    vs. avoided on the sort-merge path; fuse=False disables the fused
    one-dispatch sort-merge chain (A/B comparison, chaos seams); _resume
    (from a CapacityOverflow's .resume) replays a completed sort+probe —
    or partition+probe — at a larger capacity."""
    shared, new = _shared_and_new(a.cols, b.cols)
    if not shared:
        return cross_join(a, b, cap=cap, row_limit=row_limit)
    # A resume object encodes which pipeline produced it: a radix join
    # that fell back to sort-merge retries on the sort-merge path.
    if isinstance(_resume, _ProbeResume):
        return _join_sorted(a, b, shared, new, cap, row_limit, probe_impl,
                            telemetry=telemetry, resume=_resume, fuse=fuse)
    if isinstance(_resume, _RadixResume):
        return _join_radix(a, b, shared, new, cap, row_limit, probe_impl,
                           telemetry=telemetry, resume=_resume, fuse=fuse)
    impl = _resolve_for(a, b, impl, nested_max)
    if impl == "nested":
        return _join_nested(a, b, shared, new, cap, chunk, b_chunk,
                            row_limit)
    if impl == "radix":
        return _join_radix(a, b, shared, new, cap, row_limit, probe_impl,
                           telemetry=telemetry, fuse=fuse)
    return _join_sorted(a, b, shared, new, cap, row_limit, probe_impl,
                        telemetry=telemetry, fuse=fuse)


MAX_PRESIZE_CAP = 1 << 22     # estimate-driven preallocation ceiling (rows)


def planned_join(a: Table, b: Table, est: int | None,
                 row_limit: int | None = None, impl: str = "auto",
                 nested_max: int = DEFAULT_NESTED_MAX,
                 probe_impl: str = "auto", record=None,
                 chunk: int = 4096, b_chunk: int = 1 << 16,
                 telemetry: JoinTelemetry | None = None,
                 fuse: bool = True, tracer=None) -> Table:
    """Estimate-pre-sized join with a single exact-size overflow retry.

    The capacity hint from `est` is clamped by the worst-case output
    (|A|*|B|), the row limit, and MAX_PRESIZE_CAP, so an over-estimate can
    never pre-allocate an absurd buffer — an under-estimate costs one
    retry at the exact pow2 size.  On the sort-merge path the retry
    replays the first attempt's sort+probe results (carried on the
    exception), so only the expand re-runs.  record(impl, est, actual,
    retried, cap) feeds QueryStats telemetry and the PreparedQuery
    capacity recording.

    An `est` carrying a `.cap` attribute (planner.CapEstimate, produced
    by the warm-run ReplayEstimator from the cold run's recorded
    (rows, cap, impl) join_seq) pins the output capacity verbatim — and
    its `.impl`, when set, pins the join strategy — so warm run 1
    allocates the exact steady-state shapes (and replays the strategy
    choices) the cold run ended at: no overflow retry, no fresh jit
    compilation."""
    forced = getattr(est, "impl", None) if est is not None else None
    impl = _resolve_for(a, b, forced or impl, nested_max)
    cap_hint = None
    if est is not None:
        replay_cap = getattr(est, "cap", None)
        if row_limit is not None:
            est = min(est, row_limit)
        if replay_cap is not None:
            cap_hint = int(replay_cap)
        else:
            cap_hint = min(_pow2(int(est * 1.25) + 16),
                           _pow2(max(a.count, 1) * max(b.count, 1)),
                           MAX_PRESIZE_CAP)
            if row_limit is not None:
                cap_hint = min(cap_hint, _pow2(row_limit))
    kw = dict(row_limit=row_limit, impl=impl, probe_impl=probe_impl,
              chunk=chunk, b_chunk=b_chunk, telemetry=telemetry, fuse=fuse)
    if tracer is None:
        tracer = NULL_TRACER
    with tracer.span("join") as sp:
        sp0 = sa0 = 0
        if sp.live and telemetry is not None:
            sp0, sa0 = telemetry.sorts_performed, telemetry.sorts_avoided
        retried = False
        try:
            out = join_tables(a, b, cap=cap_hint, **kw)
        except CapacityOverflow as e:
            retried = True
            out = join_tables(a, b, cap=_pow2(e.needed),
                              _resume=getattr(e, "resume", None), **kw)
        if sp.live:
            sp.set(impl=impl, rows=out.count, cap=out.cap,
                   retried=retried, a_rows=a.count, b_rows=b.count,
                   est=None if est is None else int(est))
            if telemetry is not None:
                sp.set(sorts_performed=telemetry.sorts_performed - sp0,
                       sorts_avoided=telemetry.sorts_avoided - sa0)
    if record is not None:
        record(impl, est, out.count, retried, out.cap)
    return out


@functools.partial(jax.jit, static_argnames=("cap",))
def _cross_expand(a_rows, b_rows, a_count, b_count, cap):
    """Counts are traced scalars so distinct table sizes share one
    compilation per output capacity."""
    t = jnp.arange(cap, dtype=jnp.int32)
    bc = jnp.maximum(b_count, 1)
    # t < a*b  <=>  t // b < a: avoids the int32 product, which wraps
    # for >= 2^31-row cross products
    i0 = t // bc
    valid = (i0 < a_count) & (a_count > 0) & (b_count > 0)
    i = jnp.minimum(i0, jnp.maximum(a_count - 1, 0))
    # j as t - i0*bc, NOT t % bc: the fused int32 remainder miscompiles
    # under XLA CPU at some shapes (gather index collapses to 0 — caught
    # by test_cross_expand_xla_remainder_regression); the subtraction
    # form lowers correctly and is equivalent for t, bc >= 0.
    j = jnp.minimum(t - i0 * bc, jnp.maximum(b_count - 1, 0))
    left = jnp.where(valid[:, None], a_rows[i], -1)
    right = jnp.where(valid[:, None], b_rows[j], -1)
    return jnp.concatenate([left, right], axis=1)


def cross_join(a: Table, b: Table, cap: int | None = None,
               row_limit: int | None = None) -> Table:
    """Cartesian product (used before connectivity-check joins).

    Fully device-resident: the product is expanded with an index-arithmetic
    gather instead of host-side repeat/tile."""
    out_cols = a.cols + b.cols
    total = a.count * b.count
    truncated = False
    a_count, b_count = a.count, b.count
    if row_limit is not None and total > row_limit:
        truncated = True
        a_count = max(1, min(a_count, row_limit))
        b_count = max(1, row_limit // a_count)
        total = a_count * b_count
    if cap is None:
        cap = _pow2(total)
    if total > cap:
        raise CapacityOverflow(total)
    rows = _cross_expand(a.rows, b.rows, jnp.int32(a_count),
                         jnp.int32(b_count), cap)
    # a-major expansion: each a row becomes a contiguous block, so the
    # product stays ordered by whatever a was ordered by.
    t = Table(cols=out_cols, rows=rows, count=total,
              sort_order=a.sort_order)
    t.truncated = truncated
    return t


# ---------------------------------------------------------------------- #
def single_node_table(node: int, lo: int, hi: int,
                      passed: np.ndarray | None) -> Table:
    """Candidates of an isolated query node as a 1-column table.

    passed: full-[N] bool mask (or None)."""
    ids = np.arange(lo, hi, dtype=np.int32)
    if passed is not None:
        ids = ids[np.asarray(passed, dtype=bool)[lo:hi]]
    cap = _pow2(len(ids))
    rows = np.full((cap, 1), -1, np.int32)
    rows[: len(ids), 0] = ids
    # ids come from an arange (optionally mask-filtered): already sorted
    return Table(cols=(node,), rows=jnp.asarray(rows), count=len(ids),
                 sort_order=(node,))


def dtree_candidates(graph: RDFGraph, tree: DTree,
                     pass_masks: dict,   # node -> [N] bool mask | (lo, hi)
                     row_limit: int | None = None,
                     join_impl: str = "auto",
                     nested_max: int = DEFAULT_NESTED_MAX,
                     probe_impl: str = "auto",
                     estimator=None, record=None,
                     telemetry: JoinTelemetry | None = None,
                     fuse: bool = True, tracer=None) -> Table:
    """Generate all candidate matches of one D-tree by sequential
    edge-parallel pair generation + joins on the root column.

    estimator(left_count, pred, outgoing, pair_count) -> estimated join
    rows (or None) pre-sizes each join's capacity so the overflow retry is
    rare; record(impl, est, actual, retried) feeds QueryStats."""
    if tracer is None:
        tracer = NULL_TRACER
    with tracer.span("dtree", root=tree.root) as sp:
        table: Table | None = None
        truncated = False
        for pred, child, outgoing in tree.edges:
            if outgoing:
                pairs = edge_pairs(graph, pred, pass_masks[tree.root],
                                   pass_masks[child],
                                   cols=(tree.root, child))
            else:
                pairs = edge_pairs(graph, pred, pass_masks[child],
                                   pass_masks[tree.root],
                                   cols=(child, tree.root))
            if table is None:
                table = pairs
            else:
                est = None if estimator is None else estimator(
                    table.count, pred, outgoing, pairs.count)
                table = planned_join(table, pairs, est,
                                     row_limit=row_limit,
                                     impl=join_impl, nested_max=nested_max,
                                     probe_impl=probe_impl, record=record,
                                     telemetry=telemetry, fuse=fuse,
                                     tracer=tracer)
            truncated |= table.truncated
            if table.count == 0:
                break
        assert table is not None
        table.truncated = truncated
        if sp.live:
            sp.set(rows=table.count, edges=len(tree.edges),
                   truncated=truncated)
    return table


@functools.partial(jax.jit, static_argnames=("pairs",))
def _injective_keep(rows, pairs):
    keep = rows[:, 0] >= 0                  # padding rows never survive
    for i, j in pairs:
        keep &= rows[:, i] != rows[:, j]
    return keep


def injective_filter(table: Table) -> Table:
    """Keep rows whose values are pairwise distinct across distinct query
    nodes (subgraph-isomorphism semantics)."""
    k = len(table.cols)
    if k < 2 or table.count == 0:
        return table
    pairs = tuple((i, j) for i in range(k) for j in range(i + 1, k)
                  if table.cols[i] != table.cols[j])
    if not pairs:
        return table
    # full-capacity mask (pow2 shape, no per-count recompiles)
    keep = _injective_keep(table.rows, pairs)
    kept = int(keep.sum())
    if kept == table.count:
        return table
    return filter_rows(table, keep, kept=kept)


@functools.partial(jax.jit, static_argnames=("cap_out",))
def _filter_gather(rows, keep, cap_out):
    cap_in = rows.shape[0]
    idx = jnp.nonzero(keep, size=cap_out, fill_value=cap_in)[0]
    safe = jnp.minimum(idx, cap_in - 1)
    return jnp.where((idx < cap_in)[:, None], rows[safe], -1)


def empty_table(cols: tuple[int, ...], cap: int = 64) -> Table:
    """An empty capacity-padded table over `cols`."""
    return Table(cols=tuple(cols),
                 rows=jnp.full((cap, len(cols)), -1, jnp.int32), count=0)


def dedup_project(table: Table, cols: tuple[int, ...],
                  impl: str = "auto") -> Table:
    """Distinct rows of `table` over the column subset `cols`.

    Device-resident and fused (kernels.fused_join.lexsort_distinct):
    projection, lexsort, first-of-group mask, and kept-count run as one
    dispatch sharing the join pipeline's sort primitive — one host sync
    for the output count, then the compaction gather.  Unlike every
    other table op this tolerates valid rows anywhere in the capacity
    (not just a prefix), so callers may feed it a raw concatenation of
    padded row buffers.  Output is sorted by (and tagged with) `cols`."""
    if impl not in ("auto", "pallas", "interpret", "ref", "sorted"):
        raise ValueError(f"unknown impl {impl!r}")
    cols = tuple(cols)
    sel = tuple(table.cols.index(c) for c in cols)
    proj, keep, kept_dev = kfused.lexsort_distinct(table.rows, sel)
    kept = int(kept_dev)
    rows = _filter_gather(proj, keep, _pow2(kept))
    return Table(cols=cols, rows=rows, count=kept, truncated=table.truncated,
                 sort_order=cols)


def filter_rows(table: Table, keep, kept: int | None = None) -> Table:
    """Keep rows where keep[i] — a bool mask over either the first `count`
    rows (host callers) or the full capacity (device producers; padding
    rows must be False there).  The compaction gather runs on device and
    is shaped by pow2 capacities only, so arbitrary counts never force a
    recompile.  Pass `kept` (the known number of True entries) to skip the
    host sync of the mask sum."""
    n = np.shape(keep)[0]
    assert n in (table.count, table.cap), \
        f"keep mask length {n} matches neither count={table.count} " \
        f"nor cap={table.cap}"
    if n != table.cap:
        k = np.zeros(table.cap, bool)
        k[:n] = np.asarray(keep, bool)
        keep = k
    keep = jnp.asarray(keep, dtype=bool)
    if kept is None:
        kept = int(keep.sum())
    cap = _pow2(kept)
    rows = _filter_gather(table.rows, keep, cap)
    # compaction is order-preserving: the surviving rows keep their
    # relative order, so the sort-order tag carries across filters
    return Table(cols=table.cols, rows=rows, count=kept,
                 truncated=table.truncated, sort_order=table.sort_order)
