"""D-tree candidate generation and joins (paper Algorithm 2, steps 2-3).

TPU-native formulation: candidate generation is *edge-parallel* — one pass
over the full edge array produces all (root, child) pairs matching a query
edge (predicate + endpoint pass masks), with no per-node degree padding.
Joins are vectorized nested-loop equi-joins over padded candidate tables
(exactly the paper's join predicate: shared query nodes must map equal).

All tables are capacity-padded for jit shape stability; true counts are
tracked, and capacity overflow triggers a host-side retry with doubled
capacity (the re-plan path a real engine would take).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from .graph import RDFGraph
from .decompose import DTree
import functools


class CapacityOverflow(Exception):
    def __init__(self, needed: int):
        self.needed = int(needed)
        super().__init__(f"capacity overflow, need {needed}")


@dataclass
class Table:
    """Padded match table: rows[i] maps cols[j] -> graph node id."""
    cols: tuple[int, ...]
    rows: jax.Array            # [cap, len(cols)] int32, invalid rows = -1
    count: int                 # true number of valid rows
    truncated: bool = False    # row_limit hit (LIMIT semantics)

    @property
    def cap(self) -> int:
        return int(self.rows.shape[0])

    def numpy(self) -> np.ndarray:
        return np.asarray(self.rows[: self.count])

    def result_set(self) -> set[tuple[int, ...]]:
        return {tuple(int(x) for x in r) for r in self.numpy()}


def _pow2(x: int, lo: int = 64) -> int:
    return max(lo, 1 << (max(int(x), 1) - 1).bit_length())


# ---------------------------------------------------------------------- #
@jax.jit
def _edge_pairs_mask(src, dst, pred, pred_id, pass_src, pass_dst):
    mask = pass_src[src] & pass_dst[dst]
    mask = mask & jnp.where(pred_id < 0, True, pred == pred_id)
    return mask


@functools.partial(jax.jit, static_argnames=("cap",))
def _edge_pairs_gather(mask, src, dst, cap):
    e = src.shape[0]
    idx = jnp.nonzero(mask, size=cap, fill_value=e)[0]
    safe = jnp.minimum(idx, e - 1)
    s = jnp.where(idx < e, src[safe], -1)
    d = jnp.where(idx < e, dst[safe], -1)
    return jnp.stack([s, d], axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("size", "has_new"))
def _join_gather(eq, a_rows, b_rows, new_sel, size, has_new):
    ii, jj = jnp.nonzero(eq, size=size, fill_value=-1)
    left = jnp.where(ii[:, None] >= 0, a_rows[jnp.maximum(ii, 0)], -1)
    if has_new:
        right = jnp.where(jj[:, None] >= 0,
                          b_rows[jnp.maximum(jj, 0)][:, new_sel], -1)
        return jnp.concatenate([left, right], axis=1)
    return left


def edge_pairs(graph: RDFGraph, pred_id: int | None,
               pass_src: jax.Array, pass_dst: jax.Array,
               cols: tuple[int, int], cap: int | None = None) -> Table:
    """All edges (s, d) with pred==pred_id (None = any) and both endpoint
    masks true.  Returns a 2-column table."""
    src = jnp.asarray(graph.src)
    dst = jnp.asarray(graph.dst)
    pred = jnp.asarray(graph.pred)
    p = jnp.int32(-1 if pred_id is None else pred_id)
    mask = _edge_pairs_mask(src, dst, pred, p, pass_src, pass_dst)
    if cols[0] == cols[1]:      # query self-loop: s == d, single column
        mask = mask & (src == dst)
        count = int(mask.sum())
        cap2 = cap or _pow2(count)
        if count > cap2:
            raise CapacityOverflow(count)
        idx = jnp.nonzero(mask, size=cap2, fill_value=graph.num_edges)[0]
        s = jnp.where(idx < graph.num_edges,
                      src[jnp.minimum(idx, graph.num_edges - 1)], -1)
        return Table(cols=(cols[0],), rows=s[:, None].astype(jnp.int32),
                     count=count)
    count = int(mask.sum())
    if cap is None:
        cap = _pow2(count)
    if count > cap:
        raise CapacityOverflow(count)
    rows = _edge_pairs_gather(mask, src, dst, cap)
    return Table(cols=cols, rows=rows, count=count)


# ---------------------------------------------------------------------- #
def _shared_and_new(a_cols, b_cols):
    shared = [(a_cols.index(c), b_cols.index(c)) for c in a_cols if c in b_cols]
    new = [j for j, c in enumerate(b_cols) if c not in a_cols]
    return shared, new


@jax.jit
def _join_chunk_mask(a_rows, b_rows, a_sel, b_sel):
    """eq[i, j] = rows valid & all shared cols equal.

    a_sel: [S] indices into a cols; b_sel: [S] indices into b cols."""
    a_k = a_rows[:, a_sel]                          # [A, S]
    b_k = b_rows[:, b_sel]                          # [B, S]
    eq = (a_k[:, None, :] == b_k[None, :, :]).all(-1)
    valid = (a_rows[:, :1] >= 0) & (b_rows[None, :, 0] >= 0)
    return eq & valid


def join_tables(a: Table, b: Table, cap: int | None = None,
                chunk: int = 4096, b_chunk: int = 1 << 16,
                row_limit: int | None = None) -> Table:
    """Vectorized nested-loop equi-join on shared query-node columns.

    Both sides are chunked so the compare matrix stays bounded; with
    row_limit the join stops once the limit is reached (LIMIT semantics —
    the returned table has .truncated=True)."""
    shared, new = _shared_and_new(a.cols, b.cols)
    if not shared:
        return cross_join(a, b, cap=cap, chunk=chunk, row_limit=row_limit)
    a_sel = jnp.asarray([s[0] for s in shared], jnp.int32)
    b_sel = jnp.asarray([s[1] for s in shared], jnp.int32)
    new_sel = jnp.asarray(new, jnp.int32)
    out_cols = a.cols + tuple(b.cols[j] for j in new)

    pieces, total = [], 0
    truncated = False
    for bs in range(0, max(b.count, 1), b_chunk):
        b_rows_t = b.rows[bs: min(bs + b_chunk,
                                  min(b.cap, _pow2(b.count)))]
        if b_rows_t.shape[0] == 0:
            break
        for start in range(0, max(a.count, 1), chunk):
            a_rows = a.rows[start:start + chunk]
            eq = _join_chunk_mask(a_rows, b_rows_t, a_sel, b_sel)
            cnt = int(eq.sum())
            if cnt == 0:
                continue
            if row_limit is not None and total >= row_limit:
                truncated = True
                break
            total += cnt
            rows = _join_gather(eq, a_rows, b_rows_t,
                                new_sel if new else jnp.zeros(0, jnp.int32),
                                _pow2(cnt), bool(new))
            pieces.append(np.asarray(rows[:cnt]))
        if truncated:
            break
    if cap is None:
        cap = _pow2(total)
    if total > cap:
        raise CapacityOverflow(total)
    out = np.full((cap, len(out_cols)), -1, np.int32)
    if pieces:
        cat = np.concatenate(pieces, axis=0)
        out[: cat.shape[0]] = cat
    t = Table(cols=out_cols, rows=jnp.asarray(out), count=total)
    t.truncated = truncated
    return t


def cross_join(a: Table, b: Table, cap: int | None = None,
               chunk: int = 4096, row_limit: int | None = None) -> Table:
    """Cartesian product (used before connectivity-check joins)."""
    out_cols = a.cols + b.cols
    total = a.count * b.count
    truncated = False
    a_count, b_count = a.count, b.count
    if row_limit is not None and total > row_limit:
        truncated = True
        a_count = max(1, min(a_count, row_limit))
        b_count = max(1, row_limit // a_count)
        total = a_count * b_count
    if cap is None:
        cap = _pow2(total)
    if total > cap:
        raise CapacityOverflow(total)
    an = np.asarray(a.rows[: a_count])
    bn = np.asarray(b.rows[: b_count])
    left = np.repeat(an, bn.shape[0], axis=0)
    right = np.tile(bn, (an.shape[0], 1))
    out = np.full((cap, len(out_cols)), -1, np.int32)
    if total:
        out[:total] = np.concatenate([left, right], axis=1)
    t = Table(cols=out_cols, rows=jnp.asarray(out), count=total)
    t.truncated = truncated
    return t


# ---------------------------------------------------------------------- #
def single_node_table(node: int, lo: int, hi: int,
                      passed: np.ndarray | None) -> Table:
    """Candidates of an isolated query node as a 1-column table.

    passed: full-[N] bool mask (or None)."""
    ids = np.arange(lo, hi, dtype=np.int32)
    if passed is not None:
        ids = ids[np.asarray(passed, dtype=bool)[lo:hi]]
    cap = _pow2(len(ids))
    rows = np.full((cap, 1), -1, np.int32)
    rows[: len(ids), 0] = ids
    return Table(cols=(node,), rows=jnp.asarray(rows), count=len(ids))


def dtree_candidates(graph: RDFGraph, tree: DTree,
                     pass_masks: dict[int, jax.Array],
                     row_limit: int | None = None,
                     cap: int | None = None) -> Table:
    """Generate all candidate matches of one D-tree by sequential
    edge-parallel pair generation + joins on the root column."""
    table: Table | None = None
    truncated = False
    for pred, child, outgoing in tree.edges:
        if outgoing:
            pairs = edge_pairs(graph, pred, pass_masks[tree.root],
                               pass_masks[child], cols=(tree.root, child))
        else:
            pairs = edge_pairs(graph, pred, pass_masks[child],
                               pass_masks[tree.root], cols=(child, tree.root))
        table = pairs if table is None else join_tables(
            table, pairs, row_limit=row_limit)
        truncated |= table.truncated
        if table.count == 0:
            break
    assert table is not None
    table.truncated = truncated
    return table


def injective_filter(table: Table) -> Table:
    """Keep rows whose values are pairwise distinct across distinct query
    nodes (subgraph-isomorphism semantics)."""
    k = len(table.cols)
    if k < 2 or table.count == 0:
        return table
    rows = np.asarray(table.rows[: table.count])
    keep = np.ones(table.count, dtype=bool)
    for i in range(k):
        for j in range(i + 1, k):
            if table.cols[i] != table.cols[j]:
                keep &= rows[:, i] != rows[:, j]
    if keep.all():
        return table
    return filter_rows(table, keep)


def filter_rows(table: Table, keep: np.ndarray) -> Table:
    """Keep rows where keep[i] (bool over first `count` rows)."""
    rows = np.asarray(table.rows[: table.count])[np.asarray(keep, bool)]
    cap = _pow2(rows.shape[0])
    out = np.full((cap, len(table.cols)), -1, np.int32)
    out[: rows.shape[0]] = rows
    return Table(cols=table.cols, rows=jnp.asarray(out),
                 count=rows.shape[0], truncated=table.truncated)
