"""D-tree candidate generation and joins (paper Algorithm 2, steps 2-3).

TPU-native formulation: candidate generation is *edge-parallel* — one pass
over the full edge array produces all (root, child) pairs matching a query
edge (predicate + endpoint pass masks), with no per-node degree padding.

Joins are planned per-pair between two device-resident strategies:

  * ``sorted`` — sort-merge equi-join: shared join columns are packed into
    a single int32 key (hierarchical dense-rank packing, so any number of
    columns fits 31 bits without overflow), both sides are sorted once,
    per-row match ranges come from the merge-probe kernel
    (``kernels.merge_probe``: searchsorted on CPU, Pallas on TPU), and
    matches are expanded with a segment-offset gather.  O((A+B)·log+out)
    work, all intermediates on device.
  * ``nested`` — the vectorized nested-loop join (an |A|×|B| compare mask
    per chunk).  O(A·B) but with trivial constants; the planner keeps it
    for small tables where sort/probe setup dominates.

All tables are capacity-padded for jit shape stability; true counts are
tracked, and capacity overflow raises CapacityOverflow carrying the exact
needed size so the engine's retry re-sizes in one step (stats-driven
estimates pre-size capacities so the retry is the exception).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from .graph import RDFGraph
from .decompose import DTree
from ..kernels import ops as kops
import functools


DEFAULT_NESTED_MAX = 256      # planner: nested-loop below this table size

# Join-key space: real packed keys live in [0, 2^31 - 3]; the top two
# int32 values are invalid-row sentinels (distinct per side so an invalid
# a-row never matches an invalid b-row).
_A_INVALID = (1 << 31) - 1
_B_INVALID = (1 << 31) - 2


class CapacityOverflow(Exception):
    def __init__(self, needed: int):
        self.needed = int(needed)
        super().__init__(f"capacity overflow, need {needed}")


@dataclass
class Table:
    """Padded match table: rows[i] maps cols[j] -> graph node id."""
    cols: tuple[int, ...]
    rows: jax.Array            # [cap, len(cols)] int32, invalid rows = -1
    count: int                 # true number of valid rows
    truncated: bool = False    # row_limit hit (LIMIT semantics)

    @property
    def cap(self) -> int:
        return int(self.rows.shape[0])

    def numpy(self) -> np.ndarray:
        return np.asarray(self.rows[: self.count])

    def result_set(self) -> set[tuple[int, ...]]:
        return {tuple(int(x) for x in r) for r in self.numpy()}


def _pow2(x: int, lo: int = 64) -> int:
    return max(lo, 1 << (max(int(x), 1) - 1).bit_length())


# ---------------------------------------------------------------------- #
@jax.jit
def _edge_pairs_mask(src, dst, pred, pred_id, pass_src, pass_dst):
    mask = pass_src[src] & pass_dst[dst]
    mask = mask & jnp.where(pred_id < 0, True, pred == pred_id)
    return mask


@functools.partial(jax.jit, static_argnames=("cap",))
def _edge_pairs_gather(mask, src, dst, cap):
    e = src.shape[0]
    idx = jnp.nonzero(mask, size=cap, fill_value=e)[0]
    safe = jnp.minimum(idx, e - 1)
    s = jnp.where(idx < e, src[safe], -1)
    d = jnp.where(idx < e, dst[safe], -1)
    return jnp.stack([s, d], axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("size", "has_new"))
def _join_gather(eq, a_rows, b_rows, new_sel, size, has_new):
    ii, jj = jnp.nonzero(eq, size=size, fill_value=-1)
    left = jnp.where(ii[:, None] >= 0, a_rows[jnp.maximum(ii, 0)], -1)
    if has_new:
        right = jnp.where(jj[:, None] >= 0,
                          b_rows[jnp.maximum(jj, 0)][:, new_sel], -1)
        return jnp.concatenate([left, right], axis=1)
    return left


def edge_pairs(graph: RDFGraph, pred_id: int | None,
               pass_src: jax.Array, pass_dst: jax.Array,
               cols: tuple[int, int], cap: int | None = None) -> Table:
    """All edges (s, d) with pred==pred_id (None = any) and both endpoint
    masks true.  Returns a 2-column table."""
    src = jnp.asarray(graph.src)
    dst = jnp.asarray(graph.dst)
    pred = jnp.asarray(graph.pred)
    p = jnp.int32(-1 if pred_id is None else pred_id)
    mask = _edge_pairs_mask(src, dst, pred, p, pass_src, pass_dst)
    if cols[0] == cols[1]:      # query self-loop: s == d, single column
        mask = mask & (src == dst)
        count = int(mask.sum())
        cap2 = cap or _pow2(count)
        if count > cap2:
            raise CapacityOverflow(count)
        idx = jnp.nonzero(mask, size=cap2, fill_value=graph.num_edges)[0]
        s = jnp.where(idx < graph.num_edges,
                      src[jnp.minimum(idx, graph.num_edges - 1)], -1)
        return Table(cols=(cols[0],), rows=s[:, None].astype(jnp.int32),
                     count=count)
    count = int(mask.sum())
    if cap is None:
        cap = _pow2(count)
    if count > cap:
        raise CapacityOverflow(count)
    rows = _edge_pairs_gather(mask, src, dst, cap)
    return Table(cols=cols, rows=rows, count=count)


# ---------------------------------------------------------------------- #
def _shared_and_new(a_cols, b_cols):
    shared = [(a_cols.index(c), b_cols.index(c)) for c in a_cols if c in b_cols]
    new = [j for j, c in enumerate(b_cols) if c not in a_cols]
    return shared, new


def resolve_join_impl(a_count: int, b_count: int, impl: str = "auto",
                      nested_max: int = DEFAULT_NESTED_MAX) -> str:
    """Per-join strategy choice: nested-loop for small tables (sort/probe
    setup dominates), sort-merge otherwise."""
    if impl != "auto":
        return impl
    return "nested" if max(a_count, b_count) <= nested_max else "sorted"


# ------------------------- sort-merge path ---------------------------- #
@jax.jit
def _rank_pair(hi, lo):
    """Dense lexicographic rank of (hi, lo) pairs — order- and
    equality-preserving map into [0, len).  Keeps packed keys inside int32
    for any number of join columns (rank < |A|+|B| at every level)."""
    order = jnp.lexsort((lo, hi))
    hs, ls = hi[order], lo[order]
    boundary = (hs[1:] != hs[:-1]) | (ls[1:] != ls[:-1])
    new = jnp.concatenate([jnp.ones((1,), jnp.int32),
                           boundary.astype(jnp.int32)])
    ranks_sorted = jnp.cumsum(new) - 1
    return jnp.zeros_like(ranks_sorted).at[order].set(
        ranks_sorted).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("a_sel", "b_sel"))
def _build_join_keys(a_rows, b_rows, a_sel, b_sel):
    """Pack the shared join columns of both tables into one int32 key per
    row.  Single shared column: the node id is the key.  Multiple columns:
    hierarchical dense-rank packing over the concatenated tables, so both
    sides share one key space and equal keys <=> equal column tuples.
    Invalid rows map to per-side sentinels that sort last and never match.
    """
    n_a = a_rows.shape[0]
    a_valid = a_rows[:, 0] >= 0
    b_valid = b_rows[:, 0] >= 0

    def comp(s):
        va = jnp.where(a_valid, a_rows[:, a_sel[s]], _A_INVALID)
        vb = jnp.where(b_valid, b_rows[:, b_sel[s]], _B_INVALID)
        return jnp.concatenate([va, vb]).astype(jnp.int32)

    key = comp(0)
    for s in range(1, len(a_sel)):
        key = _rank_pair(key, comp(s))
    a_keys = jnp.where(a_valid, key[:n_a], _A_INVALID)
    b_keys = jnp.where(b_valid, key[n_a:], _B_INVALID)
    return a_keys, b_keys


@jax.jit
def _sort_rows_by_key(keys, rows):
    order = jnp.argsort(keys)
    return keys[order], rows[order]


@functools.partial(jax.jit, static_argnames=("cap", "new_sel", "has_new"))
def _merge_expand(a_rows_s, b_rows_s, start, cnt, limit, cap, new_sel,
                  has_new):
    """Expand per-a-row match ranges into output rows.

    Output slot t belongs to sorted a-row i = searchsorted(cumsum(cnt), t)
    and pairs it with sorted b-row start[i] + (t - prefix[i]) — a pure
    segment-offset gather, no host round-trip."""
    a_cap = a_rows_s.shape[0]
    csum = jnp.cumsum(cnt)
    t = jnp.arange(cap, dtype=jnp.int32)
    seg = jnp.searchsorted(csum, t, side="right").astype(jnp.int32)
    valid = (t < csum[-1]) & (t < limit)
    i = jnp.minimum(seg, a_cap - 1)
    base = csum[i] - cnt[i]
    j = jnp.clip(start[i] + (t - base), 0, b_rows_s.shape[0] - 1)
    left = jnp.where(valid[:, None], a_rows_s[i], -1)
    if has_new:
        sel = jnp.asarray(new_sel, jnp.int32)
        right = jnp.where(valid[:, None], b_rows_s[j][:, sel], -1)
        return jnp.concatenate([left, right], axis=1)
    return left


def _join_sorted(a: Table, b: Table, shared, new, cap, row_limit,
                 probe_impl: str) -> Table:
    a_sel = tuple(s[0] for s in shared)
    b_sel = tuple(s[1] for s in shared)
    out_cols = a.cols + tuple(b.cols[j] for j in new)

    a_keys, b_keys = _build_join_keys(a.rows, b.rows, a_sel, b_sel)
    a_keys_s, a_rows_s = _sort_rows_by_key(a_keys, a.rows)
    b_keys_s, b_rows_s = _sort_rows_by_key(b_keys, b.rows)
    start, cnt = kops.merge_probe(a_keys_s, b_keys_s, impl=probe_impl)

    # The per-row count vector syncs to host once per join (planning
    # metadata, not row data): summing in int64 avoids the int32 wrap a
    # skewed >2^31-match join would hit on device.
    cnt_np = np.asarray(cnt)
    total = int(cnt_np.sum(dtype=np.int64))
    out_count = total if row_limit is None else min(total, row_limit)
    truncated = row_limit is not None and total > row_limit
    if out_count >= 1 << 31:
        raise RuntimeError(
            f"join result ({total} rows) too large to materialize; "
            "set a row_limit")
    if cap is None:
        cap = _pow2(out_count)
    if out_count > cap:
        raise CapacityOverflow(out_count)
    if total >= 1 << 31:
        # device cumsum would wrap: clip per-row counts on host so the
        # running total saturates at the row limit, then expand normally.
        csum = cnt_np.astype(np.int64).cumsum()
        clipped = np.clip(out_count - (csum - cnt_np.astype(np.int64)),
                          0, cnt_np.astype(np.int64))
        cnt = jnp.asarray(clipped.astype(np.int32))
    rows = _merge_expand(a_rows_s, b_rows_s, start, cnt,
                         jnp.int32(out_count), cap=cap,
                         new_sel=tuple(new), has_new=bool(new))
    return Table(cols=out_cols, rows=rows, count=out_count,
                 truncated=truncated)


# ------------------------- nested-loop path --------------------------- #
@jax.jit
def _join_chunk_mask(a_rows, b_rows, a_sel, b_sel):
    """eq[i, j] = rows valid & all shared cols equal.

    a_sel: [S] indices into a cols; b_sel: [S] indices into b cols."""
    a_k = a_rows[:, a_sel]                          # [A, S]
    b_k = b_rows[:, b_sel]                          # [B, S]
    eq = (a_k[:, None, :] == b_k[None, :, :]).all(-1)
    valid = (a_rows[:, :1] >= 0) & (b_rows[None, :, 0] >= 0)
    return eq & valid


def _assemble(pieces: list[jax.Array], cap: int, ncols: int) -> jax.Array:
    """Stack device-resident row chunks into one padded device buffer."""
    out = jnp.full((cap, ncols), -1, jnp.int32)
    off = 0
    for p in pieces:
        out = jax.lax.dynamic_update_slice(out, p, (off, 0))
        off += int(p.shape[0])
    return out


def _join_nested(a: Table, b: Table, shared, new, cap, chunk, b_chunk,
                 row_limit) -> Table:
    a_sel = jnp.asarray([s[0] for s in shared], jnp.int32)
    b_sel = jnp.asarray([s[1] for s in shared], jnp.int32)
    new_sel = jnp.asarray(new, jnp.int32)
    out_cols = a.cols + tuple(b.cols[j] for j in new)

    pieces, total = [], 0
    truncated = False
    for bs in range(0, max(b.count, 1), b_chunk):
        b_rows_t = b.rows[bs: min(bs + b_chunk,
                                  min(b.cap, _pow2(b.count)))]
        if b_rows_t.shape[0] == 0:
            break
        for start in range(0, max(a.count, 1), chunk):
            a_rows = a.rows[start:start + chunk]
            eq = _join_chunk_mask(a_rows, b_rows_t, a_sel, b_sel)
            cnt = int(eq.sum())
            if cnt == 0:
                continue
            if row_limit is not None:
                remaining = row_limit - total
                if remaining <= 0:
                    truncated = True
                    break
                take = min(cnt, remaining)
                truncated |= take < cnt
            else:
                take = cnt
            rows = _join_gather(eq, a_rows, b_rows_t,
                                new_sel if new else jnp.zeros(0, jnp.int32),
                                _pow2(cnt), bool(new))
            pieces.append(rows[:take])
            total += take
        if truncated:
            break
    if cap is None:
        cap = _pow2(total)
    if total > cap:
        raise CapacityOverflow(total)
    t = Table(cols=out_cols, rows=_assemble(pieces, cap, len(out_cols)),
              count=total)
    t.truncated = truncated
    return t


# ---------------------------------------------------------------------- #
def join_tables(a: Table, b: Table, cap: int | None = None,
                chunk: int = 4096, b_chunk: int = 1 << 16,
                row_limit: int | None = None, impl: str = "auto",
                nested_max: int = DEFAULT_NESTED_MAX,
                probe_impl: str = "auto") -> Table:
    """Equi-join on shared query-node columns.

    impl: 'auto' (planner picks per table size), 'sorted' (sort-merge),
    or 'nested' (chunked vectorized nested loop).  With row_limit the join
    stops once the limit is reached (LIMIT semantics — appended rows are
    clamped to the remaining budget and .truncated is set iff matches were
    dropped or scanning stopped early)."""
    shared, new = _shared_and_new(a.cols, b.cols)
    if not shared:
        return cross_join(a, b, cap=cap, row_limit=row_limit)
    impl = resolve_join_impl(a.count, b.count, impl, nested_max)
    if impl == "nested":
        return _join_nested(a, b, shared, new, cap, chunk, b_chunk,
                            row_limit)
    return _join_sorted(a, b, shared, new, cap, row_limit, probe_impl)


MAX_PRESIZE_CAP = 1 << 22     # estimate-driven preallocation ceiling (rows)


def planned_join(a: Table, b: Table, est: int | None,
                 row_limit: int | None = None, impl: str = "auto",
                 nested_max: int = DEFAULT_NESTED_MAX,
                 probe_impl: str = "auto", record=None,
                 chunk: int = 4096, b_chunk: int = 1 << 16) -> Table:
    """Estimate-pre-sized join with a single exact-size overflow retry.

    The capacity hint from `est` is clamped by the worst-case output
    (|A|*|B|), the row limit, and MAX_PRESIZE_CAP, so an over-estimate can
    never pre-allocate an absurd buffer — an under-estimate costs one
    retry at the exact pow2 size.  record(impl, est, actual, retried)
    feeds QueryStats telemetry."""
    if not any(c in b.cols for c in a.cols):
        impl = "cross"              # no shared cols: join_tables delegates
    else:
        impl = resolve_join_impl(a.count, b.count, impl, nested_max)
    cap_hint = None
    if est is not None:
        if row_limit is not None:
            est = min(est, row_limit)
        cap_hint = min(_pow2(int(est * 1.25) + 16),
                       _pow2(max(a.count, 1) * max(b.count, 1)),
                       MAX_PRESIZE_CAP)
        if row_limit is not None:
            cap_hint = min(cap_hint, _pow2(row_limit))
    kw = dict(row_limit=row_limit, impl=impl, probe_impl=probe_impl,
              chunk=chunk, b_chunk=b_chunk)
    retried = False
    try:
        out = join_tables(a, b, cap=cap_hint, **kw)
    except CapacityOverflow as e:
        retried = True
        out = join_tables(a, b, cap=_pow2(e.needed), **kw)
    if record is not None:
        record(impl, est, out.count, retried)
    return out


@functools.partial(jax.jit, static_argnames=("cap",))
def _cross_expand(a_rows, b_rows, a_count, b_count, cap):
    """Counts are traced scalars so distinct table sizes share one
    compilation per output capacity."""
    t = jnp.arange(cap, dtype=jnp.int32)
    bc = jnp.maximum(b_count, 1)
    # t < a*b  <=>  t // b < a: avoids the int32 product, which wraps
    # for >= 2^31-row cross products
    valid = ((t // bc) < a_count) & (a_count > 0) & (b_count > 0)
    i = jnp.minimum(t // bc, jnp.maximum(a_count - 1, 0))
    j = jnp.minimum(t % bc, jnp.maximum(b_count - 1, 0))
    left = jnp.where(valid[:, None], a_rows[i], -1)
    right = jnp.where(valid[:, None], b_rows[j], -1)
    return jnp.concatenate([left, right], axis=1)


def cross_join(a: Table, b: Table, cap: int | None = None,
               row_limit: int | None = None) -> Table:
    """Cartesian product (used before connectivity-check joins).

    Fully device-resident: the product is expanded with an index-arithmetic
    gather instead of host-side repeat/tile."""
    out_cols = a.cols + b.cols
    total = a.count * b.count
    truncated = False
    a_count, b_count = a.count, b.count
    if row_limit is not None and total > row_limit:
        truncated = True
        a_count = max(1, min(a_count, row_limit))
        b_count = max(1, row_limit // a_count)
        total = a_count * b_count
    if cap is None:
        cap = _pow2(total)
    if total > cap:
        raise CapacityOverflow(total)
    rows = _cross_expand(a.rows, b.rows, jnp.int32(a_count),
                         jnp.int32(b_count), cap)
    t = Table(cols=out_cols, rows=rows, count=total)
    t.truncated = truncated
    return t


# ---------------------------------------------------------------------- #
def single_node_table(node: int, lo: int, hi: int,
                      passed: np.ndarray | None) -> Table:
    """Candidates of an isolated query node as a 1-column table.

    passed: full-[N] bool mask (or None)."""
    ids = np.arange(lo, hi, dtype=np.int32)
    if passed is not None:
        ids = ids[np.asarray(passed, dtype=bool)[lo:hi]]
    cap = _pow2(len(ids))
    rows = np.full((cap, 1), -1, np.int32)
    rows[: len(ids), 0] = ids
    return Table(cols=(node,), rows=jnp.asarray(rows), count=len(ids))


def dtree_candidates(graph: RDFGraph, tree: DTree,
                     pass_masks: dict[int, jax.Array],
                     row_limit: int | None = None,
                     join_impl: str = "auto",
                     nested_max: int = DEFAULT_NESTED_MAX,
                     probe_impl: str = "auto",
                     estimator=None, record=None) -> Table:
    """Generate all candidate matches of one D-tree by sequential
    edge-parallel pair generation + joins on the root column.

    estimator(left_count, pred, outgoing, pair_count) -> estimated join
    rows (or None) pre-sizes each join's capacity so the overflow retry is
    rare; record(impl, est, actual, retried) feeds QueryStats."""
    table: Table | None = None
    truncated = False
    for pred, child, outgoing in tree.edges:
        if outgoing:
            pairs = edge_pairs(graph, pred, pass_masks[tree.root],
                               pass_masks[child], cols=(tree.root, child))
        else:
            pairs = edge_pairs(graph, pred, pass_masks[child],
                               pass_masks[tree.root], cols=(child, tree.root))
        if table is None:
            table = pairs
        else:
            est = None if estimator is None else estimator(
                table.count, pred, outgoing, pairs.count)
            table = planned_join(table, pairs, est, row_limit=row_limit,
                                 impl=join_impl, nested_max=nested_max,
                                 probe_impl=probe_impl, record=record)
        truncated |= table.truncated
        if table.count == 0:
            break
    assert table is not None
    table.truncated = truncated
    return table


@functools.partial(jax.jit, static_argnames=("pairs",))
def _injective_keep(rows, pairs):
    keep = rows[:, 0] >= 0                  # padding rows never survive
    for i, j in pairs:
        keep &= rows[:, i] != rows[:, j]
    return keep


def injective_filter(table: Table) -> Table:
    """Keep rows whose values are pairwise distinct across distinct query
    nodes (subgraph-isomorphism semantics)."""
    k = len(table.cols)
    if k < 2 or table.count == 0:
        return table
    pairs = tuple((i, j) for i in range(k) for j in range(i + 1, k)
                  if table.cols[i] != table.cols[j])
    if not pairs:
        return table
    # full-capacity mask (pow2 shape, no per-count recompiles)
    keep = _injective_keep(table.rows, pairs)
    kept = int(keep.sum())
    if kept == table.count:
        return table
    return filter_rows(table, keep, kept=kept)


@functools.partial(jax.jit, static_argnames=("cap_out",))
def _filter_gather(rows, keep, cap_out):
    cap_in = rows.shape[0]
    idx = jnp.nonzero(keep, size=cap_out, fill_value=cap_in)[0]
    safe = jnp.minimum(idx, cap_in - 1)
    return jnp.where((idx < cap_in)[:, None], rows[safe], -1)


def filter_rows(table: Table, keep, kept: int | None = None) -> Table:
    """Keep rows where keep[i] — a bool mask over either the first `count`
    rows (host callers) or the full capacity (device producers; padding
    rows must be False there).  The compaction gather runs on device and
    is shaped by pow2 capacities only, so arbitrary counts never force a
    recompile.  Pass `kept` (the known number of True entries) to skip the
    host sync of the mask sum."""
    n = np.shape(keep)[0]
    assert n in (table.count, table.cap), \
        f"keep mask length {n} matches neither count={table.count} " \
        f"nor cap={table.cap}"
    if n != table.cap:
        k = np.zeros(table.cap, bool)
        k[:n] = np.asarray(keep, bool)
        keep = k
    keep = jnp.asarray(keep, dtype=bool)
    if kept is None:
        kept = int(keep.sum())
    cap = _pow2(kept)
    rows = _filter_gather(table.rows, keep, cap)
    return Table(cols=table.cols, rows=rows, count=kept,
                 truncated=table.truncated)
