"""Versioned ``Dataset`` facade: construction + incremental delta ingest.

Everything the engine consults — edge arrays/CSR, IDMap intervals, the NI
index, and ``DatasetStats`` — is derived from one frozen ``RDFGraph``.  The
``Dataset`` owns all of it under a single identity: a content ``digest``
(sha1 over the edge arrays) plus a monotone ``version`` counter, which the
serving tier uses to scope its caches (PlanCache / ReachCache / ResultCache).

``apply_delta(inserts, deletes)`` returns a NEW ``Dataset`` (never mutates —
the old one keeps answering queries with pre-delta results, i.e. snapshot
isolation) and maintains the derived structures *incrementally*:

  * edge arrays:   old-kept-order + inserts appended — exactly the order
                   ``RDFGraph.from_triples`` would produce on the post-delta
                   triple list, so digests, CSR bytes and sampled stats all
                   match a full rebuild bit-for-bit;
  * CSR:           ``csr_patch`` splices deleted rows out / inserted rows in
                   without re-sorting untouched rows;
  * NI index:      only nodes within ``d_max - 1`` reverse hops of a changed
                   edge endpoint (in the old OR new graph) get their k-hop
                   rows recomputed; untouched ``NIEntry`` tensors are shared
                   by reference, which is what lets the engine keep its
                   device-resident copies across a delta;
  * stats:         O(E) features recomputed, the expensive ones (coherence,
                   specialty, literal selectivity, diversity) patched via
                   per-type / per-predicate term caches with the summation
                   replayed in the same order as a from-scratch build.

A delta that can't be maintained incrementally — new/dropped labels, new
predicates, node-kind changes, the vertex-cover NI variant, or churn above
``churn_threshold`` — falls back to a full rebuild (``delta_info["mode"] ==
"rebuild"`` with the reason).  Incremental results are always byte-identical
to the rebuild; the fallback only changes *cost*, never answers.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field, replace
from typing import Iterable, Sequence

import numpy as np

from .graph import RDFGraph, IDMap, _csr, csr_patch, ATTR, REL, LITERAL, RESOURCE
from .ni_index import NIIndex, build_ni_index, khop_rows, patch_entry
from .stats import (DatasetStats, _find_type_predicate, coherence_from_terms,
                    coherence_terms, compute_stats, literal_diversity,
                    literal_selectivity, node_degrees, predicate_fanout,
                    predicate_selectivity, specialty_from_terms,
                    specialty_terms)

# Engine variant table (paper §5/§6 configurations).  Lives here — not in
# engine.py — so Dataset.build can size the NI index for a variant without
# importing the engine (dataset is a lower layer).
ENGINE_VARIANTS: dict[str, dict] = {
    # d: NI depth to build; policy: §4.3 check policy; var: NI variant;
    # d_check: depth the check consults.
    "stwig+":    dict(d=1, policy="never", var="full", d_check=1),
    "spath_ni2": dict(d=2, policy="always", var="full", d_check=2),
    "h2":        dict(d=2, policy="selective", var="full", d_check=2),
    "h3":        dict(d=3, policy="selective", var="full", d_check=3),
    "hvc":       dict(d=2, policy="selective", var="vc", d_check=2),
    "rdf_h":     dict(d=2, policy="selective", var="full", d_check=2),
}


def content_digest(graph: RDFGraph) -> str:
    """Content digest of the edge structure (16 hex chars).

    Identical bytes to the historical ``plan_cache.dataset_key`` so learned
    state snapshotted before this API existed still matches.
    """
    h = hashlib.sha1()
    h.update(f"{graph.num_nodes}n-{graph.num_edges}e".encode())
    for arr in (graph.src, graph.dst, graph.pred):
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()[:16]


def interval_footprint_hit(iv, touched: np.ndarray | None) -> bool:
    """True if any candidate interval [lo, hi) contains a touched node.

    ``touched`` is the sorted array of node ids whose NI rows a delta
    recomputed (None = full rebuild = everything touched); an unknown
    footprint (``iv`` None) also counts as hit.  A prepared query's
    candidate masks, a reach entry, or a cached result can only change
    if its footprint intersects the touched set — so this is the single
    soundness predicate behind every revalidate-vs-drop decision.
    """
    if iv is None or touched is None:
        return True
    if len(touched) == 0:
        return False
    for lo, hi in iv:
        if lo >= hi:
            continue
        i = int(np.searchsorted(touched, lo, side="left"))
        if i < len(touched) and int(touched[i]) < hi:
            return True
    return False


def _reach_within(csr, seeds: np.ndarray, depth: int) -> np.ndarray:
    """Multi-source BFS: all nodes within ``depth`` hops of ``seeds``
    (inclusive) following the given CSR adjacency.  Sorted int64."""
    indptr, nbr, _ = csr
    seen = np.unique(np.asarray(seeds, dtype=np.int64))
    frontier = seen
    for _ in range(max(depth, 0)):
        if frontier.size == 0:
            break
        sizes = indptr[frontier + 1] - indptr[frontier]
        if sizes.sum() == 0:
            break
        idx = np.concatenate([np.arange(indptr[f], indptr[f + 1])
                              for f in frontier])
        nxt = np.setdiff1d(np.unique(nbr[idx]).astype(np.int64), seen,
                           assume_unique=True)
        seen = np.union1d(seen, nxt)
        frontier = nxt
    return seen


# ---------------------------------------------------------------------- #
@dataclass
class Dataset:
    """Owns ``{graph, IDMap, NI index, stats, version, digest}``.

    Construct with :meth:`build` / :meth:`from_triples`; evolve with
    :meth:`apply_delta`.  Instances are immutable in use: ``apply_delta``
    returns a fresh ``Dataset`` and never touches the receiver.
    """

    graph: RDFGraph
    idmap: IDMap
    ni: NIIndex
    stats: DatasetStats
    digest: str
    version: int = 0
    # --- delta bookkeeping (for version-scoped cache revalidation) ------ #
    # Sorted node ids whose NI rows the producing delta recomputed; None
    # for a base build or a full rebuild (= treat everything as touched).
    touched: np.ndarray | None = None
    # Sorted endpoints of the delta's changed edges (incremental only).
    delta_endpoints: np.ndarray | None = None
    delta_info: dict = field(default_factory=lambda: {"mode": "base"})
    # --- rebuild parity knobs ------------------------------------------- #
    literal_forced: frozenset | None = None
    cap_quantile: float = 1.0
    max_cap: int = 4096
    # Lazy per-type / per-predicate stat term caches ({"coh":…, "spec":…}).
    _stat_terms: dict | None = field(default=None, repr=False)

    # ------------------------------------------------------------------ #
    @property
    def cache_key(self) -> str:
        """The (digest, version)-scoped identity every cache keys on."""
        return f"{self.digest}:v{self.version}"

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    @property
    def num_edges(self) -> int:
        return self.graph.num_edges

    # ------------------------------------------------------------------ #
    @classmethod
    def build(cls, graph: RDFGraph, variant: str = "rdf_h", *,
              d_max: int | None = None, ni_variant: str | None = None,
              m: int = 5, ni: NIIndex | None = None,
              stats: DatasetStats | None = None,
              cap_quantile: float = 1.0, max_cap: int = 4096,
              literal_forced: Iterable[str] | None = None) -> "Dataset":
        """Version-0 Dataset for ``graph``.

        ``variant`` picks NI depth/shape from ``ENGINE_VARIANTS``;
        ``d_max``/``ni_variant`` override it.  A pre-built ``ni``/``stats``
        is adopted as-is (its own depth/variant win).
        """
        spec = ENGINE_VARIANTS.get(variant, ENGINE_VARIANTS["rdf_h"])
        if ni is None:
            ni = build_ni_index(graph,
                                d_max=d_max if d_max is not None else spec["d"],
                                m=m,
                                variant=ni_variant or spec["var"],
                                cap_quantile=cap_quantile, max_cap=max_cap)
        if stats is None:
            stats = compute_stats(graph)
        if literal_forced is None:
            # Best-effort recovery of from_triples(literal_objects=...):
            # a LITERAL node that appears as a subject can only exist by
            # forcing, and re-forcing default literals is idempotent.
            ever_subj = np.zeros(graph.num_nodes, dtype=bool)
            ever_subj[graph.src] = True
            forced = graph.labels[(graph.node_kind == LITERAL) & ever_subj]
            literal_forced = frozenset(str(s) for s in forced) or None
        else:
            literal_forced = frozenset(literal_forced)
        return cls(graph=graph, idmap=IDMap(graph), ni=ni, stats=stats,
                   digest=content_digest(graph), version=0,
                   literal_forced=literal_forced,
                   cap_quantile=cap_quantile, max_cap=max_cap)

    @classmethod
    def from_triples(cls, triples, literal_objects=None, variant: str = "rdf_h",
                     **kw) -> "Dataset":
        graph = RDFGraph.from_triples(triples, literal_objects=literal_objects)
        forced = frozenset(literal_objects) if literal_objects else None
        return cls.build(graph, variant=variant, literal_forced=forced, **kw)

    def engine(self, variant: str = "rdf_h", **kw):
        """Convenience: build an Engine over this Dataset (local import —
        the engine layer sits above this one)."""
        from .engine import make_engine
        return make_engine(self, variant=variant, **kw)

    # ------------------------------------------------------------------ #
    # Delta ingest
    # ------------------------------------------------------------------ #
    def apply_delta(self, inserts: Sequence = (), deletes: Sequence = (),
                    churn_threshold: float = 0.05) -> "Dataset":
        """New Dataset with ``inserts`` added and ``deletes`` removed.

        Deletes use RDF set semantics: every copy of a matching triple goes;
        a delete naming an unknown label/predicate is a no-op.  Incremental
        maintenance runs when the delta keeps the label set, node kinds and
        NI variant stable and churn stays under ``churn_threshold``;
        otherwise a full rebuild on the post-delta triples (same answers,
        higher cost — see ``delta_info``).
        """
        inserts = [tuple(str(x) for x in t) for t in inserts]
        deletes = [tuple(str(x) for x in t) for t in deletes]
        g = self.graph

        if self.ni.variant != "full":
            return self._rebuild(inserts, deletes, "ni-variant")

        ins_ids = self._resolve(inserts)
        if ins_ids is None:
            return self._rebuild(inserts, deletes, "new-label")
        ins_src, ins_pred, ins_dst = ins_ids
        if ins_src.size and (g.node_kind[ins_src] != RESOURCE).any():
            return self._rebuild(inserts, deletes, "node-kind")

        # Deletes that don't resolve can't exist -> silently no-ops.
        del_ids = self._resolve(deletes, partial=True)
        del_src, del_pred, del_dst = del_ids
        del_mask = self._edge_match(del_src, del_pred, del_dst)
        n_del = int(del_mask.sum())

        churn = (len(inserts) + n_del) / max(g.num_edges, 1)
        if churn > churn_threshold:
            return self._rebuild(inserts, deletes, "churn")

        keep = ~del_mask
        new_src = np.concatenate([g.src[keep], ins_src]).astype(np.int32)
        new_dst = np.concatenate([g.dst[keep], ins_dst]).astype(np.int32)
        new_pred = np.concatenate([g.pred[keep], ins_pred]).astype(np.int32)

        if n_del:
            # A label vanishing from the edge set, or a still-deleted
            # subject losing its last subject slot, would renumber ids /
            # flip node kinds on a rebuild — incremental can't keep parity.
            ds_ = g.src[del_mask]
            dd_ = g.dst[del_mask]
            mentioned = np.zeros(g.num_nodes, dtype=bool)
            mentioned[new_src] = True
            mentioned[new_dst] = True
            if not mentioned[ds_].all() or not mentioned[dd_].all():
                return self._rebuild(inserts, deletes, "label-dropped")
            still_subj = np.zeros(g.num_nodes, dtype=bool)
            still_subj[new_src] = True
            if not still_subj[ds_].all():
                return self._rebuild(inserts, deletes, "node-kind")

        return self._incremental(new_src, new_dst, new_pred,
                                 g.src[del_mask], g.dst[del_mask],
                                 g.pred[del_mask],
                                 ins_src, ins_dst, ins_pred,
                                 n_ins=len(inserts), n_del=n_del)

    # ------------------------------------------------------------------ #
    def _resolve(self, triples, partial: bool = False):
        """(src, pred, dst) id arrays for string triples.  Exact label /
        predicate lookups only; with partial=True unresolvable triples are
        dropped, otherwise returns None."""
        g = self.graph
        if not triples:
            z = np.empty(0, dtype=np.int32)
            return z, z.copy(), z.copy()
        subs = np.asarray([t[0] for t in triples])
        prds = np.asarray([t[1] for t in triples])
        objs = np.asarray([t[2] for t in triples])

        def lookup(vals, table):
            i = np.searchsorted(table, vals)
            i = np.minimum(i, len(table) - 1) if len(table) else i
            ok = (len(table) > 0) & (table[i] == vals) if len(table) \
                else np.zeros(len(vals), dtype=bool)
            return i.astype(np.int32), ok

        si, s_ok = lookup(subs, g.labels)
        oi, o_ok = lookup(objs, g.labels)
        # predicates array is sorted (np.unique) — same trick applies
        pi, p_ok = lookup(prds, g.predicates)
        ok = s_ok & o_ok & p_ok
        if not ok.all():
            if not partial:
                return None
            si, pi, oi = si[ok], pi[ok], oi[ok]
        return si, pi, oi

    def _edge_match(self, d_src, d_pred, d_dst) -> np.ndarray:
        """Bool [E] mask of edges matching any delete triple (all copies)."""
        g = self.graph
        if d_src.size == 0:
            return np.zeros(g.num_edges, dtype=bool)
        n1 = np.int64(g.num_nodes + 1)
        p1 = np.int64(g.num_predicates + 1)
        pack = (g.src.astype(np.int64) * n1 + g.dst.astype(np.int64)) * p1 \
            + g.pred.astype(np.int64)
        dpack = (d_src.astype(np.int64) * n1 + d_dst.astype(np.int64)) * p1 \
            + d_pred.astype(np.int64)
        return np.isin(pack, dpack)

    # ------------------------------------------------------------------ #
    def _post_triples(self, inserts, deletes):
        """Post-delta triple list in rebuild-parity order: old triples in
        edge order minus deletes (set semantics), inserts appended."""
        g = self.graph
        drop = {tuple(t) for t in deletes}
        out = [t for t in zip(g.labels[g.src], g.predicates[g.pred],
                              g.labels[g.dst])
               if (str(t[0]), str(t[1]), str(t[2])) not in drop]
        out.extend(inserts)
        return out

    def _rebuild(self, inserts, deletes, reason: str) -> "Dataset":
        g2 = RDFGraph.from_triples(self._post_triples(inserts, deletes),
                                   literal_objects=self.literal_forced)
        ds = Dataset.build(g2, d_max=self.ni.d_max,
                           ni_variant=self.ni.variant, m=self.ni.m,
                           cap_quantile=self.cap_quantile,
                           max_cap=self.max_cap,
                           literal_forced=self.literal_forced)
        ds.version = self.version + 1
        ds.touched = None
        ds.delta_endpoints = None
        ds.delta_info = {"mode": "rebuild", "reason": reason,
                         "inserts": len(inserts), "deletes": len(deletes)}
        return ds

    # ------------------------------------------------------------------ #
    def _terms(self) -> dict:
        """Per-type coherence and per-predicate specialty terms for THIS
        dataset's graph (lazy; patched forward by _incremental so repeated
        deltas never pay a full recompute)."""
        if self._stat_terms is None:
            tp = self.stats.type_pred
            self._stat_terms = {
                "coh": coherence_terms(self.graph, tp) if tp is not None else {},
                "spec": specialty_terms(self.graph),
            }
        return self._stat_terms

    def _incremental(self, new_src, new_dst, new_pred,
                     del_src, del_dst, del_pred,
                     ins_src, ins_dst, ins_pred,
                     n_ins: int, n_del: int) -> "Dataset":
        g = self.graph
        n, p = g.num_nodes, g.num_predicates

        # --- graph: patched CSR, recomputed pred_kind ------------------- #
        out_csr = csr_patch(g.out_csr, n, p,
                            del_src, del_dst, del_pred,
                            ins_src, ins_dst, ins_pred)
        in_csr = csr_patch(g.in_csr, n, p,
                           del_dst, del_src, del_pred,
                           ins_dst, ins_src, ins_pred)
        new_pred_kind = np.zeros(p, dtype=np.int8)
        lit_edge = (g.node_kind[new_dst] == LITERAL).astype(np.int64)
        tot = np.bincount(new_pred, minlength=p)
        lit = np.bincount(new_pred, weights=lit_edge, minlength=p)
        new_pred_kind[(lit * 2) > tot] = ATTR
        g2 = replace(g, src=new_src, dst=new_dst, pred=new_pred,
                     pred_kind=new_pred_kind)
        if out_csr is None or in_csr is None:       # pack overflow guard
            out_csr = _csr(n, new_src, new_dst, new_pred)
            in_csr = _csr(n, new_dst, new_src, new_pred)
        g2.__dict__["out_csr"] = out_csr
        g2.__dict__["in_csr"] = in_csr
        g2.__dict__["avg_degree"] = g2.num_edges / max(n, 1)

        # --- NI: recompute k-hop rows of nodes near a changed edge ------ #
        d_max, m = self.ni.d_max, self.ni.m
        eps_u = np.unique(np.concatenate([del_src, ins_src]).astype(np.int64))
        eps_v = np.unique(np.concatenate([del_dst, ins_dst]).astype(np.int64))
        # A node's out-entry sees a changed edge u->v iff u is within
        # d_max-1 reverse (in-edge) hops — in the old or new graph.
        aff_out = np.union1d(_reach_within(g.in_csr, eps_u, d_max - 1),
                             _reach_within(in_csr, eps_u, d_max - 1)) \
            if eps_u.size else eps_u
        aff_in = np.union1d(_reach_within(g.out_csr, eps_v, d_max - 1),
                            _reach_within(out_csr, eps_v, d_max - 1)) \
            if eps_v.size else eps_v
        entries = dict(self.ni.entries)
        for sign, csr, aff in ((+1, out_csr, aff_out), (-1, in_csr, aff_in)):
            if aff.size == 0:
                continue                      # share the old tensors
            rows = khop_rows(csr, d_max, aff)
            for d in range(1, d_max + 1):
                entries[sign * d] = patch_entry(entries[sign * d], aff,
                                                rows[d - 1], m)
        ni2 = NIIndex(d_max=d_max, m=m, entries=entries,
                      vc_mask=None, variant="full")
        touched = np.union1d(aff_out, aff_in)
        endpoints = np.union1d(eps_u, eps_v)

        # --- stats ------------------------------------------------------ #
        stats2, terms2 = self._patch_stats(g2, del_pred, ins_pred,
                                           new_pred_kind, n_del, eps_u)
        ds = Dataset(graph=g2, idmap=self.idmap, ni=ni2, stats=stats2,
                     digest=content_digest(g2), version=self.version + 1,
                     touched=touched, delta_endpoints=endpoints,
                     delta_info={"mode": "incremental", "inserts": n_ins,
                                 "deletes": n_del,
                                 "touched": int(touched.size)},
                     literal_forced=self.literal_forced,
                     cap_quantile=self.cap_quantile, max_cap=self.max_cap)
        ds._stat_terms = terms2
        return ds

    # ------------------------------------------------------------------ #
    def _patch_stats(self, g2: RDFGraph, del_pred, ins_pred,
                     new_pred_kind, n_del: int, delta_subjects):
        """DatasetStats for g2, patching only delta-affected terms.  The
        sums replay in the same (sorted) order as a from-scratch
        compute_stats, so the floats come out bit-identical."""
        old = self.stats
        g = self.graph
        tp = _find_type_predicate(g2)           # predicates unchanged
        src_fan, dst_fan, avg_fan = predicate_fanout(g2)
        out_deg, in_deg = node_degrees(g2)

        flips = np.nonzero(g.pred_kind != new_pred_kind)[0]
        delta_preds = np.unique(np.concatenate(
            [del_pred.astype(np.int64), ins_pred.astype(np.int64),
             flips.astype(np.int64)]))

        # literal selectivity: per-predicate tables, per-predicate rng —
        # only delta/flipped ATTR predicates re-derive.
        lit_tab = dict(old.literal_selectivity)
        attr_aff = [int(pa) for pa in delta_preds if new_pred_kind[pa] == ATTR]
        if attr_aff:
            fresh = literal_selectivity(g2, preds=attr_aff)
            for pa in attr_aff:
                if pa in fresh:
                    lit_tab[pa] = fresh[pa]
                else:
                    lit_tab.pop(pa, None)
        for pa in delta_preds:
            if new_pred_kind[pa] != ATTR:
                lit_tab.pop(int(pa), None)

        terms = self._terms()
        # coherence: types whose member set or members' edges changed.
        coh_terms = dict(terms["coh"])
        if tp is not None:
            aff_types = [np.empty(0, dtype=np.int64)]
            for gg in (g, g2):
                tm = gg.pred == tp
                inst, typ = gg.src[tm], gg.dst[tm]
                aff_types.append(np.unique(
                    typ[np.isin(inst, delta_subjects)]).astype(np.int64))
            if int(tp) in delta_preds:
                for gg in (g, g2):
                    tm = gg.pred == tp
                    aff_types.append(np.unique(gg.dst[tm]).astype(np.int64))
            aff_types = np.unique(np.concatenate(aff_types))
            if aff_types.size:
                for t in aff_types:
                    coh_terms.pop(int(t), None)
                coh_terms.update(coherence_terms(g2, tp,
                                                 types=aff_types.tolist()))
            coh = coherence_from_terms(coh_terms)
        else:
            coh_terms = {}
            coh = 0.0

        # specialty: per-REL-predicate terms, only delta/flipped preds.
        spec_terms = dict(terms["spec"])
        if delta_preds.size:
            for pr in delta_preds:
                spec_terms.pop(int(pr), None)
            spec_terms.update(specialty_terms(
                g2, preds=[int(pr) for pr in delta_preds]))
        spec = specialty_from_terms(spec_terms)

        # diversity: attribute-edge word sample.  Kept when the attribute
        # edge multiset AND (if sampling) the edge indices are unchanged.
        attr_changed = (flips.size > 0
                        or (g.pred_kind[del_pred] == ATTR).any()
                        or (new_pred_kind[ins_pred] == ATTR).any())
        attr_count = int((new_pred_kind[g2.pred] == ATTR).sum())
        if not attr_changed and (n_del == 0 or attr_count <= 100_000):
            div = old.diversity
        else:
            div = literal_diversity(g2)

        stats2 = DatasetStats(
            pred_selectivity=predicate_selectivity(g2),
            literal_selectivity=lit_tab,
            coherence=coh,
            specialty=spec,
            diversity=div,
            type_pred=tp,
            src_fanout=src_fan,
            dst_fanout=dst_fan,
            avg_fanout=avg_fan,
            out_degree=out_deg,
            in_degree=in_deg,
        )
        return stats2, {"coh": coh_terms, "spec": spec_terms}
