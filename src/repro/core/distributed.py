"""Distributed execution of the RDF-ℏ check phase (shard_map).

Graph partitioning: node rows of each NI entry are range-partitioned
across the 'data' mesh axis; every device evaluates the neighborhood
check for its own node range (embarrassingly parallel — the paper's
phases only synchronize at join boundaries, where candidate tables are
orders of magnitude smaller than the graph: pruning is what makes the
all_gather cheap).

On the serving mesh the 'pod' axis replicates the index for
query-parallel throughput; `shard_check` only uses 'data'.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

try:                                    # jax >= 0.4.35 public location
    from jax import shard_map
except ImportError:                     # older releases
    from jax.experimental.shard_map import shard_map

from ..kernels import ref as kref


def pad_rows(arr: np.ndarray, ndev: int, fill) -> np.ndarray:
    n = arr.shape[0]
    npad = (-n) % ndev
    if npad == 0:
        return arr
    pad_shape = (npad,) + arr.shape[1:]
    return np.concatenate([arr, np.full(pad_shape, fill, arr.dtype)], 0)


def shard_check(mesh: Mesh, ids: np.ndarray, lo: np.ndarray,
                hi: np.ndarray, need: np.ndarray,
                overflow: np.ndarray) -> np.ndarray:
    """Distributed single-distance neighborhood check.

    ids [N, cap] per-node neighbor ids (-1 padded), sharded by node row
    over the 'data' axis.  lo/hi/need [J]: required intervals and counts
    (replicated).  overflow [N]: auto-pass bits.  Returns pass mask [N].
    """
    ndev = mesh.devices.size // (mesh.shape.get("model", 1)
                                 * mesh.shape.get("pod", 1))
    n = ids.shape[0]
    ids_p = pad_rows(ids.astype(np.int32), ndev, -1)
    of_p = pad_rows(overflow.astype(np.bool_), ndev, True)

    data_spec = PS("data")
    rep = PS()

    def local(ids_blk, of_blk, lo_, hi_, need_):
        cnt = kref.interval_count_ref(ids_blk, lo_, hi_)
        ok = (cnt >= need_[None, :]).all(axis=1)
        return ok | of_blk

    fn = shard_map(local, mesh=mesh,
                   in_specs=(data_spec, data_spec, rep, rep, rep),
                   out_specs=data_spec)
    with mesh:
        dev_ids = jax.device_put(ids_p, NamedSharding(mesh, data_spec))
        dev_of = jax.device_put(of_p, NamedSharding(mesh, data_spec))
        out = fn(dev_ids, dev_of,
                 jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
                 jnp.asarray(need, jnp.int32))
    return np.asarray(out)[:n]


def gather_candidates(mesh: Mesh, mask: np.ndarray, cap: int) -> np.ndarray:
    """all_gather the (compact) candidate ids from every shard.

    Demonstrates the join-boundary collective: each shard compacts its
    local pass mask to <= cap ids, then all_gathers — total bytes are
    O(pruned candidates), not O(N)."""
    ndev = mesh.shape["data"]
    n = mask.shape[0]
    mask_p = pad_rows(mask.astype(np.bool_), ndev, False)

    def local(m_blk):
        ids = jnp.nonzero(m_blk, size=cap, fill_value=-1)[0]
        base = jax.lax.axis_index("data") * m_blk.shape[0]
        ids = jnp.where(ids >= 0, ids + base, -1)
        return jax.lax.all_gather(ids, "data").reshape(-1)

    try:        # jax >= 0.6 renamed check_rep -> check_vma
        fn = shard_map(local, mesh=mesh, in_specs=(PS("data"),),
                       out_specs=PS(), check_vma=False)
    except TypeError:
        fn = shard_map(local, mesh=mesh, in_specs=(PS("data"),),
                       out_specs=PS(), check_rep=False)
    with mesh:
        dev = jax.device_put(mask_p, NamedSharding(mesh, PS("data")))
        out = np.asarray(fn(dev))
    return out[out >= 0]
