"""RDF-ℏ selective pruning decision (§4.2, §4.3) and threshold tuning.

The planner decides, per query template, whether to run the neighborhood
check.  Signature pruning is used iff:

  (complexity)  any D-tree root's candidate-generation iteration count
                exceeds τ1, OR the estimated intermediate-join product
                exceeds τ2,
  AND
  (power)       some query node's Neighborhood Selectivity N_q >= τ3.

N_q = | Σ_{p_r in k-hop} ln s(p_r) + Σ_{p_a in k-hop} ln(s(p_a)·f_{n,p_a}) |
estimates -ln P(random node exhibits q's neighborhood), i.e. the expected
pruning power of checking q's neighborhood structure.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field

import numpy as np

from .graph import RDFGraph, IDMap, ATTR
from .query import QueryTemplate
from .stats import DatasetStats
from .decompose import DTree
from .matching import choose_join_strategy, strategy_costs


@dataclass
class Thresholds:
    tau_iter: float = 1000.0       # τ1: D-tree candidate iterations
    tau_join: float = 1.0e6        # τ2: estimated intermediate joins
    tau_sel: float = 8.0           # τ3: min neighborhood selectivity
    nested_join_max: int = 256     # per-join: nested-loop below this size


@dataclass
class CostModel:
    """Multiplicative corrections to the planner's analytic cost model.

    All factors default to 1.0 (the hardcoded model); the serving layer's
    Calibrator learns them online from per-query QueryStats telemetry —
    the paper's 'when to use pruning' decision, adapted to the observed
    dataset instead of fixed constants.  Every factor only rescales an
    *estimate*, so any value yields identical query results.

      join_est_scale  multiplies JoinEstimator cardinalities (learned from
                      the signed join-estimate log error)
      conn_sel_scale  multiplies connection_selectivity estimates (learned
                      from observed vs. predicted connected-pair counts)
      reach_scale     scales the reach-join side of connection_edge_cost
      cross_scale     scales the cross+filter side — a MANUAL A/B knob
                      only: the cross path measures no observed
                      counterpart, so the Calibrator never learns it
    """
    join_est_scale: float = 1.0
    conn_sel_scale: float = 1.0
    reach_scale: float = 1.0
    cross_scale: float = 1.0


DEFAULT_COST_MODEL = CostModel()


@dataclass
class PlanDecision:
    use_check: bool
    complex_query: bool
    max_selectivity: float
    est_iterations: float
    est_join_product: float
    per_node_selectivity: dict[int, float] = field(default_factory=dict)


def neighborhood_selectivity(query: QueryTemplate, q: int,
                             stats: DatasetStats, k: int) -> float:
    """Def. 4.3 over the predicates within k query-hops of q (both
    directions, following template edges)."""
    comp = None
    for c in query.components():
        if q in c:
            comp = set(c)
            break
    assert comp is not None
    # undirected BFS distances within the template, then take every edge
    # with an endpoint at distance <= k-1 from q (its predicate is visible
    # to a k-hop neighborhood check).
    dist = {q: 0}
    comp_edges = [e for e in query.edges if e.src in comp and e.dst in comp]
    for step in range(1, k + 1):
        for e in comp_edges:
            for a, b in ((e.src, e.dst), (e.dst, e.src)):
                if a in dist and dist[a] == step - 1 and b not in dist:
                    dist[b] = step
    inf = k + 1
    seen_edges = [e for e in comp_edges
                  if min(dist.get(e.src, inf), dist.get(e.dst, inf)) <= k - 1]
    total = 0.0
    for e in seen_edges:
        if e.pred is None:
            continue  # wildcard predicate: selectivity 1, ln 1 = 0
        s = float(stats.pred_selectivity[e.pred])
        if s <= 0:
            s = 1.0 / 1e9
        if len(stats.literal_selectivity.get(e.pred, {})):
            n = len(query.keywords[e.dst])
            f = stats.lit_sel(e.pred, max(n, 1))
            total += math.log(max(s * f, 1e-300))
        else:
            total += math.log(s)
    return abs(total)


def estimate_complexity(trees: list[DTree], cand_sizes: dict[int, int]):
    """(max iterations over D-trees, product of root candidate sizes)."""
    iters = [cand_sizes.get(t.root, 0) for t in trees]
    max_iter = max(iters) if iters else 0
    prod = 1.0
    for i in iters:
        prod *= max(i, 1)
    return float(max_iter), float(prod)


def decide(query: QueryTemplate, trees_per_comp: list[list[DTree]],
           cand_sizes: dict[int, int], stats: DatasetStats,
           th: Thresholds, k: int) -> PlanDecision:
    max_iter, prod = 0.0, 1.0
    for trees in trees_per_comp:
        mi, pr = estimate_complexity(trees, cand_sizes)
        max_iter = max(max_iter, mi)
        prod *= pr
    complex_query = (max_iter > th.tau_iter) or (prod > th.tau_join)
    per_node = {q: neighborhood_selectivity(query, q, stats, k)
                for q in range(query.num_nodes)}
    max_sel = max(per_node.values()) if per_node else 0.0
    return PlanDecision(
        use_check=bool(complex_query and max_sel >= th.tau_sel),
        complex_query=bool(complex_query),
        max_selectivity=float(max_sel),
        est_iterations=max_iter,
        est_join_product=prod,
        per_node_selectivity=per_node,
    )


def decision_terms(decision: PlanDecision, th: Thresholds) -> list[dict]:
    """The §4.3 decision decomposed into its three τ comparisons, for
    EXPLAIN rendering and decision audits.  Each term: {name, value, op,
    tau, threshold, hit} — `hit` is whether that comparison fired in the
    direction that pushes toward use_check=True (the complex terms are
    OR-ed, the power term is AND-ed; see `decide`)."""
    return [
        {"name": "complex/iterations", "value": float(decision.est_iterations),
         "op": ">", "tau": "τ_iter", "threshold": float(th.tau_iter),
         "hit": decision.est_iterations > th.tau_iter},
        {"name": "complex/join_product",
         "value": float(decision.est_join_product),
         "op": ">", "tau": "τ_join", "threshold": float(th.tau_join),
         "hit": decision.est_join_product > th.tau_join},
        {"name": "power/max_selectivity",
         "value": float(decision.max_selectivity),
         "op": ">=", "tau": "τ_sel", "threshold": float(th.tau_sel),
         "hit": decision.max_selectivity >= th.tau_sel},
    ]


class JoinEstimator:
    """Stats-driven join-cardinality estimates (§4.1 features reused for
    execution planning).

    The engine uses these to pre-size join capacities so the
    CapacityOverflow -> recompile retry loop becomes the exception;
    estimator accuracy is recorded in QueryStats per query."""

    def __init__(self, stats: DatasetStats, cand_sizes: dict[int, int],
                 scale: float = 1.0):
        self.stats = stats
        self.cand_sizes = cand_sizes
        self.scale = float(scale)      # calibrated correction (CostModel)

    def edge_join(self, left_count: int, pred: int | None, outgoing: bool,
                  pair_count: int) -> int:
        """Candidate table joined with the edge table of `pred` on the
        D-tree root column: expected rows ~= left * per-endpoint fanout."""
        st = self.stats
        if st is None or st.src_fanout is None or pred is None:
            fan = st.avg_fanout if st is not None else 1.0
        else:
            fan = float((st.src_fanout if outgoing else st.dst_fanout)[pred])
        return int(left_count * max(fan, 1.0) * self.scale) + 1

    def table_join(self, a_count: int, b_count: int,
                   shared_cols: tuple[int, ...]) -> int:
        """System R equi-join estimate: |A J B| = |A||B| / V(key), with
        V(key) approximated by the smallest candidate-interval size among
        the shared query nodes, capped by both table sizes."""
        if not shared_cols:
            return a_count * b_count
        v = min(self.cand_sizes.get(q, 1) for q in shared_cols)
        v = max(1, min(v, max(a_count, 1), max(b_count, 1)))
        return int(a_count * b_count * self.scale / v) + 1


class CapEstimate(int):
    """A join-size estimate that also carries the exact pow2 capacity the
    cold run executed that join at — and, when recorded, the join
    strategy it resolved to.  Behaves as the row-count int in all
    arithmetic (min with row_limit, telemetry sums); matching.planned_join
    reads `.cap` to pin the output allocation and `.impl` to pin the
    strategy, so warm run 1 reuses the cold run's steady-state jit shapes
    and join strategies instead of re-deriving them (which could diverge
    when the cold run took an overflow retry)."""

    def __new__(cls, rows: int, cap: int, impl: str | None = None):
        obj = super().__new__(cls, int(rows))
        obj.cap = int(cap)
        obj.impl = impl
        return obj


class ReplayEstimator:
    """Exact 'estimates' for warm plan-cache executions.

    A query template run against an immutable dataset is deterministic, so
    the join sizes observed on the first execution (PreparedQuery.join_seq,
    recorded in engine call order) ARE the cardinalities of every later
    execution.  Replaying them pre-sizes each join capacity exactly — no
    CapacityOverflow retries and byte-identical jit shapes, which is what
    makes the warm path recompile-free.  Recorded entries are
    (rows, cap, impl) triples — replayed as `CapEstimate` so the executed
    *capacity* and *join strategy* (not just the row count) are pinned
    too; (rows, cap) pairs and bare-int entries from older recordings
    still replay with whatever they carry.  Falls back to the analytic
    estimator if the call sequence ever diverges (e.g. a row_limit
    change).
    """

    def __init__(self, base: JoinEstimator, recorded: list):
        self.base = base
        self.recorded = recorded
        self.cursor = 0

    def _next(self, fallback: int) -> int:
        if self.cursor < len(self.recorded):
            out = self.recorded[self.cursor]
            self.cursor += 1
            if isinstance(out, tuple):
                return CapEstimate(out[0], out[1],
                                   out[2] if len(out) > 2 else None)
            return out
        return fallback

    def edge_join(self, left_count: int, pred: int | None, outgoing: bool,
                  pair_count: int) -> int:
        return self._next(self.base.edge_join(left_count, pred, outgoing,
                                              pair_count))

    def table_join(self, a_count: int, b_count: int,
                   shared_cols: tuple[int, ...]) -> int:
        return self._next(self.base.table_join(a_count, b_count,
                                               shared_cols))


# ---------------------------------------------------------------------- #
# Whole-query join planning: cost-based join ordering over a component's
# D-tree candidate tables (Selinger-style DP over the System-R estimates
# JoinEstimator already provides) and over the cross-component connection
# edges.  The cost model knows about sort-run reuse: a sort-merge join
# whose left side is already ordered by the join key skips that sort, so
# orders that chain joins on the same key are cheaper.
# ---------------------------------------------------------------------- #
_LOG2 = math.log(2.0)
_PLAN_DP_MAX = 10           # exhaustive subset-DP up to this many tables
_CONN_PERM_MAX = 6          # exhaustive permutations up to this many edges


def _sort_cost(n: int) -> float:
    n = max(int(n), 1)
    return n * math.log(max(n, 2)) / _LOG2


def _pairwise_join_cost(left_rows: int, right_rows: int, est_out: int,
                        nested_max: int, left_sorted: bool,
                        right_sorted: bool, n_shared: int = 1) -> float:
    """Work proxy (row ops) for one equi-join under the engine's strategy
    rule — priced by the SAME matching.strategy_costs the executor's
    'auto' resolution uses (nested-loop below nested_max; sort-merge
    where each unsorted side pays a weighted n log n sort; radix-hash
    where only the build side pays a sort), plus the est_out expand."""
    impl = choose_join_strategy(left_rows, right_rows, nested_max,
                                a_sorted=left_sorted,
                                b_sorted=right_sorted, n_shared=n_shared)
    costs = strategy_costs(left_rows, right_rows, a_sorted=left_sorted,
                           b_sorted=right_sorted, n_shared=n_shared)
    if impl == "nested":
        return costs["nested"]
    return costs[impl] + float(est_out)


@dataclass
class PlannedStep:
    """One join in a component plan: table `index` is merged into the
    accumulated table."""
    index: int
    est_rows: int               # estimated accumulated rows after the join
    est_cost: float             # estimated cost of this join
    key_cols: tuple[int, ...]   # shared query nodes joined on ('' = cross)
    reuses_sort: bool           # left side's order makes the sort skippable


@dataclass
class JoinPlan:
    """Cost-based join order for one component's candidate tables, plus
    the greedy baseline evaluated under the same cost model (telemetry:
    planned vs. greedy cost lands in QueryStats)."""
    order: list[int]
    steps: list[PlannedStep]
    est_cost: float
    greedy_order: list[int]
    greedy_cost: float


def _reusable(sort_key: tuple[int, ...] | None,
              shared: tuple[int, ...]) -> bool:
    """Mirror of matching._reuse_key_order: the join may permute its key
    columns, so a sorted run is reusable iff the first |shared| sorted
    columns are exactly the shared set."""
    return (sort_key is not None and len(sort_key) >= len(shared)
            and set(sort_key[: len(shared)]) == set(shared)
            and len(shared) > 0)


def _join_step(rows, skey, count_i, order_i, shared, est_out, nested_max,
               larger_is_left: bool | None = None):
    """One simulated join: (cost, next sort key, left_reused).

    Mirrors execution fidelity: the nested regime produces an untagged
    table (no downstream reuse); the radix regime never sorts and its
    output keeps the LEFT side's order; and when both sides are sorted
    under *conflicting* permutations of a multi-column key, the executor
    can align the join key with only one of them — credit the larger
    side."""
    left_ok = _reusable(skey, shared)
    right_ok = _reusable(order_i, shared)
    if (left_ok and right_ok and len(shared) > 1
            and tuple(skey[: len(shared)]) != tuple(order_i[: len(shared)])):
        if larger_is_left is None:
            larger_is_left = rows >= count_i
        left_ok, right_ok = larger_is_left, not larger_is_left
    c = _pairwise_join_cost(rows, count_i, est_out, nested_max,
                            left_sorted=left_ok, right_sorted=right_ok,
                            n_shared=len(shared))
    if not shared:
        return c, skey, False  # cross_join propagates the left order
    impl = choose_join_strategy(rows, count_i, nested_max,
                                a_sorted=left_ok, b_sorted=right_ok,
                                n_shared=len(shared))
    if impl == "sorted":
        next_key = shared       # merge output is ordered by the join key
    elif impl == "radix":
        next_key = skey         # probe side's order is preserved
    else:
        next_key = None         # nested output is untagged
    return c, next_key, left_ok and impl == "sorted"


def simulate_join_order(order, node_sets, counts, estimator: JoinEstimator,
                        nested_max: int,
                        sort_orders=None) -> tuple[float, list[PlannedStep]]:
    """Evaluate a join order under the cost model; returns (cost, steps)."""
    if sort_orders is None:
        sort_orders = [None] * len(node_sets)
    steps: list[PlannedStep] = []
    first = order[0]
    rows = counts[first]
    nodes = set(node_sets[first])
    skey = sort_orders[first]
    cost = 0.0
    for i in order[1:]:
        shared = tuple(sorted(nodes & node_sets[i]))
        est_out = estimator.table_join(rows, counts[i], shared)
        c, skey, reused = _join_step(rows, skey, counts[i], sort_orders[i],
                                     shared, est_out, nested_max)
        cost += c
        steps.append(PlannedStep(index=i, est_rows=est_out, est_cost=c,
                                 key_cols=shared, reuses_sort=reused))
        rows = est_out
        nodes |= node_sets[i]
    return cost, steps


def plan_table_joins(node_sets: list[set[int]], counts: list[int],
                     estimator: JoinEstimator, nested_max: int,
                     sort_orders=None,
                     greedy_order: list[int] | None = None) -> JoinPlan:
    """Pick a cost-based join order over a component's candidate tables.

    Selinger-style DP over subsets (exact up to _PLAN_DP_MAX tables, one
    best state kept per subset), falling back to greedy-by-marginal-cost
    beyond that.  `greedy_order` (the seed's smallest-candidate-first
    order) is evaluated under the same model for comparison telemetry."""
    n = len(node_sets)
    node_sets = [set(s) for s in node_sets]
    if sort_orders is None:
        sort_orders = [None] * n
    if greedy_order is None:
        greedy_order = list(range(n))
    if n <= 1:
        order = list(range(n))
        return JoinPlan(order=order, steps=[], est_cost=0.0,
                        greedy_order=list(greedy_order), greedy_cost=0.0)

    def run(order):
        return simulate_join_order(order, node_sets, counts, estimator,
                                   nested_max, sort_orders)

    greedy_cost, _ = run(greedy_order)

    if n <= _PLAN_DP_MAX:
        # best[mask] = (cost, est_rows, order, sort_key)
        best: dict[int, tuple] = {
            1 << i: (0.0, counts[i], (i,), sort_orders[i])
            for i in range(n)}
        full = (1 << n) - 1
        # every nonempty subset is reachable by adding one table at a
        # time, so processing masks in popcount order visits each state
        # after all of its predecessors
        for mask in sorted(range(1, full + 1),
                           key=lambda m: (bin(m).count("1"), m)):
            cost, rows, order, skey = best[mask]
            nodes = set().union(*(node_sets[j] for j in order))
            for i in range(n):
                bit = 1 << i
                if mask & bit:
                    continue
                shared = tuple(sorted(nodes & node_sets[i]))
                est_out = estimator.table_join(rows, counts[i], shared)
                c, nkey, _ = _join_step(rows, skey, counts[i],
                                        sort_orders[i], shared, est_out,
                                        nested_max)
                nk = mask | bit
                if nk not in best or cost + c < best[nk][0]:
                    best[nk] = (cost + c, est_out, order + (i,), nkey)
        _, _, order, _ = best[full]
        order = list(order)
    else:
        # greedy by marginal cost (connected tables win automatically:
        # cross products estimate as |A|x|B|)
        remaining = set(range(n))
        start = min(remaining, key=lambda i: counts[i])
        order = [start]
        remaining.discard(start)
        rows, nodes, skey = counts[start], set(node_sets[start]), \
            sort_orders[start]
        while remaining:
            def marginal(i):
                shared = tuple(sorted(nodes & node_sets[i]))
                est_out = estimator.table_join(rows, counts[i], shared)
                return _join_step(rows, skey, counts[i], sort_orders[i],
                                  shared, est_out, nested_max)[0]
            i = min(remaining, key=marginal)
            shared = tuple(sorted(nodes & node_sets[i]))
            est_out = estimator.table_join(rows, counts[i], shared)
            _, skey, _ = _join_step(rows, skey, counts[i], sort_orders[i],
                                    shared, est_out, nested_max)
            rows = est_out
            nodes |= node_sets[i]
            order.append(i)
            remaining.discard(i)
    est_cost, steps = run(order)
    return JoinPlan(order=order, steps=steps, est_cost=est_cost,
                    greedy_order=list(greedy_order),
                    greedy_cost=greedy_cost)


@dataclass
class ConnFeatures:
    """Cardinality features of one connection edge for the reach-join
    cost model: distinct endpoint nodes per side and expected reach-set
    sizes (stats.expected_reach) for the hop split of its d_c."""
    distinct_a: int
    distinct_b: int
    reach_fwd: float
    reach_bwd: float


def connection_edge_cost(size_a: float, size_b: float, feat: ConnFeatures,
                         sel: float, num_nodes: int,
                         intra: bool = False,
                         model: CostModel | None = None) -> tuple[float, float]:
    """(cross_cost, reach_cost) work proxies for one connection edge.

    Both strategies build the reach sets of the distinct endpoints once
    (connectivity_mask memoizes per node), so that term (pa + pb) is
    billed to both.  On top of it, cross+filter pays one set
    intersection per PAIR — the full product |A|x|B| (an intra edge
    degenerates to a linear scan); reach-join instead pays sorting the
    pair tables, the merge on reach_id (expected key matches ~
    |Pa|*|Pb|/n for independent uniform reach sets), the dedup sort of
    the match stream, and the two output-bounded equi-joins
    (sort + merge + expand).

    `model` (CostModel) applies the calibrated corrections: sel is scaled
    by conn_sel_scale, and the returned (cross, reach) costs by
    cross_scale / reach_scale respectively."""
    model = model if model is not None else DEFAULT_COST_MODEL
    sel = min(1.0, sel * model.conn_sel_scale)
    sa, sb = max(float(size_a), 1.0), max(float(size_b), 1.0)
    if intra:
        pairs = sa
        out = sa * sel
        joins = _sort_cost(sa) + sa + out       # ONE semi-join of the table
    else:
        pairs = sa * sb
        out = sa * sb * sel
        joins = (_sort_cost(sa) + _sort_cost(sb)    # equi-join sorts
                 + sa + sb + 2.0 * out)             # merges + expands
    pa = max(feat.distinct_a, 1) * max(feat.reach_fwd, 1.0)
    pb = max(feat.distinct_b, 1) * max(feat.reach_bwd, 1.0)
    matches = pa * pb / max(num_nodes, 1)
    cross = pa + pb + pairs
    reach = (pa + pb + _sort_cost(pa) + _sort_cost(pb)     # pair tables
             + matches + _sort_cost(max(matches, 1.0))     # merge + dedup
             + joins)
    return cross * model.cross_scale, reach * model.reach_scale


def choose_connection_impl(size_a: float, size_b: float, feat: ConnFeatures,
                           sel: float, num_nodes: int, impl: str = "auto",
                           intra: bool = False,
                           model: CostModel | None = None) -> str:
    """Per-edge strategy choice mirroring matching.resolve_join_impl:
    'auto' picks the cheaper of cross+filter and reach-join under the
    shared work-proxy model; explicit impls force the strategy (A/B)."""
    if impl in ("cross", "reach"):
        return impl
    cross, reach = connection_edge_cost(size_a, size_b, feat, sel,
                                        num_nodes, intra=intra, model=model)
    return "reach" if reach < cross else "cross"


@dataclass
class ConnectionPlan:
    """Cost-based processing order for inter-component connection edges
    (indices into the engine's `inter` list), with the greedy
    smallest-product baseline costed under the same model."""
    order: list[int]
    est_cost: float
    greedy_cost: float


class _GroupSim:
    """Union-find over component groups with estimated sizes — the single
    source of the merge bookkeeping shared by the cost simulation and the
    greedy baseline (so the two stay comparable by construction)."""

    def __init__(self, sizes):
        self.parent = list(range(len(sizes)))
        self.size = [float(s) for s in sizes]

    def find(self, x):
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def product(self, i, j):
        """The seed's sort key: product of the two groups' current sizes
        (same-group edges square their size, exactly as the engine's
        greedy rule computes it)."""
        gi, gj = self.find(i), self.find(j)
        return max(self.size[gi], 1.0) * max(self.size[gj], 1.0)

    def apply(self, i, j, sel):
        """Process one connection edge; returns its estimated work."""
        gi, gj = self.find(i), self.find(j)
        if gi == gj:
            cost = self.size[gi]
            self.size[gi] = max(self.size[gi] * sel, 1.0)
            return cost
        prod = max(self.size[gi], 1.0) * max(self.size[gj], 1.0)
        self.parent[gj] = gi
        self.size[gi] = max(prod * sel, 1.0)
        return prod


def _sim_edge_cost(sim: _GroupSim, i, j, sel, feat, num_nodes, impl,
                   model: CostModel | None = None):
    """Cost of processing one connection edge at the sim's current group
    sizes, under the engine's strategy rule: cross+filter work when no
    features are given (legacy model / forced cross), reach-join work when
    forced, min of both under 'auto' (mirroring the execution choice)."""
    gi, gj = sim.find(i), sim.find(j)
    intra = gi == gj
    sa, sb = sim.size[gi], sim.size[gj]
    cross = sa if intra else max(sa, 1.0) * max(sb, 1.0)
    if feat is None or impl == "cross":
        return cross
    c, r = connection_edge_cost(sa, sb, feat, sel, num_nodes, intra=intra,
                                model=model)
    return r if impl == "reach" else min(c, r)


def _simulate_conn_order(order, sizes, endpoints, sels, feats=None,
                         num_nodes: int = 0, impl: str = "cross",
                         model: CostModel | None = None):
    """Total estimated work for processing connection edges in `order`
    under the per-edge strategy rule (_sim_edge_cost).  Estimated group
    size after a connection is product * selectivity regardless of the
    strategy (both produce the same result set)."""
    sim = _GroupSim(sizes)
    total = 0.0
    for idx in order:
        i, j = endpoints[idx]
        total += _sim_edge_cost(sim, i, j, sels[idx],
                                None if feats is None else feats[idx],
                                num_nodes, impl, model)
        sim.apply(i, j, sels[idx])
    return total


def _greedy_conn_order(sizes, endpoints, sels):
    """The seed engine's rule: repeatedly take the edge whose current group
    product is smallest (simulated sizes, same model as the planner)."""
    sim = _GroupSim(sizes)
    remaining = list(range(len(endpoints)))
    order = []
    while remaining:
        remaining.sort(key=lambda k: sim.product(*endpoints[k]))
        k = remaining.pop(0)
        order.append(k)
        sim.apply(*endpoints[k], sels[k])
    return order


def plan_connections(sizes: list[int], endpoints: list[tuple[int, int]],
                     sels: list[float], feats: list[ConnFeatures] | None = None,
                     num_nodes: int = 0,
                     impl: str = "auto",
                     model: CostModel | None = None) -> ConnectionPlan:
    """Order the inter-component connection edges to minimize estimated
    work.  endpoints[k] are group indices into `sizes`; sels[k] the
    connection's estimated selectivity (stats.connection_selectivity);
    feats[k] (optional) the reach-join cardinality features — when given,
    each edge is priced at the cheaper of cross+filter and reach-join
    under `impl` ('auto'/'reach'/'cross'), mirroring the engine's per-edge
    strategy choice; without them the legacy cross-product model applies.
    Exhaustive over permutations for up to _CONN_PERM_MAX edges
    (connection counts are tiny), else greedy by marginal simulated
    cost."""
    m = len(endpoints)

    def cost(order):
        return _simulate_conn_order(order, sizes, endpoints, sels,
                                    feats, num_nodes, impl, model)

    greedy = _greedy_conn_order(sizes, endpoints, sels)
    greedy_cost = cost(greedy)
    if m <= 1:
        return ConnectionPlan(order=greedy, est_cost=greedy_cost,
                              greedy_cost=greedy_cost)
    if m <= _CONN_PERM_MAX:
        best, best_cost = greedy, greedy_cost
        for perm in itertools.permutations(range(m)):
            c = cost(perm)
            if c < best_cost:
                best, best_cost = list(perm), c
        return ConnectionPlan(order=list(best), est_cost=best_cost,
                              greedy_cost=greedy_cost)
    # greedy by marginal cost of the next edge
    remaining = set(range(m))
    order: list[int] = []
    while remaining:
        k = min(remaining, key=lambda k: cost(order + [k]))
        order.append(k)
        remaining.discard(k)
    return ConnectionPlan(order=order, est_cost=cost(order),
                          greedy_cost=greedy_cost)


def tune_thresholds(run_query, queries: list[QueryTemplate],
                    grid_iter=(100.0, 1000.0, 10000.0),
                    grid_join=(1e4, 1e6, 1e8),
                    grid_sel=(4.0, 8.0, 16.0)) -> Thresholds:
    """Grid-search thresholds minimizing total runtime proxy over a sampled
    workload.  `run_query(query, thresholds) -> cost` is engine-supplied
    (wall time or work counter).  Mirrors the paper's offline tuning [28]."""
    best, best_cost = None, float("inf")
    for ti in grid_iter:
        for tj in grid_join:
            for ts in grid_sel:
                th = Thresholds(ti, tj, ts)
                cost = 0.0
                for q in queries:
                    cost += run_query(q, th)
                if cost < best_cost:
                    best, best_cost = th, cost
    return best or Thresholds()
