"""RDF-ℏ selective pruning decision (§4.2, §4.3) and threshold tuning.

The planner decides, per query template, whether to run the neighborhood
check.  Signature pruning is used iff:

  (complexity)  any D-tree root's candidate-generation iteration count
                exceeds τ1, OR the estimated intermediate-join product
                exceeds τ2,
  AND
  (power)       some query node's Neighborhood Selectivity N_q >= τ3.

N_q = | Σ_{p_r in k-hop} ln s(p_r) + Σ_{p_a in k-hop} ln(s(p_a)·f_{n,p_a}) |
estimates -ln P(random node exhibits q's neighborhood), i.e. the expected
pruning power of checking q's neighborhood structure.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .graph import RDFGraph, IDMap, ATTR
from .query import QueryTemplate
from .stats import DatasetStats
from .decompose import DTree


@dataclass
class Thresholds:
    tau_iter: float = 1000.0       # τ1: D-tree candidate iterations
    tau_join: float = 1.0e6        # τ2: estimated intermediate joins
    tau_sel: float = 8.0           # τ3: min neighborhood selectivity
    nested_join_max: int = 256     # per-join: nested-loop below this size


@dataclass
class PlanDecision:
    use_check: bool
    complex_query: bool
    max_selectivity: float
    est_iterations: float
    est_join_product: float
    per_node_selectivity: dict[int, float] = field(default_factory=dict)


def neighborhood_selectivity(query: QueryTemplate, q: int,
                             stats: DatasetStats, k: int) -> float:
    """Def. 4.3 over the predicates within k query-hops of q (both
    directions, following template edges)."""
    comp = None
    for c in query.components():
        if q in c:
            comp = set(c)
            break
    assert comp is not None
    # undirected BFS distances within the template, then take every edge
    # with an endpoint at distance <= k-1 from q (its predicate is visible
    # to a k-hop neighborhood check).
    dist = {q: 0}
    comp_edges = [e for e in query.edges if e.src in comp and e.dst in comp]
    for step in range(1, k + 1):
        for e in comp_edges:
            for a, b in ((e.src, e.dst), (e.dst, e.src)):
                if a in dist and dist[a] == step - 1 and b not in dist:
                    dist[b] = step
    inf = k + 1
    seen_edges = [e for e in comp_edges
                  if min(dist.get(e.src, inf), dist.get(e.dst, inf)) <= k - 1]
    total = 0.0
    for e in seen_edges:
        if e.pred is None:
            continue  # wildcard predicate: selectivity 1, ln 1 = 0
        s = float(stats.pred_selectivity[e.pred])
        if s <= 0:
            s = 1.0 / 1e9
        if len(stats.literal_selectivity.get(e.pred, {})):
            n = len(query.keywords[e.dst])
            f = stats.lit_sel(e.pred, max(n, 1))
            total += math.log(max(s * f, 1e-300))
        else:
            total += math.log(s)
    return abs(total)


def estimate_complexity(trees: list[DTree], cand_sizes: dict[int, int]):
    """(max iterations over D-trees, product of root candidate sizes)."""
    iters = [cand_sizes.get(t.root, 0) for t in trees]
    max_iter = max(iters) if iters else 0
    prod = 1.0
    for i in iters:
        prod *= max(i, 1)
    return float(max_iter), float(prod)


def decide(query: QueryTemplate, trees_per_comp: list[list[DTree]],
           cand_sizes: dict[int, int], stats: DatasetStats,
           th: Thresholds, k: int) -> PlanDecision:
    max_iter, prod = 0.0, 1.0
    for trees in trees_per_comp:
        mi, pr = estimate_complexity(trees, cand_sizes)
        max_iter = max(max_iter, mi)
        prod *= pr
    complex_query = (max_iter > th.tau_iter) or (prod > th.tau_join)
    per_node = {q: neighborhood_selectivity(query, q, stats, k)
                for q in range(query.num_nodes)}
    max_sel = max(per_node.values()) if per_node else 0.0
    return PlanDecision(
        use_check=bool(complex_query and max_sel >= th.tau_sel),
        complex_query=bool(complex_query),
        max_selectivity=float(max_sel),
        est_iterations=max_iter,
        est_join_product=prod,
        per_node_selectivity=per_node,
    )


class JoinEstimator:
    """Stats-driven join-cardinality estimates (§4.1 features reused for
    execution planning).

    The engine uses these to pre-size join capacities so the
    CapacityOverflow -> recompile retry loop becomes the exception;
    estimator accuracy is recorded in QueryStats per query."""

    def __init__(self, stats: DatasetStats, cand_sizes: dict[int, int]):
        self.stats = stats
        self.cand_sizes = cand_sizes

    def edge_join(self, left_count: int, pred: int | None, outgoing: bool,
                  pair_count: int) -> int:
        """Candidate table joined with the edge table of `pred` on the
        D-tree root column: expected rows ~= left * per-endpoint fanout."""
        st = self.stats
        if st is None or st.src_fanout is None or pred is None:
            fan = st.avg_fanout if st is not None else 1.0
        else:
            fan = float((st.src_fanout if outgoing else st.dst_fanout)[pred])
        return int(left_count * max(fan, 1.0)) + 1

    def table_join(self, a_count: int, b_count: int,
                   shared_cols: tuple[int, ...]) -> int:
        """System R equi-join estimate: |A J B| = |A||B| / V(key), with
        V(key) approximated by the smallest candidate-interval size among
        the shared query nodes, capped by both table sizes."""
        if not shared_cols:
            return a_count * b_count
        v = min(self.cand_sizes.get(q, 1) for q in shared_cols)
        v = max(1, min(v, max(a_count, 1), max(b_count, 1)))
        return int(a_count * b_count / v) + 1


def tune_thresholds(run_query, queries: list[QueryTemplate],
                    grid_iter=(100.0, 1000.0, 10000.0),
                    grid_join=(1e4, 1e6, 1e8),
                    grid_sel=(4.0, 8.0, 16.0)) -> Thresholds:
    """Grid-search thresholds minimizing total runtime proxy over a sampled
    workload.  `run_query(query, thresholds) -> cost` is engine-supplied
    (wall time or work counter).  Mirrors the paper's offline tuning [28]."""
    best, best_cost = None, float("inf")
    for ti in grid_iter:
        for tj in grid_join:
            for ts in grid_sel:
                th = Thresholds(ti, tj, ts)
                cost = 0.0
                for q in queries:
                    cost += run_query(q, th)
                if cost < best_cost:
                    best, best_cost = th, cost
    return best or Thresholds()
