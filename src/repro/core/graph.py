"""RDF graph representation in dense array form (TPU-native layout).

Design decision (see DESIGN.md §2): every distinct RDF label (URI or literal)
is exactly one node, and **node id == label id == lexicographic rank** of the
label.  This realizes the paper's IDMap invariant ("IDs of labels are assigned
in lexicographic order, forming an interval of consecutive integers") in its
strongest form: a prefix partial keyword resolves to a contiguous *node-id*
interval, so candidate sets, NI entries and connectivity ID-lists all live in
a single integer space.

Host-side construction uses numpy; the heavy query phases consume the arrays
directly (they are valid jnp inputs).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property

import numpy as np

RESOURCE = 0
LITERAL = 1

REL = 0   # relationship predicate (resource -> resource)
ATTR = 1  # attribute predicate  (resource -> literal)

INVALID = np.int32(-1)


def _csr(num_nodes: int, key: np.ndarray, nbr: np.ndarray, pred: np.ndarray):
    """Build CSR adjacency sorted by (key, nbr)."""
    order = np.lexsort((nbr, key))
    key, nbr, pred = key[order], nbr[order], pred[order]
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.add.at(indptr, key + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, nbr.astype(np.int32), pred.astype(np.int32)


def csr_patch(csr, num_nodes: int, num_preds: int,
              del_key: np.ndarray, del_nbr: np.ndarray, del_pred: np.ndarray,
              ins_key: np.ndarray, ins_nbr: np.ndarray, ins_pred: np.ndarray):
    """Patch a `_csr` result for an edge delta without re-sorting kept rows.

    Deletes remove EVERY row matching a (key, nbr, pred) triple; inserts are
    merge-placed after any equal-(key, nbr) kept rows.  The output is
    byte-identical to `_csr` over the post-delta edge arrays laid out as
    old-kept-order followed by appended inserts (lexsort is stable, so kept
    rows keep their relative order and appended inserts land after their
    equals).  Returns None when the int64 packing used for matching could
    overflow — callers then rebuild via `_csr`.
    """
    n1 = np.int64(num_nodes + 1)
    p1 = np.int64(num_preds + 1)
    if (np.log2(float(n1)) * 2 + np.log2(float(p1))) >= 62:
        return None
    indptr, nbr, pred = csr
    key = np.repeat(np.arange(num_nodes, dtype=np.int64), np.diff(indptr))
    if len(del_key):
        pack = (key * n1 + nbr.astype(np.int64)) * p1 + pred.astype(np.int64)
        dpack = (del_key.astype(np.int64) * n1 + del_nbr.astype(np.int64)) \
            * p1 + del_pred.astype(np.int64)
        keep = ~np.isin(pack, dpack)
        key, nbr, pred = key[keep], nbr[keep], pred[keep]
    if len(ins_key):
        order = np.lexsort((ins_nbr, ins_key))   # stable, matches _csr
        ik = ins_key[order].astype(np.int64)
        inb = ins_nbr[order]
        ip = ins_pred[order]
        kept_sortkey = key * n1 + nbr.astype(np.int64)
        pos = np.searchsorted(kept_sortkey, ik * n1 + inb.astype(np.int64),
                              side="right")
        nbr = np.insert(nbr, pos, inb)
        pred = np.insert(pred, pos, ip)
        key = np.insert(key, pos, ik)
    indptr2 = np.zeros(num_nodes + 1, dtype=np.int64)
    np.add.at(indptr2, key + 1, 1)
    np.cumsum(indptr2, out=indptr2)
    return indptr2, nbr.astype(np.int32), pred.astype(np.int32)


@dataclass
class RDFGraph:
    """Immutable array-form RDF graph.

    labels:     [N] unicode, lexicographically sorted; node id == index.
    node_kind:  [N] int8, RESOURCE | LITERAL.
    src/dst/pred: [E] int32 edge arrays (subject -> object).
    predicates: [P] unicode predicate names.
    pred_kind:  [P] int8, REL | ATTR (majority vote over edge targets).
    """

    labels: np.ndarray
    node_kind: np.ndarray
    src: np.ndarray
    dst: np.ndarray
    pred: np.ndarray
    predicates: np.ndarray
    pred_kind: np.ndarray

    # ------------------------------------------------------------------ #
    @property
    def num_nodes(self) -> int:
        return int(self.labels.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_predicates(self) -> int:
        return int(self.predicates.shape[0])

    @cached_property
    def out_csr(self):
        return _csr(self.num_nodes, self.src, self.dst, self.pred)

    @cached_property
    def in_csr(self):
        return _csr(self.num_nodes, self.dst, self.src, self.pred)

    @cached_property
    def avg_degree(self) -> float:
        return self.num_edges / max(self.num_nodes, 1)

    # ------------------------------------------------------------------ #
    def out_neighbors(self, n: int):
        indptr, nbr, pred = self.out_csr
        return nbr[indptr[n]:indptr[n + 1]], pred[indptr[n]:indptr[n + 1]]

    def in_neighbors(self, n: int):
        indptr, nbr, pred = self.in_csr
        return nbr[indptr[n]:indptr[n + 1]], pred[indptr[n]:indptr[n + 1]]

    def predicate_id(self, name: str) -> int:
        hits = np.nonzero(self.predicates == name)[0]
        if len(hits) == 0:
            raise KeyError(f"unknown predicate {name!r}")
        return int(hits[0])

    # ------------------------------------------------------------------ #
    @staticmethod
    def from_triples(triples, literal_objects=None) -> "RDFGraph":
        """Build from an iterable of (subject, predicate, object) strings.

        literal_objects: optional set of object strings to force-treat as
        literals.  Otherwise an object is a literal iff it never appears as a
        subject.
        """
        triples = list(triples)
        subs = np.asarray([t[0] for t in triples])
        preds = np.asarray([t[1] for t in triples])
        objs = np.asarray([t[2] for t in triples])

        labels, inv = np.unique(np.concatenate([subs, objs]), return_inverse=True)
        src = inv[: len(triples)].astype(np.int32)
        dst = inv[len(triples):].astype(np.int32)

        predicates, pinv = np.unique(preds, return_inverse=True)
        pred = pinv.astype(np.int32)

        node_kind = np.full(len(labels), LITERAL, dtype=np.int8)
        node_kind[src] = RESOURCE  # anything that is ever a subject is a resource
        if literal_objects is not None:
            forced = np.isin(labels, np.asarray(sorted(literal_objects)))
            node_kind[forced] = LITERAL

        # predicate kind: majority of edge targets literal -> ATTR
        pred_kind = np.zeros(len(predicates), dtype=np.int8)
        lit_edge = (node_kind[dst] == LITERAL).astype(np.int64)
        tot = np.bincount(pred, minlength=len(predicates))
        lit = np.bincount(pred, weights=lit_edge, minlength=len(predicates))
        pred_kind[(lit * 2) > tot] = ATTR

        return RDFGraph(
            labels=labels,
            node_kind=node_kind,
            src=src,
            dst=dst,
            pred=pred,
            predicates=predicates,
            pred_kind=pred_kind,
        )

    # ------------------------------------------------------------------ #
    def triples(self) -> list:
        """(subject, predicate, object) string triples in edge order — the
        exact list `from_triples` would round-trip back to this graph."""
        return list(zip(self.labels[self.src], self.predicates[self.pred],
                        self.labels[self.dst]))

    # ------------------------------------------------------------------ #
    def size_bytes(self) -> int:
        """Footprint of the raw dataset (for Fig. 3-style comparisons)."""
        lab = sum(len(s) for s in self.labels)
        return int(lab + self.node_kind.nbytes + self.src.nbytes
                   + self.dst.nbytes + self.pred.nbytes)


# ---------------------------------------------------------------------- #
# IDMap: prefix partial keyword -> contiguous id interval.
# ---------------------------------------------------------------------- #
class IDMap:
    """The paper's IDMap index.

    With node id == lexicographic label rank, the map itself is the sorted
    label array; a prefix keyword resolves via two binary searches to the
    half-open interval [lo, hi) of matching ids (O(log N)).
    """

    def __init__(self, graph: RDFGraph):
        self.labels = graph.labels

    def interval(self, prefix: str) -> tuple[int, int]:
        if prefix == "":  # wildcard: matches every label
            return 0, len(self.labels)
        lo = int(np.searchsorted(self.labels, prefix, side="left"))
        # smallest string that is > every string with this prefix
        hi = int(np.searchsorted(self.labels, prefix + "￿", side="right"))
        return lo, hi

    def cardinality(self, prefix: str) -> int:
        lo, hi = self.interval(prefix)
        return hi - lo

    def size_bytes(self) -> int:
        return int(sum(len(s) for s in self.labels) + 8 * len(self.labels))
