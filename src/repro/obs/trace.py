"""Per-query tracing: nestable spans, a trace ring buffer, Chrome export.

A *trace* is everything that happened to one submitted query, identified
by a server-assigned trace id.  Because shape batching interleaves
queries (prepare runs per future, execution runs per bucket), a trace is
a sequence of root *segments* — ``submit``, ``prepare``, then either
``execute`` (the bucket representative) or ``fanout`` (a deduped bucket
member pointing at the representative's trace) — each holding a nested
span tree.  Within a segment, ``tracer.span(...)`` nests under an
implicit current-span stack (serving is single-threaded and
synchronous), which is how governor and engine spans land inside the
right query's ``execute`` segment without any id threading through the
join stack.

Cost discipline: the hot path must pay ~zero when tracing is off.
``NULL_TRACER`` (a `NullTracer`) returns one shared `_NullSpan` whose
``set``/``__enter__``/``__exit__`` are empty-body methods — no
allocation, no clock read, no dict update.  Callers that compute span
attrs guard on ``span.live`` so attr construction is skipped too.

Clocks are monotonic (`time.perf_counter`); wall-clock never appears in
span timing.  ``export_chrome(path)`` writes the Chrome trace event
format (one ``ph: "X"`` complete event per span, pid 1, one tid per
trace) loadable in chrome://tracing or Perfetto.

Stdlib-only: imported by ``repro.core`` without creating an import cycle.
"""
from __future__ import annotations

import itertools
import json
import time
from collections import deque


class _NullSpan:
    """Shared do-nothing span: the disabled-tracing fast path."""
    __slots__ = ()
    live = False

    def set(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


NULL_SPAN = _NullSpan()


class Span:
    """One timed operation inside a trace.  Root spans (segments) have
    parent None; nested spans record their parent for structure checks.
    Use as a context manager; an exception propagating through stamps
    ``error`` with the exception type name and never swallows it."""
    __slots__ = ("name", "parent", "start", "end", "attrs", "error",
                 "_trace", "_tracer")
    live = True

    def __init__(self, tracer: "Tracer", name: str, trace: "Trace",
                 parent: "Span | None", attrs: dict):
        self._tracer = tracer
        self._trace = trace
        self.name = name
        self.parent = parent
        self.attrs = attrs
        self.error: str | None = None
        self.end: float | None = None
        self.start = time.perf_counter()

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    @property
    def trace_id(self) -> str:
        return self._trace.trace_id

    @property
    def duration_s(self) -> float:
        return (self.end if self.end is not None
                else time.perf_counter()) - self.start

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.error = exc_type.__name__
        self.end = time.perf_counter()
        stack = self._tracer._stack
        if stack and stack[-1] is self:
            stack.pop()
        else:                           # tolerate a skipped inner exit
            try:
                stack.remove(self)
            except ValueError:
                pass
        return False


class Trace:
    """All spans of one query, across its segments."""
    __slots__ = ("trace_id", "attrs", "spans", "created", "finished_at")

    def __init__(self, trace_id: str, attrs: dict):
        self.trace_id = trace_id
        self.attrs = attrs
        self.spans: list[Span] = []
        self.created = time.perf_counter()
        self.finished_at: float | None = None

    @property
    def wall_s(self) -> float:
        end = self.finished_at
        if end is None:
            end = max((s.end for s in self.spans
                       if s.end is not None), default=self.created)
        return end - self.created

    def roots(self) -> list[Span]:
        return [s for s in self.spans if s.parent is None]


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    try:                                # numpy scalars
        if hasattr(v, "item"):
            return v.item()
    except Exception:                   # noqa: BLE001
        pass
    return str(v)


class Tracer:
    """Collects traces.  `start()` mints a trace id; `segment(name, id)`
    opens a root span in that trace and makes it current; `span(name)`
    nests under the current stack top (a no-op span when no segment is
    open, so bare `Engine.execute` calls stay traceable-but-silent);
    `finish(id)` moves the trace to the `finished` ring buffer."""
    enabled = True

    def __init__(self, max_traces: int = 256,
                 max_spans_per_trace: int = 4096):
        self.max_spans_per_trace = int(max_spans_per_trace)
        self._ids = itertools.count(1)
        self._active: dict[str, Trace] = {}
        self._stack: list[Span] = []
        self.finished: deque[Trace] = deque(maxlen=int(max_traces))
        self.dropped_spans = 0          # over the per-trace span bound

    # -------------------------------------------------------------- #
    def start(self, **attrs) -> str:
        trace_id = f"t{next(self._ids):06d}"
        self._active[trace_id] = Trace(trace_id, attrs)
        return trace_id

    def segment(self, name: str, trace_id: str | None, **attrs):
        if trace_id is None:
            return NULL_SPAN
        trace = self._active.get(trace_id)
        if trace is None:               # already finished (or foreign id)
            return NULL_SPAN
        return self._open(name, trace, None, attrs)

    def span(self, name: str, **attrs):
        if not self._stack:
            return NULL_SPAN
        parent = self._stack[-1]
        return self._open(name, parent._trace, parent, attrs)

    def _open(self, name, trace, parent, attrs):
        if len(trace.spans) >= self.max_spans_per_trace:
            self.dropped_spans += 1
            return NULL_SPAN
        span = Span(self, name, trace, parent, attrs)
        trace.spans.append(span)
        self._stack.append(span)
        return span

    def finish(self, trace_id: str | None) -> Trace | None:
        if trace_id is None:
            return None
        trace = self._active.pop(trace_id, None)
        if trace is not None:
            trace.finished_at = time.perf_counter()
            self.finished.append(trace)
        return trace

    def current_trace_id(self) -> str | None:
        """Trace id of the innermost open span, or None outside any
        segment — lets error constructors name the trace that explains
        them without threading ids through call stacks."""
        return self._stack[-1].trace_id if self._stack else None

    def get(self, trace_id: str) -> Trace | None:
        """Look up a trace by id (active first, then the ring buffer)."""
        trace = self._active.get(trace_id)
        if trace is not None:
            return trace
        for tr in self.finished:
            if tr.trace_id == trace_id:
                return tr
        return None

    # -------------------------------------------------------------- #
    def to_chrome(self, include_active: bool = True) -> dict:
        """Chrome trace event format: one complete ("X") event per span,
        timestamps/durations in microseconds relative to the earliest
        span, pid 1, one tid per trace (named by a metadata event)."""
        traces = list(self.finished)
        if include_active:
            traces += list(self._active.values())
        events = []
        starts = [s.start for tr in traces for s in tr.spans]
        t0 = min(starts) if starts else 0.0
        for tid, tr in enumerate(traces, start=1):
            events.append({"name": "thread_name", "ph": "M",
                           "pid": 1, "tid": tid,
                           "args": {"name": f"query {tr.trace_id}"}})
            for s in tr.spans:
                end = s.end if s.end is not None else s.start
                args = {"trace_id": tr.trace_id}
                for k, v in s.attrs.items():
                    args[k] = _jsonable(v)
                if s.error is not None:
                    args["error"] = s.error
                events.append({
                    "name": s.name, "ph": "X",
                    "ts": (s.start - t0) * 1e6,
                    "dur": max(end - s.start, 0.0) * 1e6,
                    "pid": 1, "tid": tid, "args": args,
                })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path, include_active: bool = True) -> dict:
        """Write `to_chrome()` as JSON.  Returns a small manifest."""
        doc = self.to_chrome(include_active=include_active)
        with open(path, "w") as f:
            json.dump(doc, f)
        n_traces = len(self.finished) + (len(self._active)
                                         if include_active else 0)
        return {"path": str(path), "traces": n_traces,
                "events": len(doc["traceEvents"])}


class NullTracer:
    """Disabled tracing: same surface as `Tracer`, ~zero cost.  All span
    constructors return the shared `NULL_SPAN`; ids are never minted, so
    downstream `trace_id is None` checks short-circuit too."""
    enabled = False
    dropped_spans = 0
    finished: deque = deque()

    def start(self, **attrs):
        return None

    def segment(self, name, trace_id, **attrs):
        return NULL_SPAN

    def span(self, name, **attrs):
        return NULL_SPAN

    def finish(self, trace_id):
        return None

    def current_trace_id(self):
        return None

    def get(self, trace_id):
        return None

    def to_chrome(self, include_active: bool = True) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def export_chrome(self, path, include_active: bool = True) -> dict:
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        return {"path": str(path), "traces": 0, "events": 0}


NULL_TRACER = NullTracer()
