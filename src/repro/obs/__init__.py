"""Observability: per-query tracing, a metrics registry, and EXPLAIN.

  * `trace`   — `Tracer` with nestable spans and a ring buffer of
                completed traces, exportable as Chrome-trace JSON;
                `NULL_TRACER` is the ~zero-cost disabled variant the
                engine carries by default.
  * `metrics` — `MetricsRegistry` of counters / gauges / log-bucketed
                histograms with a pinned snapshot schema (feeds
                `QueryServer.telemetry()["metrics"]`).
  * `explain` — `render_explain(pq)`: the learned plan of one
                PreparedQuery as deterministic text (D-trees, §4.3
                check decision with its τ comparisons, join order with
                estimated vs. observed cardinalities, connection-edge
                order and strategies).

This package sits BELOW ``repro.core`` in the import order (``core``
imports ``obs``, never the reverse at module scope), so everything here
is stdlib-only or lazily bound.
"""
from .trace import (NULL_SPAN, NULL_TRACER, NullTracer, Span, Trace,
                    Tracer)
from .metrics import (HISTOGRAM_BASE, HISTOGRAM_FIELDS, Counter, Gauge,
                      Histogram, MetricsRegistry)
from .explain import render_explain

__all__ = [
    "Tracer", "NullTracer", "Span", "Trace", "NULL_TRACER", "NULL_SPAN",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "HISTOGRAM_BASE", "HISTOGRAM_FIELDS",
    "render_explain",
]
