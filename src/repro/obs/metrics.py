"""Process-local metrics: counters, gauges, log-bucketed histograms.

One `MetricsRegistry` per `QueryServer` replaces the ad-hoc latency
deques + `np.percentile` bookkeeping: histograms bucket observations
geometrically (default base 2^(1/8), ~9% resolution per bucket) in O(1)
memory regardless of stream length, keeping exact count/sum/min/max and
estimated percentiles (geometric bucket midpoint, clamped to the exact
observed [min, max]).

`snapshot()` has a PINNED flat schema — the unit of compatibility for
`QueryServer.telemetry()["metrics"]`:

    {"counters":   {name: int},
     "gauges":     {name: float},
     "histograms": {name: {"count", "sum", "min", "max",
                           "p50", "p90", "p99"}}}

A schema test asserts the key set, so extend it deliberately.

Stdlib-only: importable from ``repro.core`` without a cycle.
"""
from __future__ import annotations

import math

HISTOGRAM_BASE = 2.0 ** 0.125       # ~9% bucket resolution
HISTOGRAM_FIELDS = ("count", "sum", "min", "max", "p50", "p90", "p99")


class Counter:
    """Monotonic int counter."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins scalar."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Log-bucketed histogram of non-negative observations.

    Bucket k holds values in [base^k, base^(k+1)); values <= 0 land in a
    dedicated zero bucket (latencies and row counts are never negative,
    but a degenerate 0 must not blow up the log)."""
    __slots__ = ("base", "_log_base", "buckets", "zeros", "count", "sum",
                 "min", "max")

    def __init__(self, base: float = HISTOGRAM_BASE):
        self.base = float(base)
        self._log_base = math.log(self.base)
        self.buckets: dict[int, int] = {}
        self.zeros = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 0.0:
            self.zeros += 1
            return
        k = math.floor(math.log(v) / self._log_base)
        self.buckets[k] = self.buckets.get(k, 0) + 1

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile: cumulative walk over the buckets,
        geometric midpoint of the landing bucket, clamped to the exact
        observed range.  0.0 on an empty histogram."""
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(self.count * q / 100.0))
        if rank <= self.zeros:
            return max(0.0, self.min)
        cum = self.zeros
        for k in sorted(self.buckets):
            cum += self.buckets[k]
            if cum >= rank:
                mid = self.base ** (k + 0.5)
                return min(max(mid, self.min), self.max)
        return self.max

    def snapshot(self) -> dict:
        empty = self.count == 0
        return {
            "count": self.count,
            "sum": float(self.sum),
            "min": 0.0 if empty else float(self.min),
            "max": 0.0 if empty else float(self.max),
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Create-on-first-use registry.  Names are flat strings; a name is
    permanently bound to its first-used type (asking for a counter named
    like an existing histogram raises)."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def _check_free(self, name: str, own: dict) -> None:
        for d in (self._counters, self._gauges, self._histograms):
            if d is not own and name in d:
                raise ValueError(
                    f"metric {name!r} already registered as another type")

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            self._check_free(name, self._counters)
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            self._check_free(name, self._gauges)
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str,
                  base: float = HISTOGRAM_BASE) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            self._check_free(name, self._histograms)
            h = self._histograms[name] = Histogram(base=base)
        return h

    def snapshot(self) -> dict:
        """JSON-serializable snapshot with the pinned schema (see module
        docstring)."""
        return {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.snapshot()
                           for k, h in sorted(self._histograms.items())},
        }
