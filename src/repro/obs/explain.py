"""EXPLAIN: render a PreparedQuery's plan as deterministic text.

Answers "what did the planner decide and what did it cost" for one
template: the IDMap candidate intervals, the §4.3 check decision with
the τ comparisons that drove it, the D-tree decomposition, the Selinger
join order with estimated-vs-observed cardinalities and the chosen join
strategies, and the connection-edge order with its reach/cross pricing.

Template-level fields are available right after `Engine.prepare`; the
learned sections (join orders, strategies, observed join sizes) render
as ``(unlearned — cold execution pending)`` until the first execution
fills them in.  Everything is duck-typed over the PreparedQuery /
PlanDecision / Thresholds field names — this module imports nothing
from ``repro.core`` at module scope, so ``obs`` stays import-cycle-free
below the core.
"""
from __future__ import annotations


def _fmt(v: float) -> str:
    """Deterministic compact float: ints as ints, else 4 significant
    digits (no locale, no exponent jitter across platforms)."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return f"{f:.4g}"


def _fp_short(fingerprint) -> str:
    if not fingerprint:
        return "(unfingerprinted)"
    s = str(fingerprint)
    return s if len(s) <= 40 else s[:40] + "..."


def _check_lines(pq, thresholds) -> list[str]:
    d = pq.decision
    state = "ON" if pq.use_check else "OFF"
    if d is None:
        return [f"check decision (§4.3): {state} "
                "(forced by check_policy, no τ evaluation)"]
    lines = [f"check decision (§4.3): {state}"]
    if thresholds is not None:
        from ..core.planner import decision_terms
        for t in decision_terms(d, thresholds):
            lines.append(
                f"  {t['name']}: {_fmt(t['value'])} {t['op']} "
                f"{t['tau']}={_fmt(t['threshold'])} -> "
                f"{'hit' if t['hit'] else 'miss'}")
        lines.append(f"  => use_check = complex AND power "
                     f"= {pq.use_check}")
    else:
        lines.append(
            f"  complex={d.complex_query} "
            f"est_iterations={_fmt(d.est_iterations)} "
            f"est_join_product={_fmt(d.est_join_product)} "
            f"max_selectivity={_fmt(d.max_selectivity)}")
    sel = getattr(d, "per_node_selectivity", None) or {}
    if sel:
        body = " ".join(f"q{q}={_fmt(sel[q])}" for q in sorted(sel))
        lines.append(f"  per-node N_q selectivity: {body}")
    return lines


def _candidate_lines(pq) -> list[str]:
    lines = ["candidates (IDMap intervals):"]
    iv = pq.iv
    for q in sorted(pq.cand_sizes):
        lo, hi = int(iv[q, 0]), int(iv[q, 1])
        lines.append(f"  q{q} [{lo}, {hi}) -> {pq.cand_sizes[q]}")
    total = sum(pq.cand_sizes.values())
    after = None
    masks = getattr(pq, "masks", None)
    if masks is not None:
        after = masks[2]
    lines.append(f"  total before check: {total}"
                 + (f", after: {after}" if after is not None else ""))
    return lines


def _component_lines(pq) -> list[str]:
    lines = [f"components: {len(pq.comps)}"]
    for ci, (comp, trees) in enumerate(zip(pq.comps, pq.trees_per_comp)):
        lines.append(f"  component {ci}: nodes {list(comp)}")
        for tr in trees:
            edges = ", ".join(
                (f"q{tr.root}-[{'*' if p is None else p}]->q{c}" if out
                 else f"q{c}-[{'*' if p is None else p}]->q{tr.root}")
                for p, c, out in tr.edges)
            lines.append(f"    d-tree root=q{tr.root}: "
                         + (edges if edges else "(single node)"))
    return lines


def _join_order_lines(pq) -> list[str]:
    lines = ["join order (Selinger DP over per-tree tables):"]
    any_learned = False
    for ci in range(len(pq.comps)):
        if ci in pq.comp_orders:
            any_learned = True
            order = pq.comp_orders[ci]
            cost, greedy = pq.comp_costs.get(ci, (0.0, 0.0))
            lines.append(
                f"  component {ci}: trees in order {list(order)} "
                f"est_cost={_fmt(cost)} (greedy would be {_fmt(greedy)})")
    if not any_learned:
        lines.append("  (unlearned — cold execution pending, or single"
                     " d-tree per component)")
    return lines


def _connection_lines(pq) -> list[str]:
    conns = list(getattr(pq.query, "connections", ()) or ())
    if not conns:
        return ["connection edges: none"]
    lines = [f"connection edges: {len(conns)}"]
    for i, c in enumerate(conns):
        arrow = "<->" if c.bidirectional else "->"
        lines.append(f"  #{i} q{c.src} {arrow} q{c.dst} "
                     f"(max_dist={c.max_dist})")
    if pq.conn_order is not None:
        cost, greedy = pq.conn_costs
        lines.append(f"  merge order {list(pq.conn_order)} "
                     f"est_cost={_fmt(cost)} "
                     f"(greedy would be {_fmt(greedy)})")
    if pq.conn_impls:
        lines.append("  edge strategies (reach/cross, processing order): "
                     + " ".join(pq.conn_impls))
    if pq.conn_order is None and not pq.conn_impls:
        lines.append("  (unlearned — cold execution pending)")
    return lines


def _join_seq_lines(pq) -> list[str]:
    seq = pq.join_seq
    if not seq:
        return ["learned join sequence: (unlearned — cold execution"
                " pending)"]
    ests = list(getattr(pq, "join_est_seq", ()) or ())
    lines = [f"learned join sequence ({len(seq)} estimator-sized joins,"
             " engine call order):"]
    for i, (rows, cap, impl) in enumerate(seq):
        est = ests[i] if i < len(ests) else None
        est_s = "-" if est is None else _fmt(est)
        lines.append(f"  #{i} impl={impl} est={est_s} rows={rows} "
                     f"cap={cap}")
    return lines


def render_explain(pq, thresholds=None) -> str:
    """Multi-line EXPLAIN text for one PreparedQuery.  `thresholds`
    (a planner.Thresholds) enables the τ-comparison rendering of the
    §4.3 decision; without it only the decision inputs are shown."""
    lines = [f"EXPLAIN template {_fp_short(pq.fingerprint)}",
             f"  executions={pq.executions} "
             f"calibration_version={pq.version} "
             f"prepare_time={pq.prepare_time * 1e3:.2f}ms"]
    for block in (_candidate_lines(pq), _check_lines(pq, thresholds),
                  _component_lines(pq), _join_order_lines(pq),
                  _connection_lines(pq), _join_seq_lines(pq)):
        lines.extend(block)
    return "\n".join(lines)
