"""Fault tolerance: elastic re-meshing, step retry, straggler notes.

Failure model at 1000+ nodes: a pod (or slice) drops out mid-run.  The
recovery path implemented here:

  1. the launcher catches the step failure (`run_with_retries`),
  2. a smaller mesh is built from surviving devices (`shrink_mesh` — drop
     the 'pod' axis, or halve 'data'),
  3. state is restored from the last checkpoint and `reshard`ed onto the
     new mesh (checkpoints are global-array keyed, so this is a plain
     device_put with new shardings),
  4. training resumes; the deterministic index-based data pipeline
     (repro.data.lm_data) makes the replayed batches identical on any
     host — no data-loader state to recover.

Straggler mitigation: because every batch shard is recomputable anywhere
(stateless hash pipeline) and checkpoints are atomic, a backup worker can
shadow-execute the slowest shard and race it (documented; not exercisable
on one host).
"""
from __future__ import annotations

import logging
import time

import numpy as np
import jax

log = logging.getLogger(__name__)


def shrink_mesh(mesh, drop_axis: str = "pod"):
    """Rebuild a mesh without `drop_axis` (simulating loss of a pod), or
    halving the first axis if the axis is absent."""
    names = list(mesh.axis_names)
    shape = list(mesh.devices.shape)
    devs = mesh.devices
    if drop_axis in names:
        i = names.index(drop_axis)
        devs = np.take(devs, 0, axis=i)          # keep pod 0's devices
        names.pop(i)
        shape.pop(i)
    else:
        devs = np.split(devs, 2, axis=0)[0]
        shape[0] //= 2
    return jax.sharding.Mesh(devs, tuple(names))


def reshard(tree, mesh, pspecs):
    """device_put global arrays onto a (new) mesh."""
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, pspecs)


def run_with_retries(step_fn, max_retries: int = 3, on_failure=None):
    """Execute step_fn(); on failure invoke on_failure(attempt) (e.g.
    restore-from-checkpoint + re-mesh) and retry."""
    for attempt in range(max_retries + 1):
        try:
            return step_fn()
        except Exception as e:                       # noqa: BLE001
            if attempt == max_retries:
                raise
            log.warning("step failed (%s); recovery attempt %d",
                        e, attempt + 1)
            if on_failure is not None:
                on_failure(attempt)
            time.sleep(0.01)
    raise RuntimeError("unreachable")
