from .elastic import shrink_mesh, reshard, run_with_retries
