"""repro: RDF-ℏ (selective signature-based pruning for RDF template
matching) embedded in a multi-pod JAX training/serving framework."""
__version__ = "1.0.0"
