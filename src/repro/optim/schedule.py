import jax.numpy as jnp


def cosine_schedule(step, *, lr, warmup, total_steps, min_ratio=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = lr * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1),
                    0.0, 1.0)
    cos = lr * (min_ratio + (1 - min_ratio) * 0.5
                * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)
