"""Sharded AdamW, pure functional.

Optimizer state is a pytree congruent with params, so the same
PartitionSpecs shard it (ZeRO-style when params are dp-sharded).
`state_dtype` bf16 halves optimizer memory — used by the 400B config to
fit the single-pod mesh (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params, state_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p
           in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
