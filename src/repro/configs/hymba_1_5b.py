"""Hymba-1.5B — parallel attention + Mamba heads per layer, SWA with
global meta tokens, ssm_state=16 [arXiv:2411.13676].
We approximate the 3 global-attention layers with 128 learned meta tokens
visible everywhere (see DESIGN.md §Arch-applicability)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    head_dim=64, d_ff=5504, vocab_size=32001,
    attn_type="sliding", window=2048, num_meta_tokens=128,
    ssm_state=16, ssm_heads=25,
)
