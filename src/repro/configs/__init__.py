"""Architecture registry: --arch <id> resolves here."""
from .base import (ModelConfig, InputShape, TrainConfig, ALL_SHAPES,
                   TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
                   supported_shapes)

from . import (rwkv6_7b, granite_moe_1b_a400m, llama4_maverick_400b_a17b,
               stablelm_1_6b, starcoder2_15b, minitron_8b, qwen2_0_5b,
               paligemma_3b, hubert_xlarge, hymba_1_5b)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (rwkv6_7b, granite_moe_1b_a400m, llama4_maverick_400b_a17b,
              stablelm_1_6b, starcoder2_15b, minitron_8b, qwen2_0_5b,
              paligemma_3b, hubert_xlarge, hymba_1_5b)
}

SHAPES: dict[str, InputShape] = {s.name: s for s in ALL_SHAPES}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    import dataclasses
    small = dict(
        num_layers=2,
        d_model=max(64, cfg.hd),
        num_heads=max(2, min(4, cfg.num_heads)),
        num_kv_heads=max(1, min(2, cfg.num_kv_heads)),
        head_dim=32,
        d_ff=128,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        d_ff_expert=64 if cfg.num_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        window=min(cfg.window, 16) if cfg.window else 0,
        num_meta_tokens=min(cfg.num_meta_tokens, 4),
        num_prefix_tokens=min(cfg.num_prefix_tokens, 4),
        ssm_heads=min(cfg.ssm_heads, 4) if cfg.ssm_heads else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        rwkv_chunk=8,
        loss_chunk=16,
        dtype="float32", param_dtype="float32",
    )
    small["d_model"] = small["num_heads"] * small["head_dim"]
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
