"""RWKV-6 "Finch" 7B — attention-free, data-dependent decay [arXiv:2404.05892]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv6",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    head_dim=64, d_ff=14336, vocab_size=65536,
    rwkv_head_dim=64, tie_embeddings=False,
)
