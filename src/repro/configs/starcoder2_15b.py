"""StarCoder2-15B — dense, GQA kv=4, RoPE, non-gated FFN [arXiv:2402.19173]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
    head_dim=128, d_ff=24576, vocab_size=49152,
    gated_ffn=False, rope_theta=100_000.0, qkv_bias=True,
)
