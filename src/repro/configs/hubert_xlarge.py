"""HuBERT X-Large — encoder-only audio transformer (frame embeddings
precomputed by a stub conv frontend) [arXiv:2106.07447]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    head_dim=80, d_ff=5120, vocab_size=504,
    causal=False, gated_ffn=False, frontend="audio",
    tie_embeddings=False,
)
