"""Model / run configuration system.

One ModelConfig describes any architecture in the assigned pool; family
selects the block type.  Everything is plain dataclasses — configs are
importable, diffable, and hashable for checkpoint metadata.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | rwkv6 | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = True
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    # --- attention style -------------------------------------------------
    attn_type: str = "full"      # full | sliding
    window: int = 0              # sliding-window size
    num_meta_tokens: int = 0     # learned global prefix tokens (hymba)
    causal: bool = True          # False for encoder-only
    gated_ffn: bool = True       # SwiGLU (False: 2-matrix GELU FFN)

    # --- MoE --------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1           # routed FFN every k-th layer (llama4: 2)
    d_ff_expert: int = 0         # 0 -> d_ff
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # --- SSM / RWKV -------------------------------------------------------
    ssm_state: int = 0           # mamba state size (hymba)
    ssm_heads: int = 0           # parallel ssm heads (hymba); 0 = none
    rwkv_head_dim: int = 64

    # --- modality frontend stubs -------------------------------------------
    frontend: str | None = None  # None | vision | audio
    num_prefix_tokens: int = 0   # vision: patch tokens prepended

    # --- numerics / training ----------------------------------------------
    dtype: str = "bfloat16"       # activation dtype
    param_dtype: str = "float32"  # master param dtype
    rwkv_chunk: int = 32
    loss_chunk: int = 512         # chunked cross-entropy seq chunk

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def d_ff_e(self) -> int:
        return self.d_ff_expert or self.d_ff

    @property
    def attention_free(self) -> bool:
        return self.family == "rwkv6"

    @property
    def sub_quadratic(self) -> bool:
        """Can run 500k-token decode (state-based or windowed attention)."""
        return self.family in ("rwkv6",) or \
            (self.family == "hybrid" and self.attn_type == "sliding")

    @property
    def decoder(self) -> bool:
        return self.family != "encoder"

    def num_params(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs)."""
        d, ff, v, l = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd, h, kv = self.hd, self.num_heads, self.num_kv_heads
        per_layer = 0
        if self.family == "rwkv6":
            per_layer = 6 * d * d + 2 * d * ff     # r,k,v,g,w,o + channel mix
        else:
            attn = d * h * hd + 2 * d * kv * hd + h * hd * d
            if self.family == "hybrid" and self.ssm_heads:
                attn += 2 * d * d + d * (2 * self.ssm_state + 1) * 2
            ffn = (3 if self.gated_ffn else 2) * d * ff
            per_layer = attn + ffn
        total = l * per_layer
        if self.num_experts:
            n_moe_layers = l // self.moe_every
            expert = 3 * d * self.d_ff_e
            total += n_moe_layers * (self.num_experts - 1) * expert
            total += n_moe_layers * self.n_shared_experts * expert
            total += n_moe_layers * d * self.num_experts    # router
        total += v * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def num_active_params(self) -> int:
        if not self.num_experts:
            return self.num_params()
        d, l = self.d_model, self.num_layers
        n_moe = l // self.moe_every
        expert = 3 * d * self.d_ff_e
        inactive = n_moe * (self.num_experts - self.experts_per_token) * expert
        return int(self.num_params() - inactive)

    def config_hash(self) -> str:
        blob = json.dumps(dataclasses.asdict(self), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def supported_shapes(cfg: ModelConfig) -> list[InputShape]:
    """Per-brief skip rules: long_500k only for sub-quadratic archs; no
    decode shapes for encoder-only archs."""
    out = [TRAIN_4K, PREFILL_32K]
    if cfg.decoder:
        out.append(DECODE_32K)
        if cfg.sub_quadratic:
            out.append(LONG_500K)
    return out


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    opt_state_dtype: str = "float32"   # bf16 halves optimizer memory
    grad_dtype: str = "float32"        # bf16 halves gradient-reduce bytes
    microbatch: int = 1                # gradient accumulation steps
    zero3: bool = False                # shard params over data axes too
    remat: bool = True
    seed: int = 0
