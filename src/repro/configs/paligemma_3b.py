"""PaliGemma-3B — SigLIP (stub) + Gemma backbone, MQA (kv=1)
[arXiv:2407.07726].  input_specs feeds 256 precomputed patch embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    head_dim=256, d_ff=16384, vocab_size=257216,
    frontend="vision", num_prefix_tokens=256,
)
