"""Llama-4 Maverick-class 400B/A17B — interleaved MoE (every other layer
routed, 128 experts top-1 + 1 shared expert), GQA kv=8
[hf:meta-llama/Llama-4-*; unverified].  moe_every=2 reproduces the ~400B
total / ~17B active split with the brief's dims (see DESIGN.md)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=202048,
    num_experts=128, experts_per_token=1, moe_every=2,
    d_ff_expert=8192, n_shared_experts=1,
)
