"""Deterministic fault injection for chaos-testing the serving stack.

The harness monkeypatches a small set of *injection points* — the
load-bearing seams of the execution pipeline — with wrappers that count
calls and fire configured faults at exact call indices, so a chaos test
can say "the 3rd merge-probe dispatch of this run raises" and get the
same failure every time.

Injection points (name -> patched attributes):

  kernel_dispatch   repro.kernels.ops.merge_probe — every staged
                    sort-merge join's probe kernel dispatch (the fused
                    chain bypasses this seam; chaos configs that target
                    it run with EngineConfig.fuse_joins=False).
  join_expand       repro.core.matching._merge_expand — the jitted
                    segment-offset match expansion of staged sort-merge
                    joins (same fuse_joins caveat).
  fused_probe       repro.kernels.fused_join.sort_probe_expand /
                    sort_probe (one shared counter) — every fused-chain
                    join dispatch.
  radix_probe       repro.kernels.ops.radix_probe — the bucket-window
                    probe of every radix hash join.
  reach_gather      repro.core.connectivity.reach_pairs — the reach-set
                    pair-table gather of the reach-join path.
  cache_lookup      ReachCache.get_set / get_array (one shared counter)
                    — every reach-cache probe.

Fault kinds:

  raise             raise InjectedFault (an unexpected hard failure).
  corrupt_capacity  raise matching.CapacityOverflow(needed=1) — a lying
                    capacity estimate, exercising the overflow retry /
                    degraded-retry paths.  Deliberately NOT a silent
                    output corruption: the serving stack's contract is
                    "exact or typed error", so injected faults must be
                    *detectable* — capacity lies are the realistic
                    detectable corruption in this engine (every table is
                    capacity-padded and overflow-checked).
  delay             sleep `delay_s` then proceed normally — exercises
                    deadline budgets without changing results.

Trigger matrix (1-based per-point call index; exactly one of the three
modes is active per Fault — `first` wins over `every` wins over `at`):

  trigger     fires on calls        models
  ---------   -------------------   ----------------------------------
  at=k        k exactly (once)      an isolated one-shot blip
  every=n     n, 2n, 3n, ...        a persistent / periodic fault
  first=k     1..k, then clears     a transient fault that heals — the
                                    retry-classification case: call
                                    k+1 onward succeeds, so ONE retry
                                    recovers iff k == 1

`FaultInjector` is a context manager; the original attributes are
always restored on exit.
"""
from __future__ import annotations

import importlib
import time
from dataclasses import dataclass


class InjectedFault(RuntimeError):
    """The error raised by kind='raise' injections."""

    def __init__(self, point: str, call_index: int):
        self.point = point
        self.call_index = call_index
        super().__init__(f"injected fault at {point} (call {call_index})")


# point name -> tuple of (module path, attribute path) targets; multiple
# targets share the point's single call counter
INJECTION_POINTS: dict[str, tuple[tuple[str, str], ...]] = {
    "kernel_dispatch": (("repro.kernels.ops", "merge_probe"),),
    "join_expand": (("repro.core.matching", "_merge_expand"),),
    "fused_probe": (("repro.kernels.fused_join", "sort_probe_expand"),
                    ("repro.kernels.fused_join", "sort_probe")),
    "radix_probe": (("repro.kernels.ops", "radix_probe"),),
    "reach_gather": (("repro.core.connectivity", "reach_pairs"),),
    "cache_lookup": (("repro.core.connectivity", "ReachCache.get_set"),
                     ("repro.core.connectivity", "ReachCache.get_array")),
}

FAULT_KINDS = ("raise", "corrupt_capacity", "delay")


@dataclass(frozen=True)
class Fault:
    """One fault to inject: fire `kind` at injection point `point` on the
    `at`-th call (1-based), on every `every`-th call, or on the first
    `first` calls then clear (a healing transient) — see the trigger
    matrix in the module docstring."""
    point: str
    kind: str
    at: int = 1
    every: int | None = None
    first: int | None = None
    delay_s: float = 0.05

    def __post_init__(self):
        if self.point not in INJECTION_POINTS:
            raise ValueError(f"unknown injection point {self.point!r}; "
                             f"known: {sorted(INJECTION_POINTS)}")
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {FAULT_KINDS}")

    def triggers(self, call_index: int) -> bool:
        if self.first is not None:
            return call_index <= self.first
        if self.every is not None:
            return call_index % self.every == 0
        return call_index == self.at


def _resolve(target: tuple[str, str]):
    """(owner object, attribute name, current value) for a target like
    ('repro.core.connectivity', 'ReachCache.get_set')."""
    mod = importlib.import_module(target[0])
    owner = mod
    parts = target[1].split(".")
    for p in parts[:-1]:
        owner = getattr(owner, p)
    return owner, parts[-1], getattr(owner, parts[-1])


class FaultInjector:
    """Context manager installing the configured faults.

    `calls` maps point name -> calls observed; `fired` lists
    (point, kind, call_index) for every fault that actually triggered —
    chaos tests assert on it to prove the fault was exercised."""

    def __init__(self, *faults: Fault):
        self.faults = faults
        self._by_point: dict[str, list[Fault]] = {}
        for f in faults:
            self._by_point.setdefault(f.point, []).append(f)
        self.calls: dict[str, int] = {}
        self.fired: list[tuple[str, str, int]] = []
        self._saved: list[tuple[object, str, object]] = []

    # ---------------------------------------------------------------- #
    def _make_wrapper(self, point: str, original):
        def wrapper(*args, **kwargs):
            self.calls[point] += 1
            idx = self.calls[point]
            for f in self._by_point[point]:
                if not f.triggers(idx):
                    continue
                self.fired.append((point, f.kind, idx))
                if f.kind == "raise":
                    raise InjectedFault(point, idx)
                if f.kind == "corrupt_capacity":
                    from repro.core.matching import CapacityOverflow
                    raise CapacityOverflow(1)
                time.sleep(f.delay_s)
            return original(*args, **kwargs)
        return wrapper

    def __enter__(self) -> "FaultInjector":
        for point in self._by_point:
            self.calls[point] = 0
            for target in INJECTION_POINTS[point]:
                owner, name, original = _resolve(target)
                self._saved.append((owner, name, original))
                setattr(owner, name, self._make_wrapper(point, original))
        return self

    def __exit__(self, *exc) -> None:
        while self._saved:
            owner, name, original = self._saved.pop()
            setattr(owner, name, original)
