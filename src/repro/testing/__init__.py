"""Test-support utilities shipped with the package (not only under
tests/) so benchmarks and examples can exercise the same machinery:

  * `faults` — the deterministic fault-injection harness used by the
    chaos suite and the robustness benchmark.
"""
from .faults import (Fault, FaultInjector, InjectedFault,
                     INJECTION_POINTS)

__all__ = ["Fault", "FaultInjector", "InjectedFault", "INJECTION_POINTS"]
