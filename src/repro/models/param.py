"""Parameter definition & sharding infrastructure.

Models declare parameters as trees of PD (shape + logical axis names).
From one declaration we derive: init (real arrays, smoke tests),
abstract ShapeDtypeStructs (dry-run — no allocation), and PartitionSpecs
(logical axis -> mesh axis via a rules table with divisibility fallback).

Logical axes:
  vocab   token embedding rows          -> 'model'
  embed   d_model                        -> None (or dp axes under ZeRO-3)
  heads   flattened q-head dim (H*hd)    -> 'model' when H % tp == 0
  kv      flattened kv-head dim          -> 'model' when KV % tp == 0
  ff      feed-forward hidden            -> 'model'
  expert  MoE expert count               -> 'model'
  layers  stacked-scan leading dim       -> None
  ssm/state/misc                         -> None
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


@dataclass(frozen=True)
class PD:
    shape: tuple
    axes: tuple                  # logical axis name (or None) per dim
    init: str = "normal"         # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def tree_map_pd(fn, defs):
    return jax.tree.map(fn, defs, is_leaf=lambda x: isinstance(x, PD))


def init_params(defs, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, PD))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, pd in zip(keys, leaves):
        if pd.init == "zeros":
            out.append(jnp.zeros(pd.shape, dtype))
        elif pd.init == "ones":
            out.append(jnp.ones(pd.shape, dtype))
        else:
            out.append(jax.random.normal(k, pd.shape, dtype) * pd.scale)
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs, dtype=jnp.float32):
    return tree_map_pd(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, dtype), defs)


@dataclass
class Rules:
    """logical axis -> mesh axis (name or tuple).  Divisibility-checked."""
    table: dict
    mesh_sizes: dict             # mesh axis name -> size

    def _size(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self.mesh_sizes[a]
            return n
        return self.mesh_sizes[axis]

    def resolve(self, logical, dim) -> Any:
        axis = self.table.get(logical)
        if axis is None:
            return None
        if dim % self._size(axis) != 0:
            return None
        return axis

    def spec(self, pd: PD) -> PS:
        used = set()
        parts = []
        for dim, logical in zip(pd.shape, pd.axes):
            a = self.resolve(logical, dim)
            # a mesh axis may appear only once per spec
            flat = a if isinstance(a, tuple) else (a,) if a else ()
            if any(f in used for f in flat):
                a = None
            used.update(flat)
            parts.append(a)
        return PS(*parts)


def param_pspecs(defs, rules: Rules):
    return tree_map_pd(rules.spec, defs)


def make_rules(mesh, *, tp_heads: bool, tp_kv: bool,
               zero3: bool = False) -> Rules:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp = dp if len(dp) > 1 else dp[0] if dp else None
    table = {
        "vocab": "model",
        "ff": "model",
        "expert": "model",
        "heads": "model" if tp_heads else None,
        "kv": "model" if tp_kv else None,
        "embed": dp if zero3 else None,
        "layers": None,
        # decode caches / states
        "batch": dp,
        "cache_seq": "model",
    }
    return Rules(table=table, mesh_sizes=sizes)


def count_params(defs) -> int:
    total = 0
    for pd in jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, PD)):
        n = 1
        for s in pd.shape:
            n *= s
        total += n
    return int(total)
