"""Public model API: build train/serve step functions, input specs for the
dry-run, and sharding spec trees — everything the launcher touches."""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from ..configs.base import ModelConfig, TrainConfig, InputShape
from .param import (PD, init_params, abstract_params, param_pspecs,
                    make_rules, Rules)
from .nn_ops import Sharder, NO_SHARD
from . import transformer as tf
from ..optim import adamw_init, adamw_update, clip_by_global_norm
from ..optim.schedule import cosine_schedule

DECODE_PAD = 128     # extra slots after the prefilled cache


# ---------------------------------------------------------------------- #
def tp_size(mesh) -> int:
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def dp_axes(mesh) -> tuple:
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a != "model")


def make_sharder(cfg: ModelConfig, mesh) -> Sharder:
    tp = tp_size(mesh)
    dp = dp_axes(mesh)
    dp = dp if len(dp) != 1 else dp[0]
    return Sharder(
        mesh=mesh,
        dp=dp,
        tp_heads=cfg.num_heads % tp == 0,
        tp_kv=cfg.num_kv_heads % tp == 0,
    )


def make_param_rules(cfg: ModelConfig, mesh, zero3: bool) -> Rules:
    tp = tp_size(mesh)
    return make_rules(mesh, tp_heads=cfg.num_heads % tp == 0,
                      tp_kv=cfg.num_kv_heads % tp == 0, zero3=zero3)


def model_pspecs(cfg: ModelConfig, mesh, zero3: bool = False):
    return param_pspecs(tf.model_defs(cfg), make_param_rules(cfg, mesh, zero3))


def cache_pspecs(cfg: ModelConfig, mesh, batch: int, cache_len: int,
                 zero3: bool = False):
    return param_pspecs(tf.cache_defs(cfg, batch, cache_len),
                        make_param_rules(cfg, mesh, zero3))


def init_model(cfg: ModelConfig, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    dtype = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    return init_params(tf.model_defs(cfg), key, dtype)


def abstract_model(cfg: ModelConfig):
    dtype = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    return abstract_params(tf.model_defs(cfg), dtype)


# ---------------------------------------------------------------------- #
# Batches
# ---------------------------------------------------------------------- #
def batch_defs(cfg: ModelConfig, shape: InputShape):
    """PD tree for one input batch of the given shape."""
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind == "decode":
        return {"tokens": PD((b,), ("batch",))}
    out = {}
    if cfg.frontend == "audio":
        out["frames"] = PD((b, s, d), ("batch", None, None))
    else:
        out["tokens"] = PD((b, s), ("batch", None))
        if cfg.frontend == "vision":
            out["patches"] = PD((b, cfg.num_prefix_tokens, d),
                                ("batch", None, None))
    if shape.kind == "train":
        out["labels"] = PD((b, s), ("batch", None))
        if cfg.family == "encoder":
            out["mask"] = PD((b, s), ("batch", None))
    return out


_BATCH_DTYPES = {"tokens": jnp.int32, "labels": jnp.int32, "mask": jnp.bool_,
                 "frames": jnp.bfloat16, "patches": jnp.bfloat16}


def batch_abstract(cfg, shape):
    defs = batch_defs(cfg, shape)
    return {k: jax.ShapeDtypeStruct(pd.shape, _BATCH_DTYPES[k])
            for k, pd in defs.items()}


def batch_pspecs(cfg, shape, mesh, zero3=False):
    rules = make_param_rules(cfg, mesh, zero3)
    return {k: rules.spec(pd) for k, pd in batch_defs(cfg, shape).items()}


def concrete_batch(cfg, shape, seed=0):
    """Real (host) batch for smoke tests / examples."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, sds in batch_abstract(cfg, shape).items():
        if k in ("tokens", "labels"):
            out[k] = rng.integers(0, cfg.vocab_size, sds.shape,
                                  dtype=np.int32)
        elif k == "mask":
            out[k] = rng.random(sds.shape) < 0.1
        else:
            out[k] = rng.normal(0, 1, sds.shape).astype(np.float32)
    return out


def decode_cache_len(cfg, shape: InputShape) -> int:
    if cfg.attn_type == "sliding":
        return cfg.num_meta_tokens + cfg.window
    return shape.seq_len + DECODE_PAD


def cache_abstract(cfg, shape: InputShape):
    defs = tf.cache_defs(cfg, shape.global_batch,
                         decode_cache_len(cfg, shape))
    def dt(path_key, pd):
        if path_key in ("slot_pos", "pos"):
            return jnp.int32
        if path_key in ("S", "h"):
            return jnp.float32
        if path_key in ("prev_tm", "prev_cm"):
            return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    out = {"blocks": {}, }
    for k, pd in defs["blocks"].items():
        out["blocks"][k] = jax.ShapeDtypeStruct(pd.shape, dt(k, pd))
    out["slot_pos"] = jax.ShapeDtypeStruct(defs["slot_pos"].shape, jnp.int32)
    out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


# ---------------------------------------------------------------------- #
# Step functions
# ---------------------------------------------------------------------- #
def make_loss_fn(cfg: ModelConfig, mesh=None, *, remat=True):
    shd = make_sharder(cfg, mesh)

    def loss(params, batch):
        cast = jax.tree.map(
            lambda x: x.astype(tf.cfg_dtype(cfg))
            if x.dtype in (jnp.float32, jnp.bfloat16) else x, params)
        return tf.loss_fn(cfg, cast, batch, shd, remat=remat)
    return loss


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics)."""
    shd = make_sharder(cfg, mesh)
    bf16_grads = tcfg.grad_dtype == "bfloat16"

    def loss_inner(cast_params, batch):
        return tf.loss_fn(cfg, cast_params, batch, shd, remat=tcfg.remat)

    inner_grad = jax.value_and_grad(loss_inner, has_aux=True)

    def grad_fn(params, batch):
        if bf16_grads:
            # differentiate wrt the bf16 copies: gradients (and their DP
            # all-reduce) stay bf16 — 2x less reduce traffic; the fp32
            # master update happens in the optimizer.
            cast = jax.tree.map(
                lambda x: x.astype(tf.cfg_dtype(cfg))
                if x.dtype in (jnp.float32, jnp.bfloat16) else x, params)
            return inner_grad(cast, batch)
        loss = make_loss_fn(cfg, mesh, remat=tcfg.remat)
        return jax.value_and_grad(loss, has_aux=True)(params, batch)

    n_mb = tcfg.microbatch

    def train_step(params, opt_state, batch, step):
        if n_mb == 1:
            (l, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), m
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, l_sum), ms = jax.lax.scan(acc, (g0, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            l = l_sum / n_mb
            metrics = {k: v.mean() for k, v in ms.items()}
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        lr = cosine_schedule(step, lr=tcfg.lr, warmup=tcfg.warmup,
                             total_steps=tcfg.total_steps)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr,
            b1=tcfg.adam_b1, b2=tcfg.adam_b2, eps=tcfg.adam_eps,
            weight_decay=tcfg.weight_decay)
        metrics = {"loss": l, "grad_norm": gnorm, "lr": lr, **metrics}
        return params, opt_state, metrics
    return train_step


def make_prefill_fn(cfg: ModelConfig, mesh=None, *, cache_len=0):
    shd = make_sharder(cfg, mesh)

    def fn(params, batch):
        cast = jax.tree.map(
            lambda x: x.astype(tf.cfg_dtype(cfg))
            if x.dtype in (jnp.float32, jnp.bfloat16) else x, params)
        return tf.prefill(cfg, cast, batch, shd, cache_len=cache_len)
    return fn


def make_decode_fn(cfg: ModelConfig, mesh=None):
    shd = make_sharder(cfg, mesh)

    def fn(params, cache, tokens):
        cast = jax.tree.map(
            lambda x: x.astype(tf.cfg_dtype(cfg))
            if x.dtype in (jnp.float32, jnp.bfloat16) else x, params)
        return tf.decode_step(cfg, cast, cache, tokens, shd)
    return fn


def opt_abstract(cfg: ModelConfig, tcfg: TrainConfig):
    dt = jnp.float32 if tcfg.opt_state_dtype == "float32" else jnp.bfloat16
    p = abstract_model(cfg)
    zeros = lambda s: jax.ShapeDtypeStruct(s.shape, dt)
    return {"m": jax.tree.map(zeros, p), "v": jax.tree.map(zeros, p),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_pspecs(cfg: ModelConfig, mesh, zero3=False):
    ps = model_pspecs(cfg, mesh, zero3)
    return {"m": ps, "v": ps, "step": PS()}
