"""Mamba-style selective SSM heads (the SSM half of Hymba's hybrid block).

Per head (dim hd, state size N):
    Δ_t = softplus(x_t W_Δ + b_Δ)            [B, S, H, hd]
    B_t, C_t = x_t W_B, x_t W_C              [B, S, H, N]
    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + Δ_t ⊙ (B_t ⊗ x_t)
    y_t = (h_t · C_t) + D ⊙ x_t
A is a learned negative diagonal (stored as log).  Sequence evaluation is
an exact lax.scan (chunked parallel form is a §Perf candidate, noted in
EXPERIMENTS.md); decode is the O(1) step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .param import PD


def ssm_defs(cfg, lead=()):
    d = cfg.d_model
    h, n = cfg.ssm_heads, cfg.ssm_state
    hd = d // h
    la = ("layers",) if lead else ()
    def m(shape, axes, **kw):
        return PD(lead + shape, la + axes, **kw)
    return {
        "Wx": m((d, d), ("embed", "heads")),
        "Wdt": m((d, h), ("embed", None)),
        "bdt": m((h,), (None,), init="zeros"),
        "WB": m((d, h * n), ("embed", None)),
        "WC": m((d, h * n), ("embed", None)),
        "Alog": m((h, hd, n), (None, None, None), init="zeros"),
        "D": m((h, hd), (None, None), init="ones"),
        "Wo": m((d, d), ("heads", "embed")),
    }


def _proj(cfg, p, x):
    b, s, d = x.shape
    h, n = cfg.ssm_heads, cfg.ssm_state
    hd = d // h
    xh = (x @ p["Wx"]).reshape(b, s, h, hd)
    dt = jax.nn.softplus(x @ p["Wdt"] + p["bdt"]).astype(jnp.float32)
    bb = (x @ p["WB"]).reshape(b, s, h, n).astype(jnp.float32)
    cc = (x @ p["WC"]).reshape(b, s, h, n).astype(jnp.float32)
    a = -jnp.exp(p["Alog"].astype(jnp.float32))            # [H, hd, N] < 0
    return xh, dt, bb, cc, a


def ssm_scan(cfg, p, x, h0, chunk: int = 128):
    """x [B,S,D]; h0 [B,H,hd,N] f32.  Returns (y [B,S,D], h_fin).

    Two-level scan: the outer scan walks chunks (its carry — one state per
    chunk boundary — is all the backward pass stores), the inner per-step
    scan is wrapped in jax.checkpoint so its states are recomputed, not
    saved.  Keeps training memory at O(S/chunk + chunk) states instead of
    O(S)."""
    b, s_real, d = x.shape
    h, n = cfg.ssm_heads, cfg.ssm_state
    hd = d // h
    xh, dt, bb, cc, a = _proj(cfg, p, x)
    c = min(chunk, s_real)
    s = s_real
    if s % c:
        pad = c - s % c
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        xh, bb, cc = zp(xh), zp(bb), zp(cc)
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # Δ=0 -> state-neutral
        s += pad
    nc = s // c

    def step(hc, inp):
        xt, dtt, bt, ct = inp          # [B,H,hd], [B,H], [B,H,N], [B,H,N]
        decay = jnp.exp(dtt[..., None, None] * a[None])    # [B,H,hd,N]
        inc = dtt[..., None, None] * bt[:, :, None, :] \
            * xt.astype(jnp.float32)[..., None]
        hc = decay * hc + inc
        y = jnp.einsum("bhdn,bhn->bhd", hc, ct)
        return hc, y

    def to_chunks(t):                  # [B,S,...] -> [nc, c, B, ...]
        t = t.reshape((b, nc, c) + t.shape[2:])
        return t.transpose((1, 2, 0) + tuple(range(3, t.ndim)))

    xs = tuple(to_chunks(t) for t in (xh, dt, bb, cc))

    @jax.checkpoint
    def chunk_body(hc, inp):
        h_new, ys = jax.lax.scan(step, hc, inp)
        return h_new, ys

    h_fin, ys = jax.lax.scan(chunk_body, h0, xs)            # ys [nc, c, B, H, hd]
    y = ys.reshape(s, b, h, hd).transpose(1, 0, 2, 3)[:, :s_real]
    y = y.astype(x.dtype) + xh[:, :s_real] * p["D"][None, None]
    return y.reshape(b, s_real, d) @ p["Wo"], h_fin


def ssm_step(cfg, p, x, hc):
    """x [B,D] -> (y [B,D], h_new)."""
    b, d = x.shape
    h, n = cfg.ssm_heads, cfg.ssm_state
    hd = d // h
    xh, dt, bb, cc, a = _proj(cfg, p, x[:, None])
    xt, dtt, bt, ct = xh[:, 0], dt[:, 0], bb[:, 0], cc[:, 0]
    decay = jnp.exp(dtt[..., None, None] * a[None])
    inc = dtt[..., None, None] * bt[:, :, None, :] \
        * xt.astype(jnp.float32)[..., None]
    h_new = decay * hc + inc
    y = jnp.einsum("bhdn,bhn->bhd", h_new, ct).astype(x.dtype)
    y = y + xt * p["D"][None]
    return y.reshape(b, d) @ p["Wo"], h_new
