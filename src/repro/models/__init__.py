"""Model zoo: composable JAX implementations of the assigned architectures."""
from . import transformer, nn_ops, moe, rwkv6, ssm, param, api
from .api import (make_train_step, make_loss_fn, make_prefill_fn,
                  make_decode_fn, init_model, abstract_model, model_pspecs,
                  batch_abstract, batch_pspecs, concrete_batch,
                  cache_abstract, cache_pspecs, opt_abstract, opt_pspecs,
                  make_sharder, decode_cache_len)
