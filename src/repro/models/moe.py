"""Mixture-of-experts FFN with capacity-based sorted dispatch.

Expert-parallel layout: expert tensors are sharded on the expert dim over
'model'; tokens are data-sharded.  The scatter into the [E*C, d] dispatch
buffer crosses those shardings, which XLA lowers to the expert-parallel
all-to-all.  Capacity C = ceil(T*K/E * capacity_factor); overflow tokens
are dropped (Switch-style), with the drop fraction reported in metrics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .param import PD
from .nn_ops import Sharder, NO_SHARD


def moe_param_defs(cfg, n_layers_dim=None):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.d_ff_e
    lead = (n_layers_dim,) if n_layers_dim else ()
    la = ("layers",) if n_layers_dim else ()
    defs = {
        "router": PD(lead + (d, e), la + ("embed", "expert")),
        "w1": PD(lead + (e, d, f), la + ("expert", "embed", "ff")),
        "w3": PD(lead + (e, d, f), la + ("expert", "embed", "ff")),
        "w2": PD(lead + (e, f, d), la + ("expert", "ff", "embed")),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        defs["sw1"] = PD(lead + (d, fs), la + ("embed", "ff"))
        defs["sw3"] = PD(lead + (d, fs), la + ("embed", "ff"))
        defs["sw2"] = PD(lead + (fs, d), la + ("ff", "embed"))
    return defs


def capacity(cfg, t_tokens: int) -> int:
    c = int(t_tokens * cfg.experts_per_token / cfg.num_experts
            * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _dp_degree(shd: Sharder, b: int) -> int:
    """Data-parallel group count, if the flattened token dim aligns."""
    if shd.mesh is None:
        return 1
    sizes = dict(zip(shd.mesh.axis_names, shd.mesh.devices.shape))
    dp = shd.dp if isinstance(shd.dp, tuple) else (shd.dp,)
    n = 1
    for a in dp:
        if a:
            n *= sizes[a]
    return n if (n and b % n == 0) else 1


def moe_ffn(cfg, p, x, shd: Sharder = NO_SHARD, dispatch: str = "local"):
    """x [B, S, D] -> (y [B, S, D], metrics dict).

    dispatch='local' (default): shard-local dispatch — positions come from
    a LOCAL exclusive cumsum over each data shard's own tokens and each
    shard fills its own capacity slice, so the scatter never crosses the
    data sharding.  The only cross-device traffic is the true MoE exchange
    (dp-sharded buffer -> expert-sharded buffer = all-to-all).  The
    'global_sort' variant (our paper-faithful first cut) sorts the global
    token axis, which XLA lowers to TB-scale all-reduces — kept for the
    §Perf before/after record.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    n_dp = _dp_degree(shd, b) if dispatch == "local" else 1
    tl = t // n_dp                       # tokens per data shard
    c = capacity(cfg, tl)                # per-shard expert capacity
    xt = x.reshape(t, d)

    logits = (xt @ p["router"]).astype(jnp.float32)        # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, k)                    # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    if dispatch == "global_sort":
        return _moe_global_sort(cfg, p, x, xt, probs, gate, eid, c * n_dp,
                                shd)

    # ---- shard-local dispatch -------------------------------------------
    # [n_dp, TL*K]: expert ids of this shard's token-slots
    eid_l = eid.reshape(n_dp, tl * k)
    xt_l = shd.c(xt.reshape(n_dp, tl, d), shd.dp, None, None)
    one_hot = jax.nn.one_hot(eid_l, e, dtype=jnp.int32)    # [dp, TL*K, E]
    pos_all = jnp.cumsum(one_hot, axis=1) - one_hot        # exclusive
    pos = jnp.take_along_axis(pos_all, eid_l[..., None],
                              axis=2)[..., 0]              # [dp, TL*K]
    keep = pos < c
    dest = jnp.where(keep, eid_l * c + pos, e * c)         # local slot
    tok = jnp.arange(tl * k) // k                          # local token id

    def scatter_one(dst_idx, src):
        buf = jnp.zeros((e * c + 1, d), x.dtype)
        return buf.at[dst_idx].set(src)
    buf = jax.vmap(scatter_one)(dest, xt_l[:, tok])        # [dp, E*C+1, d]
    buf = buf[:, : e * c].reshape(n_dp, e, c, d)
    # dp-sharded -> expert-sharded: THE all-to-all
    buf = shd.c(buf.transpose(1, 0, 2, 3).reshape(e, n_dp * c, d),
                "model", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w2"])
    y_e = shd.c(y_e, "model", None, None)

    # back to dp-sharded layout (reverse all-to-all)
    y_l = y_e.reshape(e, n_dp, c, d).transpose(1, 0, 2, 3)
    y_l = shd.c(y_l.reshape(n_dp, e * c, d), shd.dp, None, None)
    y_l = jnp.concatenate([y_l, jnp.zeros((n_dp, 1, d), y_l.dtype)], 1)

    w = gate.reshape(n_dp, tl * k)

    def combine_one(y_buf, dst_idx, w_row):
        contrib = y_buf[dst_idx] * w_row[:, None].astype(y_buf.dtype)
        return jnp.zeros((tl, d), y_buf.dtype).at[tok].add(contrib)
    out = jax.vmap(combine_one)(y_l, dest, w)              # [dp, TL, d]
    out = shd.c(out, shd.dp, None, None).reshape(t, d)

    if cfg.n_shared_experts:
        hs = jax.nn.silu(xt @ p["sw1"]) * (xt @ p["sw3"])
        out = out + hs @ p["sw2"]

    frac_tok = jnp.mean(jax.nn.one_hot(eid[:, 0], e, dtype=jnp.float32), 0)
    frac_prob = probs.mean(0)
    aux = e * jnp.sum(frac_tok * frac_prob)
    dropped = 1.0 - keep.mean()
    return out.reshape(b, s, d), {"moe_aux": aux, "moe_drop": dropped}


def _moe_global_sort(cfg, p, x, xt, probs, gate, eid, c, shd):
    """First-cut dispatch via global argsort (kept for §Perf record)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    flat_e = eid.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(t * k) - first
    keep = pos < c
    dest = jnp.where(keep, sorted_e * c + pos, e * c)
    tok = order // k

    buf = jnp.zeros((e * c + 1, d), x.dtype)
    buf = buf.at[dest].set(xt[tok])
    buf = shd.c(buf[: e * c].reshape(e, c, d), "model", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w2"])
    y_e = shd.c(y_e, "model", None, None)

    y_flat = jnp.concatenate([y_e.reshape(e * c, d),
                              jnp.zeros((1, d), y_e.dtype)], 0)
    contrib = y_flat[jnp.where(keep, dest, e * c)]
    w = gate.reshape(-1)[order]
    out = jnp.zeros((t, d), x.dtype).at[tok].add(
        contrib * w[:, None].astype(contrib.dtype))
    if cfg.n_shared_experts:
        hs = jax.nn.silu(xt @ p["sw1"]) * (xt @ p["sw3"])
        out = out + hs @ p["sw2"]
    frac_tok = jnp.mean(jax.nn.one_hot(eid[:, 0], e, dtype=jnp.float32), 0)
    aux = e * jnp.sum(frac_tok * probs.mean(0))
    return out.reshape(b, s, d), {"moe_aux": aux,
                                  "moe_drop": 1.0 - keep.mean()}
