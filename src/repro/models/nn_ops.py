"""Shared neural ops: norms, rotary, flash attention (jnp, memory-bounded),
decode attention over (possibly ring) KV caches, FFNs.

Attention memory discipline: full [S, S] score materialization is never
allowed — prefill_32k would need TBs.  `flash_attention` scans KV in chunks
with an online softmax (running max / normalizer), keeping peak block
memory at B*H*S_q*kv_chunk.

Sharding is jit/SPMD-global: all shapes here are global; `Sharder`
constraints tell XLA how to partition (TP on heads when divisible, else
context-parallel on the query-sequence dim).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS


# ---------------------------------------------------------------------- #
@dataclass
class Sharder:
    mesh: object | None
    dp: tuple                      # data-parallel mesh axes, e.g. ('pod','data')
    tp_heads: bool                 # q-heads divisible by tp size
    tp_kv: bool

    def _ok(self, dim, axis):
        if axis is None:
            return None
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        flat = axis if isinstance(axis, tuple) else (axis,)
        n = 1
        for a in flat:
            n *= sizes[a]
        return axis if dim % n == 0 else None

    def c(self, x, *axes):
        """Constraint x to PartitionSpec(axes), dropping non-divisible."""
        if self.mesh is None:
            return x
        parts = [self._ok(d, a) for d, a in zip(x.shape, axes)]
        used = set()
        clean = []
        for a in parts:
            flat = a if isinstance(a, tuple) else (a,) if a else ()
            if any(f in used for f in flat):
                clean.append(None)
            else:
                clean.append(a)
                used.update(flat)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, PS(*clean)))


NO_SHARD = Sharder(mesh=None, dp=(), tp_heads=False, tp_kv=False)


# ---------------------------------------------------------------------- #
def rms_norm(x, gamma, eps=1e-5):
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def rotary(x, positions, theta=10_000.0):
    """x [..., S, hd] (hd even), positions [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs     # [..., S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def ffn(x, w1, w2, w3=None):
    """SwiGLU when w3 given, GELU 2-matrix otherwise."""
    if w3 is not None:
        h = jax.nn.silu(x @ w1) * (x @ w3)
    else:
        h = jax.nn.gelu(x @ w1)
    return h @ w2


# ---------------------------------------------------------------------- #
def _mask_block(qpos, kpos, *, causal, window, n_meta):
    """[qc, kc] additive-mask boolean: True = attend."""
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window:
        in_window = (qpos[:, None] - kpos[None, :]) < window
        is_meta = kpos[None, :] < n_meta
        ok &= in_window | is_meta
    return ok


def flash_attention(q, k, v, *, causal=True, window=0, n_meta=0,
                    kv_chunk=1024, shd: Sharder = NO_SHARD,
                    softmax_scale=None):
    """q [B, Hq, Sq, hd]; k, v [B, Hkv, Skv, hd] -> [B, Hq, Sq, hd].

    GQA via head grouping; online-softmax scan over KV chunks.  Causal
    rectangle is masked, not skipped (triangular scheduling is a recorded
    §Perf candidate).
    """
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    scale = softmax_scale or hd ** -0.5
    qg = q.reshape(b, hkv, g, sq, hd)
    kv_chunk = min(kv_chunk, skv)
    skv_real = skv
    if skv % kv_chunk:                       # pad KV; padded keys masked off
        pad = kv_chunk - skv % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        skv = skv + pad
    nk = skv // kv_chunk

    kc = k.reshape(b, hkv, nk, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nk, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(sq)

    def step(carry, inp):
        acc, m, l = carry
        kb, vb, ki = inp                                  # [B,Hkv,kc,hd]
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        kpos = ki * kv_chunk + jnp.arange(kv_chunk)
        ok = _mask_block(qpos, kpos, causal=causal, window=window,
                         n_meta=n_meta)
        ok &= (kpos < skv_real)[None, :]
        s = jnp.where(ok[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1))
        # guard -inf rows (no valid key yet)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(ok[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (kc, vc, jnp.arange(nk)))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    return out.reshape(b, hq, sq, hd).astype(q.dtype)


# ---------------------------------------------------------------------- #
def decode_attention(q, k_cache, v_cache, slot_positions, pos, *,
                     window=0, n_meta=0, shd: Sharder = NO_SHARD,
                     softmax_scale=None):
    """Single-step attention over a cache.

    q [B, Hq, hd]; caches [B, Hkv, C, hd]; slot_positions [C] int32 (the
    absolute position stored in each slot, -1 = empty); pos = current
    query position (scalar int32).
    """
    b, hq, hd = q.shape
    _, hkv, c, _ = k_cache.shape
    g = hq // hkv
    scale = softmax_scale or hd ** -0.5
    qg = q.reshape(b, hkv, g, hd)
    s = jnp.einsum("bhgd,bhcd->bhgc", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = (slot_positions >= 0) & (slot_positions <= pos)
    if window:
        in_w = (pos - slot_positions) < window
        valid &= in_w | (slot_positions < n_meta)
    s = jnp.where(valid[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgc,bhcd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------- #
def chunked_cross_entropy(x, embed, labels, *, chunk=512,
                          shd: Sharder = NO_SHARD, mask=None):
    """Next-token CE without materializing [B, S, V] logits.

    x [B, S, D]; embed [V, D]; labels [B, S] int32; mask [B, S] optional.
    Scans sequence chunks; each chunk's logits are vocab-sharded.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    ns = s // chunk
    assert s % chunk == 0, (s, chunk)
    xc = x.reshape(b, ns, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, ns, chunk).transpose(1, 0, 2)
    mc = (mask.reshape(b, ns, chunk).transpose(1, 0, 2)
          if mask is not None else jnp.ones((ns, b, chunk), bool))

    @functools.partial(jax.checkpoint, policy=None)
    def step(carry, inp):
        tot, cnt = carry
        xb, yb, mb = inp
        logits = shd.c(
            jnp.einsum("bsd,vd->bsv", xb, embed,
                       preferred_element_type=jnp.float32),
            shd.dp, None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mb
        return (tot + nll.sum(), cnt + mb.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
