"""RWKV-6 "Finch" blocks: time-mix with data-dependent decay + channel-mix.

Recurrence (per head, k/v dims = hd):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + (u ⊙ k_t)^T v_t)
with w_t = exp(-exp(w0 + lora(x_t)))  (data-dependent decay, per channel).

Training/prefill uses an exact *chunked* evaluation: within a chunk of
length c the pairwise decay products exp(Λ_{t-1} - Λ_j) (j <= t-1, Λ =
cumsum log w) are always <= 1, so no overflow is possible — unlike the
factorized exp(Λ_t)·exp(-Λ_j) form, which this implementation deliberately
avoids (see DESIGN.md).  Cross-chunk state is carried by a lax.scan.

Decode is the O(1) recurrent step on the state — this is what makes
long_500k runnable for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .param import PD
from .nn_ops import rms_norm


LORA_R = 64


def rwkv_heads(cfg):
    assert cfg.d_model % cfg.rwkv_head_dim == 0
    return cfg.d_model // cfg.rwkv_head_dim


def time_mix_defs(cfg, lead=()):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = rwkv_heads(cfg)
    la = ("layers",) if lead else ()
    def m(shape, axes, **kw):
        return PD(lead + shape, la + axes, **kw)
    return {
        "mu": m((5, d), (None, "embed")),           # token-shift lerp r,k,v,w,g
        "w0": m((d,), ("embed",), init="zeros"),
        "wA": m((d, LORA_R), ("embed", None)),
        "wB": m((LORA_R, d), (None, "embed")),
        "Wr": m((d, d), ("embed", "heads")),
        "Wk": m((d, d), ("embed", "heads")),
        "Wv": m((d, d), ("embed", "heads")),
        "Wg": m((d, d), ("embed", "heads")),
        "Wo": m((d, d), ("heads", "embed")),
        "u": m((h, hd), ("heads", None), init="zeros"),
        "ln_y": m((d,), ("embed",), init="ones"),
    }


def channel_mix_defs(cfg, lead=()):
    d, f = cfg.d_model, cfg.d_ff
    la = ("layers",) if lead else ()
    def m(shape, axes, **kw):
        return PD(lead + shape, la + axes, **kw)
    return {
        "mu": m((2, d), (None, "embed")),
        "Wk": m((d, f), ("embed", "ff")),
        "Wv": m((f, d), ("ff", "embed")),
        "Wr": m((d, d), ("embed", "embed")),
    }


def _shift(x, prev):
    """x [B,S,D], prev [B,D] = last token of previous segment."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _projections(p, x, xprev):
    def lerp(i):
        return x + (xprev - x) * p["mu"][i]
    r, k, v, w_in, g = (lerp(i) for i in range(5))
    logw = -jnp.exp(p["w0"] + jnp.tanh(w_in @ p["wA"]) @ p["wB"])
    logw = jnp.clip(logw, -50.0, -1e-4).astype(jnp.float32)
    return r @ p["Wr"], k @ p["Wk"], v @ p["Wv"], logw, jax.nn.silu(g @ p["Wg"])


def time_mix_chunked(cfg, p, x, state, chunk=None):
    """x [B,S,D]; state (S [B,H,hd,hd] f32, prev_x [B,D]).

    Returns (y [B,S,D], new_state)."""
    b, s_real, d = x.shape
    hd = cfg.rwkv_head_dim
    h = rwkv_heads(cfg)
    c = min(chunk or cfg.rwkv_chunk, s_real)
    S0, prev_x = state
    x_last = x[:, -1]

    r, k, v, logw, g = _projections(p, x, _shift(x, prev_x))
    s = s_real
    if s % c:
        # pad tail: k=0 and logw=0 make padded steps state-neutral
        pad = c - s % c
        z = lambda t, fill=0.0: jnp.pad(t, ((0, 0), (0, pad), (0, 0)),
                                        constant_values=fill)
        r, k, v, g = z(r), z(k), z(v), z(g)
        logw = z(logw)
        s = s + pad
    nc = s // c

    def heads(z):  # [B,S,D] -> [nc, B, H, c, hd]
        return (z.reshape(b, nc, c, h, hd).transpose(1, 0, 3, 2, 4))
    rh, kh, vh = heads(r), heads(k), heads(v)
    lw = heads(logw)                                  # [nc,B,H,c,hd] f32
    u = p["u"].astype(jnp.float32)

    def chunk_step(S, inp):
        rc, kc, vc, lwc = inp                          # [B,H,c,hd]
        rc32, kc32, vc32 = (z.astype(jnp.float32) for z in (rc, kc, vc))
        lam = jnp.cumsum(lwc, axis=2)                  # inclusive Λ_t
        lam_ex = lam - lwc                             # exclusive Λ_{t-1}
        # state contribution: (r_t ⊙ e^{Λ_{t-1}}) S_prev
        rS = jnp.einsum("bhtd,bhde->bhte", rc32 * jnp.exp(lam_ex), S)
        # intra-chunk: A[t,j] = Σ_d r_t k_j e^{Λ_{t-1}-Λ_j}, j < t.
        # For j = t-1 the difference is exactly 0 in real arithmetic but
        # can round to +eps in fp32 cumsums — clamp, don't mask (j >= t is
        # excluded by the tri mask below).
        diff = lam_ex[:, :, :, None, :] - lam[:, :, None, :, :]  # [B,H,t,j,d]
        decay = jnp.exp(jnp.minimum(diff, 0.0))
        a = jnp.einsum("bhtd,bhjd,bhtjd->bhtj", rc32, kc32, decay)
        tri = jnp.tril(jnp.ones((c, c), bool), -1)
        a = jnp.where(tri[None, None], a, 0.0)
        diag = jnp.einsum("bhtd,hd,bhtd->bht", rc32, u, kc32)
        y = rS + jnp.einsum("bhtj,bhjd->bhtd", a, vc32) \
            + diag[..., None] * vc32
        # new state: e^{Λ_c} ⊙ S + Σ_j e^{Λ_c - Λ_j} k_j ⊗ v_j
        lam_c = lam[:, :, -1:, :]                      # [B,H,1,d]
        kdec = kc32 * jnp.exp(lam_c - lam)
        S_new = jnp.exp(lam_c[:, :, 0, :, None]) * S \
            + jnp.einsum("bhjd,bhje->bhde", kdec, vc32)
        return S_new, y

    S_fin, ys = jax.lax.scan(chunk_step, S0, (rh, kh, vh, lw))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, d)[:, :s_real]
    y = rms_norm(y.astype(x.dtype), p["ln_y"], cfg.norm_eps) * g[:, :s_real]
    out = y @ p["Wo"]
    return out, (S_fin, x_last)


def time_mix_step(cfg, p, x, state):
    """Single-token decode: x [B,D] -> (y [B,D], new_state)."""
    b, d = x.shape
    hd = cfg.rwkv_head_dim
    h = rwkv_heads(cfg)
    S0, prev_x = state
    r, k, v, logw, g = _projections(p, x[:, None], prev_x[:, None])
    def hs(z):
        return z.reshape(b, h, hd).astype(jnp.float32)
    rh, kh, vh = hs(r[:, 0]), hs(k[:, 0]), hs(v[:, 0])
    w = jnp.exp(logw[:, 0].reshape(b, h, hd))
    u = p["u"].astype(jnp.float32)
    kv = jnp.einsum("bhd,bhe->bhde", kh, vh)
    y = jnp.einsum("bhd,bhde->bhe", rh, S0 + u[None, :, :, None] * kv)
    S_new = w[..., None] * S0 + kv
    y = y.reshape(b, d).astype(x.dtype)
    y = rms_norm(y, p["ln_y"], cfg.norm_eps) * g[:, 0]
    return y @ p["Wo"], (S_new, x)


def channel_mix(cfg, p, x, prev_x):
    """x [B,S,D], prev_x [B,D] -> (y, last_x)."""
    xprev = _shift(x, prev_x)
    xk = x + (xprev - x) * p["mu"][0]
    xr = x + (xprev - x) * p["mu"][1]
    kk = jnp.square(jax.nn.relu(xk @ p["Wk"]))
    return jax.nn.sigmoid(xr @ p["Wr"]) * (kk @ p["Wv"]), x[:, -1]


def channel_mix_step(cfg, p, x, prev_x):
    xk = x + (prev_x - x) * p["mu"][0]
    xr = x + (prev_x - x) * p["mu"][1]
    kk = jnp.square(jax.nn.relu(xk @ p["Wk"]))
    return jax.nn.sigmoid(xr @ p["Wr"]) * (kk @ p["Wv"]), x
