"""Composable model assembly for every assigned architecture family.

One scan "block" covers `moe_every` layers (so interleaved-MoE models stay
scan-uniform); block params are stacked on a leading 'layers' dim and the
trunk is a lax.scan over blocks (small HLO, XLA can pipeline ZeRO-3
gathers), with optional per-block remat.

Entry points (all pure functions of (cfg, params, ...)):
  loss_fn       train loss (chunked CE / masked CE for encoders)
  prefill       full-sequence forward producing decode caches + last logits
  decode_step   one token with cache/state (the serve_step of decode shapes)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .param import PD
from .nn_ops import (Sharder, NO_SHARD, rms_norm, rotary, ffn,
                     flash_attention, decode_attention,
                     chunked_cross_entropy)
from . import moe as moe_mod
from . import rwkv6 as rwkv_mod
from . import ssm as ssm_mod


# ====================================================================== #
# Parameter definitions
# ====================================================================== #
def n_blocks(cfg) -> int:
    if cfg.family == "moe":
        assert cfg.num_layers % cfg.moe_every == 0
        return cfg.num_layers // cfg.moe_every
    return cfg.num_layers


def layers_per_block(cfg) -> int:
    return cfg.moe_every if cfg.family == "moe" else 1


def _attn_defs(cfg, lead):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    la = ("layers",) if lead else ()
    def m(shape, axes, **kw):
        return PD(lead + shape, la + axes, **kw)
    defs = {
        "norm": m((d,), ("embed",), init="ones"),
        "wq": m((d, h * hd), ("embed", "heads")),
        "wk": m((d, kv * hd), ("embed", "kv")),
        "wv": m((d, kv * hd), ("embed", "kv")),
        "wo": m((h * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = m((h * hd,), ("heads",), init="zeros")
        defs["bk"] = m((kv * hd,), ("kv",), init="zeros")
        defs["bv"] = m((kv * hd,), ("kv",), init="zeros")
    return defs


def _ffn_defs(cfg, lead):
    d, f = cfg.d_model, cfg.d_ff
    la = ("layers",) if lead else ()
    def m(shape, axes, **kw):
        return PD(lead + shape, la + axes, **kw)
    defs = {
        "norm": m((d,), ("embed",), init="ones"),
        "w1": m((d, f), ("embed", "ff")),
        "w2": m((f, d), ("ff", "embed")),
    }
    if cfg.gated_ffn:
        defs["w3"] = m((d, f), ("embed", "ff"))
    return defs


def block_defs(cfg):
    nb = n_blocks(cfg)
    lead = (nb,)
    fam = cfg.family
    if fam == "rwkv6":
        return {
            "tm": rwkv_mod.time_mix_defs(cfg, lead),
            "tm_norm": PD(lead + (cfg.d_model,), ("layers", "embed"),
                          init="ones"),
            "cm": rwkv_mod.channel_mix_defs(cfg, lead),
            "cm_norm": PD(lead + (cfg.d_model,), ("layers", "embed"),
                          init="ones"),
        }
    if fam == "hybrid":
        return {
            "attn": _attn_defs(cfg, lead),
            "ssm": ssm_mod.ssm_defs(cfg, lead),
            "ssm_norm": PD(lead + (cfg.d_model,), ("layers", "embed"),
                           init="ones"),
            "mlp": _ffn_defs(cfg, lead),
        }
    if fam == "moe":
        out = {}
        for i in range(cfg.moe_every):
            out[f"attn{i}"] = _attn_defs(cfg, lead)
            if i == cfg.moe_every - 1:
                out[f"moe{i}"] = moe_mod.moe_param_defs(cfg, nb)
                out[f"moe{i}"]["norm"] = PD(
                    lead + (cfg.d_model,), ("layers", "embed"), init="ones")
            else:
                out[f"mlp{i}"] = _ffn_defs(cfg, lead)
        return out
    # dense / vlm / encoder
    return {"attn": _attn_defs(cfg, lead), "mlp": _ffn_defs(cfg, lead)}


def model_defs(cfg):
    d, v = cfg.d_model, cfg.vocab_size
    defs = {
        "blocks": block_defs(cfg),
        "final_norm": PD((d,), ("embed",), init="ones"),
    }
    if cfg.frontend != "audio":
        defs["embed"] = PD((v, d), ("vocab", "embed"))
    if not cfg.tie_embeddings or cfg.frontend == "audio":
        defs["unembed"] = PD((v, d), ("vocab", "embed"))
    if cfg.num_meta_tokens:
        defs["meta"] = PD((cfg.num_meta_tokens, d), (None, "embed"))
    return defs


def unembed_matrix(cfg, params):
    return params.get("unembed", params.get("embed"))


def prefix_len(cfg) -> int:
    return cfg.num_prefix_tokens + cfg.num_meta_tokens


# ====================================================================== #
# Block forward (full sequence: train / prefill)
# ====================================================================== #
def _attention_seq(cfg, p, x, shd, *, make_cache=False, cache_len=0):
    """Full-sequence attention sublayer.  Returns (y, cache | None)."""
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    hin = rms_norm(x, p["norm"], cfg.norm_eps)
    q = hin @ p["wq"]
    k = hin @ p["wk"]
    v = hin @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    pos = jnp.arange(s)
    q = rotary(q, pos[None, None], cfg.rope_theta)
    k = rotary(k, pos[None, None], cfg.rope_theta)
    if shd.tp_heads:
        q = shd.c(q, shd.dp, "model", None, None)
        k = shd.c(k, shd.dp, "model" if shd.tp_kv else None, None, None)
        v = shd.c(v, shd.dp, "model" if shd.tp_kv else None, None, None)
    else:   # context parallel: shard query sequence, replicate KV
        q = shd.c(q, shd.dp, None, "model", None)
        k = shd.c(k, shd.dp, None, None, None)
        v = shd.c(v, shd.dp, None, None, None)
    y = flash_attention(
        q, k, v, causal=cfg.causal,
        window=cfg.window if cfg.attn_type == "sliding" else 0,
        n_meta=cfg.num_meta_tokens, shd=shd)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    out = y @ p["wo"]
    cache = None
    if make_cache:
        cl = cache_len or s
        ck = jnp.zeros((b, kv, cl, hd), k.dtype)
        cv = jnp.zeros((b, kv, cl, hd), v.dtype)
        if cfg.attn_type == "sliding":
            # meta region + ring region, entries placed at their decode
            # write-slots so prefill and decode_step stay consistent
            n_meta = cfg.num_meta_tokens
            w = cl - n_meta
            take = min(s - n_meta, w)
            ck = ck.at[:, :, :n_meta].set(k[:, :, :n_meta])
            cv = cv.at[:, :, :n_meta].set(v[:, :, :n_meta])
            p_arr = jnp.arange(s - take, s)
            slots = n_meta + (p_arr - n_meta) % w
            ck = ck.at[:, :, slots].set(k[:, :, p_arr])
            cv = cv.at[:, :, slots].set(v[:, :, p_arr])
        else:
            take = min(s, cl)
            ck = ck.at[:, :, :take].set(k[:, :, s - take:])
            cv = cv.at[:, :, :take].set(v[:, :, s - take:])
        cache = {"k": shd.c(ck, shd.dp, None, "model", None),
                 "v": shd.c(cv, shd.dp, None, "model", None)}
    return out, cache


def _ffn_seq(cfg, p, x, shd):
    hin = rms_norm(x, p["norm"], cfg.norm_eps)
    return ffn(hin, p["w1"], p["w2"], p.get("w3"))


def block_forward(cfg, bp, x, shd, *, make_cache=False, cache_len=0):
    """One scan block over the full sequence.

    Returns (x, (cache, metrics))."""
    fam = cfg.family
    metrics = {}
    cache = {}
    if fam == "rwkv6":
        b = x.shape[0]
        hd, d = cfg.rwkv_head_dim, cfg.d_model
        h = rwkv_mod.rwkv_heads(cfg)
        s0 = (jnp.zeros((b, h, hd, hd), jnp.float32), jnp.zeros((b, d), x.dtype))
        y, (s_fin, prev_tm) = rwkv_mod.time_mix_chunked(
            cfg, bp["tm"], rms_norm(x, bp["tm_norm"], cfg.norm_eps), s0)
        x = x + y
        y, prev_cm = rwkv_mod.channel_mix(
            cfg, bp["cm"], rms_norm(x, bp["cm_norm"], cfg.norm_eps),
            jnp.zeros((b, d), x.dtype))
        x = x + y
        if make_cache:
            cache = {"S": s_fin, "prev_tm": prev_tm, "prev_cm": prev_cm}
    elif fam == "hybrid":
        y_attn, c = _attention_seq(cfg, bp["attn"], x, shd,
                                   make_cache=make_cache, cache_len=cache_len)
        hin = rms_norm(x, bp["ssm_norm"], cfg.norm_eps)
        b = x.shape[0]
        h0 = jnp.zeros((b, cfg.ssm_heads, cfg.d_model // cfg.ssm_heads,
                        cfg.ssm_state), jnp.float32)
        y_ssm, h_fin = ssm_mod.ssm_scan(cfg, bp["ssm"], hin, h0)
        x = x + y_attn + y_ssm
        x = x + _ffn_seq(cfg, bp["mlp"], x, shd)
        if make_cache:
            cache = {**(c or {}), "h": h_fin}
    elif fam == "moe":
        for i in range(cfg.moe_every):
            y, c = _attention_seq(cfg, bp[f"attn{i}"], x, shd,
                                  make_cache=make_cache, cache_len=cache_len)
            x = x + y
            if make_cache:
                cache[f"k{i}"] = c["k"]
                cache[f"v{i}"] = c["v"]
            if i == cfg.moe_every - 1:
                mp = bp[f"moe{i}"]
                hin = rms_norm(x, mp["norm"], cfg.norm_eps)
                y, m = moe_mod.moe_ffn(cfg, mp, hin, shd)
                metrics.update(m)
                x = x + y
            else:
                x = x + _ffn_seq(cfg, bp[f"mlp{i}"], x, shd)
    else:  # dense / vlm / encoder
        y, c = _attention_seq(cfg, bp["attn"], x, shd,
                              make_cache=make_cache, cache_len=cache_len)
        x = x + y
        x = x + _ffn_seq(cfg, bp["mlp"], x, shd)
        if make_cache:
            cache = c or {}
    return x, (cache, metrics)


# ====================================================================== #
# Trunk
# ====================================================================== #
def embed_inputs(cfg, params, batch, shd: Sharder):
    """Build x0 [B, prefix + S, D] from the batch dict."""
    if cfg.frontend == "audio":
        x = batch["frames"].astype(cfg_dtype(cfg))
    else:
        emb = params["embed"]
        x = emb[batch["tokens"]].astype(cfg_dtype(cfg))
        if cfg.frontend == "vision":
            x = jnp.concatenate(
                [batch["patches"].astype(x.dtype), x], axis=1)
    if cfg.num_meta_tokens:
        b = x.shape[0]
        meta = jnp.broadcast_to(params["meta"][None].astype(x.dtype),
                                (b, cfg.num_meta_tokens, x.shape[-1]))
        x = jnp.concatenate([meta, x], axis=1)
    return shd.c(x, shd.dp, None, None)


def cfg_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def trunk(cfg, params, x, shd, *, remat=True, make_cache=False,
          cache_len=0):
    """Scan over blocks.  Returns (x, caches, metrics)."""
    def body(carry, bp):
        y, (cache, m) = block_forward(cfg, bp, carry, shd,
                                      make_cache=make_cache,
                                      cache_len=cache_len)
        return y, (cache, m)

    f = jax.checkpoint(body) if remat else body
    x, (caches, metrics) = jax.lax.scan(f, x, params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    metrics = {k: v.mean() for k, v in metrics.items()} if metrics else {}
    return x, caches, metrics


# ====================================================================== #
# Losses
# ====================================================================== #
def loss_fn(cfg, params, batch, shd: Sharder = NO_SHARD, *, remat=True):
    """Returns (loss, metrics)."""
    x = embed_inputs(cfg, params, batch, shd)
    x, _, metrics = trunk(cfg, params, x, shd, remat=remat)
    pl = prefix_len(cfg)
    if pl:
        x = x[:, pl:]
    un = unembed_matrix(cfg, params).astype(x.dtype)
    mask = batch.get("mask")
    ce = chunked_cross_entropy(x, un, batch["labels"],
                               chunk=cfg.loss_chunk, shd=shd, mask=mask)
    loss = ce
    if "moe_aux" in metrics:
        loss = loss + 0.01 * metrics["moe_aux"]
    metrics = {"ce": ce, **metrics}
    return loss, metrics


# ====================================================================== #
# Prefill & decode
# ====================================================================== #
def init_slot_positions(cfg, cache_len: int, filled: int):
    pos = jnp.arange(cache_len)
    return jnp.where(pos < filled, pos, -1).astype(jnp.int32)


def prefill(cfg, params, batch, shd: Sharder = NO_SHARD, *,
            cache_len: int = 0):
    """Full-sequence forward; returns (last_logits, cache_tree)."""
    x = embed_inputs(cfg, params, batch, shd)
    s_total = x.shape[1]
    cache_len = cache_len or s_total
    x, caches, _ = trunk(cfg, params, x, shd, remat=False,
                         make_cache=True, cache_len=cache_len)
    un = unembed_matrix(cfg, params).astype(x.dtype)
    last = x[:, -1]
    logits = shd.c(jnp.einsum("bd,vd->bv", last, un,
                              preferred_element_type=jnp.float32),
                   shd.dp, "model")
    if cfg.family in ("rwkv6",):
        slot_pos = jnp.zeros((0,), jnp.int32)
    elif cfg.attn_type == "sliding":
        n_meta = cfg.num_meta_tokens
        w = cache_len - n_meta
        take = min(s_total - n_meta, w)
        slot_pos = jnp.full((cache_len,), -1, jnp.int32)
        slot_pos = slot_pos.at[:n_meta].set(jnp.arange(n_meta))
        p_arr = jnp.arange(s_total - take, s_total)
        slot_pos = slot_pos.at[n_meta + (p_arr - n_meta) % w].set(p_arr)
    else:
        take = min(s_total, cache_len)
        slot_pos = init_slot_positions(cfg, cache_len, take)
        slot_pos = jnp.where(slot_pos >= 0,
                             slot_pos + (s_total - take), -1)
    cache = {"blocks": caches, "slot_pos": slot_pos,
             "pos": jnp.asarray(s_total, jnp.int32)}
    return logits, cache


def _write_slot(cfg, pos, cache_len):
    """Slot to write position `pos` into (ring for sliding windows)."""
    if cfg.attn_type == "sliding":
        n_meta = cfg.num_meta_tokens
        w = cache_len - n_meta
        return jnp.where(pos < n_meta, pos, n_meta + (pos - n_meta) % w)
    return jnp.minimum(pos, cache_len - 1)


def _attention_step(cfg, p, x, cache, slot_pos, pos, slot, shd):
    b, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    hin = rms_norm(x, p["norm"], cfg.norm_eps)
    q = hin @ p["wq"]
    k = hin @ p["wk"]
    v = hin @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, h, hd)
    k = k.reshape(b, kv, hd)
    v = v.reshape(b, kv, hd)
    q = rotary(q, jnp.full((b, h), pos), cfg.rope_theta)
    k = rotary(k, jnp.full((b, kv), pos), cfg.rope_theta)
    ck = jax.lax.dynamic_update_index_in_dim(cache["k"], k, slot, 2)
    cv = jax.lax.dynamic_update_index_in_dim(cache["v"], v, slot, 2)
    y = decode_attention(
        q, ck, cv, slot_pos, pos,
        window=cfg.window if cfg.attn_type == "sliding" else 0,
        n_meta=cfg.num_meta_tokens, shd=shd)
    return y.reshape(b, h * hd) @ p["wo"], {"k": ck, "v": cv}


def decode_step(cfg, params, cache, tokens, shd: Sharder = NO_SHARD):
    """One decode step.  tokens [B] int32.  Returns (logits, new cache)."""
    pos = cache["pos"]
    emb = params["embed"]
    x = emb[tokens].astype(cfg_dtype(cfg))
    x = shd.c(x, shd.dp, None)

    cache_len = 0
    if cfg.family != "rwkv6":
        cache_len = _first_attn_len(cache["blocks"])
    slot = _write_slot(cfg, pos, cache_len) if cache_len else jnp.int32(0)
    slot_pos = cache["slot_pos"]
    if cache_len:
        slot_pos = slot_pos.at[slot].set(pos)

    def body(x, inp):
        bp, bc = inp
        new_c = dict(bc)
        fam = cfg.family
        if fam == "rwkv6":
            st = (bc["S"], bc["prev_tm"])
            y, (s_new, prev_tm) = rwkv_mod.time_mix_step(
                cfg, bp["tm"], rms_norm(x, bp["tm_norm"], cfg.norm_eps), st)
            x = x + y
            y, prev_cm = rwkv_mod.channel_mix_step(
                cfg, bp["cm"], rms_norm(x, bp["cm_norm"], cfg.norm_eps),
                bc["prev_cm"])
            x = x + y
            new_c = {"S": s_new, "prev_tm": prev_tm, "prev_cm": prev_cm}
        elif fam == "hybrid":
            y_attn, kc = _attention_step(cfg, bp["attn"], x,
                                         {"k": bc["k"], "v": bc["v"]},
                                         slot_pos, pos, slot, shd)
            hin = rms_norm(x, bp["ssm_norm"], cfg.norm_eps)
            y_ssm, h_new = ssm_mod.ssm_step(cfg, bp["ssm"], hin, bc["h"])
            x = x + y_attn + y_ssm
            x = x + _ffn_step(cfg, bp["mlp"], x)
            new_c = {**kc, "h": h_new}
        elif fam == "moe":
            new_c = {}
            for i in range(cfg.moe_every):
                y, kc = _attention_step(cfg, bp[f"attn{i}"], x,
                                        {"k": bc[f"k{i}"], "v": bc[f"v{i}"]},
                                        slot_pos, pos, slot, shd)
                x = x + y
                new_c[f"k{i}"] = kc["k"]
                new_c[f"v{i}"] = kc["v"]
                if i == cfg.moe_every - 1:
                    mp = bp[f"moe{i}"]
                    hin = rms_norm(x, mp["norm"], cfg.norm_eps)
                    y, _ = moe_mod.moe_ffn(cfg, mp, hin[:, None], shd)
                    x = x + y[:, 0]
                else:
                    x = x + _ffn_step(cfg, bp[f"mlp{i}"], x)
        else:
            y, kc = _attention_step(cfg, bp["attn"], x,
                                    {"k": bc["k"], "v": bc["v"]},
                                    slot_pos, pos, slot, shd)
            x = x + y
            x = x + _ffn_step(cfg, bp["mlp"], x)
            new_c = kc
        return x, new_c

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"],
                                           cache["blocks"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    un = unembed_matrix(cfg, params).astype(x.dtype)
    logits = shd.c(jnp.einsum("bd,vd->bv", x, un,
                              preferred_element_type=jnp.float32),
                   shd.dp, "model")
    new_cache = {"blocks": new_blocks, "slot_pos": slot_pos,
                 "pos": pos + 1}
    return logits, new_cache


def _ffn_step(cfg, p, x):
    hin = rms_norm(x, p["norm"], cfg.norm_eps)
    return ffn(hin, p["w1"], p["w2"], p.get("w3"))


def _first_attn_len(blocks) -> int:
    """Static cache length from any k-cache leaf [nB, B, kv, C, hd]."""
    for key in ("k", "k0"):
        node = blocks.get(key) if isinstance(blocks, dict) else None
        if node is not None:
            return node.shape[3]
    # search nested
    for v in blocks.values():
        if isinstance(v, dict):
            r = _first_attn_len(v)
            if r:
                return r
    return 0


# ====================================================================== #
# Cache construction (decode-shape dry-run inputs)
# ====================================================================== #
def cache_defs(cfg, batch: int, cache_len: int):
    """PD tree describing a fully-populated decode cache."""
    nb = n_blocks(cfg)
    kv, hd = cfg.num_kv_heads, cfg.hd
    d = cfg.d_model

    def kv_pd():
        return PD((nb, batch, kv, cache_len, hd),
                  ("layers", "batch", None, "cache_seq", None))

    fam = cfg.family
    if fam == "rwkv6":
        rhd = cfg.rwkv_head_dim
        h = rwkv_mod.rwkv_heads(cfg)
        blocks = {
            "S": PD((nb, batch, h, rhd, rhd),
                    ("layers", "batch", "heads", None, None)),
            "prev_tm": PD((nb, batch, d), ("layers", "batch", "embed")),
            "prev_cm": PD((nb, batch, d), ("layers", "batch", "embed")),
        }
        slot = PD((0,), (None,))
    elif fam == "hybrid":
        hd_ssm = d // cfg.ssm_heads
        blocks = {
            "k": kv_pd(), "v": kv_pd(),
            "h": PD((nb, batch, cfg.ssm_heads, hd_ssm, cfg.ssm_state),
                    ("layers", "batch", None, None, None)),
        }
        slot = PD((cache_len,), ("cache_seq",))
    elif fam == "moe":
        blocks = {}
        for i in range(cfg.moe_every):
            blocks[f"k{i}"] = kv_pd()
            blocks[f"v{i}"] = kv_pd()
        slot = PD((cache_len,), ("cache_seq",))
    else:
        blocks = {"k": kv_pd(), "v": kv_pd()}
        slot = PD((cache_len,), ("cache_seq",))
    return {"blocks": blocks, "slot_pos": slot, "pos": PD((), ())}
