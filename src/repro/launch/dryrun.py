"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, record memory / cost / collective statistics.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every supported cell, both meshes
  python -m repro.launch.dryrun --all --mesh single
Results are appended incrementally to --out (JSON), keyed by cell id.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import (the docstring is not
# code): jax locks the device count at first init.

import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from ..configs import ARCHS, SHAPES, get_config, supported_shapes
from ..configs.base import TrainConfig, InputShape
from ..models import api
from .mesh import make_production_mesh
from . import hlo_analysis


# Per-arch training settings chosen for single-pod memory feasibility
# (§Dry-run in EXPERIMENTS.md justifies each).
TRAIN_SETTINGS: dict[str, dict] = {
    "llama4-maverick-400b-a17b": dict(zero3=True, microbatch=8,
                                      opt_state_dtype="bfloat16",
                                      grad_dtype="bfloat16",
                                      param_dtype="bfloat16"),
    "starcoder2-15b": dict(zero3=True, microbatch=8),
    "granite-moe-1b-a400m": dict(grad_dtype="bfloat16"),
    "minitron-8b": dict(zero3=True, microbatch=4),
    "rwkv6-7b": dict(zero3=True, microbatch=4,
                     cfg_overrides={"rwkv_chunk": 64}),
    "paligemma-3b": dict(microbatch=2),
    "hubert-xlarge": dict(microbatch=2),
    "hymba-1.5b": dict(microbatch=2),
    "stablelm-1.6b": dict(microbatch=2),
}


def cell_settings(arch: str) -> dict:
    s = dict(zero3=False, microbatch=1, opt_state_dtype="float32",
             grad_dtype="float32", param_dtype=None)
    s.update(TRAIN_SETTINGS.get(arch, {}))
    return s


# ---------------------------------------------------------------------- #
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in post-SPMD HLO."""
    stats: dict[str, dict] = {c: {"count": 0, "bytes": 0}
                              for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for c in _COLLECTIVES:
            idx = stripped.find(f" {c}(")
            if idx < 0 or "start" in stripped[:idx].split("=")[0]:
                # count -start ops once (skip -done)
                idx2 = stripped.find(f" {c}-start(")
                if idx2 < 0:
                    continue
                idx = idx2
                c_open = stripped.index("(", idx)
            else:
                c_open = stripped.index("(", idx)
            operands = stripped[c_open:]
            shapes = _SHAPE_RE.findall(operands)
            if not shapes:  # fall back to result shape
                shapes = _SHAPE_RE.findall(stripped[:idx])
            b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
            stats[c]["count"] += 1
            stats[c]["bytes"] += b
            break
    stats["total_bytes"] = sum(v["bytes"] for v in stats.values()
                               if isinstance(v, dict))
    return stats


def memory_stats(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
        if out:
            out["peak_estimate_bytes"] = (
                out.get("argument_size_in_bytes", 0)
                + out.get("output_size_in_bytes", 0)
                + out.get("temp_size_in_bytes", 0)
                - out.get("alias_size_in_bytes", 0))
    except Exception as e:                                   # noqa: BLE001
        out["error"] = str(e)
    return out


def cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception as e:                                   # noqa: BLE001
        return {"error": str(e)}


# ---------------------------------------------------------------------- #
def ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, PS))


def lower_cell(arch: str, shape_name: str, mesh):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    st = cell_settings(arch)
    if st.get("param_dtype"):
        cfg = dataclasses.replace(cfg, param_dtype=st["param_dtype"])
    if st.get("cfg_overrides"):
        cfg = dataclasses.replace(cfg, **st["cfg_overrides"])

    if shape.kind == "train":
        tcfg = TrainConfig(microbatch=st["microbatch"], zero3=st["zero3"],
                           opt_state_dtype=st["opt_state_dtype"],
                           grad_dtype=st["grad_dtype"])
        fn = api.make_train_step(cfg, tcfg, mesh)
        p_specs = api.model_pspecs(cfg, mesh, zero3=st["zero3"])
        o_specs = api.opt_pspecs(cfg, mesh, zero3=st["zero3"])
        b_specs = api.batch_pspecs(cfg, shape, mesh)
        args = (api.abstract_model(cfg), api.opt_abstract(cfg, tcfg),
                api.batch_abstract(cfg, shape),
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (ns(mesh, p_specs), ns(mesh, o_specs), ns(mesh, b_specs),
                 NamedSharding(mesh, PS()))
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=(0, 1))
    elif shape.kind == "prefill":
        cache_len = shape.seq_len + api.DECODE_PAD \
            if cfg.attn_type != "sliding" else api.decode_cache_len(cfg, shape)
        fn = api.make_prefill_fn(cfg, mesh, cache_len=cache_len)
        p_specs = api.model_pspecs(cfg, mesh, zero3=st["zero3"])
        b_specs = api.batch_pspecs(cfg, shape, mesh)
        args = (api.abstract_model(cfg), api.batch_abstract(cfg, shape))
        in_sh = (ns(mesh, p_specs), ns(mesh, b_specs))
        jitted = jax.jit(fn, in_shardings=in_sh)
    else:  # decode
        fn = api.make_decode_fn(cfg, mesh)
        p_specs = api.model_pspecs(cfg, mesh, zero3=st["zero3"])
        c_specs = api.cache_pspecs(cfg, mesh, shape.global_batch,
                                   api.decode_cache_len(cfg, shape))
        b_specs = api.batch_pspecs(cfg, shape, mesh)
        args = (api.abstract_model(cfg), api.cache_abstract(cfg, shape),
                api.batch_abstract(cfg, shape)["tokens"])
        in_sh = (ns(mesh, p_specs), ns(mesh, c_specs),
                 NamedSharding(mesh, b_specs["tokens"]))
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=(1,))
    return jitted, args


def model_flops(arch: str, shape: InputShape) -> float:
    """Analytic 'useful' FLOPs for the MODEL_FLOPS/HLO_FLOPs ratio."""
    cfg = get_config(arch)
    n = cfg.num_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": list(mesh.devices.shape),
           "settings": cell_settings(arch)}
    t0 = time.time()
    with mesh:
        jitted, args = lower_cell(arch, shape_name, mesh)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
    rec["memory"] = memory_stats(compiled)
    rec["cost"] = cost_stats(compiled)
    txt = compiled.as_text()
    rec["collectives"] = collective_stats(txt)          # static text counts
    rec["analysis"] = hlo_analysis.analyze(txt)         # trip-count-aware
    rec["hlo_bytes"] = len(txt)
    rec["model_flops"] = model_flops(arch, SHAPES[shape_name])
    rec["status"] = "ok"
    return rec


# ---------------------------------------------------------------------- #
def all_cells(mesh_kinds=("single", "multi")):
    for arch, cfg in ARCHS.items():
        for shape in supported_shapes(cfg):
            for mk in mesh_kinds:
                yield arch, shape.name, mk


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    out_path = Path(args.out)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    if args.all:
        cells = list(all_cells(("single", "multi") if args.both_meshes
                               or args.mesh is None else (args.mesh,)))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, args.mesh)]

    for arch, shape, mk in cells:
        key = f"{arch}|{shape}|{mk}"
        if args.skip_done and results.get(key, {}).get("status") == "ok":
            print(f"[skip] {key}")
            continue
        print(f"[cell] {key} ...", flush=True)
        try:
            rec = run_cell(arch, shape, mk)
            mem = rec["memory"].get("peak_estimate_bytes")
            print(f"  ok: lower {rec['lower_s']}s compile {rec['compile_s']}s"
                  f" flops={rec['cost'].get('flops', 0):.3g}"
                  f" peak/dev={mem/2**30 if mem else -1:.2f}GiB"
                  f" coll={rec['collectives']['total_bytes']/2**20:.1f}MiB",
                  flush=True)
        except Exception as e:                               # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "mesh": mk,
                   "status": "error", "error": str(e),
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"  ERROR: {e}", flush=True)
        results[key] = rec
        out_path.write_text(json.dumps(results, indent=1))
    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    print(f"done: {n_ok}/{len(results)} cells ok -> {out_path}")


if __name__ == "__main__":
    main()


# ---------------------------------------------------------------------- #
# Extra (beyond the mandated arch cells): the paper's own check phase on
# the production mesh — node rows of the NI tensor sharded over 'data',
# intervals replicated, per-shard interval counting, global candidate
# count via psum.  Proves the RDF-h engine's heavy phase distributes.
# ---------------------------------------------------------------------- #
def lower_rdfh_check(mesh, n_nodes: int = 1 << 22, cap: int = 256,
                     j: int = 8):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as PS
    from ..kernels import ref as kref

    def check_step(ids, lo, hi, need):
        cnt = kref.interval_count_ref(ids, lo, hi)
        ok = (cnt >= need[None, :]).all(axis=1)
        return ok, ok.sum()

    args = (jax.ShapeDtypeStruct((n_nodes, cap), jnp.int32),
            jax.ShapeDtypeStruct((j,), jnp.int32),
            jax.ShapeDtypeStruct((j,), jnp.int32),
            jax.ShapeDtypeStruct((j,), jnp.int32))
    in_sh = (NamedSharding(mesh, PS(("pod", "data")
                                    if "pod" in mesh.axis_names
                                    else "data")),
             NamedSharding(mesh, PS()), NamedSharding(mesh, PS()),
             NamedSharding(mesh, PS()))
    return jax.jit(check_step, in_shardings=in_sh), args


def run_rdfh_cell(mesh_kind: str) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": "rdfh-check-phase", "shape": "n4M_cap256",
           "mesh": mesh_kind, "settings": {}}
    t0 = time.time()
    with mesh:
        jitted, args = lower_rdfh_check(mesh)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
    rec["memory"] = memory_stats(compiled)
    rec["cost"] = cost_stats(compiled)
    rec["analysis"] = hlo_analysis.analyze(compiled.as_text())
    rec["model_flops"] = 0.0
    rec["status"] = "ok"
    return rec
