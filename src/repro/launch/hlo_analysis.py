"""Trip-count-aware analysis of post-SPMD optimized HLO.

XLA's HloCostAnalysis (what compiled.cost_analysis() reports) counts a
while-loop body ONCE — scan-over-layers, microbatch accumulation and
flash-attention KV scans therefore under-report FLOPs by orders of
magnitude, and collectives inside loop bodies likewise appear once in the
HLO text.  This module parses `compiled.as_text()` and:

  * reads each while loop's trip count from its backend_config
    ("known_trip_count"), falling back to the condition's constant,
  * builds a per-computation symbol table (operands are bare %refs in
    scheduled HLO) to recover operand shapes,
  * sums dot FLOPs (2 * prod(out) * prod(contracting)) through calls,
    fusions and while bodies with loop multipliers,
  * sums HBM traffic as operand+output bytes of *top-level* ops per
    executed computation (ops inside a fusion don't round-trip HBM, so
    fusions are counted at their boundary — a faithful traffic model),
  * sums collective bytes (operand sizes) per collective kind, with loop
    multipliers.

All quantities are PER DEVICE: the text is the SPMD-partitioned module.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1,
                "f8e5m2": 1, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_OPLINE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z]\d*[a-z0-9]*\[[\d,]*\]"
    r"(?:\{[\d,]*\})?)\s+([\w\-]+)\((.*)$")
_HDR = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\((.*?)\)\s*->")
_TRIP = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')
_CONST_INT = re.compile(r"constant\((\d+)\)")
_REF = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(shape_text: str) -> int:
    """Total bytes of all tensor shapes appearing in text."""
    total = 0
    for dt, dims in _SHAPE.findall(shape_text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Op:
    name: str
    result: str                 # result type text
    kind: str
    args: str                   # operand region (inside parens)
    attrs: str                  # everything after operands


@dataclass
class Computation:
    name: str
    is_entry: bool
    symtable: dict = field(default_factory=dict)   # %name -> type text
    ops: list = field(default_factory=list)


def _split_args(rest: str) -> tuple[str, str]:
    """Split 'operands), attrs...' at the matching close paren."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_computations(text: str) -> tuple[dict, str | None]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        if not line:
            continue
        if line[0] != " ":
            m = _HDR.match(line)
            if m and line.rstrip().endswith("{"):
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                if cur.is_entry:
                    entry = cur.name
                # parameter shapes from the header
                for pm in re.finditer(r"([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                      m.group(3)):
                    cur.symtable[pm.group(1)] = pm.group(2)
                # tuple params: record the whole header text too
                cur.symtable["__header__"] = m.group(3)
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OPLINE.match(line)
        if not m:
            continue
        name, result, kind, rest = m.groups()
        args, attrs = _split_args(rest)
        cur.symtable[name] = result
        cur.ops.append(Op(name=name, result=result, kind=kind,
                          args=args, attrs=attrs))
    return comps, entry


def _operand_bytes(comp: Computation, op: Op) -> int:
    total = 0
    for ref in _REF.findall(op.args):
        total += _shape_bytes(comp.symtable.get(ref, ""))
    # inline literals with shapes (rare in scheduled HLO)
    if not _REF.search(op.args):
        total += _shape_bytes(op.args)
    return total


def _traffic_bytes(comp: Computation, op: Op) -> float:
    """HBM traffic model for one top-level op.

    dynamic-slice reads only the slice; dynamic-update-slice writes only
    the update region (in-place) — counting their full operands would
    charge a scan body the whole stacked parameter array per iteration."""
    name_l = op.name
    if op.kind == "dynamic-slice" or (
            op.kind == "fusion" and "dynamic-slice" in name_l
            and "update" not in name_l):
        return 2.0 * _shape_bytes(op.result)
    if op.kind == "dynamic-update-slice" or (
            op.kind == "fusion" and "dynamic-update-slice" in name_l):
        sizes = sorted(_shape_bytes(comp.symtable.get(r, ""))
                       for r in _REF.findall(op.args))
        if sizes:
            return 2.0 * sum(sizes[:-1])      # all but the in-place buffer
        return 2.0 * _shape_bytes(op.result)
    return _operand_bytes(comp, op) + _shape_bytes(op.result)


def _dot_flops(comp: Computation, op: Op) -> float:
    out_shapes = _SHAPE.findall(op.result)
    if not out_shapes:
        return 0.0
    out_elems = _shape_elems(out_shapes[0][1])
    refs = _REF.findall(op.args)
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if m and refs:
        lhs_shape = _SHAPE.findall(comp.symtable.get(refs[0], ""))
        if lhs_shape:
            dims = [int(x) for x in lhs_shape[0][1].split(",") if x]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id", "iota", "domain", "opt-barrier",
               "get-dimension-size", "add-dependency"}


@dataclass
class Analysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    hbm_bytes_min: float = 0.0   # perfect-elementwise-fusion lower bound
    collectives: dict = field(default_factory=lambda: {
        c: {"count": 0.0, "bytes": 0.0} for c in _COLLECTIVES})
    while_loops: list = field(default_factory=list)

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.collectives.values())

    def as_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "hbm_bytes_min": self.hbm_bytes_min,
                "collective_bytes": self.collective_bytes,
                "collectives": self.collectives,
                "while_loops": self.while_loops}


def analyze(text: str) -> dict:
    comps, entry = parse_computations(text)
    flops_memo: dict[str, "Analysis"] = {}

    def called(op: Op, key: str) -> str | None:
        m = re.search(key + r"=%([\w\.\-]+)", op.attrs)
        return m.group(1) if m else None

    def visit(name: str, mult: float, acc: Analysis, bytes_mode: bool):
        comp = comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            kind = op.kind
            base = kind.replace("-start", "")
            if base in _COLLECTIVES and not kind.endswith("-done"):
                b = _operand_bytes(comp, op)
                acc.collectives[base]["count"] += mult
                acc.collectives[base]["bytes"] += mult * b
                if bytes_mode:
                    t = mult * (b + _shape_bytes(op.result))
                    acc.hbm_bytes += t
                    acc.hbm_bytes_min += t
                continue
            if kind == "dot":
                acc.flops += mult * _dot_flops(comp, op)
                if bytes_mode:
                    t = mult * _traffic_bytes(comp, op)
                    acc.hbm_bytes += t
                    acc.hbm_bytes_min += t
                continue
            if kind == "while":
                body = called(op, "body")
                cond = called(op, "condition")
                m = _TRIP.search(op.attrs)
                if m:
                    trips = int(m.group(1))
                elif cond in comps:
                    trips = 1
                    for o in comps[cond].ops:
                        for c in _CONST_INT.finditer(o.args + o.attrs):
                            trips = max(trips, int(c.group(1)))
                else:
                    trips = 1
                acc.while_loops.append({"name": op.name, "trips": trips,
                                        "mult": mult})
                if body:
                    visit(body, mult * trips, acc, bytes_mode)
                continue
            if kind in ("fusion", "call"):
                sub_name = called(op, "calls") or called(op, "to_apply")
                if sub_name:
                    if sub_name not in flops_memo:
                        sub = Analysis()
                        visit(sub_name, 1.0, sub, False)
                        flops_memo[sub_name] = sub
                    sub = flops_memo[sub_name]
                    acc.flops += mult * sub.flops
                    for c, v in sub.collectives.items():
                        acc.collectives[c]["count"] += mult * v["count"]
                        acc.collectives[c]["bytes"] += mult * v["bytes"]
                if bytes_mode:
                    t = mult * _traffic_bytes(comp, op)
                    acc.hbm_bytes += t
                    if "dynamic" in op.name:
                        acc.hbm_bytes_min += t
                continue
            if kind == "conditional":
                m = re.search(r"branch_computations=\{([^\}]*)\}", op.attrs)
                if m:
                    first = m.group(1).split(",")[0].strip().lstrip("%")
                    visit(first, mult, acc, bytes_mode)
                continue
            if kind in _SKIP_BYTES:
                continue
            if bytes_mode:
                acc.hbm_bytes += mult * _traffic_bytes(comp, op)

    acc = Analysis()
    if entry:
        visit(entry, 1.0, acc, True)
    return acc.as_dict()
