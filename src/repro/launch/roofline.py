"""Roofline analysis over dry-run results (§Roofline in EXPERIMENTS.md).

Reads dryrun_results.json and prints, per (arch x shape x mesh):
  compute   = HLO_FLOPs_per_device / peak_FLOPs            (197 TF/s bf16)
  memory    = HBM_bytes_per_device / HBM_bw                (819 GB/s)
              [min, max]: max = as-scheduled CPU-backend HLO traffic,
              min = perfect-elementwise-fusion bound (dots+collectives+
              cache slices only) — the TPU compile lands between.
  collective= collective_bytes_per_device / ICI_bw         (~50 GB/s/link;
              3D-torus v5e: 45 GB/s/dir x ~3 usable links -> we use the
              conservative single-link 50 GB/s)
plus the dominant term, MODEL_FLOPS/HLO_FLOPs, and a one-line lever.

Usage: python -m repro.launch.roofline [--json dryrun_results.json] [--md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (conservative single-link)

CHIPS = {"single": 256, "multi": 512}


def terms(rec: dict) -> dict | None:
    a = rec.get("analysis")
    if not a or rec.get("status") != "ok":
        return None
    n_chips = CHIPS[rec["mesh"]]
    compute = a["flops"] / PEAK_FLOPS
    mem_max = a["hbm_bytes"] / HBM_BW
    mem_min = a["hbm_bytes_min"] / HBM_BW
    coll = a["collective_bytes"] / LINK_BW
    model_flops_dev = rec["model_flops"] / n_chips
    terms_ = {"compute": compute, "memory(min)": mem_min,
              "memory(max)": mem_max, "collective": coll}
    # dominant: use mem_min (optimistic) so "memory-bound" calls are robust
    dom = max(("compute", compute), ("memory", mem_min),
              ("collective", coll), key=lambda kv: kv[1])[0]
    useful = model_flops_dev / max(a["flops"], 1)
    # roofline fraction: useful work time / dominant bottleneck time
    ideal_t = model_flops_dev / PEAK_FLOPS
    bound_t = max(compute, mem_min, coll)
    return {
        "compute_s": compute, "mem_min_s": mem_min, "mem_max_s": mem_max,
        "coll_s": coll, "dominant": dom,
        "model_flops": rec["model_flops"],
        "useful_ratio": useful,
        "roofline_frac": ideal_t / max(bound_t, 1e-12),
        "peak_gib": (rec.get("memory", {}).get("peak_estimate_bytes") or 0)
        / 2 ** 30,
        "lower_s": rec.get("lower_s"), "compile_s": rec.get("compile_s"),
    }


LEVERS = {
    "compute": "cut redundant FLOPs (remat policy, causal-block skipping, "
               "MoE capacity factor)",
    "memory": "fuse/widen arithmetic intensity (bigger microbatch, fused "
              "attention blocks, bf16 stores)",
    "collective": "re-shard to cut resharding collectives (CP<->TP choice, "
                  "ZeRO-3 gather scheduling, bf16 grad reduce)",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.json")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    results = json.loads(Path(args.json).read_text())

    rows = []
    for key, rec in sorted(results.items()):
        if rec.get("mesh") != args.mesh:
            continue
        t = terms(rec)
        if t is None:
            rows.append((rec.get("arch"), rec.get("shape"), None))
            continue
        rows.append((rec["arch"], rec["shape"], t))

    if args.md:
        print("| arch | shape | compute s | mem s [min,max] | coll s |"
              " dominant | MF/HLO | roofline frac | peak GiB |")
        print("|---|---|---|---|---|---|---|---|---|")
    else:
        print(f"{'arch':28s} {'shape':12s} {'compute':>9s} "
              f"{'mem[min,max]':>19s} {'coll':>8s} {'dom':>10s} "
              f"{'MF/HLO':>7s} {'roof%':>6s} {'GiB/dev':>8s}")
    for arch, shape, t in rows:
        if t is None:
            print(f"{arch:28s} {shape:12s}  FAILED")
            continue
        if args.md:
            print(f"| {arch} | {shape} | {t['compute_s']:.3f} |"
                  f" [{t['mem_min_s']:.3f}, {t['mem_max_s']:.3f}] |"
                  f" {t['coll_s']:.3f} | {t['dominant']} |"
                  f" {t['useful_ratio']:.2f} | {t['roofline_frac']:.2f} |"
                  f" {t['peak_gib']:.1f} |")
        else:
            print(f"{arch:28s} {shape:12s} {t['compute_s']:9.4f} "
                  f"[{t['mem_min_s']:8.4f},{t['mem_max_s']:8.4f}] "
                  f"{t['coll_s']:8.4f} {t['dominant']:>10s} "
                  f"{t['useful_ratio']:7.2f} {100*t['roofline_frac']:5.1f}% "
                  f"{t['peak_gib']:8.2f}")
    print()
    for dom, lever in LEVERS.items():
        print(f"lever[{dom}]: {lever}")


if __name__ == "__main__":
    main()
