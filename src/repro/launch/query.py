"""RDF query-serving driver: batched query workload through the RDF-ℏ
engine with planner statistics and throughput report.

On the production serving mesh the 'pod' axis replicates the index for
query parallelism (each pod serves its own query stream); this driver is
the per-pod loop, and `repro.core.distributed.shard_check` is the
data-axis-parallel check each pod runs internally.

    PYTHONPATH=src python -m repro.launch.query --dataset dblp --queries 20
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import Dataset, tune_thresholds, Thresholds
from ..data import DATASETS, random_query


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dblp", choices=sorted(DATASETS))
    ap.add_argument("--scale", type=float, default=0.08)
    ap.add_argument("--queries", type=int, default=20)
    ap.add_argument("--size", type=int, default=6)
    ap.add_argument("--variant", default="rdf_h")
    ap.add_argument("--tune", action="store_true",
                    help="grid-tune thresholds on a held-out sample first")
    args = ap.parse_args()

    g = DATASETS[args.dataset](scale=args.scale, seed=1)
    ds = Dataset.build(g, variant=args.variant)
    st = ds.stats
    print(f"dataset={args.dataset} triples={g.num_edges} "
          f"coherence={st.coherence:.3f} specialty={st.specialty:.1f}")

    thresholds = Thresholds(500, 1e5, 6.0)
    if args.tune:
        sample = [random_query(g, size=args.size, seed=5000 + i)
                  for i in range(4)]

        def cost(q, th):
            eng = ds.engine(args.variant, thresholds=th)
            t0 = time.perf_counter()
            eng.execute(q)
            return time.perf_counter() - t0
        thresholds = tune_thresholds(cost, sample)
        print(f"tuned thresholds: iter={thresholds.tau_iter} "
              f"join={thresholds.tau_join} sel={thresholds.tau_sel}")

    eng = ds.engine(args.variant, thresholds=thresholds)
    queries = [random_query(g, size=args.size, seed=100 + i)
               for i in range(args.queries)]
    # warm jit caches on one query
    eng.execute(queries[0])

    t0 = time.perf_counter()
    n_match = checks_on = truncated = 0
    lat = []
    for q in queries:
        t1 = time.perf_counter()
        r = eng.execute(q)
        lat.append(time.perf_counter() - t1)
        n_match += r.count
        checks_on += r.stats.used_check
        truncated += r.stats.truncated
    wall = time.perf_counter() - t0
    lat = np.asarray(lat)
    print(f"{args.queries} queries in {wall:.2f}s "
          f"({args.queries / wall:.2f} qps)")
    print(f"latency p50={np.percentile(lat, 50)*1e3:.1f}ms "
          f"p95={np.percentile(lat, 95)*1e3:.1f}ms "
          f"max={lat.max()*1e3:.1f}ms")
    print(f"matches={n_match} planner-enabled-check={checks_on}"
          f"/{args.queries} truncated={truncated}")


if __name__ == "__main__":
    main()
