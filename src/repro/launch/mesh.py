"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count BEFORE first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ('data', 'model').
    Multi-pod:  2x16x16 = 512 chips ('pod', 'data', 'model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
