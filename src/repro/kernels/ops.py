"""Jitted public wrappers with implementation dispatch.

impl:
  'auto'    -> pallas on TPU, pure-jnp reference elsewhere (CPU container);
  'pallas'  -> compiled Pallas (TPU only);
  'interpret' -> Pallas interpret mode (CPU-executable kernel body; slow,
                 used by tests to validate kernels);
  'ref'     -> pure-jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import functools

from . import ref as _ref
from .interval_count import interval_count_pallas
from .bitmask_contains import bitmask_contains_pallas
from .sorted_intersect import intersect_any_pallas
from .merge_probe import merge_probe_pallas


def _resolve(impl: str, cpu_default: str = "ref") -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else cpu_default
    return impl


_interval_count_sorted_jit = jax.jit(_ref.interval_count_sorted)
_interval_count_ref_jit = jax.jit(_ref.interval_count_ref)


def interval_count(ids, lo, hi, *, impl: str = "auto"):
    impl = _resolve(impl, cpu_default="sorted")
    ids = jnp.asarray(ids, jnp.int32)
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    if impl == "sorted":
        return _interval_count_sorted_jit(ids, lo, hi)
    if impl == "ref":
        return _interval_count_ref_jit(ids, lo, hi)
    return interval_count_pallas(ids, lo, hi, interpret=(impl == "interpret"))


def bitmask_contains(cand, query, *, impl: str = "auto"):
    impl = _resolve(impl)
    cand = jnp.asarray(cand, jnp.uint32)
    query = jnp.asarray(query, jnp.uint32)
    if impl == "ref":
        return _ref.bitmask_contains_ref(cand, query)
    return bitmask_contains_pallas(cand, query, interpret=(impl == "interpret"))


_intersect_sorted_jit = jax.jit(_ref.intersect_any_sorted)
_intersect_ref_jit = jax.jit(_ref.intersect_any_ref)

_merge_probe_sorted_jit = jax.jit(_ref.merge_probe_sorted)
_merge_probe_ref_jit = jax.jit(_ref.merge_probe_ref)


def merge_probe(a_keys, b_keys, *, impl: str = "auto"):
    """Match ranges of sorted a_keys in sorted b_keys: (start, cnt)."""
    impl = _resolve(impl, cpu_default="sorted")
    a_keys = jnp.asarray(a_keys, jnp.int32)
    b_keys = jnp.asarray(b_keys, jnp.int32)
    if impl == "sorted":
        return _merge_probe_sorted_jit(a_keys, b_keys)
    if impl == "ref":
        return _merge_probe_ref_jit(a_keys, b_keys)
    return merge_probe_pallas(a_keys, b_keys, interpret=(impl == "interpret"))


def radix_probe(a_keys, win_keys, *, impl: str = "auto"):
    """Window probe of the radix hash join: per-probe-row match mask,
    exclusive prefix, and count over the [A, Lmax] bucket-window matrix.
    (eq, pref, cnt)."""
    from . import radix_join as _rj
    impl = _resolve(impl, cpu_default="sorted")
    a_keys = jnp.asarray(a_keys, jnp.int32)
    win_keys = jnp.asarray(win_keys, jnp.int32)
    if impl in ("sorted", "ref"):
        return _radix_probe_ref_jit(a_keys, win_keys)
    return _rj.window_probe_pallas(a_keys, win_keys,
                                   interpret=(impl == "interpret"))


@jax.jit
def _radix_probe_ref_jit(a_keys, win_keys):
    from .radix_join import window_probe_ref
    return window_probe_ref(a_keys, win_keys)


_distinct_mask_jit = jax.jit(_ref.distinct_mask_sorted)


def distinct_mask(rows, *, impl: str = "auto"):
    """First-of-group mask over lexicographically sorted rows [N, K].

    All impls share the jnp form: the op is a memory-bound elementwise
    compare that XLA already fuses optimally on TPU, so there is no
    separate Pallas kernel — `impl` is validated for API uniformity."""
    if impl not in ("auto", "pallas", "interpret", "ref", "sorted"):
        raise ValueError(f"unknown impl {impl!r}")
    return _distinct_mask_jit(jnp.asarray(rows, jnp.int32))


def intersect_any(a, b, *, impl: str = "auto"):
    impl = _resolve(impl, cpu_default="sorted")
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    if impl == "sorted":
        return _intersect_sorted_jit(a, b)
    if impl == "ref":
        return _intersect_ref_jit(a, b)
    return intersect_any_pallas(a, b, interpret=(impl == "interpret"))
