"""Radix-partitioned hash join — the priced alternative to sort-merge.

Sort-merge pays two O(n log n) XLA sorts per join.  When the probe side
is large, its keys single-column, and the build side comparatively small,
a hash join does strictly less work: partition ONLY the build (B) side
into pow2 buckets by a multiplicative hash of the key, then stream every
probe (A) row against its bucket's contiguous window with pure SIMD
compares — no sort of A at all, and A's original row order is preserved
in the output (a planner-visible property: downstream joins keep A's
sort-order tag, where sort-merge would re-sort).

Pipeline (matching._join_radix drives it):

  radix_partition   stable-sort B by (bucket id, key) — two cheap sorts
                    of the SMALL side — so every bucket's span is
                    key-sorted and each key's matches are CONTIGUOUS;
                    bucket edges via searchsorted, max real bucket
                    length for static window sizing
  radix_window      gather each A row's bucket window into an [A, Lmax]
                    matrix (B_INVALID-filled past the bucket end)
  window_probe      two per-row reductions over the window matrix: keys
                    below the probe key (= the match run's offset, since
                    buckets are key-sorted) and keys equal to it — the
                    Pallas kernel here; ref twin `window_probe_ref` for
                    CPU ('sorted'/'ref')
  radix_scatter     pure-arithmetic gather of matches to output slots
                    ordered by A row (no sort, no scatter: XLA CPU
                    serializes scatters and its sorts are the very cost
                    this join exists to avoid)

Skew is the classic hash-join failure mode: one hot key inflates Lmax
and the window matrix goes quadratic.  matching gates on a static work
bound and falls back to sort-merge deterministically, so serving replay
re-derives the same decision.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_join import B_INVALID

# Knuth multiplicative hash: odd constant, top bits well-mixed, so the
# bucket id = top `bits` of key * KNUTH distributes clustered node ids.
_KNUTH = jnp.uint32(2654435761)


def _bucket_of(keys, bits: int):
    h = (keys.astype(jnp.uint32) * _KNUTH) >> jnp.uint32(32 - bits)
    nb = 1 << bits
    # invalid keys (sentinels) go to a reserved overflow bucket nb so
    # they never pad a real bucket's window
    return jnp.where(keys >= B_INVALID, nb, h.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("bits",))
def radix_partition(b_keys, b_rows, bits: int):
    """Partition the build side: (keys_p, rows_p, edges[nb+1], maxlen).
    edges[k]:edges[k+1] is bucket k's contiguous span in the partitioned
    arrays; maxlen counts REAL buckets only (invalid tail excluded).
    Two stable sorts (by key, then by bucket) leave every bucket span
    key-sorted, so a probe key's matches are one contiguous run whose
    in-bucket offset is just the count of smaller keys — which is what
    lets the probe and the output assembly stay sort- and scatter-free
    on the big side."""
    nb = 1 << bits
    ord1 = jnp.argsort(b_keys, stable=True)
    k1 = b_keys[ord1]
    bk1 = _bucket_of(k1, bits)
    ord2 = jnp.argsort(bk1, stable=True)
    keys_p = k1[ord2]
    rows_p = b_rows[ord1[ord2]]
    bk_p = bk1[ord2]
    edges = jnp.searchsorted(bk_p, jnp.arange(nb + 1, dtype=jnp.int32),
                             side="left").astype(jnp.int32)
    maxlen = jnp.max(edges[1:] - edges[:-1])
    return keys_p, rows_p, edges, maxlen


@functools.partial(jax.jit, static_argnames=("bits", "lmax"))
def radix_window(a_keys, edges, keys_p, bits: int, lmax: int):
    """Per-probe-row bucket windows: (win_keys [A,lmax], win_start [A]).
    win_start is each row's bucket offset into the partitioned build
    arrays; slots past the bucket end carry B_INVALID keys (match
    nothing, and — being the largest valid-sortable values — never
    perturb the below-key count either)."""
    nb = 1 << bits
    abk = _bucket_of(a_keys, bits)
    s = edges[jnp.minimum(abk, nb)]
    # invalid probe rows get an empty window (e == s)
    e = jnp.where(abk >= nb, s, edges[jnp.minimum(abk + 1, nb)])
    off = jnp.arange(lmax, dtype=jnp.int32)
    pos = s[:, None] + off[None, :]
    inside = pos < e[:, None]
    pos_c = jnp.clip(pos, 0, keys_p.shape[0] - 1)
    win_keys = jnp.where(inside, keys_p[pos_c], B_INVALID)
    return win_keys.astype(jnp.int32), s.astype(jnp.int32)


# ------------------------------ probe ---------------------------------- #
def window_probe_ref(a_keys, win_keys):
    """(lt, cnt): per-row count of window keys below the probe key and of
    keys equal to it.  The partition key-sorts every bucket, so lt is the
    offset of the key's contiguous match run inside the window and cnt
    its length — the probe's entire output is two [A] vectors, never a
    match matrix."""
    a = a_keys[:, None]
    lt = jnp.sum((win_keys < a).astype(jnp.int32), axis=1)
    cnt = jnp.sum((win_keys == a).astype(jnp.int32), axis=1)
    return lt, cnt


_PROBE_BLOCK_R = 8


def _window_kernel(a_ref, w_ref, lt_ref, cnt_ref):
    a = a_ref[...]
    w = w_ref[...]
    lt_ref[...] = jnp.sum((w < a).astype(jnp.int32), axis=1, keepdims=True)
    cnt_ref[...] = jnp.sum((w == a).astype(jnp.int32), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def window_probe_pallas(a_keys, win_keys, interpret: bool = False):
    """Pallas twin of window_probe_ref: block rows of the window matrix
    through VMEM, compare + row-reduce on the VPU."""
    n, lmax = win_keys.shape
    br = _PROBE_BLOCK_R
    n_pad = -(-max(n, 1) // br) * br
    a_p = jnp.full((n_pad, 1), -1, jnp.int32).at[:n, 0].set(a_keys)
    w_p = jnp.full((n_pad, lmax), B_INVALID, jnp.int32).at[:n].set(win_keys)
    lt, cnt = pl.pallas_call(
        _window_kernel,
        grid=(n_pad // br,),
        in_specs=[pl.BlockSpec((br, 1), lambda i: (i, 0)),
                  pl.BlockSpec((br, lmax), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, 1), lambda i: (i, 0)),
                   pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
                   jax.ShapeDtypeStruct((n_pad, 1), jnp.int32)],
        interpret=interpret,
    )(a_p, w_p)
    return lt[:n, 0], cnt[:n, 0]


# ------------------------ output assembly ------------------------------ #
@functools.partial(jax.jit, static_argnames=("cap", "new_sel", "has_new"))
def radix_scatter(a_rows, b_rows_p, lt, cnt, win_start, limit, *,
                  cap, new_sel, has_new):
    """Assemble matches into `cap` output slots ordered by probe row (so
    the output inherits A's row order).

    Gather form, despite the name: XLA CPU serializes scatters and its
    sorts are the very cost this join avoids, so each output slot t
    PULLS its match with pure index arithmetic — probe row i by
    searchsorted over the cumulative counts, match ordinal
    k = t - base[i] (subtraction form, never a fused remainder+gather),
    and build row win_start[i] + lt[i] + k, since row i's matches are
    the contiguous run starting lt[i] into its key-sorted bucket.
    Slots at or past min(limit, total) are -1-filled."""
    csum = jnp.cumsum(cnt)
    base = csum - cnt                                # exclusive, by A row
    t = jnp.arange(cap, dtype=jnp.int32)
    i = jnp.minimum(jnp.searchsorted(csum, t, side="right")
                    .astype(jnp.int32), cnt.shape[0] - 1)
    k = t - base[i]
    valid = t < jnp.minimum(limit, csum[-1])
    left = jnp.where(valid[:, None], a_rows[i], -1)
    if has_new:
        sel = jnp.asarray(new_sel, jnp.int32)
        bj = jnp.clip(win_start[i] + lt[i] + k, 0, b_rows_p.shape[0] - 1)
        right = jnp.where(valid[:, None], b_rows_p[bj][:, sel], -1)
        return jnp.concatenate([left, right], axis=1)
    return left
