"""Fused sort-merge join pipeline: pack -> sort -> probe -> expand in ONE
XLA dispatch.

The staged join path (matching._join_sorted) runs ~5 device dispatches per
join — key packing, two side sorts, the merge probe, the segment expand —
and syncs the full per-row count vector to host between probe and expand.
The fused entry points here trace the whole chain into a single jitted
computation, so the match-range arrays (start/cnt) never round-trip
through host memory between stages and only ONE scalar (the match total)
is synced per join:

  sort_probe_expand   the full chain at a known output capacity (the
                      planner pre-sizes joins from cardinality
                      estimates).  The sorted sides and match ranges are
                      returned as device-resident byproducts so the
                      CapacityOverflow retry contract is preserved: on
                      overflow the caller re-runs ONLY the expand.
  sort_probe          pack+sort+probe when the capacity is not known up
                      front; the caller syncs the total, sizes the
                      output, and dispatches the expand separately.
  pack_keys           the fused dense-rank key packing alone, for the
                      staged path (sorted-run reuse, resume replays):
                      ONE lexsort over all shared columns replaces the
                      seed's per-column rank/pack chain (S-1 lexsorts),
                      and single-column keys take an identity path with
                      no concat/split device ops at all.
  lexsort_distinct    the fused projection+lexsort+distinct-mask+count
                      used by matching.dedup_project, so reach-join
                      dedup rides the same fused sort primitive.

Multi-column joins exploit a structural win the staged path cannot: the
ONE lexsort over the concatenated sides yields the dense-rank keys AND
both sides' sorted orders (stable sort => filtering the combined order by
side preserves each side's order), so pack + sort(A) + sort(B) collapse
into a single sort of A+B rows.

Probe impls mirror kernels.ops.merge_probe ('sorted' searchsorted /
'ref' oracle on CPU, Pallas kernel under 'pallas'/'interpret'); under the
Pallas impls the segment-offset expand uses `expand_segments_pallas`, a
merge_probe-style block-skipping counting kernel that replaces the
output-side searchsorted.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref
from .merge_probe import merge_probe_pallas

# Join-key space (shared with core.matching): real packed keys live in
# [0, 2^31 - 3]; the top two int32 values are invalid-row sentinels,
# distinct per side so an invalid a-row never matches an invalid b-row.
A_INVALID = (1 << 31) - 1
B_INVALID = (1 << 31) - 2

_I32_MAX = jnp.iinfo(jnp.int32).max


# ------------------------- fused dense-rank pack ----------------------- #
def _side_cols(rows, sel, valid, sentinel):
    return tuple(jnp.where(valid, rows[:, s], sentinel).astype(jnp.int32)
                 for s in sel)


def _ranks_sorted(sorted_cols):
    """Dense ranks of lexicographically sorted column tuples: rank
    increments exactly at rows that differ from their predecessor."""
    boundary = jnp.zeros((sorted_cols[0].shape[0] - 1,), bool)
    for c in sorted_cols:
        boundary |= c[1:] != c[:-1]
    new = jnp.concatenate([jnp.ones((1,), jnp.int32),
                           boundary.astype(jnp.int32)])
    return jnp.cumsum(new) - 1


@functools.partial(jax.jit, static_argnames=("a_sel", "b_sel"))
def pack_keys(a_rows, b_rows, a_sel, b_sel):
    """Pack the shared join columns of both tables into one int32 key per
    row (original row order).  Single shared column: the node id IS the
    key — identity path, no concatenate/split dispatches.  Multiple
    columns: ONE lexsort over the concatenated sides assigns dense ranks
    to the full column tuple (order- and equality-preserving, so equal
    keys <=> equal tuples and any number of columns fits 31 bits)."""
    n_a = a_rows.shape[0]
    a_valid = a_rows[:, 0] >= 0
    b_valid = b_rows[:, 0] >= 0
    if len(a_sel) == 1:
        a_keys = jnp.where(a_valid, a_rows[:, a_sel[0]],
                           A_INVALID).astype(jnp.int32)
        b_keys = jnp.where(b_valid, b_rows[:, b_sel[0]],
                           B_INVALID).astype(jnp.int32)
        return a_keys, b_keys
    cols = tuple(jnp.concatenate([va, vb]) for va, vb in zip(
        _side_cols(a_rows, a_sel, a_valid, A_INVALID),
        _side_cols(b_rows, b_sel, b_valid, B_INVALID)))
    order = jnp.lexsort(tuple(reversed(cols)))
    ranks = _ranks_sorted(tuple(c[order] for c in cols))
    key = jnp.zeros_like(ranks).at[order].set(ranks).astype(jnp.int32)
    a_keys = jnp.where(a_valid, key[:n_a], A_INVALID)
    b_keys = jnp.where(b_valid, key[n_a:], B_INVALID)
    return a_keys, b_keys


# --------------------------- fused side sort --------------------------- #
def _sort_sides(a_rows, b_rows, a_sel, b_sel):
    """(a_keys_s, a_rows_s, b_keys_s, b_rows_s), both sides sorted by the
    packed key.  Single column: identity keys, one argsort per side.
    Multiple columns: the pack lexsort is REUSED as the sort — the stable
    combined order, filtered by side, is each side's sorted order."""
    n_a, n_b = a_rows.shape[0], b_rows.shape[0]
    a_valid = a_rows[:, 0] >= 0
    b_valid = b_rows[:, 0] >= 0
    if len(a_sel) == 1:
        a_keys = jnp.where(a_valid, a_rows[:, a_sel[0]],
                           A_INVALID).astype(jnp.int32)
        b_keys = jnp.where(b_valid, b_rows[:, b_sel[0]],
                           B_INVALID).astype(jnp.int32)
        ao = jnp.argsort(a_keys)
        bo = jnp.argsort(b_keys)
        return a_keys[ao], a_rows[ao], b_keys[bo], b_rows[bo]
    cols = tuple(jnp.concatenate([va, vb]) for va, vb in zip(
        _side_cols(a_rows, a_sel, a_valid, A_INVALID),
        _side_cols(b_rows, b_sel, b_valid, B_INVALID)))
    order = jnp.lexsort(tuple(reversed(cols)))
    key_sorted = _ranks_sorted(tuple(c[order] for c in cols)).astype(
        jnp.int32)
    from_a = order < n_a
    ia = jnp.nonzero(from_a, size=n_a)[0]           # exactly n_a entries
    ib = jnp.nonzero(~from_a, size=n_b)[0]
    return (key_sorted[ia], a_rows[order[ia]],
            key_sorted[ib], b_rows[order[ib] - n_a])


def _probe(a_keys_s, b_keys_s, probe: str):
    if probe == "sorted":
        return _ref.merge_probe_sorted(a_keys_s, b_keys_s)
    if probe == "ref":
        return _ref.merge_probe_ref(a_keys_s, b_keys_s)
    return merge_probe_pallas(a_keys_s, b_keys_s,
                              interpret=(probe == "interpret"))


# ----------------- segment-offset expand (Pallas seg) ------------------ #
SEG_TILE_R = 8              # sublane rows per output tile -> 8*128 slots
SEG_BLOCK = 128             # csum entries per block (one lane row)


def _seg_kernel(csum_ref, seg_ref):
    """seg[t] = #{i : csum[i] <= t} == searchsorted(csum, t, 'right').

    Same block-skipping accumulation as merge_probe: csum is
    nondecreasing, so a csum block entirely <= the tile's smallest t
    contributes its full width, a block entirely > the largest t
    contributes nothing, and only boundary blocks run the lane loop."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        seg_ref[...] = jnp.zeros_like(seg_ref)

    t0 = pl.program_id(0) * (SEG_TILE_R * 128)
    r = jax.lax.broadcasted_iota(jnp.int32, seg_ref.shape, 0)
    l = jax.lax.broadcasted_iota(jnp.int32, seg_ref.shape, 1)
    t = t0 + r * 128 + l
    c = csum_ref[...]                           # [1, SEG_BLOCK]
    c_lo = c[0, 0]
    c_hi = c[0, SEG_BLOCK - 1]
    below = c_hi <= t0                          # block counts for every t
    above = c_lo > t0 + SEG_TILE_R * 128 - 1

    @pl.when(below)
    def _all_below():
        seg_ref[...] += jnp.full(seg_ref.shape, SEG_BLOCK, jnp.int32)

    @pl.when(jnp.logical_not(below | above))
    def _overlap():
        acc = jnp.zeros(seg_ref.shape, jnp.int32)
        for j in range(SEG_BLOCK):
            acc += (c[0, j] <= t).astype(jnp.int32)
        seg_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("cap", "interpret"))
def expand_segments_pallas(csum, cap: int, interpret: bool = False):
    """Segment index of every output slot t in [0, cap): the sorted a-row
    whose cumulative match-count range contains t."""
    n = csum.shape[0]
    span = SEG_TILE_R * 128
    cap_pad = -(-max(cap, 1) // span) * span
    n_pad = -(-max(n, 1) // SEG_BLOCK) * SEG_BLOCK
    # padding with INT32_MAX never counts: csum values are < 2^31 totals
    c_p = jnp.full((n_pad,), _I32_MAX, jnp.int32).at[:n].set(
        csum.astype(jnp.int32))
    c_m = c_p.reshape(n_pad // SEG_BLOCK, SEG_BLOCK)
    grid = (cap_pad // span, n_pad // SEG_BLOCK)
    seg = pl.pallas_call(
        _seg_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, SEG_BLOCK), lambda i, k: (k, 0))],
        out_specs=pl.BlockSpec((SEG_TILE_R, 128), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cap_pad // 128, 128), jnp.int32),
        interpret=interpret,
    )(c_m)
    return seg.reshape(-1)[:cap]


def _expand(a_rows_s, b_rows_s, start, cnt, limit, cap, new_sel, has_new,
            probe):
    """Segment-offset expansion of (start, cnt) match ranges — the fused
    in-jit twin of matching._merge_expand, returning the match total as a
    device scalar byproduct."""
    a_cap = a_rows_s.shape[0]
    csum = jnp.cumsum(cnt)
    total = csum[a_cap - 1]
    if probe in ("pallas", "interpret"):
        seg = expand_segments_pallas(csum, cap,
                                     interpret=(probe == "interpret"))
    else:
        t_idx = jnp.arange(cap, dtype=jnp.int32)
        seg = jnp.searchsorted(csum, t_idx, side="right").astype(jnp.int32)
    t = jnp.arange(cap, dtype=jnp.int32)
    valid = (t < total) & (t < limit)
    i = jnp.minimum(seg, a_cap - 1)
    base = csum[i] - cnt[i]
    # offset as t - base (subtraction form), never a fused int32
    # remainder: see matching._cross_expand's XLA-CPU miscompile note
    j = jnp.clip(start[i] + (t - base), 0, b_rows_s.shape[0] - 1)
    left = jnp.where(valid[:, None], a_rows_s[i], -1)
    if has_new:
        sel = jnp.asarray(new_sel, jnp.int32)
        right = jnp.where(valid[:, None], b_rows_s[j][:, sel], -1)
        return jnp.concatenate([left, right], axis=1), total
    return left, total


# --------------------------- fused entry points ------------------------ #
@functools.partial(jax.jit, static_argnames=("a_sel", "b_sel", "cap",
                                             "new_sel", "has_new", "probe"))
def sort_probe_expand(a_rows, b_rows, limit, *, a_sel, b_sel, cap,
                      new_sel, has_new, probe):
    """The full fused join chain at a known output capacity.

    Returns (rows, total, a_keys_s, a_rows_s, b_keys_s, b_rows_s, start,
    cnt): the output rows plus the device-resident sorted sides and match
    ranges, so the caller can cache sorted runs and — on capacity
    overflow — retry ONLY the expand at the exact size.  `limit` is a
    traced scalar (row-limit truncation without recompiles).  Caller
    contract: |A|*|B| < 2^31 so the total fits the int32 device scalar
    (larger joins stay on the staged path with its int64 host sum)."""
    a_keys_s, a_rows_s, b_keys_s, b_rows_s = _sort_sides(
        a_rows, b_rows, a_sel, b_sel)
    start, cnt = _probe(a_keys_s, b_keys_s, probe)
    rows, total = _expand(a_rows_s, b_rows_s, start, cnt, limit, cap,
                          new_sel, has_new, probe)
    return rows, total, a_keys_s, a_rows_s, b_keys_s, b_rows_s, start, cnt


@functools.partial(jax.jit, static_argnames=("a_sel", "b_sel", "probe"))
def sort_probe(a_rows, b_rows, *, a_sel, b_sel, probe):
    """Fused pack+sort+probe for joins with no capacity hint: the caller
    syncs the int32 total, sizes the output, and expands separately.
    Same |A|*|B| < 2^31 caller contract as sort_probe_expand."""
    a_keys_s, a_rows_s, b_keys_s, b_rows_s = _sort_sides(
        a_rows, b_rows, a_sel, b_sel)
    start, cnt = _probe(a_keys_s, b_keys_s, probe)
    total = jnp.sum(cnt)
    return a_keys_s, a_rows_s, b_keys_s, b_rows_s, start, cnt, total


# ------------------------ fused sort-distinct -------------------------- #
@functools.partial(jax.jit, static_argnames=("sel",))
def lexsort_distinct(rows, sel):
    """Fused projection + lexsort + first-of-group mask + count for
    dedup_project: (sorted projection, keep mask, kept count) in one
    dispatch.  Invalid rows map every projected value to the a-side
    sentinel, so they sort last and are masked out."""
    valid = rows[:, 0] >= 0
    cols = _side_cols(rows, sel, valid, A_INVALID)
    order = jnp.lexsort(tuple(reversed(cols)))
    proj = jnp.stack(cols, axis=1)[order]
    keep = _ref.distinct_mask_sorted(proj) & (proj[:, 0] != A_INVALID)
    return proj, keep, jnp.sum(keep.astype(jnp.int32))
