"""Pure-jnp oracles for every kernel in this package.

These define the semantics; the Pallas kernels must match them exactly
(integer ops, so exact equality is asserted in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def interval_count_ref(ids: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """counts[c, j] = #{b : lo[j] <= ids[c, b] < hi[j]}.

    ids: [C, B] int32, padded with -1 (all real ids >= 0, all lo >= 0 so
    padding never counts).  lo, hi: [J] int32.  Returns [C, J] int32.
    """
    def one(bounds):
        l, h = bounds
        return jnp.sum((ids >= l) & (ids < h), axis=1, dtype=jnp.int32)
    # sequential over J keeps peak memory at C*B instead of C*B*J
    counts = jax.lax.map(one, (lo, hi))           # [J, C]
    return counts.T


def bitmask_contains_ref(cand: jax.Array, query: jax.Array) -> jax.Array:
    """ok[c] = 1 iff every bit set in query is set in cand[c].

    cand: [C, W] uint32, query: [W] uint32.  Returns [C] int32.
    """
    miss = jnp.bitwise_and(query[None, :], jnp.bitwise_not(cand))
    return (~jnp.any(miss != 0, axis=1)).astype(jnp.int32)


def intersect_any_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """hit[p] = 1 iff the valid (>=0) entries of a[p] and b[p] intersect.

    a: [P, A] int32, b: [P, B] int32, both -1 padded.  Returns [P] int32.
    """
    eq = a[:, :, None] == b[:, None, :]
    valid = (a[:, :, None] >= 0) & (b[:, None, :] >= 0)
    return jnp.any(eq & valid, axis=(1, 2)).astype(jnp.int32)


def interval_count_sorted(ids: jax.Array, lo: jax.Array,
                          hi: jax.Array) -> jax.Array:
    """Binary-search formulation: rows of `ids` are sorted ascending with
    -1 padding; counts via two searchsorted per interval — O(J log B)
    per row instead of O(J*B).  Semantics identical to interval_count_ref
    (validated in tests); this is the CPU fast path, while the Pallas
    kernel keeps the compare-reduce form (VPU-friendly on TPU)."""
    big = jnp.iinfo(jnp.int32).max
    rows = jnp.where(ids < 0, big, ids)
    rows = jnp.sort(rows, axis=1)   # pads move to the tail; already sorted
    bounds = jnp.concatenate([lo, hi]).astype(jnp.int32)

    def one(row):
        return jnp.searchsorted(row, bounds, side="left")
    idx = jax.vmap(one)(rows)                       # [C, 2J]
    j = lo.shape[0]
    return (idx[:, j:] - idx[:, :j]).astype(jnp.int32)


def merge_probe_ref(a_keys: jax.Array,
                    b_keys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per a-key match range in sorted b: start[i] = #{j: b[j] < a[i]},
    cnt[i] = #{j: b[j] == a[i]}.

    a_keys [A] int32, b_keys [B] int32, both ascending.  O(A*B) compare
    oracle defining the semantics of the merge-probe kernel.
    """
    lt = b_keys[None, :] < a_keys[:, None]
    eq = b_keys[None, :] == a_keys[:, None]
    return (lt.sum(axis=1).astype(jnp.int32),
            eq.sum(axis=1).astype(jnp.int32))


def merge_probe_sorted(a_keys: jax.Array,
                       b_keys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Binary-search formulation of merge_probe_ref — O((A+B) log B).
    CPU fast path of the sort-merge join; exact same semantics."""
    start = jnp.searchsorted(b_keys, a_keys, side="left")
    end = jnp.searchsorted(b_keys, a_keys, side="right")
    return start.astype(jnp.int32), (end - start).astype(jnp.int32)


def distinct_mask_sorted(rows: jax.Array) -> jax.Array:
    """mask[i] = 1 iff rows[i] differs from rows[i-1] (row 0 always 1).

    rows: [N, K] int32, lexicographically sorted.  On sorted input this
    marks exactly the first row of every duplicate group — the dedup
    primitive of the reach-join's connected-pair table.  Memory-bound
    elementwise compare: XLA fuses it optimally on every backend, so the
    reference form IS the kernel (no Pallas variant needed)."""
    neq = jnp.any(rows[1:] != rows[:-1], axis=1)
    head = jnp.ones((min(rows.shape[0], 1),), bool)   # [] for 0-row input
    return jnp.concatenate([head, neq])


def intersect_any_sorted(a: jax.Array, b: jax.Array) -> jax.Array:
    """Membership-test formulation of intersect_any_ref: sort each a-row,
    binary-search every b element — O(P*B log A) time and O(P*B) memory
    instead of the oracle's O(P*A*B) compare cube.  CPU fast path; exact
    same semantics (validated in tests)."""
    big = jnp.iinfo(jnp.int32).max
    a_s = jnp.sort(jnp.where(a < 0, big, a), axis=1)

    def row(ar, br):
        idx = jnp.clip(jnp.searchsorted(ar, br), 0, ar.shape[0] - 1)
        return jnp.any((ar[idx] == br) & (br >= 0))
    return jax.vmap(row)(a_s, b).astype(jnp.int32)
