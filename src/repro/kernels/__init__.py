"""Pallas TPU kernels for the paper's compute hot-spots (Alg. 1 & 3).

Each kernel ships with a pure-jnp oracle in ref.py; ops.py dispatches by
backend (pallas on TPU, ref on CPU, interpret for kernel-body validation).
"""
from . import ops, ref
from .ops import (interval_count, bitmask_contains, intersect_any,
                  merge_probe)
