"""Pallas TPU kernel: batched interval-containment counting.

This is the compute hot-spot of the paper's Algorithm 1 (Neighborhood
Check): for a tile of candidate nodes, count how many of each candidate's
k-hop neighbor ids fall inside each query keyword interval.

TPU mapping: the candidate axis is the grid; each step loads one
(TILE_C, B) block of neighbor-id rows into VMEM and produces a
(TILE_C, J_pad) count block.  The J loop is unrolled at trace time (J is
the number of distinct keywords around one query node — single digits).
Compares/reductions run on the VPU; blocks are sized to the (8, 128)
lane layout, with B a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_C = 256


def _kernel(ids_ref, lo_ref, hi_ref, out_ref, *, j_real: int):
    ids = ids_ref[...]                    # [TILE_C, B] int32
    for j in range(out_ref.shape[1]):
        if j < j_real:
            l = lo_ref[0, j]
            h = hi_ref[0, j]
            cnt = jnp.sum((ids >= l) & (ids < h), axis=1, dtype=jnp.int32)
        else:
            cnt = jnp.zeros((ids.shape[0],), jnp.int32)
        out_ref[:, j] = cnt


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def interval_count_pallas(ids: jax.Array, lo: jax.Array, hi: jax.Array,
                          *, tile_c: int = DEFAULT_TILE_C,
                          interpret: bool = False) -> jax.Array:
    """ids [C, B] int32 (-1 padded, sorted rows); lo, hi [J] int32.

    Returns counts [C, J] int32.  See ref.interval_count_ref.
    """
    c, b = ids.shape
    j = lo.shape[0]
    j_pad = max(8, -(-j // 8) * 8)
    tile_c = min(tile_c, max(8, -(-c // 8) * 8))
    c_pad = -(-c // tile_c) * tile_c
    b_pad = max(128, -(-b // 128) * 128)

    ids_p = jnp.full((c_pad, b_pad), -1, jnp.int32).at[:c, :b].set(ids)
    lo_p = jnp.zeros((1, j_pad), jnp.int32).at[0, :j].set(lo)
    hi_p = jnp.zeros((1, j_pad), jnp.int32).at[0, :j].set(hi)

    grid = (c_pad // tile_c,)
    out = pl.pallas_call(
        functools.partial(_kernel, j_real=j),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_c, b_pad), lambda i: (i, 0)),
            pl.BlockSpec((1, j_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, j_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_c, j_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c_pad, j_pad), jnp.int32),
        interpret=interpret,
    )(ids_p, lo_p, hi_p)
    return out[:c, :j]
