"""Pallas TPU kernel: sorted-key merge probe for the sort-merge join.

Given two ascending int32 key arrays (the packed join keys of both sides
of an equi-join, invalid rows carrying distinct top-of-range sentinels),
produce for every a-key the half-open range of equal b-keys:

    start[i] = #{j : b[j] <  a[i]}     (== searchsorted left)
    cnt[i]   = #{j : b[j] == a[i]}     (== right - left)

The expand/gather step of the join consumes (start, cnt) directly.

TPU mapping: a is reshaped to (rows, 128) lanes and tiled over grid dim 0;
b is walked in 128-wide blocks over grid dim 1, accumulating lt/eq counts
into the revisited output block (the standard accumulation pattern).
Because both sides are sorted, each b block first compares its min/max
against the a tile's range: blocks entirely below contribute a uniform
+TILE_B to `start`, blocks entirely above contribute nothing, and only the
O(#a_tiles + #b_blocks) boundary-overlapping pairs run the lane-unrolled
compare loop — the merge property that makes this near-linear despite the
tiled formulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_R = 8          # sublane rows per a tile -> 8*128 keys
TILE_B = 128                # b keys per block (one lane row)

_I32_MAX = jnp.iinfo(jnp.int32).max


def _kernel(a_ref, b_ref, start_ref, cnt_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        start_ref[...] = jnp.zeros_like(start_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    a = a_ref[...]                              # [TR, 128] sorted overall
    b = b_ref[...]                              # [1, TILE_B] sorted
    a_min = jnp.min(a)
    a_max = jnp.max(a)
    b_lo = b[0, 0]
    b_hi = b[0, TILE_B - 1]

    below = b_hi < a_min                        # whole block < every a key
    above = b_lo > a_max                        # whole block > every a key

    @pl.when(below)
    def _all_below():
        start_ref[...] += jnp.full(start_ref.shape, TILE_B, jnp.int32)

    @pl.when(jnp.logical_not(below | above))
    def _overlap():
        lt = jnp.zeros(a.shape, jnp.int32)
        eq = jnp.zeros(a.shape, jnp.int32)
        for j in range(TILE_B):
            bj = b[0, j]
            lt += (bj < a).astype(jnp.int32)
            eq += (bj == a).astype(jnp.int32)
        start_ref[...] += lt
        cnt_ref[...] += eq


@functools.partial(jax.jit, static_argnames=("tile_r", "interpret"))
def merge_probe_pallas(a_keys: jax.Array, b_keys: jax.Array,
                       *, tile_r: int = DEFAULT_TILE_R,
                       interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """a_keys [A] int32 ascending, b_keys [B] int32 ascending.

    Real keys must be < INT32_MAX - 1 (the join packs keys into
    [0, 2^31 - 3] and reserves the top two values for invalid-row
    sentinels); kernel padding uses INT32_MAX which sorts last and never
    equals a real key.  Returns (start [A], cnt [A]) int32.
    """
    a = jnp.asarray(a_keys, jnp.int32)
    b = jnp.asarray(b_keys, jnp.int32)
    n_a, n_b = a.shape[0], b.shape[0]

    span = tile_r * 128
    a_pad = -(-max(n_a, 1) // span) * span
    b_pad = -(-max(n_b, 1) // TILE_B) * TILE_B
    a_p = jnp.full((a_pad,), _I32_MAX, jnp.int32).at[:n_a].set(a)
    b_p = jnp.full((b_pad,), _I32_MAX, jnp.int32).at[:n_b].set(b)
    a_m = a_p.reshape(a_pad // 128, 128)
    b_m = b_p.reshape(b_pad // TILE_B, TILE_B)

    grid = (a_pad // span, b_pad // TILE_B)
    start, cnt = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, 128), lambda i, k: (i, 0)),
            pl.BlockSpec((1, TILE_B), lambda i, k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_r, 128), lambda i, k: (i, 0)),
            pl.BlockSpec((tile_r, 128), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((a_pad // 128, 128), jnp.int32),
            jax.ShapeDtypeStruct((a_pad // 128, 128), jnp.int32),
        ],
        interpret=interpret,
    )(a_m, b_m)
    start = start.reshape(-1)[:n_a]
    cnt = cnt.reshape(-1)[:n_a]
    # kernel padding of b (INT32_MAX) is > every real key, so it never
    # perturbs `start`; it only inflates `cnt` for a-keys that are
    # themselves INT32_MAX (the caller's invalid-row sentinel) — subtract
    # that contribution so invalid rows report zero matches.
    pad_b = b_pad - n_b
    if pad_b:
        cnt = jnp.where(a == _I32_MAX, cnt - pad_b, cnt)
    return start, cnt
