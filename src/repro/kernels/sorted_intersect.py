"""Pallas TPU kernel: batched id-list intersection test.

The core of the paper's Algorithm 3 (Connectivity Check): for P candidate
pairs, test whether the forward neighbor-id list of n_i intersects the
backward neighbor-id list of n_j.  Lists are -1 padded.

TPU mapping: grid over pair tiles; the B-side list is walked with an
unrolled compare-any against the full A-side block — an O(A*B) VPU
compare-reduce whose working set (TILE_P * (A + B) ints) is tiled to VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_P = 256


def _kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]                                  # [TP, A]
    b = b_ref[...]                                  # [TP, B]
    hit = jnp.zeros((a.shape[0], 1), jnp.bool_)
    for j in range(b.shape[1]):
        bj = b[:, j:j + 1]                          # [TP, 1]
        m = jnp.any((a == bj) & (bj >= 0), axis=1, keepdims=True)
        hit = hit | m
    out_ref[...] = jnp.broadcast_to(hit.astype(jnp.int32), out_ref.shape)


@functools.partial(jax.jit, static_argnames=("tile_p", "interpret"))
def intersect_any_pallas(a: jax.Array, b: jax.Array,
                         *, tile_p: int = DEFAULT_TILE_P,
                         interpret: bool = False) -> jax.Array:
    """a [P, A] int32, b [P, B] int32 (-1 padded) -> hit [P] int32."""
    p, a_w = a.shape
    _, b_w = b.shape
    tile_p = min(tile_p, max(8, -(-p // 8) * 8))
    p_pad = -(-p // tile_p) * tile_p
    a_pad = max(128, -(-a_w // 128) * 128)

    a_p = jnp.full((p_pad, a_pad), -1, jnp.int32).at[:p, :a_w].set(a)
    b_p = jnp.full((p_pad, b_w), -1, jnp.int32).at[:p, :b_w].set(b)

    out = pl.pallas_call(
        _kernel,
        grid=(p_pad // tile_p,),
        in_specs=[
            pl.BlockSpec((tile_p, a_pad), lambda i: (i, 0)),
            pl.BlockSpec((tile_p, b_w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_p, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p_pad, 128), jnp.int32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:p, 0]
