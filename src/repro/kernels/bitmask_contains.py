"""Pallas TPU kernel: bloom/bitstring signature containment (gStore-style).

ok[c] = 1 iff (query & ~cand[c]) == 0 across all signature words — i.e. the
query signature's bits are a subset of the candidate's.  Used as the compact
signature variant for exact-keyword neighborhoods (intervals of width 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_C = 256


def _kernel(cand_ref, query_ref, out_ref):
    cand = cand_ref[...]                       # [TILE_C, W] uint32
    q = query_ref[...]                         # [1, W] uint32
    miss = jnp.bitwise_and(q, jnp.bitwise_not(cand))
    ok = ~jnp.any(miss != jnp.uint32(0), axis=1, keepdims=True)
    out_ref[...] = jnp.broadcast_to(ok.astype(jnp.int32), out_ref.shape)


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def bitmask_contains_pallas(cand: jax.Array, query: jax.Array,
                            *, tile_c: int = DEFAULT_TILE_C,
                            interpret: bool = False) -> jax.Array:
    """cand [C, W] uint32; query [W] uint32 -> ok [C] int32."""
    c, w = cand.shape
    w_pad = max(128, -(-w // 128) * 128)
    tile_c = min(tile_c, max(8, -(-c // 8) * 8))
    c_pad = -(-c // tile_c) * tile_c

    cand_p = jnp.zeros((c_pad, w_pad), jnp.uint32).at[:c, :w].set(cand)
    query_p = jnp.zeros((1, w_pad), jnp.uint32).at[0, :w].set(query)

    out = pl.pallas_call(
        _kernel,
        grid=(c_pad // tile_c,),
        in_specs=[
            pl.BlockSpec((tile_c, w_pad), lambda i: (i, 0)),
            pl.BlockSpec((1, w_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_c, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c_pad, 128), jnp.int32),
        interpret=interpret,
    )(cand_p, query_p)
    return out[:c, 0]
