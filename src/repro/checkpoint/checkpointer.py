"""Async, atomic, resharding-tolerant checkpointing.

Design for 1000+ nodes (adapted to this container's single host):
  * save is ASYNC: arrays are device_get'd, then written on a background
    thread so the train loop keeps stepping;
  * atomic commit: write to `step_<n>.tmp/`, fsync, rename to `step_<n>/`
    — a crash mid-write never corrupts the latest checkpoint;
  * integrity: every leaf gets a crc32 recorded in the manifest, verified
    on restore;
  * resharding: checkpoints store GLOBAL arrays keyed by pytree path, so a
    restart may use a different mesh shape (elastic) — restore just
    device_puts with the new shardings;
  * retention: keep the last `keep` checkpoints.

On a real multi-host pod each host would write only the shards it owns
(process-local addressable shards) under the same manifest scheme; the
single-host writer here is the degenerate case of that layout.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path

import numpy as np
import jax


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, meta: dict | None = None,
             async_: bool = True):
        flat, _ = _flatten(tree)
        # device_get NOW (so training may mutate buffers afterwards)
        host = {k: np.asarray(v) for k, v in flat.items()}

        def write():
            tmp = self.dir / f"step_{step:010d}.tmp"
            final = self.dir / f"step_{step:010d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "meta": meta or {}, "leaves": {}}
            for k, arr in host.items():
                fname = k.replace("/", "__") + ".npy"
                np.save(tmp / fname, arr)
                manifest["leaves"][k] = {
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
                }
            with open(tmp / "manifest.json", "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self.wait()
        if async_:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ------------------------------------------------------------------ #
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") \
                    and not p.name.endswith(".tmp"):
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, *, template=None,
                shardings=None, verify: bool = True):
        """Returns (tree, meta).  With `template` (a pytree of anything with
        the target structure), leaves are re-assembled into that structure;
        otherwise a flat {path: array} dict is returned.  `shardings` (same
        structure) device_puts each leaf — this is where elastic restarts
        reshard."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {}
        for k, info in manifest["leaves"].items():
            arr = np.load(d / info["file"])
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != info["crc32"]:
                    raise IOError(f"checksum mismatch for {k} at step {step}")
            flat[k] = arr
        if template is None:
            return flat, manifest["meta"]
        tflat, treedef = _flatten(template)
        missing = set(tflat) - set(flat)
        if missing:
            raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
        leaves = [flat[k] for k in tflat]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest["meta"]
