from .checkpointer import Checkpointer
