"""Deterministic, index-addressable synthetic token pipeline.

Every (step, row, position) maps to a token via a stateless splitmix64
hash, so ANY host can recompute ANY shard of ANY step without coordination
— this is the fault-tolerance/straggler story: no data-loader state to
checkpoint or hand off, restart = recompute.
"""
from __future__ import annotations

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


class TokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed

    def _tokens(self, step: int, rows: np.ndarray) -> np.ndarray:
        pos = np.arange(self.seq_len + 1, dtype=np.uint64)[None, :]
        base = (np.uint64(self.seed) << np.uint64(48)) \
            ^ (np.uint64(step) << np.uint64(24))
        idx = base ^ (rows.astype(np.uint64)[:, None] << np.uint64(40)) ^ pos
        h = _splitmix64(idx)
        return (h % np.uint64(self.vocab_size)).astype(np.int32)

    def global_batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Full batch: tokens [B, S], labels [B, S] (next-token)."""
        rows = np.arange(self.global_batch)
        seq = self._tokens(step, rows)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def shard_at(self, step: int, shard: int, num_shards: int):
        """Rows owned by one data-parallel shard; recomputable anywhere."""
        assert self.global_batch % num_shards == 0
        per = self.global_batch // num_shards
        rows = np.arange(shard * per, (shard + 1) * per)
        seq = self._tokens(step, rows)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def mask_at(self, step: int, mask_prob: float = 0.08) -> np.ndarray:
        """Deterministic mask positions (encoder-only masked prediction)."""
        rows = np.arange(self.global_batch, dtype=np.uint64)
        pos = np.arange(self.seq_len, dtype=np.uint64)[None, :]
        idx = (np.uint64(self.seed + 7) << np.uint64(48)) \
            ^ (np.uint64(step) << np.uint64(24)) \
            ^ (rows[:, None] << np.uint64(40)) ^ pos
        h = _splitmix64(idx)
        return (h % np.uint64(10_000)) < np.uint64(int(mask_prob * 10_000))
