from .rdf_gen import (lubm_like, dblp_like, imdb_like, sp2b_like,
                      random_graph, DATASETS)
from .queries import random_query, generalize_literal, keyword_for_node
from .lm_data import TokenPipeline
