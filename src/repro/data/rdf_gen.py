"""Synthetic RDF dataset generators mimicking the paper's four workloads.

Each generator is a parameterized entity-relationship synthesizer whose
knobs target the paper's three dataset-evaluation metrics:

  coherence   <- attribute presence probability (1.0 = every instance of a
                 type carries every attribute = relational-like)
  specialty   <- target-selection distribution of relationships (zipf hubs
                 = prolific authors / busy actors -> high kurtosis)
  diversity   <- literal vocabulary size (enum pools vs open word pools)

  lubm_like : high coherence, low specialty, low diversity   (paper: LUBM)
  sp2b_like : mid coherence, low-mid specialty, mid diversity (paper: SP2B)
  dblp_like : mid-high coherence, high specialty, mid diversity (paper: DBLP)
  imdb_like : low coherence, high specialty, high diversity  (paper: IMDB)

URIs are "Type/<zero-padded id>" so a type's instances form one contiguous
IDMap interval — the paper's partial-keyword convention ("remove the long
IDs") maps to prefix lookup directly.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.graph import RDFGraph

_WORDS = np.asarray([
    "graph", "query", "index", "sparse", "neural", "learning", "database",
    "signature", "pruning", "template", "matching", "semantic", "parallel",
    "quantum", "bayesian", "optimal", "dynamic", "stream", "cloud", "secure",
    "logic", "vision", "speech", "robust", "latent", "kernel", "tensor",
    "random", "deep", "fast", "scalable", "hybrid", "adaptive", "efficient",
    "distributed", "probabilistic", "structured", "relational", "temporal",
    "spatial", "federated", "incremental", "approximate", "exact", "greedy",
    "evolutionary", "symbolic", "causal", "generative", "contrastive",
])

_FIRST = np.asarray(["wei", "jun", "anna", "ivan", "maria", "chen", "raj",
                     "sofia", "omar", "lena", "paul", "mira", "igor", "jose",
                     "akira", "nina", "tomas", "priya", "hugo", "elif"])
_LAST = np.asarray(["zhang", "kumar", "silva", "novak", "tanaka", "gruber",
                    "rossi", "olsen", "ivanov", "garcia", "kim", "chen",
                    "papas", "dubois", "moretti", "haas", "lindt", "okafor"])


_SYL = np.asarray(["ka", "ro", "mi", "ta", "lu", "ne", "si", "vo", "da",
                   "pe", "zu", "fa", "gi", "ho", "xe", "bo", "ri", "ma"])


def _word_bank(vocab_size: int) -> np.ndarray:
    """Deterministic open vocabulary: real words first, then synthetic
    syllable words ("karomi", ...) up to vocab_size."""
    if vocab_size <= len(_WORDS):
        return _WORDS[:max(2, vocab_size)]
    rng = np.random.default_rng(1234)
    extra = vocab_size - len(_WORDS)
    synth = np.asarray(["".join(rng.choice(_SYL, size=3)) for _ in range(extra)])
    return np.concatenate([_WORDS, np.unique(synth)])


def _title_pool(rng, n, vocab_size, lo=2, hi=5):
    words = _word_bank(vocab_size)
    counts = rng.integers(lo, hi + 1, n)
    return np.asarray([" ".join(rng.choice(words, size=c)) for c in counts])


def _name_pool(rng, n):
    return np.asarray([f"{rng.choice(_FIRST)} {rng.choice(_LAST)}"
                       for _ in range(n)])


def _year_pool(rng, n, lo=1980, hi=2015):
    return rng.integers(lo, hi, n).astype(str)


def _enum_pool(rng, n, k, prefix="v"):
    return np.asarray([f"{prefix}{i}" for i in rng.integers(0, k, n)])


@dataclass
class TypeSpec:
    name: str
    count: int
    # (predicate name, pool fn(rng, n), presence probability)
    attrs: list = field(default_factory=list)


@dataclass
class RelSpec:
    name: str
    src: str
    dst: str
    out_deg: tuple = ("const", 2)     # ("const", k) | ("zipf", alpha, max)
    target: tuple = ("uniform",)      # ("uniform",) | ("zipf", alpha)
    presence: float = 1.0


def _degrees(rng, n, spec):
    if spec[0] == "const":
        return np.full(n, spec[1], dtype=np.int64)
    if spec[0] == "zipf":
        _, alpha, mx = spec
        d = rng.zipf(alpha, n)
        return np.minimum(d, mx).astype(np.int64)
    raise ValueError(spec)


def _targets(rng, total, n_dst, spec):
    if spec[0] == "uniform":
        return rng.integers(0, n_dst, total)
    if spec[0] == "zipf":
        alpha = spec[1]
        ranks = rng.zipf(alpha, total)
        return np.minimum(ranks - 1, n_dst - 1)
    raise ValueError(spec)


def generate(types: list[TypeSpec], rels: list[RelSpec],
             seed: int = 0, with_types: bool = True) -> RDFGraph:
    rng = np.random.default_rng(seed)
    uris: dict[str, np.ndarray] = {}
    triples_s, triples_p, triples_o = [], [], []

    for t in types:
        uris[t.name] = np.asarray(
            [f"{t.name}/{i:08d}" for i in range(t.count)])
        if with_types:
            triples_s.append(uris[t.name])
            triples_p.append(np.full(t.count, "type"))
            triples_o.append(np.full(t.count, f"Class/{t.name}"))
        for pred, pool_fn, prob in t.attrs:
            present = rng.random(t.count) < prob
            n_present = int(present.sum())
            if n_present == 0:
                continue
            vals = pool_fn(rng, n_present)
            triples_s.append(uris[t.name][present])
            triples_p.append(np.full(n_present, pred))
            triples_o.append(vals)

    for r in rels:
        src_uris = uris[r.src]
        n_src = len(src_uris)
        present = rng.random(n_src) < r.presence
        deg = _degrees(rng, n_src, r.out_deg) * present
        total = int(deg.sum())
        if total == 0:
            continue
        s = np.repeat(src_uris, deg)
        tgt = _targets(rng, total, len(uris[r.dst]), r.target)
        o = uris[r.dst][tgt]
        triples_s.append(s)
        triples_p.append(np.full(total, r.name))
        triples_o.append(o)

    subs = np.concatenate(triples_s)
    preds = np.concatenate(triples_p)
    objs = np.concatenate(triples_o)
    # literal objects: everything that is not a generated URI / class node
    uri_set = set()
    for a in uris.values():
        uri_set.update(a.tolist())
    lit = {o for o in np.unique(objs).tolist()
           if o not in uri_set and not o.startswith("Class/")}
    return RDFGraph.from_triples(
        list(zip(subs.tolist(), preds.tolist(), objs.tolist())),
        literal_objects=lit)


# -------------------------------------------------------------------- #
# The four paper-like workloads.  `scale=1.0` ~ 60-100k triples.
# -------------------------------------------------------------------- #
def lubm_like(scale: float = 1.0, seed: int = 0) -> RDFGraph:
    s = max(1, int(1000 * scale))
    types = [
        TypeSpec("University", s // 10 + 1, attrs=[
            ("name", lambda r, n: _enum_pool(r, n, 40, "univ"), 1.0)]),
        TypeSpec("Department", s // 2 + 1, attrs=[
            ("name", lambda r, n: _enum_pool(r, n, 25, "dept"), 1.0)]),
        TypeSpec("Professor", 2 * s, attrs=[
            ("name", _name_pool, 1.0),
            ("email", lambda r, n: _enum_pool(r, n, 60, "mail"), 1.0)]),
        TypeSpec("Student", 8 * s, attrs=[
            ("name", _name_pool, 1.0)]),
        TypeSpec("Course", 3 * s, attrs=[
            ("name", lambda r, n: _enum_pool(r, n, 50, "course"), 1.0)]),
    ]
    rels = [
        RelSpec("subOrganizationOf", "Department", "University",
                ("const", 1), ("uniform",)),
        RelSpec("worksFor", "Professor", "Department",
                ("const", 1), ("uniform",)),
        RelSpec("memberOf", "Student", "Department",
                ("const", 1), ("uniform",)),
        RelSpec("takesCourse", "Student", "Course",
                ("const", 3), ("uniform",)),
        RelSpec("teacherOf", "Professor", "Course",
                ("const", 2), ("uniform",)),
        RelSpec("advisor", "Student", "Professor",
                ("const", 1), ("uniform",)),
    ]
    return generate(types, rels, seed)


def dblp_like(scale: float = 1.0, seed: int = 0) -> RDFGraph:
    s = max(1, int(1000 * scale))
    types = [
        TypeSpec("Paper", 10 * s, attrs=[
            ("title", lambda r, n: _title_pool(r, n, 400), 1.0),
            ("year", _year_pool, 0.95),
            ("pages", lambda r, n: _enum_pool(r, n, 400, "p"), 0.6),
        ]),
        TypeSpec("Author", 3 * s, attrs=[
            ("name", _name_pool, 1.0)]),
        TypeSpec("Venue", s // 5 + 2, attrs=[
            ("name", lambda r, n: _enum_pool(r, n, 80, "venue"), 1.0)]),
    ]
    rels = [
        # prolific-author hubs: zipf targets => high specialty
        RelSpec("author", "Paper", "Author", ("const", 3), ("zipf", 1.7)),
        RelSpec("venue", "Paper", "Venue", ("const", 1), ("zipf", 1.5)),
        RelSpec("cites", "Paper", "Paper", ("zipf", 2.2, 40), ("zipf", 1.9),
                presence=0.7),
    ]
    return generate(types, rels, seed)


def imdb_like(scale: float = 1.0, seed: int = 0) -> RDFGraph:
    s = max(1, int(1000 * scale))
    types = [
        TypeSpec("Movie", 6 * s, attrs=[
            ("title", lambda r, n: _title_pool(r, n, 4000, 2, 6), 1.0),
            ("year", _year_pool, 0.9),
            ("genre", lambda r, n: _enum_pool(r, n, 28, "genre"), 0.75),
            ("rating", lambda r, n: _enum_pool(r, n, 90, "r"), 0.5),
            ("language", lambda r, n: _enum_pool(r, n, 35, "lang"), 0.4),
        ]),
        TypeSpec("Actor", 4 * s, attrs=[
            ("name", _name_pool, 1.0),
            ("birthYear", _year_pool, 0.35)]),
        TypeSpec("Director", s, attrs=[
            ("name", _name_pool, 1.0)]),
    ]
    rels = [
        # busy-actor hubs, high average degree (paper: ~8 for IMDB)
        RelSpec("actedBy", "Movie", "Actor", ("const", 6), ("zipf", 1.5)),
        RelSpec("directedBy", "Movie", "Director", ("const", 1), ("zipf", 1.6)),
        RelSpec("sequelOf", "Movie", "Movie", ("const", 1), ("zipf", 2.0),
                presence=0.15),
    ]
    return generate(types, rels, seed)


def sp2b_like(scale: float = 1.0, seed: int = 0) -> RDFGraph:
    s = max(1, int(1000 * scale))
    types = [
        TypeSpec("Article", 8 * s, attrs=[
            ("title", lambda r, n: _title_pool(r, n, 30), 1.0),
            ("year", _year_pool, 0.85),
            ("abstract", lambda r, n: _title_pool(r, n, 30, 4, 8), 0.55),
        ]),
        TypeSpec("Person", 3 * s, attrs=[
            ("name", _name_pool, 1.0)]),
        TypeSpec("Journal", s // 4 + 2, attrs=[
            ("name", lambda r, n: _enum_pool(r, n, 60, "jrnl"), 1.0)]),
    ]
    rels = [
        # weaker hubs than dblp (SP2B is synthetic-DBLP: milder kurtosis)
        RelSpec("creator", "Article", "Person", ("const", 2), ("zipf", 2.6)),
        RelSpec("journal", "Article", "Journal", ("const", 1), ("uniform",)),
        RelSpec("references", "Article", "Article", ("zipf", 2.6, 20),
                ("zipf", 2.6), presence=0.5),
    ]
    return generate(types, rels, seed)


def random_graph(n_nodes: int = 200, n_edges: int = 500, n_preds: int = 4,
                 n_literals: int = 50, seed: int = 0) -> RDFGraph:
    """Small arbitrary graph for property tests (no structure guarantees)."""
    rng = np.random.default_rng(seed)
    res = [f"R/{i:04d}" for i in range(n_nodes)]
    lits = [f"lit {i:03d}" for i in range(n_literals)]
    triples = []
    for _ in range(n_edges):
        s = res[rng.integers(0, n_nodes)]
        p = f"p{rng.integers(0, n_preds)}"
        if rng.random() < 0.3:
            o = lits[rng.integers(0, n_literals)]
        else:
            o = res[rng.integers(0, n_nodes)]
        triples.append((s, p, o))
    return RDFGraph.from_triples(triples, literal_objects=set(lits))


DATASETS = {
    "lubm": lubm_like,
    "sp2b": sp2b_like,
    "dblp": dblp_like,
    "imdb": imdb_like,
}
