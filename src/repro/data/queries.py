"""Random query template generation (paper §6).

Queries are sampled subgraphs of the data graph (guaranteeing >=1 match),
then labels are *generalized* into partial keywords:
  - resource URIs: drop the long id, keep the "Type/" prefix;
  - literals: strip trailing characters until the prefix matches 1..200
    labels in the graph (random choice among valid cut points).
Optionally, template edges are rewritten into connection edges with a
distance constraint, or an extra connection edge is added between two
random template nodes.
"""
from __future__ import annotations

import numpy as np

from ..core.graph import RDFGraph, IDMap, LITERAL
from ..core.query import QueryTemplate, QueryEdge, ConnectionEdge


def generalize_literal(idmap: IDMap, label: str, rng,
                       lo_matches: int = 1, hi_matches: int = 200) -> str:
    """Strip last chars until the prefix matches [lo, hi] labels."""
    options = []
    for cut in range(len(label), 0, -1):
        p = label[:cut]
        c = idmap.cardinality(p)
        if lo_matches <= c <= hi_matches:
            options.append(p)
        if c > hi_matches:
            break
    if not options:
        return label
    return options[rng.integers(0, len(options))]


def keyword_for_node(graph: RDFGraph, idmap: IDMap, node: int, rng) -> str:
    label = str(graph.labels[node])
    if graph.node_kind[node] == LITERAL:
        return generalize_literal(idmap, label, rng)
    if "/" in label:                       # URI: strip the long id
        return label.split("/")[0] + "/"
    return generalize_literal(idmap, label, rng)


def random_query(graph: RDFGraph, size: int = 6, seed: int = 0,
                 n_connection: int = 0, d_c: int = 4,
                 exact_nodes: float = 0.0) -> QueryTemplate:
    """Sample a connected subgraph with `size` nodes; generalize labels.

    n_connection template edges are converted to connection edges (their
    endpoints stay in the template).  exact_nodes: probability a node keeps
    its full label (exact match) instead of a generalized keyword.
    """
    rng = np.random.default_rng(seed)
    idmap = IDMap(graph)
    out_indptr, out_nbr, out_pred = graph.out_csr
    in_indptr, in_nbr, in_pred = graph.in_csr

    # --- grow a random connected subgraph -----------------------------
    # templates whose keyword multiset has >= 3 copies of one keyword are
    # rejected (symmetric candidate explosion: k interchangeable query
    # nodes multiply the result set by ~|C|^k) and resampled.
    for _attempt in range(64):
        e0 = int(rng.integers(0, graph.num_edges))
        nodes = [int(graph.src[e0]), int(graph.dst[e0])]
        edges = [(int(graph.src[e0]), int(graph.dst[e0]), int(graph.pred[e0]))]
        seen_edges = {e0}
        stall = 0
        while len(nodes) < size and stall < 200:
            v = nodes[rng.integers(0, len(nodes))]
            # random incident edge (either direction)
            cands = []
            s, e = out_indptr[v], out_indptr[v + 1]
            cands += [(v, int(out_nbr[i]), int(out_pred[i]))
                      for i in range(s, e)]
            s, e = in_indptr[v], in_indptr[v + 1]
            cands += [(int(in_nbr[i]), v, int(in_pred[i]))
                      for i in range(s, e)]
            if not cands:
                stall += 1
                continue
            s2, d2, p2 = cands[rng.integers(0, len(cands))]
            key = (s2, d2, p2)
            if key in [(a, b, p) for a, b, p in edges]:
                stall += 1
                continue
            edges.append(key)
            for x in (s2, d2):
                if x not in nodes:
                    nodes.append(x)
            stall = 0
        if len(nodes) < min(size, 3):
            continue
        keywords = []
        for g in nodes:
            if rng.random() < exact_nodes:
                keywords.append(str(graph.labels[g]))
            else:
                keywords.append(keyword_for_node(graph, idmap, g, rng))
        from collections import Counter
        if max(Counter(keywords).values()) <= 2:
            break
    node_idx = {g: i for i, g in enumerate(nodes)}

    qedges = [QueryEdge(node_idx[s], node_idx[d], p) for s, d, p in edges]
    conns: list[ConnectionEdge] = []

    # --- convert some edges to connection edges ------------------------
    rng.shuffle(qedges)
    for _ in range(min(n_connection, max(len(qedges) - 1, 0))):
        e = qedges.pop()
        conns.append(ConnectionEdge(e.src, e.dst, d_c))
    return QueryTemplate(keywords=keywords, edges=qedges, connections=conns)
