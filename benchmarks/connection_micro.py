"""Connection-edge micro-benchmark: reach-join vs cross+filter.

Sweeps one connection edge between two candidate tables over table sizes,
distinct-endpoint ratios, and distance constraints d_c (including
d_c > d_max, the exact-BFS fallback regime).  The baseline is the seed
cross-product + per-pair connectivity_mask path; the contender is the
device-resident reach-join (distinct endpoints -> reach-set pair tables ->
one sort-merge join on reach_id -> output-bounded equi-joins).

Result-set identity is asserted at every point where both impls run —
including the flagship 1e4x1e4-row edge with 1e3 distinct endpoints per
side — and across the engine-level connection_impl x plan_mode grid.
Emits BENCH_conn.json.

REPRO_BENCH_CONN_SMOKE=1 restricts to CI-sized tables (no flagship).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (build_ni_index, connectivity_mask, cross_join,
                        filter_rows, Dataset, reach_join, ReachCache,
                        ReachJoinInfo)
from repro.core.matching import Table, _pow2
from repro.data import random_graph, random_query

REPEATS = 3
CROSS_MAX_PAIRS = 1_200_000     # repeat-timed cross baseline up to here
SMOKE = bool(int(os.environ.get("REPRO_BENCH_CONN_SMOKE", "0")))
# (rows per side, distinct endpoints per side)
POINTS = ([(1_000, 100), (1_000, 1_000)] if SMOKE else
          [(1_000, 100), (1_000, 1_000), (10_000, 100), (10_000, 10_000)])
DCS = (2, 5)                    # covered by d_max=2 / BFS-fallback regime
FLAGSHIP = (10_000, 1_000, 2)   # rows, distinct, d_c — acceptance point


def _mk(col, vals):
    vals = np.asarray(vals, np.int32)
    rows = np.full((_pow2(len(vals)), 1), -1, np.int32)
    rows[: len(vals), 0] = vals
    return Table(cols=(col,), rows=jnp.asarray(rows), count=len(vals))


def _time(fn, repeats=REPEATS, warm=True):
    """(best us, last output).  warm=False skips the warm-up call — used
    for the minutes-slow flagship cross baseline so it executes exactly
    once (jit compile time is noise at that scale)."""
    if warm:
        fn()                                    # warm: jit + first shapes
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        out.rows.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out                      # us


def _cross_filter(g, ni, ta, tb, d_c):
    x = cross_join(ta, tb)
    rows = np.asarray(x.rows[: x.count])
    keep = connectivity_mask(g, ni, rows[:, 0], rows[:, 1], d_c)
    return filter_rows(x, keep)


def _sweep_point(g, ni, rng, rows, distinct, d_c, run_cross, repeats):
    pa = rng.choice(g.num_nodes, distinct, replace=False)
    pb = rng.choice(g.num_nodes, distinct, replace=False)
    ta = _mk(0, rng.choice(pa, rows))
    tb = _mk(1, rng.choice(pb, rows))
    cell = {}

    def run_reach():
        info = ReachJoinInfo()                  # fresh per call: the info
        out = reach_join(g, ni, ta, tb, 0, 1, d_c,  # fields accumulate
                         cache=ReachCache(), info=info)
        cell["info"] = info
        return out
    reach_us, out = _time(run_reach, repeats)
    info = cell["info"]
    rec = {"rows": rows, "distinct": distinct, "d_c": d_c,
           "reach_us": reach_us, "cross_us": None, "speedup": None,
           "matches": out.count, "reach_pairs": info.reach_pairs,
           "connected_pairs": info.connected_pairs,
           "peak_cap": info.peak_cap, "identity": None}
    if run_cross:
        # the timed run's output doubles as the identity oracle; no
        # warm-up when repeats == 1 so the flagship baseline runs once
        cross_us, want = _time(lambda: _cross_filter(g, ni, ta, tb, d_c),
                               repeats, warm=repeats > 1)
        rec["identity"] = out.result_set() == want.result_set()
        assert rec["identity"], f"result mismatch at {rows}x{distinct}"
        rec["cross_us"] = cross_us
        rec["speedup"] = cross_us / reach_us
    return rec


def _engine_identity_grid():
    """connection_impl x plan_mode grid on a query with connection edges:
    identical result sets across all four configurations."""
    g = random_graph(n_nodes=400, n_edges=1400, n_preds=3, seed=77)
    q = random_query(g, size=5, seed=5, n_connection=2, d_c=3)
    results = {}
    ds = Dataset.build(g, variant="h2")
    for ci in ("reach", "cross"):
        for pm in ("cost", "greedy"):
            eng = ds.engine("h2")
            eng.cfg.connection_impl = ci
            eng.cfg.plan_mode = pm
            results[f"{ci}/{pm}"] = eng.execute(q).result_set()
    vals = list(results.values())
    ok = all(v == vals[0] for v in vals)
    assert ok, "engine connection_impl x plan_mode results diverge"
    return ok, len(vals[0])


def run():
    n_nodes = 4_000 if SMOKE else 20_000
    g = random_graph(n_nodes=n_nodes, n_edges=2 * n_nodes, n_preds=2,
                     seed=42)
    ni = build_ni_index(g, d_max=2)
    rng = np.random.default_rng(0)
    results = {"graph": {"nodes": g.num_nodes, "edges": g.num_edges,
                         "d_max": ni.d_max},
               "smoke": SMOKE, "sweep": [], "flagship": None}

    for rows, distinct in POINTS:
        for d_c in DCS:
            run_cross = rows * rows <= CROSS_MAX_PAIRS
            rec = _sweep_point(g, ni, rng, rows, distinct, d_c,
                               run_cross, REPEATS)
            results["sweep"].append(rec)
            tag = f"conn.reach.{rows}x{distinct}.d{d_c}"
            if rec["speedup"] is not None:
                yield (tag, rec["reach_us"],
                       f"speedup={rec['speedup']:.1f}x")
            else:
                yield (tag, rec["reach_us"], f"matches={rec['matches']}")

    if not SMOKE:
        # acceptance point: 1e4x1e4-row edge, 1e3 distinct per side; the
        # cross baseline materializes the 1e8-pair product and filters it
        # with the per-pair host loop — timed once (it is minutes-slow)
        rows, distinct, d_c = FLAGSHIP
        rec = _sweep_point(g, ni, rng, rows, distinct, d_c,
                           run_cross=True, repeats=1)
        results["flagship"] = rec
        yield (f"conn.flagship.{rows}x{distinct}.d{d_c}", rec["reach_us"],
               f"speedup={rec['speedup']:.1f}x")

    ok, n = _engine_identity_grid()
    results["engine_identity"] = {"ok": ok, "matches": n}
    yield ("conn.engine_identity", 0.0, f"ok={ok} matches={n}")

    out_path = os.environ.get("REPRO_BENCH_CONN_JSON", "BENCH_conn.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
