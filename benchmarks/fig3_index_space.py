"""Paper Fig. 3: index space as % of raw dataset size, per NI variant.

Validates C3: space grows sharply with d_max, steeper for high-degree
graphs (LUBM/IMDB ~deg 5 vs SP2B/DBLP ~deg 3)."""
from __future__ import annotations

import time

from repro.core import IDMap
from .common import get_graph, get_ni


def run(scale=None):
    for name in ("lubm", "sp2b", "dblp", "imdb"):
        g = get_graph(name, scale)
        base = g.size_bytes()
        idm = IDMap(g)
        yield (f"fig3.{name}.idmap_pct", 0.0,
               round(100 * idm.size_bytes() / base, 2))
        for label, d, var in (("1hop", 1, "full"), ("2hop", 2, "full"),
                              ("3hop", 3, "full"), ("vc", 2, "vc")):
            t0 = time.perf_counter()
            ni = get_ni(g, d, var)
            us = (time.perf_counter() - t0) * 1e6
            yield (f"fig3.{name}.ni_{label}_pct", us,
                   round(100 * ni.size_bytes() / base, 2))
