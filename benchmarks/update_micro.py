"""Mutable-graph micro-benchmark -> BENCH_update.json.

Measures the two costs the versioned ``Dataset`` API was built to cut:

1. **Delta ingest** — ``Dataset.apply_delta`` (incremental CSR patch +
   touched-node NI recompute) vs a full rebuild from triples, across
   delta sizes 1e1..1e4 against a ~1e5-edge graph.  The headline claim:
   at <=1% churn the incremental path is >= 5x faster than rebuilding.
2. **Result-cache serving** — warm latency of an exact repeat with the
   version-scoped ResultCache on (hit: no engine execution) vs off
   (miss: plan-cache hit, full execution).

Every incremental ingest is parity-checked against the rebuilt oracle
by content digest, so the speedup numbers can't come from skipped work.

Smoke mode (REPRO_BENCH_UPDATE_SMOKE=1, used by CI) shrinks the graph
and the delta grid so the whole module runs in a few seconds.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import Dataset
from repro.data import random_graph, random_query
from repro.serve import QueryServer

SMOKE = os.environ.get("REPRO_BENCH_UPDATE_SMOKE", "") not in ("", "0")
N_EDGES = 8_000 if SMOKE else 100_000
N_NODES = N_EDGES // 4
DELTA_SIZES = (10, 100) if SMOKE else (10, 100, 1_000, 10_000)
REPS = 2 if SMOKE else 3
WARM_REPS = 5 if SMOKE else 20


def _base_dataset(seed: int = 1):
    g = random_graph(n_nodes=N_NODES, n_edges=N_EDGES, n_preds=8,
                     n_literals=N_NODES // 8, seed=seed)
    return Dataset.build(g, variant="rdf_h")


def _make_delta(ds, n, seed):
    """n deletes that keep every label alive + n recombination inserts,
    so the incremental path is eligible (no new labels, no orphans)."""
    g = ds.graph
    rng = np.random.default_rng(seed)
    subj = np.bincount(g.src, minlength=g.num_nodes)
    ment = subj + np.bincount(g.dst, minlength=g.num_nodes)
    # greedy pick: a delete is accepted only while both endpoints keep
    # >= 2 mentions and the subject keeps >= 1 outgoing edge, so even a
    # large batch can't jointly orphan a label or flip a node's kind
    dels = []
    for i in rng.permutation(g.num_edges):
        s, d = g.src[i], g.dst[i]
        if ment[s] >= 3 and ment[d] >= 3 and subj[s] >= 2:
            dels.append(i)
            ment[s] -= 1
            ment[d] -= 1
            subj[s] -= 1
            if len(dels) == n:
                break
    deletes = [(g.labels[g.src[i]], g.predicates[g.pred[i]],
                g.labels[g.dst[i]]) for i in dels]
    picks = rng.choice(g.num_edges, size=2 * n, replace=False)
    inserts = [(g.labels[g.src[i]], g.predicates[g.pred[i]],
                g.labels[g.dst[j]])
               for i, j in zip(picks, np.roll(picks, 1))
               if g.pred[i] == g.pred[j]][:n]
    return inserts, deletes


def _time(fn, reps):
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


# ----------------- incremental ingest vs full rebuild ------------------ #
def _ingest_grid(ds):
    rows = []
    for n in DELTA_SIZES:
        inserts, deletes = _make_delta(ds, n, seed=n)
        churn = (len(inserts) + len(deletes)) / ds.graph.num_edges
        inc_s, inc_ds = _time(
            lambda: ds.apply_delta(inserts, deletes, churn_threshold=1.0),
            REPS)
        reb_s, reb_ds = _time(
            lambda: ds.apply_delta(inserts, deletes, churn_threshold=-1.0),
            REPS)
        assert inc_ds.delta_info["mode"] == "incremental"
        assert reb_ds.delta_info["mode"] == "rebuild"
        assert inc_ds.digest == reb_ds.digest, "parity vs rebuilt oracle"
        rows.append({
            "delta_edges": len(inserts) + len(deletes),
            "churn": churn,
            "incremental_ms": inc_s * 1e3,
            "rebuild_ms": reb_s * 1e3,
            "speedup": reb_s / max(inc_s, 1e-9),
            "touched_nodes": int(inc_ds.delta_info["touched"]),
            "default_policy_mode": ds.apply_delta(
                inserts, deletes).delta_info["mode"],
        })
    low_churn = [r for r in rows if r["churn"] <= 0.01]
    return {
        "graph_edges": int(ds.graph.num_edges),
        "graph_nodes": int(ds.graph.num_nodes),
        "grid": rows,
        "low_churn_min_speedup": min((r["speedup"] for r in low_churn),
                                     default=None),
        "low_churn_speedup_ge_5": bool(low_churn) and all(
            r["speedup"] >= 5 for r in low_churn),
    }


# ------------------- result-cache hit vs warm miss --------------------- #
def _result_cache_latency(ds):
    pool = [random_query(ds.graph, size=4, seed=900 + i) for i in range(3)]
    out = {"templates": []}
    hit_srv = QueryServer(ds, batching=False, calibrate=False,
                          result_cache_size=64)
    miss_srv = QueryServer(ds, batching=False, calibrate=False)
    for q in pool:
        ref = miss_srv.query(q).result_set()       # warms the plan cache
        r = hit_srv.query(q)                       # warms plan + result
        assert r.result_set() == ref
        miss_s, _ = _time(lambda: miss_srv.query(q), WARM_REPS)
        hit_s, r = _time(lambda: hit_srv.query(q), WARM_REPS)
        assert r.stats.result_cache_hit and r.result_set() == ref
        out["templates"].append({
            "warm_miss_us": miss_s * 1e6,
            "hit_us": hit_s * 1e6,
            "speedup": miss_s / max(hit_s, 1e-9),
        })
    t = hit_srv.telemetry()["result_cache"]
    out["hit_rate"] = t["hit_rate"]
    out["median_speedup"] = float(np.median(
        [r["speedup"] for r in out["templates"]]))
    return out


def run():
    ds = _base_dataset()
    results = {"bench": "update", "smoke": SMOKE,
               "n_edges": N_EDGES, "delta_sizes": list(DELTA_SIZES)}

    results["ingest"] = _ingest_grid(ds)
    for row in results["ingest"]["grid"]:
        yield (f"update.apply_delta[{row['delta_edges']}]",
               row["incremental_ms"] * 1e3,
               f"rebuild={row['rebuild_ms']:.1f}ms "
               f"speedup={row['speedup']:.1f}x "
               f"churn={row['churn']:.4f} "
               f"policy={row['default_policy_mode']}")
    yield ("update.low_churn_speedup_ge_5", 0.0,
           results["ingest"]["low_churn_speedup_ge_5"])

    results["result_cache"] = _result_cache_latency(ds)
    yield ("update.result_cache_hit",
           float(np.median([r["hit_us"]
                            for r in results["result_cache"]["templates"]])),
           f"median_speedup={results['result_cache']['median_speedup']:.1f}x")
    yield ("update.result_cache_warm_miss",
           float(np.median([r["warm_miss_us"]
                            for r in results["result_cache"]["templates"]])),
           f"hit_rate={results['result_cache']['hit_rate']:.2f}")

    out_path = os.environ.get("REPRO_BENCH_UPDATE_JSON", "BENCH_update.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    yield ("update.json_written", 0.0, out_path)


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}", flush=True)
