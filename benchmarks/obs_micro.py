"""Observability overhead micro-benchmark -> BENCH_obs.json.

Tracing must be ~free when off and cheap when on:

  * null_span — ns per disabled span enter/exit (the NULL_TRACER fast
    path every engine join pays when no tracer is installed) vs. a live
    span on an enabled tracer;
  * serve_overhead — the same warm zipfian template stream through two
    QueryServers, tracer off vs. on, reporting the median-latency
    overhead of full tracing (submit/prepare/execute segments, governor
    spans, per-join engine spans) plus a second tracer-off run as the
    noise floor.  Result sets are asserted identical — tracing must
    never change semantics;
  * chrome_export — the enabled run's trace buffer exported to the
    Chrome trace event format and structurally validated (one thread
    lane per query, every complete event carrying its trace id).

Smoke mode (REPRO_BENCH_OBS_SMOKE=1, used by CI) shrinks the dataset
and stream so the module runs in seconds.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import Dataset
from repro.data import DATASETS, random_query
from repro.obs import Tracer
from repro.serve import QueryServer

SMOKE = os.environ.get("REPRO_BENCH_OBS_SMOKE", "") not in ("", "0")
SCALE = 0.03 if SMOKE else float(os.environ.get("REPRO_BENCH_SCALE", "0.08"))
N_TEMPLATES = 4 if SMOKE else 6
N_STREAM = 24 if SMOKE else 80
N_NULL = 50_000 if SMOKE else 200_000


def _workload(seed: int = 1):
    g = DATASETS["dblp"](scale=SCALE, seed=seed)
    ds = Dataset.build(g, variant="rdf_h")
    pool = [random_query(g, size=5, seed=100 + i, n_connection=i % 2, d_c=3)
            for i in range(N_TEMPLATES)]
    rng = np.random.default_rng(0)
    ranks = np.minimum(rng.zipf(1.3, N_STREAM), len(pool)) - 1
    return ds, pool, [pool[r] for r in ranks]


# ----------------------------- null spans ------------------------------ #
def _span_cost(tracer, open_segment: bool) -> float:
    """ns per span enter/exit.  With `open_segment` the span is live
    (appended, clocked, popped); otherwise it is the shared NULL_SPAN."""
    tid = tracer.start() if open_segment else None
    seg = tracer.segment("bench", tid) if open_segment else None
    n = N_NULL
    t0 = time.perf_counter()
    for _ in range(n):
        with tracer.span("x") as sp:
            if sp.live:
                sp.set(rows=1)
    wall = time.perf_counter() - t0
    if seg is not None:
        seg.__exit__(None, None, None)
        tracer.finish(tid)
    return wall / n * 1e9


def _null_span():
    from repro.obs import NULL_TRACER
    off_ns = _span_cost(NULL_TRACER, open_segment=False)
    # live spans under a capacious trace (the span cap would null them)
    on_ns = _span_cost(Tracer(max_spans_per_trace=N_NULL + 4),
                       open_segment=True)
    return {"disabled_ns_per_span": off_ns,
            "enabled_ns_per_span": on_ns}


# --------------------------- serving overhead -------------------------- #
def _serve(ds, pool, stream, tracer):
    srv = QueryServer(ds, calibrate=False, tracer=tracer)
    for q in pool:                       # warm plans + jit shapes first
        srv.query(q)
    lats, sets = [], []
    for s in range(0, len(stream), 8):
        for f in srv.submit_many(stream[s:s + 8], wait=True):
            sets.append(f.result().result_set())
            lats.append(f.latency)
    return float(np.median(lats)), sets, srv


def _serve_overhead(ds, pool, stream):
    cap = Tracer(max_traces=len(stream) + len(pool) + 4)
    off1, sets_off, _ = _serve(ds, pool, stream, None)
    on, sets_on, srv_on = _serve(ds, pool, stream, cap)
    off2, sets_off2, _ = _serve(ds, pool, stream, None)
    identical = sets_off == sets_on == sets_off2
    base = min(off1, off2)
    noise_pct = abs(off1 - off2) / base * 100.0
    overhead_pct = (on - base) / base * 100.0
    return {
        "off_median_ms": off1 * 1e3,
        "off_rerun_median_ms": off2 * 1e3,
        "on_median_ms": on * 1e3,
        "noise_floor_pct": noise_pct,
        "overhead_pct": overhead_pct,
        "overhead_within_5pct": overhead_pct <= max(5.0, noise_pct),
        "identical_result_sets": identical,
    }, srv_on


# ---------------------------- chrome export ---------------------------- #
def _chrome_export(srv, n_queries: int):
    path = os.environ.get("REPRO_BENCH_OBS_TRACE", "BENCH_obs_trace.json")
    info = srv.tracer.export_chrome(path)
    with open(path) as f:
        doc = json.load(f)
    assert sorted(doc) == ["displayTimeUnit", "traceEvents"]
    by_tid: dict = {}
    for ev in doc["traceEvents"]:
        if ev["ph"] == "X":
            assert sorted(ev) == ["args", "dur", "name", "ph", "pid",
                                  "tid", "ts"]
            by_tid.setdefault(ev["tid"], set()).add(ev["args"]["trace_id"])
    assert all(len(ids) == 1 for ids in by_tid.values()), \
        "a thread lane mixed trace ids"
    assert info["traces"] >= n_queries, \
        f"expected >= {n_queries} traces, exported {info['traces']}"
    return {"path": info["path"], "traces": info["traces"],
            "events": info["events"], "valid": True}


# ---------------------------------------------------------------------- #
def run():
    ds, pool, stream = _workload()
    results = {"scale": SCALE, "n_templates": N_TEMPLATES,
               "n_stream": N_STREAM, "smoke": SMOKE}

    results["null_span"] = _null_span()
    ns = results["null_span"]
    yield ("obs.null_span", ns["disabled_ns_per_span"] / 1e3,
           f"disabled={ns['disabled_ns_per_span']:.0f}ns "
           f"enabled={ns['enabled_ns_per_span']:.0f}ns")

    results["serve_overhead"], srv_on = _serve_overhead(ds, pool, stream)
    so = results["serve_overhead"]
    assert so["identical_result_sets"], "tracing changed result sets"
    yield ("obs.serve_traced", so["on_median_ms"] * 1e3,
           f"overhead={so['overhead_pct']:.1f}% "
           f"noise={so['noise_floor_pct']:.1f}% "
           f"identical={so['identical_result_sets']}")

    results["chrome_export"] = _chrome_export(srv_on,
                                              len(stream) + len(pool))
    ce = results["chrome_export"]
    yield ("obs.chrome_export", float(ce["events"]),
           f"traces={ce['traces']} events={ce['events']} "
           f"valid={ce['valid']}")

    out_path = os.environ.get("REPRO_BENCH_OBS_JSON", "BENCH_obs.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
