"""Join micro-benchmark: nested-loop vs sort-merge equi-join.

Sweeps square table sizes 1e2-1e5 with unit-average fanout (key domain ==
table size, so |out| ~ |in|), timing warm jitted runs of both strategies
plus the planner's 'auto' pick at the small end.  Emits BENCH_join.json so
future PRs can track the speedup trajectory.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax.numpy as jnp

from repro.core.matching import Table, join_tables, _pow2

SIZES = (100, 1_000, 10_000, 100_000)
NESTED_MAX_SIZE = 10_000        # nested above this is minutes-slow on CPU
SMALL = 256                     # planner hands tables this size to nested
REPEATS = 3


def _mk(cols, n, domain, seed):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, max(domain, 1), (n, len(cols))).astype(np.int32)
    cap = _pow2(n)
    rows = np.full((cap, len(cols)), -1, np.int32)
    rows[:n] = data
    return Table(cols=tuple(cols), rows=jnp.asarray(rows), count=n)


def _time(fn, repeats=REPEATS):
    fn()                                        # warm: jit + first shapes
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        out.rows.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6                           # us


def run():
    results = {"sizes": [], "nested_us": [], "sorted_us": [],
               "speedup": [], "small": {}}
    for n in SIZES:
        a = _mk((0, 1), n, n, seed=n)
        b = _mk((1, 2), n, n, seed=n + 1)
        sorted_us = _time(lambda: join_tables(a, b, impl="sorted"))
        if n <= NESTED_MAX_SIZE:
            nested_us = _time(lambda: join_tables(a, b, impl="nested"))
        else:
            nested_us = None
        results["sizes"].append(n)
        results["nested_us"].append(nested_us)
        results["sorted_us"].append(sorted_us)
        speedup = (nested_us / sorted_us) if nested_us else None
        results["speedup"].append(speedup)
        yield (f"join.sorted.{n}", sorted_us, f"rows={n}")
        if nested_us is not None:
            yield (f"join.nested.{n}", nested_us,
                   f"speedup={speedup:.1f}x")

    # small-table regime: the planner must not regress vs pure nested
    a = _mk((0, 1), SMALL, SMALL, seed=9)
    b = _mk((1, 2), SMALL, SMALL, seed=10)
    auto_us = _time(lambda: join_tables(a, b, impl="auto"))
    nested_us = _time(lambda: join_tables(a, b, impl="nested"))
    ratio = auto_us / nested_us
    results["small"] = {"size": SMALL, "auto_us": auto_us,
                        "nested_us": nested_us, "auto_over_nested": ratio}
    yield (f"join.auto_small.{SMALL}", auto_us,
           f"auto/nested={ratio:.2f}")

    out_path = os.environ.get("REPRO_BENCH_JOIN_JSON", "BENCH_join.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
