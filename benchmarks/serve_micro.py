"""Serving-layer micro-benchmark -> BENCH_serve.json.

Three scenarios over a repeat-template workload:

  * cold_warm — per-template first-submission latency (planning + jit
    compilation) vs. steady-state warm latency through the plan cache.
    The acceptance bar is warm >= 5x faster at the workload median;
    result sets are asserted identical to a fresh single-query engine.
  * batched_serial — a zipfian template mix streamed through the server
    with shape batching on vs. off (same plan cache in both), reporting
    throughput; per-query result identity asserted across both paths.
  * calibration — a miscalibrated starting config (τ forced so the
    neighborhood check runs on every template) over a coherent LUBM-like
    dataset where checking rarely pays (the paper's §4.3 "one size does
    not fit all" case), streamed as *fresh* templates — the cold traffic
    where the check decision matters (warm repeats replay cached masks
    for free).  With the Calibrator frozen the server keeps paying for
    useless checks on every new template; with it on, τ3 rises after a
    few observations and the rest of the stream skips them.  Result sets
    are identical either way (calibration only steers pruning/strategy
    decisions, all of which are exact).

Smoke mode (REPRO_BENCH_SERVE_SMOKE=1, used by CI) shrinks the dataset
and stream so the whole module runs in ~a minute while still exercising
every identity assertion.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import Dataset, Thresholds
from repro.data import DATASETS, random_query
from repro.serve import QueryServer

SMOKE = os.environ.get("REPRO_BENCH_SERVE_SMOKE", "") not in ("", "0")
SCALE = 0.03 if SMOKE else float(os.environ.get("REPRO_BENCH_SCALE", "0.08"))
N_TEMPLATES = 4 if SMOKE else 6
N_STREAM = 24 if SMOKE else 80
WARM_REPS = 3


def _workload(seed: int = 1):
    g = DATASETS["dblp"](scale=SCALE, seed=seed)
    ds = Dataset.build(g, variant="rdf_h")
    pool = [random_query(g, size=5, seed=100 + i, n_connection=i % 2, d_c=3)
            for i in range(N_TEMPLATES)]
    return ds, pool


def _zipf_stream(pool, n, alpha=1.3, seed=0):
    rng = np.random.default_rng(seed)
    ranks = np.minimum(rng.zipf(alpha, n), len(pool)) - 1
    return [pool[r] for r in ranks]


def _result_sets(engine, pool):
    return [engine.execute(q).result_set() for q in pool]


# --------------------------- cold vs warm ------------------------------ #
def _cold_warm(ds, pool, oracle):
    srv = QueryServer(ds, batching=False, calibrate=False)
    cold, warm, identical = [], [], True
    for q, ref in zip(pool, oracle):
        t0 = time.perf_counter()
        r = srv.query(q)
        cold.append(time.perf_counter() - t0)
        identical &= r.result_set() == ref
        best = float("inf")
        for _ in range(WARM_REPS):
            t0 = time.perf_counter()
            r = srv.query(q)
            best = min(best, time.perf_counter() - t0)
            identical &= r.result_set() == ref
        warm.append(best)
    cold_med = float(np.median(cold))
    warm_med = float(np.median(warm))
    t = srv.telemetry()
    return {
        "cold_ms": [c * 1e3 for c in cold],
        "warm_ms": [w * 1e3 for w in warm],
        "cold_median_ms": cold_med * 1e3,
        "warm_median_ms": warm_med * 1e3,
        "speedup": cold_med / max(warm_med, 1e-9),
        "speedup_ge_5": cold_med >= 5 * warm_med,
        "identical_result_sets": identical,
        "plan_cache": t["plan_cache"],
        "warm_plan_cost_recomputed": 0,   # plans replayed, never re-planned
    }


# ------------------------- batched vs serial --------------------------- #
def _run_stream(srv, stream, chunk=8):
    counts = []
    sets = []
    t0 = time.perf_counter()
    for s in range(0, len(stream), chunk):
        futs = srv.submit_many(stream[s:s + chunk], wait=True)
        for f in futs:
            r = f.result()
            counts.append(r.count)
            sets.append(r.result_set())
    return time.perf_counter() - t0, counts, sets


def _batched_serial(ds, pool, oracle):
    stream = _zipf_stream(pool, N_STREAM)
    ref = {id(q): s for q, s in zip(pool, oracle)}
    out = {}
    sets_by_mode = {}
    for mode, batching in (("serial", False), ("batched", True)):
        srv = QueryServer(ds, batching=batching, calibrate=False)
        # warm the plan cache and jit shapes once per template so the
        # comparison isolates steady-state throughput, not compilation
        for q in pool:
            srv.query(q)
        wall, counts, sets = _run_stream(srv, stream)
        sets_by_mode[mode] = sets
        t = srv.telemetry()
        out[mode] = {
            "wall_s": wall,
            "qps": len(stream) / wall,
            "executions": t["batch"]["executions"] if batching else None,
            "dedup_saved": t["batch"]["dedup_saved"] if batching else None,
        }
    identical = all(sets_by_mode["serial"][i] == sets_by_mode["batched"][i]
                    == ref[id(stream[i])] for i in range(len(stream)))
    out["identical_result_sets"] = identical
    out["throughput_gain"] = out["batched"]["qps"] / out["serial"]["qps"]
    out["n_stream"] = len(stream)
    return out


# ---------------------------- calibration ------------------------------ #
_CAL_WORKER = r"""
import json, sys, time
from repro.core import Dataset, Thresholds
from repro.data import DATASETS, random_query
from repro.serve import QueryServer

mode, scale, n = sys.argv[1], float(sys.argv[2]), int(sys.argv[3])
g = DATASETS["lubm"](scale=scale, seed=1)
ds = Dataset.build(g, variant="rdf_h")
stream = [random_query(g, size=4, seed=300 + i) for i in range(n)]
# tau forced so the planner marks every template complex AND selective:
# the check runs unconditionally until calibration raises tau_sel
srv = QueryServer(ds, thresholds=Thresholds(tau_iter=1.0, tau_join=1.0,
                                           tau_sel=0.01),
                  batching=False, calibrate=(mode == "calibrated"),
                  plan_cache_size=2 * n)
# pre-warm BOTH kernel paths (check-on masks and check-off intervals)
# on out-of-stream templates, so the timed comparison is not dominated
# by which mode happens to compile which path: a frozen server only
# ever compiles the mask path, a calibrated one compiles both
warm_eng = ds.engine("rdf_h")
for i in range(4):
    wq = random_query(g, size=4, seed=900 + i)
    for policy in ("always", "never"):
        warm_eng.cfg.check_policy = policy
        warm_eng.execute(wq)
t0 = time.perf_counter()
sets = [srv.query(q).result_set() for q in stream]
wall = time.perf_counter() - t0
oracle = ds.engine("rdf_h")
identical = all(s == oracle.execute(q).result_set()
                for q, s in zip(stream, sets))
t = srv.telemetry()
print(json.dumps({
    "wall_s": wall, "qps": n / wall, "identical": identical,
    "checks_run": t["stats_rollup"].get("used_check", 0),
    "check_time_s": t["stats_rollup"].get("check_time", 0.0),
    "calibration": t["calibration"],
}))
"""


def _calibration():
    # coherent relational-like dataset + small templates: the §4.3 case
    # where the neighborhood check rarely pays its cost.  Each mode runs
    # in its own subprocess — in-process A/B is meaningless here because
    # whichever mode runs first pays the shared jit compilations.
    import subprocess
    import sys
    n = 16 if SMOKE else 40
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = {}
    identical = True
    for mode in ("default", "calibrated"):
        proc = subprocess.run(
            [sys.executable, "-c", _CAL_WORKER, mode, str(SCALE), str(n)],
            capture_output=True, text=True, env=env, timeout=1200)
        if proc.returncode != 0:
            raise RuntimeError(f"calibration worker {mode} failed:\n"
                               f"{proc.stderr[-2000:]}")
        res = json.loads(proc.stdout.strip().splitlines()[-1])
        identical &= res.pop("identical")
        out[mode] = res
    out["identical_result_sets"] = identical
    out["n_stream"] = n
    out["speedup"] = out["calibrated"]["qps"] / out["default"]["qps"]
    return out


# ---------------------------------------------------------------------- #
def run():
    ds, pool = _workload()
    oracle_engine = ds.engine("rdf_h")
    oracle = _result_sets(oracle_engine, pool)
    results = {"scale": SCALE, "n_templates": N_TEMPLATES,
               "n_stream": N_STREAM, "smoke": SMOKE}

    results["cold_warm"] = _cold_warm(ds, pool, oracle)
    cw = results["cold_warm"]
    assert cw["identical_result_sets"], "cold/warm result sets diverged"
    yield ("serve.cold_warm", cw["warm_median_ms"] * 1e3,
           f"cold/warm={cw['speedup']:.1f}x "
           f"identical={cw['identical_result_sets']}")

    results["batched_serial"] = _batched_serial(ds, pool, oracle)
    bs = results["batched_serial"]
    assert bs["identical_result_sets"], "batched/serial result sets diverged"
    yield ("serve.batched", 1e6 / bs["batched"]["qps"],
           f"batched/serial={bs['throughput_gain']:.2f}x "
           f"identical={bs['identical_result_sets']}")

    results["calibration"] = _calibration()
    cal = results["calibration"]
    assert cal["identical_result_sets"], "calibrated results diverged"
    yield ("serve.calibrated", 1e6 / cal["calibrated"]["qps"],
           f"calibrated/miscalibrated={cal['speedup']:.2f}x "
           f"checks {cal['default']['checks_run']}->"
           f"{cal['calibrated']['checks_run']} "
           f"identical={cal['identical_result_sets']}")

    out_path = os.environ.get("REPRO_BENCH_SERVE_JSON", "BENCH_serve.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
