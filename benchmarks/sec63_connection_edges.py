"""Paper §6.3: connection-edge queries (d_c=5) under 1/2/3-hop NI indexes.

The paper reports the connectivity check taking 92.45% / 41.17% / 3.6% of
query time with 1/2/3-hop indexes — more indexed hops collapse the
reach-set expansion cost.  We report the connectivity-check share and
absolute times."""
from __future__ import annotations

from .common import get_graph, make_queries, engine_for, time_query


def run(scale=None):
    g = get_graph("dblp", scale)
    # exact keywords on most nodes keep candidate tables small so the
    # timing isolates the connectivity-evaluation cost (as in the paper)
    queries = make_queries(g, n=8, size=5, seed0=700, n_connection=1,
                           d_c=5, exact_nodes=0.5)
    for variant, label in (("stwig+", "1hop"), ("h2", "2hop"),
                           ("h3", "3hop")):
        eng = engine_for(g, variant)
        # force the check OFF so timing isolates connectivity evaluation
        eng.cfg.check_policy = "never"
        tot, conn = 0.0, 0.0
        for q in queries:
            t, res = time_query(eng, q)
            tot += t
            conn += res.stats.conn_time
        share = 100 * conn / max(tot, 1e-9)
        yield (f"sec63.conn_share_{label}", tot / len(queries) * 1e6,
               round(share, 2))
