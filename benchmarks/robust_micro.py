"""Resilient-serving micro-benchmark -> BENCH_robust.json.

Five scenarios over a governed :class:`QueryServer` (forcing engine
config so the sort-merge kernel and reach-join actually dispatch — the
same seams the fault injector targets):

  * overload_shed — a bursty arrival pattern far above capacity, served
    by an unbounded server vs. one with admission control
    (``max_pending``).  The bounded server sheds excess load at submit
    time with a typed ``RejectedError`` and keeps per-burst flush wall
    (p99) bounded near the healthy per-burst cost; the unbounded server
    absorbs every burst and its p99 grows with burst size.  Shed is the
    point: bounded_p99 ~ accepted_fraction * unbounded_p99, not a
    queue-collapse.  Every accepted result is asserted identical to a
    fresh fault-free engine.
  * degraded_overhead — a persistent ``kernel_dispatch`` fault (every
    sort-merge probe raises) forces every query down the degradation
    ladder to the nested/cross rung.  Reports the median-latency
    overhead of ladder-served traffic vs. a healthy server, and asserts
    the degraded results are still exact (the ladder trades speed, never
    correctness).
  * quarantine_recovery — a fault that defeats the whole ladder
    (``cache_lookup``) trips the per-fingerprint circuit breaker.  While
    quarantined, the server answers in microseconds (typed
    ``QuarantinedError``, no engine work) instead of burning a full
    ladder walk per attempt; once the fault clears, a half-open probe
    restores service within one cooldown.  Reports denied-fast latency
    vs. the cost of a failing ladder walk, and the wall time from fault
    removal to first successful result.
  * rung_memory — a persistent ``kernel_dispatch`` fault served twice:
    once with rung memory off (every request re-walks the full ladder,
    burning the failing primary + intermediate rungs) and once with it
    on (repeat traffic jumps straight to the last-good rung).  Reports
    the per-request speedup of jumping vs. re-walking, the jump/probe
    counters proving the routing, and — after the fault clears — the
    wall time until a re-probe restores full-quality service.
  * snapshot_restore — a warm server's learned state is saved with
    ``save_snapshot``; a fresh process restores it and serves the whole
    pool on the WARM path (plan-cache hits, zero misses) vs. a cold
    server re-learning everything.  Reports restore-vs-relearn wall
    time and asserts both passes are exact.

Smoke mode (REPRO_BENCH_ROBUST_SMOKE=1, used by CI) shrinks the graph
and burst counts so the module runs in well under a minute while still
exercising every identity assertion.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import Dataset, Thresholds
from repro.core.engine import EngineConfig
from repro.data import random_graph, random_query
from repro.serve import (QueryServer, GovernorConfig, QuarantinedError,
                         RejectedError, ServingError)
from repro.testing import Fault, FaultInjector

SMOKE = os.environ.get("REPRO_BENCH_ROBUST_SMOKE", "") not in ("", "0")
N_NODES = 80 if SMOKE else 240
N_EDGES = 220 if SMOKE else 680
N_TEMPLATES = 4 if SMOKE else 6
N_BURSTS = 4 if SMOKE else 10
BURST = 12 if SMOKE else 24
MAX_PENDING = 4


def _cfg():
    # Route joins through the sort-merge kernel and connections through
    # the reach-join so kernel_dispatch / cache_lookup faults land.
    return EngineConfig(check_policy="selective", d_check=2, impl="ref",
                        thresholds=Thresholds(nested_join_max=1),
                        join_impl="sorted", connection_impl="reach")


def _workload(seed: int = 1):
    g = random_graph(n_nodes=N_NODES, n_edges=N_EDGES, n_preds=3,
                     n_literals=20, seed=seed)
    pool = [random_query(g, size=4, seed=40 + i, n_connection=i % 2,
                         d_c=2) for i in range(N_TEMPLATES)]
    ds = Dataset.build(g, variant="rdf_h")
    oracle_eng = ds.engine("rdf_h", impl="ref")
    oracle = [oracle_eng.execute(q).result_set() for q in pool]
    return ds, pool, oracle


def _p(xs, q):
    return float(np.percentile(np.asarray(xs, dtype=float), q))


# --------------------------- overload shed ----------------------------- #
def _overload_shed(ds, pool, oracle):
    out = {}
    for mode, gov in (("unbounded", GovernorConfig()),
                      ("bounded", GovernorConfig(max_pending=MAX_PENDING))):
        srv = QueryServer(ds, cfg=_cfg(), governor=gov)
        for q in pool:                       # warm plans + jit shapes
            srv.query(q)
        walls, shed, served, identical = [], 0, 0, True
        for b in range(N_BURSTS):
            accepted = []
            t0 = time.perf_counter()
            for i in range(BURST):
                qi = (b + i) % len(pool)
                f = srv.submit(pool[qi])
                accepted.append((qi, f))
            srv.flush()
            walls.append(time.perf_counter() - t0)
            for qi, f in accepted:
                try:
                    identical &= f.result().result_set() == oracle[qi]
                    served += 1
                except RejectedError:
                    shed += 1
        out[mode] = {
            "burst_wall_p50_ms": _p(walls, 50) * 1e3,
            "burst_wall_p99_ms": _p(walls, 99) * 1e3,
            "shed": shed,
            "served": served,
            "identical_result_sets": identical,
        }
    b, u = out["bounded"], out["unbounded"]
    out["n_bursts"] = N_BURSTS
    out["burst_size"] = BURST
    out["max_pending"] = MAX_PENDING
    out["p99_ratio"] = u["burst_wall_p99_ms"] / max(b["burst_wall_p99_ms"],
                                                    1e-9)
    # shed-not-collapse: the bounded server shed exactly the overflow at
    # admission and its per-burst wall did not grow past the unbounded
    # server's (median with noise headroom — per-flush fixed overhead
    # dominates at smoke scale, so strict p99 ordering would be flaky)
    out["bounded_under_overload"] = (
        b["shed"] == N_BURSTS * (BURST - MAX_PENDING)
        and b["burst_wall_p50_ms"] <= 1.25 * u["burst_wall_p50_ms"])
    return out


# ------------------------- degraded overhead --------------------------- #
def _degraded_overhead(ds, pool, oracle):
    reps = 2 if SMOKE else 4
    out = {}
    for mode in ("healthy", "degraded"):
        # rung memory off: this scenario measures the cost of a FULL
        # ladder walk per request; with memory on, repeat traffic would
        # jump to the last-good rung and hide the walk being measured
        # (that saving is what _rung_memory quantifies).
        srv = QueryServer(ds, cfg=_cfg(),
                          governor=GovernorConfig(rung_memory=False,
                                                  transient_retry=False))
        for q in pool:                       # healthy warm-up both modes
            srv.query(q)
        # warm the ladder rung's shapes too so the degraded timing is
        # steady-state ladder cost, not one-off jit compilation
        lat, identical = [], True
        fault = [Fault("kernel_dispatch", "raise", every=1)] \
            if mode == "degraded" else []
        with FaultInjector(*fault):
            for _ in range(2):               # shape/plan warm-up in-mode
                srv.query(pool[0])
            for _ in range(reps):
                for qi, q in enumerate(pool):
                    t0 = time.perf_counter()
                    r = srv.query(q)
                    lat.append(time.perf_counter() - t0)
                    identical &= r.result_set() == oracle[qi]
        snap = srv.telemetry()["governor"]
        out[mode] = {
            "median_ms": _p(lat, 50) * 1e3,
            "p99_ms": _p(lat, 99) * 1e3,
            "identical_result_sets": identical,
            "degraded_queries": snap["degraded_queries"],
            "degraded_by_rung": snap["degraded_by_rung"],
        }
    out["overhead_x"] = (out["degraded"]["median_ms"]
                         / max(out["healthy"]["median_ms"], 1e-9))
    out["all_ladder_served"] = (
        out["degraded"]["degraded_queries"] >= len(pool)
        and out["degraded"]["identical_result_sets"])
    return out


# ------------------------ quarantine recovery -------------------------- #
def _quarantine_recovery(ds, pool, oracle):
    cooldown = 0.2 if SMOKE else 0.5
    srv = QueryServer(ds, cfg=_cfg(),
                      governor=GovernorConfig(breaker_threshold=2,
                                              breaker_cooldown_s=cooldown))
    q, ref = pool[1], oracle[1]          # has a connection edge: the
    srv.query(q)                         # cache_lookup fault lands on it
    t0 = time.perf_counter()
    srv.query(q)
    healthy_ms = (time.perf_counter() - t0) * 1e3

    failing_ms = []
    with FaultInjector(Fault("cache_lookup", "raise", every=1)):
        for _ in range(2):                   # trip the breaker
            t0 = time.perf_counter()
            try:
                srv.query(q)
            except ServingError:
                pass
            failing_ms.append((time.perf_counter() - t0) * 1e3)
        denied_ms = []
        for _ in range(8):                   # quarantined: denied fast
            t0 = time.perf_counter()
            try:
                srv.query(q)
            except QuarantinedError:
                pass
            denied_ms.append((time.perf_counter() - t0) * 1e3)
    # fault cleared: wall time until the half-open probe restores service
    t0 = time.perf_counter()
    while True:
        try:
            r = srv.query(q)
            break
        except QuarantinedError:
            time.sleep(cooldown / 10)
    recovery_s = time.perf_counter() - t0
    snap = srv.telemetry()["governor"]["breaker"]
    return {
        "healthy_ms": healthy_ms,
        "failing_ladder_walk_ms": float(np.median(failing_ms)),
        "denied_median_ms": _p(denied_ms, 50),
        "denied_p99_ms": _p(denied_ms, 99),
        "denied_speedup_vs_failing": (float(np.median(failing_ms))
                                      / max(_p(denied_ms, 50), 1e-9)),
        "recovery_s": recovery_s,
        "recovered_within_2_cooldowns": recovery_s < 2 * cooldown + 0.5,
        "identical_after_recovery": r.result_set() == ref,
        "breaker": snap,
    }


# ---------------------------- rung memory ------------------------------ #
def _rung_memory(ds, pool, oracle):
    """Full-ladder-per-request vs. memory-jump under a persistent fault,
    plus recovery within one re-probe interval after the fault clears."""
    reps = 3 if SMOKE else 6
    interval = 0.2 if SMOKE else 0.5
    q, ref = pool[1], oracle[1]          # has a connection edge: the
    # kernel_dispatch fault lands on its sort-merge probe
    out = {}
    configs = (
        ("full_ladder", GovernorConfig(rung_memory=False,
                                       transient_retry=False)),
        ("memory_jump", GovernorConfig(rung_memory=True,
                                       transient_retry=False,
                                       reprobe_interval_s=interval)),
    )
    for mode, gov in configs:
        srv = QueryServer(ds, cfg=_cfg(), governor=gov)
        for qq in pool:                  # healthy warm-up: plans + shapes
            srv.query(qq)
        lat, identical = [], True
        with FaultInjector(Fault("kernel_dispatch", "raise", every=1)):
            for _ in range(2):           # learn the rung / warm in-mode
                srv.query(q)
            for _ in range(reps):
                t0 = time.perf_counter()
                r = srv.query(q)
                lat.append(time.perf_counter() - t0)
                identical &= r.result_set() == ref
        snap = srv.telemetry()["governor"]
        out[mode] = {
            "median_ms": _p(lat, 50) * 1e3,
            "p99_ms": _p(lat, 99) * 1e3,
            "identical_result_sets": identical,
            "ladder_entries": snap["ladder_entries"],
            "rung_memory": snap["rung_memory"],
        }
        if mode == "memory_jump":
            # fault cleared: the next re-probe slot retries the primary
            # config and should restore full quality within ~1 interval
            time.sleep(interval)
            t0 = time.perf_counter()
            while True:
                r = srv.query(q)
                if not r.stats.degraded_steps:
                    break
                time.sleep(interval / 10)
            out["recovery_s"] = time.perf_counter() - t0
            out["recovered_full_quality"] = r.result_set() == ref
            out["recovered_within_2_intervals"] = \
                out["recovery_s"] < 2 * interval + 0.5
    fl, mj = out["full_ladder"], out["memory_jump"]
    out["reps"] = reps
    out["reprobe_interval_s"] = interval
    out["jump_speedup_x"] = (fl["median_ms"] / max(mj["median_ms"], 1e-9))
    # routing proof: without memory every request re-enters the ladder;
    # with it the measured reps are (almost) all jumps — a re-probe may
    # fire mid-run on a slow machine, hence the small headroom
    out["memory_routed_jumps"] = (
        mj["rung_memory"]["jumps"] >= reps - 2
        and fl["ladder_entries"] >= reps + 2
        and mj["ladder_entries"] <= 2 + mj["rung_memory"]["probe_failures"])
    return out


# -------------------------- snapshot restore --------------------------- #
def _snapshot_restore(ds, pool, oracle):
    """Restore-vs-relearn: a restored server serves its first pass over
    the pool entirely on the warm path; a cold server pays prepare +
    planning + decide + check for every template."""
    import tempfile

    srv = QueryServer(ds, cfg=_cfg(), governor=GovernorConfig())
    for _ in range(2):                   # cold pass + warm pass
        for q in pool:
            srv.query(q)
    path = os.path.join(tempfile.mkdtemp(prefix="repro_snap_"),
                        "robust.snap")
    manifest = srv.save_snapshot(path)

    cold = QueryServer(ds, cfg=_cfg(), governor=GovernorConfig())
    t0 = time.perf_counter()
    cold_ok = all(cold.query(q).result_set() == want
                  for q, want in zip(pool, oracle))
    relearn_s = time.perf_counter() - t0

    warm = QueryServer(ds, cfg=_cfg(), governor=GovernorConfig())
    t0 = time.perf_counter()
    warm.restore_snapshot(path)
    results = [warm.query(q) for q in pool]
    restore_s = time.perf_counter() - t0
    warm_ok = all(r.result_set() == want
                  for r, want in zip(results, oracle))
    all_warm_hits = all(r.stats.cache_hit for r in results)
    os.unlink(path)
    return {
        "snapshot_bytes": manifest["bytes"],
        "plans": manifest["plans"],
        "relearn_first_pass_s": relearn_s,
        "restore_plus_first_pass_s": restore_s,
        "restore_speedup_x": relearn_s / max(restore_s, 1e-9),
        "restored_first_pass_all_warm": all_warm_hits,
        "restored_plan_cache_misses":
            warm.telemetry()["plan_cache"]["misses"],
        "identical_result_sets": cold_ok and warm_ok,
    }


# ---------------------------------------------------------------------- #
def run():
    ds, pool, oracle = _workload()
    results = {"n_nodes": N_NODES, "n_templates": N_TEMPLATES,
               "n_bursts": N_BURSTS, "burst_size": BURST, "smoke": SMOKE}

    results["overload_shed"] = _overload_shed(ds, pool, oracle)
    ov = results["overload_shed"]
    assert ov["bounded"]["identical_result_sets"], \
        "accepted results diverged under admission control"
    assert ov["bounded_under_overload"], \
        "admission control failed to bound p99 under overload"
    yield ("robust.overload", ov["bounded"]["burst_wall_p99_ms"] * 1e3,
           f"p99 bounded/unbounded={1 / ov['p99_ratio']:.2f}x "
           f"shed={ov['bounded']['shed']} "
           f"identical={ov['bounded']['identical_result_sets']}")

    results["degraded_overhead"] = _degraded_overhead(ds, pool, oracle)
    dg = results["degraded_overhead"]
    assert dg["all_ladder_served"], \
        "ladder failed to serve exact results under persistent fault"
    yield ("robust.degraded", dg["degraded"]["median_ms"] * 1e3,
           f"overhead={dg['overhead_x']:.2f}x "
           f"rungs={dg['degraded']['degraded_by_rung']} "
           f"identical={dg['degraded']['identical_result_sets']}")

    results["quarantine_recovery"] = _quarantine_recovery(ds, pool, oracle)
    qr = results["quarantine_recovery"]
    assert qr["identical_after_recovery"], \
        "post-recovery result diverged from oracle"
    yield ("robust.quarantine", qr["denied_p99_ms"] * 1e3,
           f"denied/failing={1 / max(qr['denied_speedup_vs_failing'], 1e-9):.4f}x "
           f"recovery={qr['recovery_s']:.2f}s "
           f"recovered={qr['recovered_within_2_cooldowns']}")

    results["rung_memory"] = _rung_memory(ds, pool, oracle)
    rm = results["rung_memory"]
    assert rm["memory_jump"]["identical_result_sets"] \
        and rm["full_ladder"]["identical_result_sets"], \
        "rung-memory routing changed results under persistent fault"
    assert rm["memory_routed_jumps"], \
        "rung memory failed to absorb repeat ladder walks"
    assert rm["recovered_full_quality"], \
        "re-probe failed to restore full-quality service"
    yield ("robust.rung_memory", rm["memory_jump"]["median_ms"] * 1e3,
           f"jump_speedup={rm['jump_speedup_x']:.2f}x "
           f"jumps={rm['memory_jump']['rung_memory']['jumps']} "
           f"recovery={rm['recovery_s']:.2f}s")

    results["snapshot_restore"] = _snapshot_restore(ds, pool, oracle)
    sr = results["snapshot_restore"]
    assert sr["identical_result_sets"], \
        "restored server's results diverged from oracle"
    assert sr["restored_first_pass_all_warm"] \
        and sr["restored_plan_cache_misses"] == 0, \
        "restored server fell back to the cold path"
    yield ("robust.snapshot", sr["restore_plus_first_pass_s"] * 1e3,
           f"restore_speedup={sr['restore_speedup_x']:.2f}x "
           f"plans={sr['plans']} bytes={sr['snapshot_bytes']}")

    out_path = os.environ.get("REPRO_BENCH_ROBUST_JSON", "BENCH_robust.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
