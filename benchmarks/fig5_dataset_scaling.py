"""Paper Fig. 5: dataset scalability (DBLP at growing sizes, same query
workload).  Validates C5a: the no-pruning baseline degrades fastest."""
from __future__ import annotations

from .common import get_graph, make_queries, bench_queries, BENCH_SCALE


def run(scale=None):
    base_scale = BENCH_SCALE if scale is None else scale
    for mult in (0.5, 1.0, 2.0):
        s = base_scale * mult
        g = get_graph("dblp", s)
        queries = make_queries(g, size=6)
        res = bench_queries(g, queries,
                            variants=["stwig+", "spath_ni2", "h2", "h3"])
        for v, (mean_s, matches, work) in res.items():
            yield (f"fig5.dblp_x{mult}.{v}", mean_s * 1e6,
                   f"triples={g.num_edges}")
