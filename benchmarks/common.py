"""Shared benchmark machinery.

Every figure module exposes run(scale) -> iterable of (name, us_per_call,
derived) rows.  REPRO_BENCH_SCALE (default 0.12) sizes the synthetic
datasets; the paper's 1-5M-triple runs correspond to scale 10-50 and are
reproduced in EXPERIMENTS.md with the scales noted.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import (Dataset, ENGINE_VARIANTS, make_engine,
                        build_ni_index, Thresholds)
from repro.data import DATASETS, random_query

BENCH_SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "0.12"))
N_QUERIES = int(os.environ.get("REPRO_BENCH_QUERIES", "12"))

VARIANTS = ["stwig+", "spath_ni2", "h2", "h3", "hvc"]

_GRAPH_CACHE: dict = {}
_NI_CACHE: dict = {}
_DS_CACHE: dict = {}


def get_graph(name: str, scale: float | None = None, seed: int = 1):
    scale = BENCH_SCALE if scale is None else scale
    key = (name, round(scale, 4), seed)
    if key not in _GRAPH_CACHE:
        _GRAPH_CACHE[key] = DATASETS[name](scale=scale, seed=seed)
    return _GRAPH_CACHE[key]


def get_ni(graph, d_max: int, variant: str = "full"):
    key = (id(graph), d_max, variant)
    if key not in _NI_CACHE:
        _NI_CACHE[key] = build_ni_index(graph, d_max=d_max, variant=variant)
    return _NI_CACHE[key]


def get_dataset(graph, variant: str = "rdf_h"):
    """Dataset facade for `graph` with the NI the variant needs.  Cached
    per (graph, NI spec): variants sharing an index shape (e.g. h2 and
    spath_ni2) share one Dataset, exactly as the old NI cache did."""
    b = ENGINE_VARIANTS[variant]
    key = (id(graph), b["d"], b["var"])
    if key not in _DS_CACHE:
        _DS_CACHE[key] = Dataset.build(
            graph, variant=variant,
            ni=get_ni(graph, b["d"], b["var"]))
    return _DS_CACHE[key]


def engine_for(graph, variant: str, thresholds=None):
    return make_engine(get_dataset(graph, variant), variant,
                       thresholds=thresholds or Thresholds(
                           tau_iter=500, tau_join=1e5, tau_sel=6.0),
                       impl="auto")


def time_query(engine, query, warm: bool = True):
    """Seconds for a warm run (2nd execution reuses jit caches)."""
    if warm:
        engine.execute(query)
    t0 = time.perf_counter()
    res = engine.execute(query)
    return time.perf_counter() - t0, res


def bench_queries(graph, queries, variants=VARIANTS, thresholds=None):
    """Returns {variant: (mean_s, total_matches, mean_join_work)}."""
    out = {}
    for v in variants:
        eng = engine_for(graph, v, thresholds)
        times, matches, work = [], 0, 0
        for q in queries:
            t, res = time_query(eng, q)
            times.append(t)
            matches += res.count
            work += res.stats.join_work + res.stats.dtree_work
        out[v] = (float(np.mean(times)), matches, work / max(len(queries), 1))
    return out


def make_queries(graph, n=None, size=6, seed0=100, **kw):
    n = N_QUERIES if n is None else n
    return [random_query(graph, size=size, seed=seed0 + i, **kw)
            for i in range(n)]
