"""Paper Fig. 4: query performance per technique per dataset
(40 random size-6 queries in the paper; N_QUERIES here).

Validates C1 (pruning not beneficial on LUBM-like, beneficial elsewhere)
and C2 (the selective hybrid beats both always- and never-prune)."""
from __future__ import annotations

from .common import get_graph, make_queries, bench_queries, VARIANTS


def run(scale=None):
    from .common import engine_for, time_query
    for name in ("lubm", "sp2b", "dblp", "imdb"):
        g = get_graph(name, scale)
        queries = make_queries(g, size=6)
        res = bench_queries(g, queries)
        base = res["stwig+"][0]
        for v in VARIANTS:
            mean_s, matches, work = res[v]
            yield (f"fig4.{name}.{v}", mean_s * 1e6,
                   round(mean_s / base, 3))
        yield (f"fig4.{name}.matches", 0.0, res["h2"][1])
        yield (f"fig4.{name}.work_stwig+", 0.0, int(res["stwig+"][2]))
        yield (f"fig4.{name}.work_h2", 0.0, int(res["h2"][2]))
        # check-phase overhead + pruning power of the always-check engine
        eng = engine_for(g, "spath_ni2")
        check_t, tot_t, before, after = 0.0, 0.0, 0, 0
        for q in queries:
            t, r = time_query(eng, q)
            tot_t += t
            check_t += r.stats.check_time
            before += r.stats.candidates_before
            after += r.stats.candidates_after
        yield (f"fig4.{name}.check_share_pct", 0.0,
               round(100 * check_t / max(tot_t, 1e-9), 2))
        yield (f"fig4.{name}.prune_rate_pct", 0.0,
               round(100 * (1 - after / max(before, 1)), 2))
