"""End-to-end query benchmark: whole-query join plan vs. the seed greedy
order, plus the sort-run-reuse win at the join layer.

Three scenarios, emitted to BENCH_query.json:

  * conn3 — a 7-node, 3-component template with two connection edges of
    very different selectivity (d_c=6 through a hub vs. d_c=1 diagonal).
    The seed's smallest-product-first rule merges the wrong pair first
    and drags a full cross product through the expensive connectivity
    filter; the cost-based ConnectionPlan does the selective merge first.
    3 joins end-to-end (one D-tree-internal + two component merges);
    asserts the two orders return identical result sets.
  * tree_skew — three candidate tables where the seed's smallest-table-
    first join order explodes through a low-V(key) hub column; the
    Selinger DP (plan_table_joins) routes around it.
  * sort_reuse — a 3-join chain on one key, executed with CandidateTable
    sort-order propagation vs. with order metadata stripped (PR 1
    behavior: every join re-sorts both sides).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (RDFGraph, QueryTemplate, QueryEdge, ConnectionEdge,
                        Dataset, JoinEstimator, JoinTelemetry)
from repro.core.matching import Table, planned_join, _pow2
from repro.core.planner import plan_table_joins

REPEATS = 3


def _best(fn, repeats=REPEATS):
    fn()                                        # warm: jit + first shapes
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3                           # ms


# ----------------------------- conn3 ---------------------------------- #
def _conn3_graph(n_xy=10, n_y=200, n_z=200, n_fill=1500):
    """X: 10 pX edges + hub link; Y: 200-row 2-edge chain, every yc
    diagonally pC-linked to za; Z: 200 pZ edges.  Fillers raise the
    average fanout so the d_c=6 connection estimates as non-selective."""
    triples = []
    for i in range(n_xy):
        triples.append((f"xa/{i:04d}", "pX", f"xb/{i:04d}"))
        triples.append((f"xb/{i:04d}", "pH", "hub/0"))
    for i in range(n_y):
        triples.append((f"ya/{i:04d}", "pY", f"yb/{i:04d}"))
        triples.append((f"yb/{i:04d}", "pY2", f"yc/{i:04d}"))
        triples.append(("hub/0", "pH", f"ya/{i:04d}"))
        triples.append((f"yc/{i:04d}", "pC", f"za/{i:04d}"))
    for i in range(n_z):
        triples.append((f"za/{i:04d}", "pZ", f"zb/{i:04d}"))
    for i in range(n_fill):
        for k in (1, 2, 3, 5, 7, 11):
            triples.append((f"fil/{i:05d}", "pF",
                            f"fil/{(i + k) % n_fill:05d}"))
    return RDFGraph.from_triples(triples, literal_objects=set())


def _conn3():
    g = _conn3_graph()
    pid = {str(p): i for i, p in enumerate(g.predicates)}
    q = QueryTemplate(
        keywords=["xa/", "xb/", "ya/", "yb/", "yc/", "za/", "zb/"],
        edges=[QueryEdge(0, 1, pid["pX"]), QueryEdge(2, 3, pid["pY"]),
               QueryEdge(3, 4, pid["pY2"]), QueryEdge(5, 6, pid["pZ"])],
        connections=[ConnectionEdge(1, 2, 6), ConnectionEdge(4, 5, 1)])
    out = {}
    result_sets = {}
    ds = Dataset.build(g, variant="stwig+")
    for pm in ("cost", "greedy"):
        eng = ds.engine("stwig+")
        eng.cfg.plan_mode = pm
        r = eng.execute(q)
        result_sets[pm] = r.result_set()
        out[f"{pm}_ms"] = _best(lambda: eng.execute(q))
        # stable telemetry schema (QueryStats.to_dict) instead of
        # re-plucking fields ad hoc
        d = r.stats.to_dict()
        out[f"{pm}_stats"] = {k: d[k] for k in (
            "sorts_performed", "sorts_avoided", "plan_cost",
            "greedy_plan_cost", "join_work")}
        out[f"{pm}_rows"] = r.count
    out["identical_result_sets"] = result_sets["cost"] == result_sets["greedy"]
    out["speedup"] = out["greedy_ms"] / out["cost_ms"]
    out["n_joins"] = 3
    return out


# --------------------------- tree_skew -------------------------------- #
def _mk(cols, data):
    data = np.asarray(data, np.int32).reshape(-1, len(cols))
    cap = _pow2(len(data))
    rows = np.full((cap, len(cols)), -1, np.int32)
    rows[: len(data)] = data
    return Table(cols=tuple(cols), rows=jnp.asarray(rows), count=len(data))


def _tree_skew_tables(n_small=100, n_big=3000, n_match=10, seed=0):
    """T0 (n0,n1,n2): hub value on n2; T1 (n2,n3,n4): same hub, distinct
    n4; T2 (n4,n5,n6): only n_match rows share T1's n4 values.  Greedy
    (T0 first) materializes n_small*n_big rows; cost order keeps every
    intermediate tiny."""
    rng = np.random.default_rng(seed)
    hub = 7
    t0 = _mk((0, 1, 2), np.column_stack(
        [10_000 + np.arange(n_small), 20_000 + np.arange(n_small),
         np.full(n_small, hub)]))
    t1 = _mk((2, 3, 4), np.column_stack(
        [np.full(n_big, hub), 30_000 + np.arange(n_big),
         40_000 + np.arange(n_big)]))
    n4 = np.concatenate([40_000 + rng.choice(n_big, n_match, replace=False),
                         90_000 + np.arange(n_big - n_match)])
    t2 = _mk((4, 5, 6), np.column_stack(
        [n4, 50_000 + np.arange(n_big), 60_000 + np.arange(n_big)]))
    return [t0, t1, t2]


def _run_order(tables, order, est):
    acc = tables[order[0]]
    for i in order[1:]:
        shared = tuple(c for c in acc.cols if c in tables[i].cols)
        e = est.table_join(acc.count, tables[i].count, shared)
        acc = planned_join(acc, tables[i], e)
    acc.rows.block_until_ready()
    return acc


def _strip(t):
    """Drop sort-order metadata / cached runs (fresh buffers, same data)."""
    return Table(cols=t.cols, rows=t.rows, count=t.count)


def _tree_skew():
    tables = _tree_skew_tables()
    # V(key): n2 is an (effectively) single-candidate hub node, n4 a wide
    # interval — exactly what IDMap candidate intervals would report.
    est = JoinEstimator(None, {2: 1, 4: 6000, 0: 100, 1: 100, 3: 3000,
                               5: 3000, 6: 3000})
    node_sets = [set(t.cols) for t in tables]
    counts = [t.count for t in tables]
    greedy = [0, 1, 2]                  # seed rule: smallest table first
    plan = plan_table_joins(node_sets, counts, est, nested_max=256,
                            greedy_order=greedy)
    out = {"plan_order": plan.order, "greedy_order": greedy,
           "plan_est_cost": plan.est_cost, "greedy_est_cost": plan.greedy_cost}
    r_greedy = _run_order([_strip(t) for t in tables], greedy, est)
    r_plan = _run_order([_strip(t) for t in tables], plan.order, est)
    assert r_greedy.result_set() == r_plan.result_set()
    out["identical_result_sets"] = True
    out["rows"] = r_plan.count
    out["greedy_ms"] = _best(
        lambda: _run_order([_strip(t) for t in tables], greedy, est))
    out["cost_ms"] = _best(
        lambda: _run_order([_strip(t) for t in tables], plan.order, est))
    out["speedup"] = out["greedy_ms"] / out["cost_ms"]
    return out


# --------------------------- sort_reuse ------------------------------- #
def _sort_reuse(n=50_000, seed=3):
    rng = np.random.default_rng(seed)
    chain = [_mk((0, 1), np.column_stack(
        [rng.integers(0, n, n), rng.integers(0, n, n)]))]
    for k in (2, 3, 4):
        chain.append(_mk((1, k), np.column_stack(
            [rng.integers(0, n, n), rng.integers(0, n, n)])))

    def run(reuse: bool, tel=None):
        tabs = chain if reuse else [_strip(t) for t in chain]
        acc = tabs[0]
        for t in tabs[1:]:
            acc = planned_join(acc, t, est=n, impl="sorted", telemetry=tel)
        acc.rows.block_until_ready()
        return acc

    tel = JoinTelemetry()
    run(True, tel)                      # populate caches + counters
    tel2 = JoinTelemetry()
    run(True, tel2)                     # steady state: all runs cached
    out = {"first_pass": vars(tel), "steady_state": vars(tel2)}
    out["reuse_ms"] = _best(lambda: run(True))
    out["resort_ms"] = _best(lambda: run(False))
    out["speedup"] = out["resort_ms"] / out["reuse_ms"]
    return out


# ---------------------------------------------------------------------- #
def run():
    results = {}
    results["conn3"] = _conn3()
    yield ("query.conn3.cost", results["conn3"]["cost_ms"] * 1e3,
           f"speedup={results['conn3']['speedup']:.2f}x "
           f"identical={results['conn3']['identical_result_sets']}")
    results["tree_skew"] = _tree_skew()
    yield ("query.tree_skew.cost", results["tree_skew"]["cost_ms"] * 1e3,
           f"speedup={results['tree_skew']['speedup']:.2f}x")
    results["sort_reuse"] = _sort_reuse()
    yield ("query.sort_reuse", results["sort_reuse"]["reuse_ms"] * 1e3,
           f"resort/reuse={results['sort_reuse']['speedup']:.2f}x "
           f"avoided={results['sort_reuse']['steady_state']['sorts_avoided']}")
    out_path = os.environ.get("REPRO_BENCH_QUERY_JSON", "BENCH_query.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
