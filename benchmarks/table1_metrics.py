"""Paper Table 1: dataset characteristics (coherence / specialty /
diversity) for the four workloads."""
from __future__ import annotations

import time

from repro.core import compute_stats
from .common import get_graph


def run(scale=None):
    for name in ("lubm", "sp2b", "dblp", "imdb"):
        g = get_graph(name, scale)
        t0 = time.perf_counter()
        st = compute_stats(g, m_sample=100_000)
        us = (time.perf_counter() - t0) * 1e6
        yield (f"table1.{name}.coherence", us, round(st.coherence, 4))
        yield (f"table1.{name}.specialty", us, round(st.specialty, 2))
        yield (f"table1.{name}.diversity", us, st.diversity)
        yield (f"table1.{name}.triples", us, g.num_edges)
        yield (f"table1.{name}.avg_degree", us, round(g.avg_degree, 2))
