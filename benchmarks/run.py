"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  REPRO_BENCH_SCALE (default 0.12)
sizes the synthetic datasets; REPRO_BENCH_QUERIES the workload size.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (table1_metrics, fig3_index_space, fig4_query_datasets,
                   fig5_dataset_scaling, fig6_template_scaling,
                   sec63_connection_edges, kernel_micro, join_micro,
                   query_micro, connection_micro, serve_micro,
                   robust_micro, obs_micro, update_micro)
    modules = [table1_metrics, fig3_index_space, fig4_query_datasets,
               fig5_dataset_scaling, fig6_template_scaling,
               sec63_connection_edges, kernel_micro, join_micro,
               query_micro, connection_micro, serve_micro,
               robust_micro, obs_micro, update_micro]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for mod in modules:
        short = mod.__name__.split(".")[-1]
        if only and only not in short:
            continue
        t0 = time.time()
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:                               # noqa: BLE001
            print(f"{short}.ERROR,0,{e!r}", flush=True)
        print(f"# {short} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
