"""Kernel micro-benchmarks: ref (jnp) implementations on CPU; the Pallas
paths are validated in interpret mode by tests (timing them on CPU is
meaningless)."""
from __future__ import annotations

import time

import numpy as np

from repro.kernels import ops


def _time(fn, *args, reps=5):
    fn(*args)  # warm/jit
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    np.asarray(r)
    return (time.perf_counter() - t0) / reps * 1e6


def run(scale=None):
    rng = np.random.default_rng(0)
    for c, b, j in ((1024, 64, 4), (8192, 128, 8), (32768, 256, 8)):
        ids = np.sort(rng.integers(0, 1 << 20, (c, b)), 1).astype(np.int32)
        lo = rng.integers(0, 1 << 19, j).astype(np.int32)
        hi = lo + (1 << 18)
        us = _time(lambda *a: ops.interval_count(*a, impl="ref"),
                   ids, lo, hi)
        yield (f"kernel.interval_count.c{c}b{b}j{j}", round(us, 1),
               round(c * b * j / max(us, 1e-9), 1))
    for c, w in ((4096, 8), (65536, 16)):
        cand = rng.integers(0, 1 << 32, (c, w), dtype=np.uint32)
        q = rng.integers(0, 1 << 32, w, dtype=np.uint32)
        us = _time(lambda *a: ops.bitmask_contains(*a, impl="ref"), cand, q)
        yield (f"kernel.bitmask.c{c}w{w}", round(us, 1), c)
    for p, a, b in ((2048, 64, 64), (8192, 128, 128)):
        x = rng.integers(-1, 1 << 20, (p, a)).astype(np.int32)
        y = rng.integers(-1, 1 << 20, (p, b)).astype(np.int32)
        us = _time(lambda *z: ops.intersect_any(*z, impl="ref"), x, y)
        yield (f"kernel.intersect.p{p}", round(us, 1), p * a * b)
