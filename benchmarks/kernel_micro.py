"""Kernel micro-benchmarks -> BENCH_kernel.json.

Three sections:

  fused    Fused one-dispatch sort-merge chain (kernels.fused_join.
           sort_probe_expand) vs the staged pack/sort/probe/expand path,
           unit-fanout joins 1e2-1e5 rows in two key shapes: 'single'
           (one shared column — identity keys, both paths sort the same
           arrays, the win is the collapsed dispatch/sync overhead) and
           'multi' (two shared columns — dense-rank packing, where the
           fused chain extracts both sides' sorted orders from its ONE
           lexsort while the staged path pays the packing lexsort PLUS
           two argsorts, so the win persists at every size).  Warm wall
           time AND host->device dispatch counts at the module seams
           (fused = 1 dispatch, staged = 5).
  radix    Radix hash join vs sort-merge on the asymmetric shape it is
           built for (large probe side A, small build side B = A/32):
           wall-time sweep locating the crossover, plus the planner's
           resolve_join_impl pick at each point — the bench asserts
           nothing, the JSON lets future PRs track whether 'auto' still
           picks the winner.
  legacy   ref (jnp) interval/bitmask/intersect rows (CPU; the Pallas
           paths are validated in interpret mode by tests).

Timing clears each table's cached sorted runs between calls so every
iteration pays the full chain (run reuse is join_micro's subject, not
this bench's).  Smoke mode (REPRO_BENCH_KERNEL_SMOKE=1, used by CI)
shrinks the sweeps and asserts fused/staged/radix result identity.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax.numpy as jnp

import repro.core.matching as matching
import repro.kernels.fused_join as kfused
import repro.kernels.ops as kops
from repro.core.matching import Table, join_tables, resolve_join_impl, _pow2

SMOKE = os.environ.get("REPRO_BENCH_KERNEL_SMOKE", "") not in ("", "0")
FUSED_SIZES = (100, 1_000) if SMOKE else (100, 1_000, 10_000, 100_000)
RADIX_A_SIZES = ((1 << 12, 1 << 14) if SMOKE
                 else (1 << 12, 1 << 14, 1 << 16, 1 << 17))
REPEATS = 2 if SMOKE else 5

# Module seams whose calls == host->device dispatch points of a join.
# matching binds _pack_keys at import, so the matching-level aliases are
# patched (same seams the chaos FaultInjector uses).
_SEAMS = (
    (matching, "_pack_keys"),
    (matching, "_sort_rows_by_key"),
    (matching, "_merge_expand"),
    (kops, "merge_probe"),
    (kops, "radix_probe"),
    (kfused, "sort_probe_expand"),
    (kfused, "sort_probe"),
)


def _mk(cols, n, domain, seed):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, max(domain, 1), (n, len(cols))).astype(np.int32)
    cap = _pow2(n)
    rows = np.full((cap, len(cols)), -1, np.int32)
    rows[:n] = data
    return Table(cols=tuple(cols), rows=jnp.asarray(rows), count=n)


def _time_join(fn, repeats=REPEATS):
    fn()                                        # warm: jit + first shapes
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        out.rows.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6                           # us


def _count_dispatches(fn):
    """Run fn once with every seam wrapped by a counter; returns total
    seam calls (the dispatch count of one join)."""
    counts = {"n": 0}
    saved = []

    def wrap(orig):
        def wrapper(*a, **kw):
            counts["n"] += 1
            return orig(*a, **kw)
        return wrapper

    for mod, name in _SEAMS:
        orig = getattr(mod, name)
        saved.append((mod, name, orig))
        setattr(mod, name, wrap(orig))
    try:
        fn()
    finally:
        for mod, name, orig in saved:
            setattr(mod, name, orig)
    return counts["n"]


def _rows_multiset(t):
    return sorted(tuple(int(x) for x in r) for r in t.numpy())


def run(scale=None):
    fused_tmpl = lambda: {"sizes": [], "fused_us": [], "staged_us": [],
                          "speedup": [], "fused_dispatches": [],
                          "staged_dispatches": []}
    results = {"smoke": SMOKE,
               "fused": {"single": fused_tmpl(), "multi": fused_tmpl()},
               "radix": {"a_sizes": [], "b_sizes": [], "sorted_us": [],
                         "radix_us": [], "speedup": [], "auto_picks": []}}

    # ------------------ fused vs staged sort-merge -------------------- #
    for variant in ("single", "multi"):
        for n in FUSED_SIZES:
            if variant == "single":
                a = _mk((0, 1), n, n, seed=n)
                b = _mk((1, 2), n, n, seed=n + 1)
            else:
                # two shared cols, key domain dom^2 ~ n (unit fanout);
                # the build side shrinks at the top so |A|*|B| stays
                # inside the fused chain's int32 product gate
                dom = max(int(n ** 0.5), 4)
                bn = min(n, ((1 << 31) - 1) // max(n, 1))
                a = _mk((0, 1), n, dom, seed=n)
                b = _mk((0, 1, 2), bn, dom, seed=n + 1)
            cold = join_tables(a, b, impl="sorted", fuse=True)
            cap = cold.cap                      # steady-state capacity

            def fused():
                a._runs.clear(), b._runs.clear()
                return join_tables(a, b, impl="sorted", fuse=True, cap=cap)

            def staged():
                a._runs.clear(), b._runs.clear()
                return join_tables(a, b, impl="sorted", fuse=False, cap=cap)

            if SMOKE:
                assert _rows_multiset(fused()) == _rows_multiset(staged())
            fused_us = _time_join(fused)
            staged_us = _time_join(staged)
            fd = _count_dispatches(fused)
            sd = _count_dispatches(staged)
            speedup = staged_us / fused_us
            r = results["fused"][variant]
            r["sizes"].append(n)
            r["fused_us"].append(fused_us)
            r["staged_us"].append(staged_us)
            r["speedup"].append(speedup)
            r["fused_dispatches"].append(fd)
            r["staged_dispatches"].append(sd)
            yield (f"kernel.join_fused.{variant}.{n}", round(fused_us, 1),
                   f"dispatches={fd}")
            yield (f"kernel.join_staged.{variant}.{n}", round(staged_us, 1),
                   f"dispatches={sd};fused_speedup={speedup:.2f}x")

    # --------------------- radix vs sorted sweep ---------------------- #
    for an in RADIX_A_SIZES:
        bn = max(an // 32, 256)
        a = _mk((0, 1), an, bn, seed=an)        # key domain == |B|:
        b = _mk((1, 2), bn, bn, seed=an + 1)    # ~unit fanout, |out|~|A|
        cold = join_tables(a, b, impl="sorted")
        cap = cold.cap

        def srt():
            a._runs.clear(), b._runs.clear()
            return join_tables(a, b, impl="sorted", cap=cap)

        def rdx():
            a._runs.clear(), b._runs.clear()
            return join_tables(a, b, impl="radix", cap=cap)

        if SMOKE:
            assert _rows_multiset(srt()) == _rows_multiset(rdx())
        sorted_us = _time_join(srt)
        radix_us = _time_join(rdx)
        pick = resolve_join_impl(an, bn)
        speedup = sorted_us / radix_us
        r = results["radix"]
        r["a_sizes"].append(an)
        r["b_sizes"].append(bn)
        r["sorted_us"].append(sorted_us)
        r["radix_us"].append(radix_us)
        r["speedup"].append(speedup)
        r["auto_picks"].append(pick)
        yield (f"kernel.join_radix.a{an}b{bn}", round(radix_us, 1),
               f"vs_sorted={speedup:.2f}x;auto={pick}")

    out_path = os.environ.get("REPRO_BENCH_KERNEL_JSON", "BENCH_kernel.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)

    # --------------------------- legacy rows -------------------------- #
    rng = np.random.default_rng(0)
    legacy_iv = ((1024, 64, 4),) if SMOKE else ((1024, 64, 4),
                                                (8192, 128, 8),
                                                (32768, 256, 8))
    for c, bb, j in legacy_iv:
        ids = np.sort(rng.integers(0, 1 << 20, (c, bb)), 1).astype(np.int32)
        lo = rng.integers(0, 1 << 19, j).astype(np.int32)
        hi = lo + (1 << 18)
        us = _time_scalar(lambda *a: kops.interval_count(*a, impl="ref"),
                          ids, lo, hi)
        yield (f"kernel.interval_count.c{c}b{bb}j{j}", round(us, 1),
               round(c * bb * j / max(us, 1e-9), 1))
    for c, w in ((4096, 8),) if SMOKE else ((4096, 8), (65536, 16)):
        cand = rng.integers(0, 1 << 32, (c, w), dtype=np.uint32)
        q = rng.integers(0, 1 << 32, w, dtype=np.uint32)
        us = _time_scalar(lambda *a: kops.bitmask_contains(*a, impl="ref"),
                          cand, q)
        yield (f"kernel.bitmask.c{c}w{w}", round(us, 1), c)
    for p, aa, bb in ((2048, 64, 64),) if SMOKE else ((2048, 64, 64),
                                                      (8192, 128, 128)):
        x = rng.integers(-1, 1 << 20, (p, aa)).astype(np.int32)
        y = rng.integers(-1, 1 << 20, (p, bb)).astype(np.int32)
        us = _time_scalar(lambda *z: kops.intersect_any(*z, impl="ref"), x, y)
        yield (f"kernel.intersect.p{p}", round(us, 1), p * aa * bb)


def _time_scalar(fn, *args, reps=5):
    fn(*args)  # warm/jit
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    np.asarray(r)
    return (time.perf_counter() - t0) / reps * 1e6


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
