"""Paper Fig. 6: query-template scalability (sizes 4/6/8 on DBLP).
Validates C5b: pruning benefit grows with template size."""
from __future__ import annotations

from .common import get_graph, make_queries, bench_queries


def run(scale=None):
    g = get_graph("dblp", scale)
    for size in (4, 6, 8):
        queries = make_queries(g, size=size, seed0=300 + size)
        res = bench_queries(g, queries,
                            variants=["stwig+", "spath_ni2", "h2", "h3",
                                      "hvc"])
        base = res["stwig+"][0]
        for v, (mean_s, matches, work) in res.items():
            yield (f"fig6.size{size}.{v}", mean_s * 1e6,
                   round(mean_s / base, 3))
