"""Serving driver: batched prefill + decode loop on a reduced-config
model, reporting per-phase throughput.

    PYTHONPATH=src python examples/serve_lm.py --arch stablelm-1.6b --tokens 32
"""
import argparse
import time

import numpy as np
import jax

from repro.configs import ARCHS, reduced_config
from repro.configs.base import InputShape
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced_config(ARCHS[args.arch], num_layers=4)
    if not cfg.decoder:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    params = api.init_model(cfg, 0)
    shape = InputShape("serve", args.prompt, args.batch, "prefill")
    batch = api.concrete_batch(cfg, shape, seed=1)
    cache_len = api.decode_cache_len(
        cfg, InputShape("d", args.prompt + args.tokens, args.batch, "decode"))

    prefill = jax.jit(api.make_prefill_fn(cfg, cache_len=cache_len))
    decode = jax.jit(api.make_decode_fn(cfg))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt} tokens in {t_prefill:.3f}s "
          f"({args.batch*args.prompt/t_prefill:,.0f} tok/s)")

    toks = np.argmax(np.asarray(logits), -1).astype(np.int32)
    out = [toks]
    t0 = time.time()
    for _ in range(args.tokens):
        logits, cache = decode(params, cache, toks)
        toks = np.argmax(np.asarray(logits), -1).astype(np.int32)
        out.append(toks)
    dt = time.time() - t0
    print(f"decode: {args.tokens} steps x batch {args.batch} in {dt:.3f}s "
          f"({args.tokens*args.batch/dt:,.0f} tok/s, "
          f"{dt/args.tokens*1e3:.1f} ms/step)")
    print("sample token ids:", np.stack(out, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
