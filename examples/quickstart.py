"""Quickstart: build a synthetic RDF dataset, inspect its characteristics,
and run template queries through every engine variant.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core import Dataset
from repro.data import dblp_like, random_query
from repro.serve import QueryServer


def main():
    print("== 1. build a DBLP-like RDF graph ==")
    g = dblp_like(scale=0.08, seed=7)
    print(f"   {g.num_nodes} nodes, {g.num_edges} triples, "
          f"avg degree {g.avg_degree:.2f}")

    print("== 2. dataset evaluation metrics (paper §5) ==")
    # Dataset owns everything derived from the graph: stats, the NI
    # index, signatures, and a (digest, version) identity for caches
    ds = Dataset.build(g, variant="rdf_h")
    st = ds.stats
    print(f"   coherence={st.coherence:.3f}  specialty={st.specialty:.1f}  "
          f"diversity={st.diversity}")
    print("   (high coherence + low specialty + low diversity would predict "
          "little pruning benefit)")

    print("== 3. run the same query through every variant ==")
    q = random_query(g, size=6, seed=11)
    print(f"   keywords: {q.keywords}")
    for variant in ("stwig+", "spath_ni2", "h2", "h3", "hvc", "rdf_h"):
        # each variant gets the NI depth/shape it needs
        eng = Dataset.build(g, variant=variant).engine(variant)
        eng.execute(q)                      # warm jit caches
        t0 = time.perf_counter()
        res = eng.execute(q)
        dt = time.perf_counter() - t0
        print(f"   {variant:10s} {res.count:7d} matches  {dt*1e3:8.1f} ms  "
              f"check={'on ' if res.stats.used_check else 'off'}  "
              f"join_work={res.stats.join_work + res.stats.dtree_work}")

    print("== 4. the RDF-h planner decision ==")
    eng = ds.engine("rdf_h")
    # Joins default to join_impl="auto": the cost model picks nested-loop,
    # fused sort-merge, or the radix hash join per table pair (radix wins
    # when a large probe side meets a small build side on a single-column
    # key).  Force one strategy with e.g. eng.cfg.join_impl = "radix".
    res = eng.execute(q)
    plan = res.stats.plan
    if plan:
        print(f"   complex_query={plan.complex_query} "
              f"(iters={plan.est_iterations:.0f}, joins={plan.est_join_product:.2g})")
        print(f"   max neighborhood selectivity={plan.max_selectivity:.2f} "
              f"-> use_check={plan.use_check}")

    print("== 5. serving: plan cache makes repeat templates cheap ==")
    srv = QueryServer(ds)
    for label in ("cold", "warm", "warm"):
        t0 = time.perf_counter()
        r = srv.query(q)
        print(f"   {label}: {r.count} matches in "
              f"{(time.perf_counter() - t0)*1e3:8.1f} ms  "
              f"plan_cache_hit={r.stats.cache_hit}")
    pc = srv.telemetry()["plan_cache"]
    print(f"   plan cache: {pc['hits']} hits / {pc['misses']} misses")
    print("   (full repeat-template workload: examples/serve_queries.py;"
          " add --snapshot PATH there to save the learned state and"
          " warm-restart a fresh server from it)")

    print("== 6. observability: EXPLAIN the plan the server learned ==")
    # srv.explain(q) renders the §4.3 check decision with its τ terms,
    # the Selinger join order, and the learned join sequence; pass
    # tracer=repro.obs.Tracer() to QueryServer (or --trace PATH to
    # serve_queries.py) for per-query Chrome traces of every pruning
    # decision and join.
    print("\n".join("   " + line
                    for line in srv.explain(q).splitlines()[:6]))
    print("   ... (srv.explain(q) for the full report)")


if __name__ == "__main__":
    main()
