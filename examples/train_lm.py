"""End-to-end training driver: train a reduced-config model for a few
hundred steps on the deterministic synthetic pipeline, with checkpointing
and restart.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b --resume
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced_config
from repro.configs.base import TrainConfig
from repro.data.lm_data import TokenPipeline
from repro.checkpoint import Checkpointer
from repro.models import api
from repro.optim import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(ARCHS[args.arch], num_layers=4)
    tcfg = TrainConfig(lr=1e-3, warmup=20, total_steps=args.steps,
                       microbatch=1)
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0)
    step_fn = jax.jit(api.make_train_step(cfg, tcfg))
    ck = Checkpointer(args.ckpt_dir)

    params = api.init_model(cfg, seed=0)
    opt = adamw_init(params)
    start = 0
    if args.resume and ck.latest_step() is not None:
        state, meta = ck.restore(template={"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start = meta["step"] + 1
        print(f"resumed from step {meta['step']} "
              f"(config hash {meta.get('config')})")

    t0 = time.time()
    for i in range(start, args.steps):
        b = pipe.global_batch_at(i)
        params, opt, m = step_fn(params, opt,
                                 {"tokens": b["tokens"],
                                  "labels": b["labels"]}, i)
        if i % 20 == 0 or i == args.steps - 1:
            toks = args.batch * args.seq * (i - start + 1)
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"lr={float(m['lr']):.2e} "
                  f"tok/s={toks/(time.time()-t0):,.0f}")
        if i and i % args.ckpt_every == 0:
            ck.save(i, {"params": params, "opt": opt},
                    meta={"step": i, "config": cfg.config_hash()})
    ck.wait()
    print("done; checkpoints:", ck.all_steps())


if __name__ == "__main__":
    main()
