"""Serving driver: repeat-template RDF query traffic through QueryServer.

Generates a synthetic RDF dataset, samples a pool of query templates, and
replays a zipfian mix of them (the serving assumption: the same templates
arrive over and over).  Prints per-phase latency, plan-cache hit rate,
batch dedup, and the calibration state the server learned online.

    PYTHONPATH=src JAX_PLATFORMS=cpu python examples/serve_queries.py \\
        --dataset dblp --scale 0.05 --templates 6 --queries 60

Governed serving (deadlines + admission control + degradation ladder +
circuit breaker) with optional injected chaos:

    PYTHONPATH=src JAX_PLATFORMS=cpu python examples/serve_queries.py \\
        --governed --deadline-ms 250 --max-pending 6 --chaos

Warm-restart durability: ``--snapshot PATH`` saves the server's learned
state (plans, calibration, governor memory) after the stream, then
"restarts" into a fresh server via ``restore_snapshot`` and replays one
query per template — every one should hit the plan cache warm:

    PYTHONPATH=src JAX_PLATFORMS=cpu python examples/serve_queries.py \\
        --governed --snapshot /tmp/serve.snap

Observability: ``--trace PATH`` records every query (one trace id from
submit through batching, governor routing, and each engine join) and
exports a Chrome trace viewable in chrome://tracing or ui.perfetto.dev;
``--explain`` prints each template's EXPLAIN report — the §4.3 check
decision with its τ terms, the Selinger join order, and the learned
join sequence with estimated-vs-observed rows:

    PYTHONPATH=src JAX_PLATFORMS=cpu python examples/serve_queries.py \\
        --governed --chaos --trace /tmp/serve_trace.json --explain
"""
import argparse
import json

import numpy as np

from repro.core import Dataset
from repro.data import DATASETS, random_query
from repro.serve import GovernorConfig, QueryServer, ServingError


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dblp", choices=sorted(DATASETS))
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--templates", type=int, default=6,
                    help="distinct query templates in the pool")
    ap.add_argument("--queries", type=int, default=60,
                    help="total queries in the zipfian stream")
    ap.add_argument("--size", type=int, default=5)
    ap.add_argument("--zipf", type=float, default=1.3,
                    help="template popularity skew (higher = hotter head)")
    ap.add_argument("--no-batch", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--governed", action="store_true",
                    help="enable the resource governor (deadlines, "
                         "admission control, ladder, circuit breaker)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-execution-attempt deadline (implies "
                         "--governed)")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="admission-control pending bound (implies "
                         "--governed)")
    ap.add_argument("--chaos", action="store_true",
                    help="inject a persistent sort-merge kernel fault "
                         "during the stream: traffic is served exactly "
                         "through the degradation ladder (implies "
                         "--governed)")
    ap.add_argument("--delta", action="store_true",
                    help="after the stream, apply a triple delta to the "
                         "live server (apply_delta) and show warm-state "
                         "migration plus the exact-repeat result cache")
    ap.add_argument("--snapshot", metavar="PATH", default=None,
                    help="after the stream, save learned state to PATH, "
                         "restore it into a fresh server, and replay one "
                         "query per template on the warm path")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="trace every query and export a Chrome trace "
                         "(chrome://tracing / Perfetto) to PATH after "
                         "the stream")
    ap.add_argument("--explain", action="store_true",
                    help="print the EXPLAIN report (check decision, "
                         "join order, learned join sizes) for each "
                         "template after the stream")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    governed = (args.governed or args.chaos or args.deadline_ms is not None
                or args.max_pending is not None)

    print(f"== build {args.dataset} graph (scale={args.scale}) ==")
    g = DATASETS[args.dataset](scale=args.scale, seed=1)
    ds = Dataset.build(g, variant="rdf_h")
    print(f"   {g.num_nodes} nodes, {g.num_edges} triples  "
          f"(dataset {ds.cache_key})")

    print(f"== template pool: {args.templates} templates ==")
    pool = [random_query(g, size=args.size, seed=100 + i,
                         n_connection=i % 2, d_c=3)
            for i in range(args.templates)]

    rng = np.random.default_rng(args.seed)
    ranks = np.minimum(rng.zipf(args.zipf, args.queries),
                       args.templates) - 1
    stream = [pool[r] for r in ranks]

    srv_kw = {}
    if governed:
        srv_kw["governor"] = GovernorConfig(
            deadline_s=(args.deadline_ms / 1e3
                        if args.deadline_ms is not None else None),
            max_pending=args.max_pending)
    if args.chaos:
        # route joins through the sort-merge kernel so the injected
        # fault actually lands (tiny tables otherwise go nested)
        from repro.core import Thresholds
        from repro.core.engine import EngineConfig
        srv_kw["cfg"] = EngineConfig(
            check_policy="selective", d_check=2, impl="ref",
            thresholds=Thresholds(nested_join_max=1),
            join_impl="sorted", connection_impl="reach")
    if args.trace is not None:
        from repro.obs import Tracer
        srv_kw["tracer"] = Tracer(max_traces=args.queries + 16)
    if args.delta:
        # exact repeats after the delta should be served from stored
        # rows without touching the engine
        srv_kw["result_cache_size"] = 64
    srv = QueryServer(ds, batching=not args.no_batch,
                      calibrate=not args.no_calibrate, **srv_kw)
    print(f"== serve {args.queries} queries "
          f"(zipf alpha={args.zipf}, batching={srv.batching}, "
          f"governed={governed}, chaos={args.chaos}) ==")

    from contextlib import nullcontext
    if args.chaos:
        from repro.testing import Fault, FaultInjector
        injector = FaultInjector(Fault("kernel_dispatch", "raise", every=1))
    else:
        injector = nullcontext()

    # chunked submission: each flush is one shape-batched admission window
    chunk = 8
    matches, errors = 0, {}
    with injector:
        for s in range(0, len(stream), chunk):
            futs = srv.submit_many(stream[s:s + chunk], wait=True)
            for f in futs:
                try:
                    matches += f.result().count
                except ServingError as e:
                    kind = type(e).__name__
                    errors[kind] = errors.get(kind, 0) + 1

    t = srv.telemetry()
    lat, pc, b = t["latency"], t["plan_cache"], t["batch"]
    print(f"   matches={matches}  typed-errors={errors or 0}")
    print(f"   latency p50={lat['p50']*1e3:.1f}ms p99={lat['p99']*1e3:.1f}ms")
    print(f"   cold p50={lat['cold_p50']*1e3:.1f}ms ({lat['n_cold']} queries)"
          f"  warm p50={lat['warm_p50']*1e3:.1f}ms ({lat['n_warm']} queries)")
    print(f"   plan cache: {pc['hits']}/{pc['hits'] + pc['misses']} hits "
          f"({pc['hit_rate']:.0%}), {pc['entries']} entries")
    print(f"   batching: {b['queries']} queries -> {b['executions']} "
          f"executions ({b['dedup_saved']} deduped, {b['shed']} shed)")
    rc = t["reach_cache"]
    if rc is not None:
        print(f"   reach cache: {rc['entries']} entries, {rc['bytes']}B"
              f" (budget {rc['max_bytes']})")
    if t["calibration"] is not None:
        print("   calibration:", json.dumps(
            {k: round(v, 4) if isinstance(v, float) else v
             for k, v in t["calibration"].items()}))
    gov = t.get("governor")
    if gov is not None:
        print(f"   governor: shed_submit={gov['shed_submit']} "
              f"shed_flush={gov['shed_flush']} "
              f"budget_exceeded={gov['budget_exceeded']} "
              f"degraded={gov['degraded_queries']} "
              f"by_rung={gov['degraded_by_rung']} "
              f"exhausted={gov['exhausted']}")
        br = gov["breaker"]
        print(f"   breaker: trips={br['trips']} denials={br['denials']} "
              f"probes={br['probes']} recoveries={br['recoveries']} "
              f"open={br['open']}")

    if args.trace is not None:
        info = srv.tracer.export_chrome(args.trace)
        print(f"== trace: {info['traces']} traces, {info['events']} "
              f"events -> {info['path']} (open in chrome://tracing or "
              "ui.perfetto.dev) ==")

    if args.explain:
        print("== EXPLAIN per template ==")
        for i, q in enumerate(pool):
            print(f"-- template {i} --")
            print(srv.explain(q))

    if args.delta:
        print("== delta ingest: mutate the live dataset ==")
        lab, prd = g.labels, g.predicates
        k = max(6, g.num_edges // 200)
        rng2 = np.random.default_rng(args.seed + 1)
        # deletable = edges whose endpoints stay mentioned afterwards
        # (dropping a node's last edge would renumber ids => full rebuild)
        subj = np.bincount(g.src, minlength=g.num_nodes)
        ment = subj + np.bincount(g.dst, minlength=g.num_nodes)
        safe = np.flatnonzero((subj[g.src] >= 2) & (ment[g.src] >= 3)
                              & (ment[g.dst] >= 3))
        pick = rng2.choice(g.num_edges, size=2 * k, replace=False)
        dels = rng2.choice(safe, size=min(k, safe.size), replace=False)
        deletes = [(lab[g.src[i]], prd[g.pred[i]], lab[g.dst[i]])
                   for i in dels]
        # inserts recombine subject/object pairs within one predicate so
        # node kinds stay consistent and the incremental path can run
        inserts = [(lab[g.src[i]], prd[g.pred[i]], lab[g.dst[j]])
                   for i, j in zip(pick[k:], np.roll(pick[k:], 1))
                   if g.pred[i] == g.pred[j]]
        q0 = pool[0]
        srv.query(q0)                        # warm an exact-repeat entry
        info = srv.apply_delta(inserts, deletes)
        print(f"   {len(inserts)} inserts / {len(deletes)} deletes -> "
              f"mode={info['mode']}, now {info['dataset_id']}")
        print(f"   plans kept={info['plans_kept']} "
              f"invalidated={info['plans_invalidated']} "
              f"dropped={info['plans_dropped']}; "
              f"reach entries dropped={info['reach_dropped']}; "
              f"results kept={info['results_kept']} "
              f"dropped={info['results_dropped']}")
        srv.query(q0)                        # first post-delta execution
        r2 = srv.query(q0)                   # exact repeat
        rcache = srv.telemetry()["result_cache"]
        print(f"   repeat after delta: result_cache_hit="
              f"{r2.stats.result_cache_hit} "
              f"(cache: {rcache['hits']} hits, "
              f"{rcache['entries']} entries, {rcache['bytes']}B)")

    if args.snapshot is not None:
        import time
        print(f"== snapshot round trip: {args.snapshot} ==")
        manifest = srv.save_snapshot(args.snapshot)
        print(f"   saved {manifest['plans']} plans, "
              f"{manifest['bytes']}B (format v{manifest['format_version']})")
        srv2 = QueryServer(srv.dataset, batching=not args.no_batch,
                           calibrate=not args.no_calibrate, **srv_kw)
        t0 = time.perf_counter()
        srv2.restore_snapshot(args.snapshot)
        restore_ms = (time.perf_counter() - t0) * 1e3
        warm = degraded = 0
        for q in pool:
            r = srv2.query(q)
            warm += bool(r.stats.cache_hit)
            degraded += bool(r.stats.degraded_steps)
        pc2 = srv2.telemetry()["plan_cache"]
        print(f"   restored in {restore_ms:.1f}ms; replayed "
              f"{len(pool)} templates: plan cache {pc2['hits']} hits / "
              f"{pc2['misses']} misses, {warm} warm executions"
              + (f", {degraded} still rung-memory-degraded (the snapshot"
                 " preserves fault memory too)" if degraded else
                 " (first post-restore execution skips"
                 " prepare/plan/decide/check)"))


if __name__ == "__main__":
    main()
