"""Serving driver: repeat-template RDF query traffic through QueryServer.

Generates a synthetic RDF dataset, samples a pool of query templates, and
replays a zipfian mix of them (the serving assumption: the same templates
arrive over and over).  Prints per-phase latency, plan-cache hit rate,
batch dedup, and the calibration state the server learned online.

    PYTHONPATH=src JAX_PLATFORMS=cpu python examples/serve_queries.py \\
        --dataset dblp --scale 0.05 --templates 6 --queries 60
"""
import argparse
import json

import numpy as np

from repro.data import DATASETS, random_query
from repro.serve import QueryServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dblp", choices=sorted(DATASETS))
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--templates", type=int, default=6,
                    help="distinct query templates in the pool")
    ap.add_argument("--queries", type=int, default=60,
                    help="total queries in the zipfian stream")
    ap.add_argument("--size", type=int, default=5)
    ap.add_argument("--zipf", type=float, default=1.3,
                    help="template popularity skew (higher = hotter head)")
    ap.add_argument("--no-batch", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"== build {args.dataset} graph (scale={args.scale}) ==")
    g = DATASETS[args.dataset](scale=args.scale, seed=1)
    print(f"   {g.num_nodes} nodes, {g.num_edges} triples")

    print(f"== template pool: {args.templates} templates ==")
    pool = [random_query(g, size=args.size, seed=100 + i,
                         n_connection=i % 2, d_c=3)
            for i in range(args.templates)]

    rng = np.random.default_rng(args.seed)
    ranks = np.minimum(rng.zipf(args.zipf, args.queries),
                       args.templates) - 1
    stream = [pool[r] for r in ranks]

    srv = QueryServer(g, batching=not args.no_batch,
                      calibrate=not args.no_calibrate)
    print(f"== serve {args.queries} queries "
          f"(zipf alpha={args.zipf}, batching={srv.batching}) ==")
    # chunked submission: each flush is one shape-batched admission window
    chunk = 8
    matches = 0
    for s in range(0, len(stream), chunk):
        futs = srv.submit_many(stream[s:s + chunk], wait=True)
        matches += sum(f.result().count for f in futs)

    t = srv.telemetry()
    lat, pc, b = t["latency"], t["plan_cache"], t["batch"]
    print(f"   matches={matches}")
    print(f"   latency p50={lat['p50']*1e3:.1f}ms p99={lat['p99']*1e3:.1f}ms")
    print(f"   cold p50={lat['cold_p50']*1e3:.1f}ms ({lat['n_cold']} queries)"
          f"  warm p50={lat['warm_p50']*1e3:.1f}ms ({lat['n_warm']} queries)")
    print(f"   plan cache: {pc['hits']}/{pc['hits'] + pc['misses']} hits "
          f"({pc['hit_rate']:.0%}), {pc['entries']} entries")
    print(f"   batching: {b['queries']} queries -> {b['executions']} "
          f"executions ({b['dedup_saved']} deduped)")
    if t["calibration"] is not None:
        print("   calibration:", json.dumps(
            {k: round(v, 4) if isinstance(v, float) else v
             for k, v in t["calibration"].items()}))


if __name__ == "__main__":
    main()
