"""Scenario: when does signature pruning pay?  (the paper's core question)

Runs the same workload on a LUBM-like (coherent, uniform) and a DBLP-like
(hub-heavy) dataset and shows the planner choosing differently, plus a
connection-edge query evaluated through the NI index.

    PYTHONPATH=src python examples/rdf_scenario.py
"""
import time

from repro.core import Dataset
from repro.core.query import QueryTemplate, QueryEdge, ConnectionEdge
from repro.data import lubm_like, dblp_like, random_query


def workload(name, g):
    ds = Dataset.build(g, variant="spath_ni2")   # d=2 NI serves all three
    st = ds.stats
    print(f"-- {name}: coherence={st.coherence:.3f} "
          f"specialty={st.specialty:.1f} diversity={st.diversity}")
    never = ds.engine("stwig+")
    always = ds.engine("spath_ni2")
    hybrid = ds.engine("rdf_h")
    tot = {"never": 0.0, "always": 0.0, "hybrid": 0.0}
    pruned = kept = 0
    for s in range(6):
        q = random_query(g, size=6, seed=900 + s)
        for label, eng in (("never", never), ("always", always),
                           ("hybrid", hybrid)):
            eng.execute(q)
            t0 = time.perf_counter()
            r = eng.execute(q)
            tot[label] += time.perf_counter() - t0
        r = always.execute(q)
        pruned += r.stats.candidates_before - r.stats.candidates_after
        kept += r.stats.candidates_after
    rate = 100 * pruned / max(pruned + kept, 1)
    print(f"   candidate prune rate with 2-hop check: {rate:.1f}%")
    for label, t in tot.items():
        print(f"   {label:7s} {t*1e3:8.1f} ms total")


def connection_edge_demo(g):
    """Paper Fig. 1: a paper by author A connected within 4 hops to a
    paper by author B — anchored on two real author names."""
    print("-- connection-edge query (paper Fig. 1 style) --")
    import numpy as np
    pa = g.predicate_id("author")
    authors = np.unique(g.dst[g.pred == pa])
    a1, a2 = (str(g.labels[authors[3]]), str(g.labels[authors[7]]))
    q = QueryTemplate(
        keywords=["Paper/", a1, "Paper/", a2],
        edges=[QueryEdge(0, 1, pa), QueryEdge(2, 3, pa)],
        connections=[ConnectionEdge(0, 2, max_dist=4)],
    )
    eng = Dataset.build(g, variant="h3").engine("h3")
    t0 = time.perf_counter()
    r = eng.execute(q)
    print(f"   authors: {a1!r} / {a2!r}")
    print(f"   matches={r.count} in {time.perf_counter()-t0:.2f}s "
          f"(connectivity check: {r.stats.conn_time:.2f}s)")
    if r.count:
        from repro.core import instantiate_connections
        inst = instantiate_connections(g, r, q, max_paths=3)
        path = next(iter(inst[0].values()))[0]
        print("   one instantiated path:",
              " -> ".join(str(g.labels[n]) for n in path))


def main():
    workload("LUBM-like", lubm_like(scale=0.06, seed=1))
    g = dblp_like(scale=0.06, seed=1)
    workload("DBLP-like", g)
    connection_edge_demo(g)


if __name__ == "__main__":
    main()
